"""Tensor-parallel serving on 8 virtual CPU devices (subprocess: the
device count must be fixed before jax initializes, and other tests need
1 device).

The contract under test is the tentpole's bit-identity anchor: a tp=8
engine — page pool sharded on the "model" axis, serving through the
`paged_decode_sharded` / `verify_attn_sharded` exec-plan routes whose
wire carries format-width codes + per-row scales — must emit exactly the
tokens the tp=1 engine emits, across Table-I KV formats, through prefix-
cache hits and speculative decoding, and must *replicate instead of
crash* when the geometry doesn't divide the mesh axis.
"""
import json
import os
import subprocess
import sys
import textwrap

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(body: str) -> dict:
    """Run `body` in a subprocess with 8 host devices; it must print a
    single JSON line prefixed RESULT: (same harness as
    tests/test_distributed.py)."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import jax, jax.numpy as jnp
        import numpy as np
    """) + textwrap.dedent(body)
    env = dict(os.environ,
               PYTHONPATH=os.path.join(_REPO, "src"),
               XLA_FLAGS="")
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    for line in out.stdout.splitlines():
        if line.startswith("RESULT:"):
            return json.loads(line[len("RESULT:"):])
    raise AssertionError(f"no RESULT line in: {out.stdout[-2000:]}")


_ENGINE_PRELUDE = """
    from repro.models import ModelConfig, build_model
    from repro.launch.engine import (Engine, EngineConfig, SpecConfig,
                                     synthetic_workload)

    def build(policy):
        cfg = ModelConfig("t", "decoder", 2, 64, 4, 2, 128, 256,
                          policy=policy)
        model = build_model(cfg)
        return cfg, model, model.init(jax.random.PRNGKey(0))

    def tokens_of(engine):
        return {str(r.rid): [int(t) for t in r.out_tokens]
                for r in engine.finished}
"""


def test_tp_engine_bit_identical_across_formats():
    """Greedy tp=8 == tp=1, token for token, across three Table-I KV
    formats (fp16, fp8, packed-fp4 cache), and the tp=8 report names the
    sharded route."""
    r = _run(_ENGINE_PRELUDE + """
    out = {}
    for policy in ("attn_fp16_dpa", "kv8_attn_f32", "kv4_attn8_packed"):
        cfg, model, params = build(policy)
        per_tp = {}
        routes = {}
        for tp in (1, 8):
            ecfg = EngineConfig(page_size=8, n_pages=32, max_batch=4,
                                max_pages_per_req=4, token_budget=16,
                                prefill_chunk=8, tp=tp)
            eng = Engine(model, params, ecfg)
            rep = eng.run(synthetic_workload(
                4, vocab=cfg.vocab_size, seed=0, prompt_range=(6, 18),
                gen_range=(4, 8)))
            per_tp[tp] = tokens_of(eng)
            routes[tp] = (rep["decode_route"], rep["tp"])
        out[policy] = {"match": per_tp[1] == per_tp[8],
                       "route_1": routes[1], "route_8": routes[8],
                       "n_reqs": len(per_tp[1])}
    print("RESULT:" + json.dumps(out))
    """)
    for policy, res in r.items():
        assert res["match"], (policy, res)
        assert res["n_reqs"] == 4, (policy, res)
        assert res["route_8"] == ["paged_decode_sharded", 8], (policy, res)
        assert res["route_1"][0] != "paged_decode_sharded", (policy, res)


def test_tp_prefix_and_spec_decode_bit_identical():
    """The sharded engine composes with the other serving features
    without numeric drift: a prefix-cache workload (shared system
    prompt, sequential requests so later ones hit + CoW off shared
    pages) and a speculative run (fp4 draft, `verify_attn_sharded`
    verify) both emit tp=1's exact tokens."""
    r = _run(_ENGINE_PRELUDE + """
    cfg, model, params = build("kv4_attn8_packed")
    out = {}

    def prefix_run(tp):
        ecfg = EngineConfig(page_size=8, n_pages=48, max_batch=4,
                            max_pages_per_req=4, token_budget=16,
                            prefill_chunk=8, prefix_cache=True, tp=tp)
        eng = Engine(model, params, ecfg)
        reqs = synthetic_workload(5, vocab=cfg.vocab_size, seed=0,
                                  prompt_range=(4, 10), gen_range=(4, 6),
                                  shared_prefix=12)
        for req in reqs:                    # sequential: later ones hit
            eng.run([req])
        rep = eng.report(1.0)
        return tokens_of(eng), rep["prefix_hits"], rep["prefix_cow_copies"]

    t1, h1, c1 = prefix_run(1)
    t8, h8, c8 = prefix_run(8)
    out["prefix"] = {"match": t1 == t8, "hits": [h1, h8],
                     "cow": [c1, c8]}

    def spec_run(tp):
        ecfg = EngineConfig(page_size=8, n_pages=48, max_batch=4,
                            max_pages_per_req=4, token_budget=32,
                            prefill_chunk=8, tp=tp)
        eng = Engine(model, params, ecfg,
                     spec=SpecConfig("w4a4_kv4_attn4", k=2))
        rep = eng.run(synthetic_workload(4, vocab=cfg.vocab_size, seed=2,
                                         prompt_range=(6, 14),
                                         gen_range=(4, 8)))
        return tokens_of(eng), rep
    s1, _ = spec_run(1)
    s8, rep8 = spec_run(8)
    out["spec"] = {"match": s1 == s8,
                   "verify_route": rep8["verify_route"],
                   "draft_route": rep8["draft_route"],
                   "acceptance": rep8["acceptance_rate"]}
    print("RESULT:" + json.dumps(out))
    """)
    assert r["prefix"]["match"], r["prefix"]
    assert r["prefix"]["hits"][0] == r["prefix"]["hits"][1] > 0, r["prefix"]
    assert r["prefix"]["cow"][0] == r["prefix"]["cow"][1], r["prefix"]
    assert r["spec"]["match"], r["spec"]
    assert r["spec"]["verify_route"] == "verify_attn_sharded", r["spec"]
    assert r["spec"]["draft_route"] == "paged_decode_sharded", r["spec"]


def test_tp_divisibility_fallback():
    """Geometry that doesn't divide the mesh axis must replicate, not
    crash: page_size % tp != 0 and tp > n_devices both fall back to
    tp=1 with a stated reason and tp=1's exact outputs."""
    r = _run(_ENGINE_PRELUDE + """
    cfg, model, params = build("kv4_attn8_packed")
    out = {}
    base = dict(n_pages=32, max_batch=4, max_pages_per_req=4,
                token_budget=16, prefill_chunk=6)
    runs = {}
    for name, kw in (("ref", dict(page_size=12, tp=1)),
                     ("indivisible", dict(page_size=12, tp=8)),
                     ("too_wide", dict(page_size=12, tp=16))):
        eng = Engine(model, params, EngineConfig(**base, **kw))
        rep = eng.run(synthetic_workload(3, vocab=cfg.vocab_size, seed=0,
                                         prompt_range=(6, 18),
                                         gen_range=(4, 8)))
        runs[name] = (tokens_of(eng), rep)
    out["indivisible"] = {
        "match": runs["ref"][0] == runs["indivisible"][0],
        "tp": runs["indivisible"][1]["tp"],
        "reason": runs["indivisible"][1].get("tp_fallback_reason", ""),
        "route": runs["indivisible"][1]["decode_route"]}
    out["too_wide"] = {
        "match": runs["ref"][0] == runs["too_wide"][0],
        "tp": runs["too_wide"][1]["tp"],
        "reason": runs["too_wide"][1].get("tp_fallback_reason", "")}
    print("RESULT:" + json.dumps(out))
    """)
    assert r["indivisible"]["match"], r["indivisible"]
    assert r["indivisible"]["tp"] == 1, r["indivisible"]
    assert "not divisible" in r["indivisible"]["reason"], r["indivisible"]
    assert r["indivisible"]["route"] != "paged_decode_sharded"
    assert r["too_wide"]["match"], r["too_wide"]
    assert r["too_wide"]["tp"] == 1, r["too_wide"]
    assert "exceeds" in r["too_wide"]["reason"], r["too_wide"]


def test_wire_collectives_parity():
    """The wire primitives under shard_map on 8 devices: the pool-shard
    all-gather is a pure relayout (bit-for-bit), the lossy fp16/fp8 wire
    reductions land within pinned tolerances of the f32 collective."""
    r = _run("""
    from functools import partial
    from jax.sharding import PartitionSpec as P
    from repro.distributed import tp as TP
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh(n_data=1, n_model=8)
    out = {}

    # (a) pure relayout: uint8 codes + f32 scales sharded on the row
    # axis, all-gathered back inside shard_map == the original pool
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    codes = jax.random.randint(ks[0], (6, 16, 4, 8), 0, 256,
                               dtype=jnp.int32).astype(jnp.uint8)
    scales = jax.random.uniform(ks[1], (6, 16, 4, 1), jnp.float32)

    def gather_body(c, s):
        full = TP._gather_pool({"k_codes": c, "k_scale": s}, "model")
        return full["k_codes"], full["k_scale"]

    spec = P(None, "model", None, None)
    fn = TP.shard_map_compat(gather_body, mesh, (spec, spec),
                             (P(), P()), "model")
    gc, gs = fn(codes, scales)
    out["relayout_exact"] = bool(
        np.array_equal(np.asarray(gc), np.asarray(codes))
        and np.array_equal(np.asarray(gs), np.asarray(scales)))

    # (b) lossy wire reductions: psum_wire vs the f32 psum
    x = jax.random.normal(jax.random.PRNGKey(3), (8, 64, 32), jnp.float32)

    def red_body(fmt, xs):
        return TP.psum_wire(xs[0], "model", fmt)

    def f32_body(xs):
        return jax.lax.psum(xs[0], "model")

    want = np.asarray(TP.shard_map_compat(f32_body, mesh, (P("model"),),
                                          P(), "model")(x))
    for fmt in ("fp16", "fp8_e4m3"):
        got = np.asarray(TP.shard_map_compat(
            partial(red_body, fmt), mesh, (P("model"),), P(), "model")(x))
        err = float(np.max(np.abs(got - want)) / np.max(np.abs(want)))
        out["psum_" + fmt] = err

    # (c) tiled all_gather_wire vs the exact gather
    def ag_body(fmt, xs):
        return TP.all_gather_wire(xs, "model", fmt, gather_axis=0)

    for fmt in ("fp16", "fp8_e4m3"):
        got = np.asarray(TP.shard_map_compat(
            partial(ag_body, fmt), mesh, (P("model"),), P(), "model")(x))
        err = float(np.max(np.abs(got - x)) / np.max(np.abs(np.asarray(x))))
        out["gather_" + fmt] = err
    print("RESULT:" + json.dumps(out))
    """)
    assert r["relayout_exact"] is True, r
    # pinned wire tolerances: fp16 keeps ~3 decimal digits, fp8-e4m3 ~2
    assert r["psum_fp16"] < 2e-3, r
    assert r["psum_fp8_e4m3"] < 8e-2, r
    assert r["gather_fp16"] < 2e-3, r
    assert r["gather_fp8_e4m3"] < 8e-2, r
    # and the narrow wire really is lossy-but-bounded, not exact-by-luck
    assert r["psum_fp8_e4m3"] > 1e-6, r
