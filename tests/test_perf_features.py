"""Equivalence tests for the §Perf machinery: every optimization knob
must be a pure performance transform (same math, different schedule)."""
import jax
import jax.numpy as jnp

from repro.models import ModelConfig, build_model

CFG = ModelConfig("t", "decoder", 8, 64, 4, 2, 128, 256, remat="full")


def test_remat_block_equivalence():
    """Two-level remat: identical logits, grads within bf16 noise."""
    m0 = build_model(CFG)
    m1 = build_model(CFG.replace(remat_block=4))
    p = m0.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 256)
    l0, _ = m0.train_logits(p, {"tokens": toks})
    l1, _ = m1.train_logits(p, {"tokens": toks})
    assert float(jnp.abs(l0 - l1).max()) == 0.0
    g0 = jax.grad(lambda w: m0.train_logits(w, {"tokens": toks})[0].sum())(p)
    g1 = jax.grad(lambda w: m1.train_logits(w, {"tokens": toks})[0].sum())(p)
    rel = max(float(jnp.abs(a - b).max() / (jnp.abs(a).max() + 1e-9))
              for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)))
    assert rel < 1e-2, rel


def test_chunked_loss_checkpoint_equivalence():
    from repro.distributed.step import make_loss_fn
    cfg = CFG.replace(logits_chunk=8, n_layers=2)
    m = build_model(cfg)
    m0 = build_model(cfg.replace(logits_chunk=0))
    p = m.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32),
                                          0, 256),
             "labels": jax.random.randint(jax.random.PRNGKey(2), (2, 32),
                                          0, 256)}
    l1, _ = make_loss_fn(m)(p, batch)
    l0, _ = make_loss_fn(m0)(p, batch)
    assert abs(float(l1) - float(l0)) < 1e-4


def test_native_fp8_weight_dot():
    """fp8-stored weights keep a native dot path; result tracks the f32
    matmul within fp8 quantization error."""
    from repro.core import apply_linear, get_policy
    k = jax.random.PRNGKey(3)
    w = jax.random.normal(k, (64, 32), jnp.float32) * 0.1
    x = jax.random.normal(jax.random.PRNGKey(4), (8, 64), jnp.bfloat16)
    ref = (x.astype(jnp.float32) @ w)
    y = apply_linear({"w": w.astype(jnp.float8_e4m3fn)}, x,
                     get_policy("fp8_dpa"))
    rel = float(jnp.abs(y.astype(jnp.float32) - ref).max()
                / jnp.abs(ref).max())
    assert rel < 0.15, rel


def test_serve_quant_spec_dtype():
    from repro.configs import get_config
    from repro.launch.specs import param_shapes
    cfg = get_config("granite-moe-1b-a400m").replace(serve_quant="fp8_e4m3")
    shapes = param_shapes(cfg, serve=True)
    dts = {str(x.dtype) for x in jax.tree.leaves(shapes)}
    assert "float8_e4m3fn" in dts          # matmul weights quantized
    assert "bfloat16" in dts               # norms/embeds stay bf16


def test_mesh_plan_fully_dp_specs():
    import os
    from repro.distributed import sharding as shd
    shd.set_mesh_plan("fully_dp")
    try:
        assert shd.model_axis() is None
    finally:
        shd.set_mesh_plan("tp")
    assert shd.model_axis() == "model"


def test_flash_decode_single_device_fallback():
    """Without a mesh the flash_decode flag must fall back to the plain
    path and still match train logits."""
    cfg = CFG.replace(n_layers=2, flash_decode=True, policy="fp32")
    m = build_model(cfg)
    p = m.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, 256)
    full, _ = m.train_logits(p, {"tokens": toks})
    caches = m.init_caches(2, 12)
    errs = []
    for t in range(12):
        lg, caches = m.decode_step(
            p, {"tokens": toks[:, t:t + 1], "index": jnp.int32(t)}, caches)
        errs.append(float(jnp.abs(lg[:, 0] - full[:, t]).max()))
    assert max(errs) < 2e-4


def test_flash_decode_sharded_matches_train():
    """shard_map flash-decoding == teacher forcing, on an 8-device mesh
    (subprocess: device count must precede jax init)."""
    import json
    import os
    import subprocess
    import sys
    import textwrap
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import jax, jax.numpy as jnp
        from repro.models import ModelConfig, build_model
        from repro.launch.mesh import make_host_mesh
        from repro.distributed import sharding as shd

        cfg = ModelConfig("t","decoder",2,64,4,2,128,256, policy="fp32")
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, 256)
        m0 = build_model(cfg)
        params = m0.init(jax.random.PRNGKey(0))
        full, _ = m0.train_logits(params, {"tokens": toks})
        mesh = make_host_mesh(n_data=2, n_model=4)
        m1 = build_model(cfg.replace(flash_decode=True))
        with mesh:
            caches = jax.device_put(m1.init_caches(4, 16),
                                    shd.cache_spec(m1.init_caches(4, 16), mesh))
            errs = []
            for t in range(16):
                lg, caches = m1.decode_step(
                    params, {"tokens": toks[:, t:t+1], "index": jnp.int32(t)},
                    caches)
                errs.append(float(jnp.abs(lg[:,0]-full[:,t]).max()))
        print("RESULT:" + json.dumps({"err": max(errs)}))
    """)
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, timeout=600,
                         env=dict(os.environ, PYTHONPATH=os.path.join(
                             repo, "src"), XLA_FLAGS=""))
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT:")]
    r = json.loads(line[0][len("RESULT:"):])
    assert r["err"] < 3e-4, r
