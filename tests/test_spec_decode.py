"""Self-speculative decoding conformance.

The load-bearing claims:

  1. `verify_attn` row i == a plain paged decode step at position
     positions[b] + i, bit for bit — the verify pass's logits ARE the
     plain decode path's logits.
  2. Greedy speculative engine outputs are token-for-token identical to
     the plain (non-speculative) engine, across (draft, verify) policy
     pairs spanning fp4 / fp8 / fp16 drafts over shared cache formats.
  3. Self-drafting (draft policy == verify policy) accepts every draft:
     the k sequential draft steps and the one batched verify pass are
     the same computation, so argmax prefix-match cannot fail.
  4. Paged-KV rollback keeps the allocator honest: after every round
     (and at drain) no page is leaked or double-freed, committed pages
     equal what live block tables reference, and reservations balance.
  5. Sampled mode stays per-request deterministic (same request alone ==
     inside a mixed batch) and drains cleanly.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import exec_plan
from repro.core import kvcache as KV
from repro.core.policy import get_policy
from repro.launch.engine import Engine, EngineConfig, Request
from repro.serving import SamplerConfig, SpecConfig
from repro.serving.spec_decode import validate_policy_pair

VERIFY_POLICY = "kv4_attn8_packed"
ECFG = EngineConfig(page_size=8, n_pages=32, max_batch=3,
                    max_pages_per_req=4, token_budget=16, prefill_chunk=8)
LENS = [(9, 5), (14, 7), (5, 4)]
K = 3

# (draft, verify) pairs spanning fp4 / fp8 / fp16 drafts; each pair
# shares one KV-cache storage format (the page pool is common to both)
POLICY_PAIRS = [
    ("w4a4_kv4_attn4", "kv4_attn8_packed"),    # all-fp4 draft, fp4 cache
    ("attn_fp8_dpa", "kv8_attn_f32"),          # fp8 draft, fp8 cache
    ("attn_fp16_dpa", "kv16_attn_f32"),        # fp16 draft, fp16 cache
]


@pytest.fixture(scope="module")
def base():
    from repro.configs import get_config, reduce_config
    from repro.models import build_model
    cfg = reduce_config(get_config("qwen3-4b")).replace(policy=VERIFY_POLICY)
    model = build_model(cfg)
    # params are policy-independent: one init serves every policy pair
    return cfg, model, model.init(jax.random.PRNGKey(0))


def _requests(vocab, seed=3):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, vocab, size=s0).astype(np.int32),
                    max_new=g)
            for i, (s0, g) in enumerate(LENS)]


def _by_rid(engine, rid):
    return [r for r in engine.finished if r.rid == rid][0]


# -----------------------------------------------------------------------------
# 1. verify_attn == stepped paged decode, bit for bit
# -----------------------------------------------------------------------------

def _paged_cache(pol, lengths, ps=8, n_kv=2, hd=16, seed=3):
    B = len(lengths)
    S = max(-(-n // ps) for n in lengths) * ps
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    k = jax.random.normal(ks[0], (B, S, n_kv, hd))
    v = jax.random.normal(ks[1], (B, S, n_kv, hd))
    ref = KV.update_kv_cache(
        KV.init_kv_cache(B, S, n_kv, hd, fmt=pol.fmt_kv,
                         packed=pol.kv_packed),
        k, v, 0, fmt=pol.fmt_kv, packed=pol.kv_packed)
    return KV.paged_from_contiguous(ref, lengths, page_size=ps)


@pytest.mark.parametrize("pol_name", ["kv4_attn8_packed", "kv8_attn_f32",
                                      "attn_fp16_dpa", "attn_fp4_packed"])
def test_verify_attn_matches_stepped_paged_decode(pol_name):
    """Row i of one Sq-token verify pass == a single-token paged decode
    at position positions[b] + i, for every row and request — the
    exactness greedy speculation stands on."""
    pol = get_policy(pol_name)
    lengths, sq, hd = [13, 17, 9], 3, 16
    cache = _paged_cache(pol, lengths)
    q = jax.random.normal(jax.random.PRNGKey(5), (3, sq, 4, hd))
    positions = jnp.asarray([n - sq for n in lengths], jnp.int32)
    verify = exec_plan.resolve("verify_attn", pol, sq=sq)
    assert verify.name == "jnp_gather"
    got = verify.run(q, cache, positions, policy=pol, scale=hd ** -0.5)
    decode = exec_plan.route("paged_decode", "jnp_gather")
    for i in range(sq):
        want = decode.run(q[:, i:i + 1], cache, positions + i, policy=pol,
                          scale=hd ** -0.5)
        assert np.array_equal(np.asarray(got[:, i:i + 1]),
                              np.asarray(want)), (pol_name, i)


def test_verify_attn_registered_and_described():
    """The op is a first-class plan-table citizen: resolvable,
    introspectable, and refused for raw-f32-cache policies."""
    assert "verify_attn" in exec_plan.ops()
    d = exec_plan.describe("verify_attn", VERIFY_POLICY, sq=K + 1,
                           batch=3, page_size=8, max_pages=4, kv_heads=2,
                           hd=16)
    assert d["route"] == "jnp_gather" and d["bytes_moved"] > 0
    with pytest.raises(exec_plan.PlanError, match="kv_quantized"):
        exec_plan.resolve("verify_attn", "fp16_dpa", sq=2)


# -----------------------------------------------------------------------------
# 2-3. greedy bit-identity + self-draft full acceptance
# -----------------------------------------------------------------------------

@pytest.mark.parametrize("draft,verify", POLICY_PAIRS,
                         ids=[f"{d}->{v}" for d, v in POLICY_PAIRS])
def test_spec_engine_greedy_matches_plain_engine(base, draft, verify):
    """The pinned invariant: greedy speculative decoding emits exactly
    the plain engine's tokens, whatever the draft precision."""
    from repro.models import build_model
    cfg, _, params = base
    model = build_model(cfg.replace(policy=verify))
    plain = Engine(model, params, ECFG)
    plain.run(_requests(cfg.vocab_size))
    spec = Engine(model, params, ECFG, spec=SpecConfig(draft, k=K))
    rep = spec.run(_requests(cfg.vocab_size))
    assert rep["n_requests"] == len(LENS)
    for r in plain.finished:
        got = _by_rid(spec, r.rid)
        assert got.out_tokens == r.out_tokens, (r.rid, draft, verify)
        assert np.array_equal(got.tokens(), r.tokens())
    # report plumbing: the engine states who drafted and who verified
    assert rep["spec_draft_policy"] == draft
    assert rep["draft_route"] in ("pallas_block_table", "jnp_gather")
    assert rep["verify_route"] == "jnp_gather"
    assert 0.0 <= rep["acceptance_rate"] <= 1.0
    assert 1.0 <= rep["eff_tokens_per_round"] <= K + 1


def test_self_draft_accepts_every_token(base):
    """draft == verify: the k draft steps recompute exactly what the
    batched verify recomputes, so every draft is accepted and rounds
    advance k+1 tokens (modulo max_new clamping)."""
    cfg, model, params = base
    spec = Engine(model, params, ECFG, spec=SpecConfig(VERIFY_POLICY, k=K))
    rep = spec.run(_requests(cfg.vocab_size))
    assert rep["acceptance_rate"] == 1.0
    assert rep["eff_tokens_per_round"] > K * 0.5   # clamp-limited, not
    assert spec.drafted == spec.drafts_accepted    # rejection-limited


# -----------------------------------------------------------------------------
# 4. paged-KV rollback: allocator invariants
# -----------------------------------------------------------------------------

def _check_alloc_invariants(engine):
    alloc = engine.alloc
    live = [r for r in engine.slots if r is not None]
    assert alloc.in_use == sum(len(r.pages) for r in live)
    assert alloc.reserved == sum(r.reserved_left for r in live)
    assert alloc.reserved <= alloc.n_free
    assert alloc.in_use + alloc.n_free == alloc.capacity - 1
    # every committed page is referenced by its owner's table row only
    # once prefill lands (a PREFILL slot's row stays scratch by design)
    from repro.launch.engine import DECODE
    for r in live:
        row = engine._table[r.slot]
        if r.state == DECODE:
            assert list(row[:len(r.pages)]) == r.pages
            assert np.all(row[len(r.pages):] == KV.SCRATCH_PAGE)
        else:
            assert np.all(row == KV.SCRATCH_PAGE)


def test_spec_rollback_allocator_invariants(base):
    """Step the spec engine tick by tick: after every tick the allocator
    balances (no leaked/double-freed pages, reservations match), at
    least one rollback returned pages mid-flight, and the drain is
    clean."""
    cfg, model, params = base
    engine = Engine(model, params, ECFG,
                    spec=SpecConfig("w4a4_kv4_attn4", k=K))
    rollbacks = []
    orig_free = engine.alloc.free

    def spy_free(pages, **kw):
        if kw.get("to_reserved"):
            rollbacks.append(list(pages))
        return orig_free(pages, **kw)

    engine.alloc.free = spy_free
    for req in _requests(cfg.vocab_size):
        engine.submit(req)
    now = 0.0
    while engine.waiting or any(engine.slots):
        engine.step(now)
        _check_alloc_invariants(engine)
        now += 1.0
    assert engine.alloc.in_use == 0
    assert engine.alloc.reserved == 0
    assert np.all(engine._table == KV.SCRATCH_PAGE)
    # the draft window crossed page boundaries: rollback really ran
    assert rollbacks, "no speculative rollback exercised"
    assert all(p != KV.SCRATCH_PAGE for pages in rollbacks for p in pages)


def test_page_allocator_reservation_api():
    """Unit-level reservation/commit/rollback accounting + error paths."""
    a = KV.PageAllocator(8)                    # 7 allocatable
    a.reserve(5)
    assert a.n_available == 2 and a.n_free == 7
    assert not a.can_alloc(3)                  # reserved pages untouchable
    other = a.alloc(2)                         # the unreserved remainder
    with pytest.raises(MemoryError):
        a.alloc(1)                             # only reserved pages left
    got = a.alloc(3, reserved=True)            # commit from reservation
    assert a.reserved == 2 and a.in_use == 5
    a.free(got[1:], to_reserved=True)          # rollback
    assert a.reserved == 4 and a.in_use == 3
    with pytest.raises(ValueError, match="double free"):
        a.free([got[1]])
    with pytest.raises(ValueError, match="exceeds reserved"):
        a.alloc(5, reserved=True)
    with pytest.raises(ValueError, match="unreserve"):
        a.unreserve(5)
    a.unreserve(4)
    a.free([got[0]])
    a.free(other)
    assert a.in_use == 0 and a.reserved == 0 and a.n_free == 7
    with pytest.raises(MemoryError):
        a.reserve(8)


# -----------------------------------------------------------------------------
# 5. sampled mode: determinism + drain
# -----------------------------------------------------------------------------

SAMPLED = SamplerConfig(temperature=0.8, top_k=16, top_p=0.95, seed=7)


def test_sampled_request_alone_matches_mixed_batch(base):
    """The deterministic-sampling regression: a request's sampled tokens
    are identical whether it is served alone or inside a mixed batch
    (per-request threefry streams, no batch-composition coupling)."""
    cfg, model, params = base
    batch = Engine(model, params, ECFG, sampler=SAMPLED)
    batch.run(_requests(cfg.vocab_size))
    for req in _requests(cfg.vocab_size):
        alone = Engine(model, params, ECFG, sampler=SAMPLED)
        alone.run([req])
        assert alone.finished[0].out_tokens == \
            _by_rid(batch, req.rid).out_tokens, req.rid


def test_sampled_spec_deterministic_and_drains(base):
    """Speculative + sampled: reruns reproduce token-for-token (all
    randomness is keyed, none is ambient) and the allocator drains."""
    cfg, model, params = base
    outs = []
    for _ in range(2):
        e = Engine(model, params, ECFG, sampler=SAMPLED,
                   spec=SpecConfig("w4a4_kv4_attn4", k=K))
        rep = e.run(_requests(cfg.vocab_size))
        assert rep["n_requests"] == len(LENS)
        assert e.alloc.in_use == 0 and e.alloc.reserved == 0
        outs.append({r.rid: list(r.out_tokens) for r in e.finished})
    assert outs[0] == outs[1]
    # every request emitted exactly max_new tokens (no eos in play)
    for (_, g), (rid, toks) in zip(LENS, sorted(outs[0].items())):
        assert len(toks) == g, rid


def test_accept_fn_survives_all_nan_target_row():
    """An all-NaN verify-logits row must not poison rejection sampling:
    the filtered target degenerates to one-hot token 0 (the sampler's
    dead-row rule), so p/q stays finite, the accept decision is defined,
    and the emitted tokens are valid vocabulary ids — sampled and greedy
    accept paths both."""
    from repro.serving.spec_decode import make_accept_fn
    k, V = 2, 8
    rids = jnp.asarray([0, 1], jnp.int32)
    pos = jnp.asarray([5, 9], jnp.int32)
    drafts = jnp.asarray([[3, 4], [2, 6]], jnp.int32)
    tl = jax.random.normal(jax.random.PRNGKey(0), (2, k + 1, V))
    tl = tl.at[0].set(jnp.nan)                     # request 0: dead rows
    scfg = SamplerConfig(temperature=0.9, top_k=4, top_p=0.9, seed=13)
    q = jax.nn.softmax(
        jax.random.normal(jax.random.PRNGKey(1), (2, k, V)), axis=-1)
    emitted, acc = make_accept_fn(scfg, k)(drafts, q, tl, rids, pos)
    emitted, acc = np.asarray(emitted), np.asarray(acc)
    assert np.all((emitted >= 0) & (emitted < V))
    assert np.all((acc >= 0) & (acc <= k))
    # dead target: p(draft) == 0 for any nonzero draft -> no accepts,
    # and the correction draw lands on the surviving token 0
    assert acc[0] == 0 and emitted[0, 0] == 0
    g_emit, g_acc = make_accept_fn(SamplerConfig(), k)(
        drafts, None, tl, rids, pos)
    g_emit, g_acc = np.asarray(g_emit), np.asarray(g_acc)
    assert np.all((g_emit >= 0) & (g_emit < V))
    assert g_acc[0] == 0 and g_emit[0, 0] == 0     # argmax of all-(-inf)


# -----------------------------------------------------------------------------
# validation
# -----------------------------------------------------------------------------

def test_mismatched_cache_formats_rejected(base):
    cfg, model, params = base
    with pytest.raises(ValueError, match="cache format"):
        Engine(model, params, ECFG, spec=SpecConfig("attn_fp8_dpa"))
    with pytest.raises(ValueError, match="raw f32 cache"):
        validate_policy_pair("fp16_dpa", VERIFY_POLICY)
    with pytest.raises(ValueError, match="k must be"):
        SpecConfig("w4a4_kv4_attn4", k=0)


def test_spec_window_counts_against_s_max(base):
    """A request whose prompt+max_new fits S_max but whose draft window
    does not is rejected up front (the reservation prices speculation)."""
    cfg, model, params = base
    engine = Engine(model, params, ECFG,
                    spec=SpecConfig("w4a4_kv4_attn4", k=K))
    big = Request(rid=99, prompt=np.zeros(ECFG.s_max - K + 1, np.int32),
                  max_new=K - 1)
    with pytest.raises(ValueError, match="draft"):
        engine.submit(big)
