"""DPA-quantized attention conformance: kernel / jnp fallback / decode
path vs the `kernels.ref` oracles, across head dims x seq lens x Table-I
modes, plus NaN/Inf propagation through the f32 softmax and packed-fp4
KV-cache bit-identity.

Tolerance structure mirrors the matmul conformance suite:

  vs `dpa_flash_attention_ref` (the semantic spec): near bit-tight.  The
  only legitimate slack is absmax-tie rounding — XLA fuses the in-kernel
  quantize into the dot, so logits can differ from the spec by an ulp and
  flip a probability across a grid-rounding boundary (one grid step at
  most, hence the per-format atol).
  vs `flash_attention_ref` (f32 accuracy): the matmul suite's policy
  tolerances — fp16 0.002(x), fp8 0.1, fp4-operand modes 0.35.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import kvcache as KV
from repro.core import get_policy
from repro.kernels import flash_attention as fa
from repro.kernels import ops as O
from repro.kernels import ref

MODES = ["fp16", "bf16", "fp8_e4m3", "fp4_e2m1"]   # Table-I: 2/2/4/8-term
# one-grid-step headroom for quantization tie flips (see module docstring)
SPEC_ATOL = {"fp16": 1e-3, "bf16": 1e-3, "fp8_e4m3": 0.05,
             "fp4_e2m1": 0.05}
# f32-accuracy budget == matmul conformance suite tolerances
F32_TOL = {"fp16": 0.002, "bf16": 0.02, "fp8_e4m3": 0.1, "fp4_e2m1": 0.35}


def _qkv(seed, B=2, H=4, Hkv=2, S=128, hd=64, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, H, S, hd), dtype)
    k = jax.random.normal(ks[1], (B, Hkv, S, hd), dtype)
    v = jax.random.normal(ks[2], (B, Hkv, S, hd), dtype)
    return q, k, v


def _rel(got, want):
    got, want = np.asarray(got), np.asarray(want)
    return float(np.abs(got - want).max() / np.abs(want).max())


# -----------------------------------------------------------------------------
# kernel vs the semantic spec and vs f32 accuracy
# -----------------------------------------------------------------------------

@pytest.mark.parametrize("fmt", MODES)
@pytest.mark.parametrize("hd,seq", [(16, 128), (64, 128), (64, 256)])
def test_dpa_flash_attention_vs_spec(fmt, hd, seq):
    q, k, v = _qkv(hd + seq, S=seq, hd=hd)
    got = O.dpa_flash_attention(q, k, v, fmt=fmt)
    want = ref.dpa_flash_attention_ref(q, k, v, fmt=fmt, bk=128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=SPEC_ATOL[fmt])


@pytest.mark.parametrize("fmt", MODES)
@pytest.mark.parametrize("hd,seq", [(16, 128), (64, 128), (64, 256)])
def test_dpa_flash_attention_accuracy_vs_f32(fmt, hd, seq):
    """The acceptance contract: DPA attention stays inside the matmul
    conformance suite's per-format budget vs the f32 reference."""
    q, k, v = _qkv(hd + seq, S=seq, hd=hd)
    got = O.dpa_flash_attention(q, k, v, fmt=fmt)
    want = ref.flash_attention_ref(q, k, v)
    assert _rel(got, want) < F32_TOL[fmt], (fmt, hd, seq)


def test_kv4_attn8_trans_precision_accuracy():
    """The serving sweet spot: fp8 attention arithmetic over a (packed)
    fp4 KV cache holds the matmul suite's fp4 budget vs f32."""
    for hd, seq in [(16, 128), (64, 256)]:
        q, k, v = _qkv(7 * hd + seq, S=seq, hd=hd)
        got = O.dpa_flash_attention(q, k, v, fmt="fp8_e4m3",
                                    fmt_kv="fp4_e2m1")
        want = ref.flash_attention_ref(q, k, v)
        assert _rel(got, want) < F32_TOL["fp4_e2m1"], (hd, seq)


@pytest.mark.parametrize("causal,window", [(False, None), (True, 32)])
def test_dpa_flash_attention_masks_vs_spec(causal, window):
    q, k, v = _qkv(3, S=128, hd=32)
    got = O.dpa_flash_attention(q, k, v, fmt="fp8_e4m3", causal=causal,
                                window=window)
    want = ref.dpa_flash_attention_ref(q, k, v, fmt="fp8_e4m3",
                                       causal=causal, window=window, bk=128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=SPEC_ATOL["fp8_e4m3"])


def test_dpa_flash_attention_kv_longer_than_q():
    """Sq < Sk (chunked-prefill cache-suffix attention)."""
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(ks[0], (1, 4, 128, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, 256, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 2, 256, 64), jnp.float32)
    got = O.dpa_flash_attention(q, k, v, fmt="fp8_e4m3")
    want = ref.dpa_flash_attention_ref(q, k, v, fmt="fp8_e4m3", bk=128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=SPEC_ATOL["fp8_e4m3"])


# -----------------------------------------------------------------------------
# quantized KV cache: kernel prologue-dequant path + packed bit-identity
# -----------------------------------------------------------------------------

@pytest.mark.parametrize("fmt_kv", ["fp16", "fp8_e4m3", "fp4_e2m1"])
def test_kernel_cache_path_matches_raw(fmt_kv):
    """Pre-quantized cache rows through the kernel == raw K/V quantized
    in the prologue (same recipe, so only fused-dot ulp noise remains)."""
    q, k, v = _qkv(11, S=256, hd=64)
    kc, ks = KV.quantize_kv(k, fmt=fmt_kv)
    vc, vs = KV.quantize_kv(v, fmt=fmt_kv)
    raw = fa.dpa_flash_attention(q, k, v, fmt="fp8_e4m3", fmt_kv=fmt_kv,
                                 interpret=True)
    cached = fa.dpa_flash_attention(q, kc, vc, ks, vs, fmt="fp8_e4m3",
                                    fmt_kv=fmt_kv, kv_quant=True,
                                    interpret=True)
    np.testing.assert_allclose(np.asarray(raw), np.asarray(cached),
                               rtol=1e-4, atol=SPEC_ATOL["fp8_e4m3"])


def test_packed_fp4_kv_bit_identity():
    """The packed layout contract, attention edition: nibble-packing the
    fp4 KV cache is pure I/O layout — codes round-trip exactly and the
    kernel output is BIT-identical to the unpacked cache."""
    from repro.core.packing import pack_fp4, unpack_fp4
    q, k, v = _qkv(13, S=256, hd=64)
    kc, ks = KV.quantize_kv(k, fmt="fp4_e2m1", packed=False)
    vc, vs = KV.quantize_kv(v, fmt="fp4_e2m1", packed=False)
    kp, ksp = KV.quantize_kv(k, fmt="fp4_e2m1", packed=True)
    vp, vsp = KV.quantize_kv(v, fmt="fp4_e2m1", packed=True)
    assert kp.shape[-1] == kc.shape[-1] // 2 and kp.dtype == jnp.uint8
    assert np.array_equal(np.asarray(unpack_fp4(kp)), np.asarray(kc))
    assert np.array_equal(np.asarray(pack_fp4(vc)), np.asarray(vp))
    np.testing.assert_array_equal(np.asarray(ks), np.asarray(ksp))
    unpacked = fa.dpa_flash_attention(q, kc, vc, ks, vs, fmt="fp8_e4m3",
                                      fmt_kv="fp4_e2m1", kv_quant=True,
                                      interpret=True)
    packed = fa.dpa_flash_attention(q, kp, vp, ksp, vsp, fmt="fp8_e4m3",
                                    fmt_kv="fp4_e2m1", kv_quant=True,
                                    kv_packed=True, interpret=True)
    assert np.array_equal(np.asarray(unpacked), np.asarray(packed))


def test_kvcache_roundtrip_matches_fake_quant():
    """Cache round-trip == quant_rows_grid fake-quant, bit for bit (the
    prefill-vs-decode consistency contract)."""
    from repro.core.quantize import quant_rows_grid
    x = jax.random.normal(jax.random.PRNGKey(17), (2, 64, 2, 32),
                          jnp.float32) * 4
    for fmt, packed in [("fp16", False), ("fp8_e4m3", False),
                        ("fp4_e2m1", False), ("fp4_e2m1", True)]:
        c, s = KV.quantize_kv(x, fmt=fmt, packed=packed)
        grid, scale = quant_rows_grid(x, fmt)
        assert np.array_equal(np.asarray(KV.dequantize_kv(
            c, s, fmt=fmt, packed=packed)), np.asarray(grid * scale)), fmt
        np.testing.assert_array_equal(np.asarray(s), np.asarray(scale))


def test_kv_cache_bytes_reduction():
    """The bandwidth acceptance bar: packed-fp4 KV moves >=4x (here ~7x)
    fewer bytes than the f32 cache; fp8 ~3.9x; fp16 ~2x."""
    nb4 = KV.kv_cache_nbytes(8, 1024, 8, 128, fmt="fp4_e2m1", packed=True)
    nb8 = KV.kv_cache_nbytes(8, 1024, 8, 128, fmt="fp8_e4m3")
    nb16 = KV.kv_cache_nbytes(8, 1024, 8, 128, fmt="fp16")
    assert nb4["reduction_vs_f32"] >= 4.0
    assert 3.5 < nb8["reduction_vs_f32"] < 4.0
    assert 1.9 < nb16["reduction_vs_f32"] <= 2.0


# -----------------------------------------------------------------------------
# jnp fallback + decode path
# -----------------------------------------------------------------------------

@pytest.mark.parametrize("fmt", ["fp16", "fp8_e4m3", "fp4_e2m1"])
def test_jnp_fallback_matches_single_block_spec(fmt):
    """`decode_attn.dpa_attention` (the XLA path serving non-aligned
    shapes) == the spec with one key block (global max)."""
    from repro.models.decode_attn import dpa_attention
    B, H, Hkv, S, hd = 2, 4, 2, 96, 32          # non-128-multiple seq
    q, k, v = _qkv(19, B=B, H=H, Hkv=Hkv, S=S, hd=hd)
    # layers layout (B,S,{H|KV},hd), grouped K/V, causal mask
    qpos = jnp.arange(S)[:, None]
    mask = (jnp.arange(S)[None, :] <= qpos)[None, None]
    got = dpa_attention(q.transpose(0, 2, 1, 3),
                        k.transpose(0, 2, 1, 3),
                        v.transpose(0, 2, 1, 3),
                        mask, fmt=fmt, scale=hd ** -0.5)
    want = ref.dpa_flash_attention_ref(q, k, v, fmt=fmt, bk=S)
    np.testing.assert_allclose(
        np.asarray(got.transpose(0, 2, 1, 3)), np.asarray(want),
        rtol=1e-4, atol=SPEC_ATOL[fmt])


@pytest.mark.parametrize("pol", ["attn_fp8_dpa", "kv4_attn8_packed"])
def test_dpa_decode_attn_matches_spec(pol):
    """Single-token decode off the quantized cache == the spec evaluated
    at the last position (Sq=1, one key block)."""
    from repro.models.decode_attn import dpa_decode_attn
    p = get_policy(pol)
    B, H, Hkv, S, hd = 2, 4, 2, 64, 32
    q, k, v = _qkv(23, B=B, H=H, Hkv=Hkv, S=S, hd=hd)
    cache = KV.init_kv_cache(B, S, Hkv, hd, fmt=p.fmt_kv,
                             packed=p.kv_packed)
    cache = KV.update_kv_cache(cache, k.transpose(0, 2, 1, 3),
                               v.transpose(0, 2, 1, 3), 0,
                               fmt=p.fmt_kv, packed=p.kv_packed)
    q_last = q[:, :, -1:, :]                       # (B,H,1,hd)
    got = dpa_decode_attn(q_last.transpose(0, 2, 1, 3), cache, S - 1,
                          fmt=p.fmt_attn, fmt_kv=p.fmt_kv,
                          kv_packed=p.kv_packed, scale=hd ** -0.5)
    want = ref.dpa_flash_attention_ref(q_last, k, v, fmt=p.fmt_attn,
                                       fmt_kv=p.fmt_kv, bk=S)
    np.testing.assert_allclose(
        np.asarray(got.transpose(0, 2, 1, 3)), np.asarray(want),
        rtol=1e-4, atol=SPEC_ATOL[p.fmt_attn])


def test_cache_spec_sequence_shards_quantized_leaves():
    """`distributed.sharding.cache_spec` must put the sequence axis of a
    quantized cache on the "model" axis for codes AND scales — a shard
    holding codes without their scales cannot dequantize anything."""
    from jax.sharding import Mesh
    from repro.distributed import sharding as shd
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1, 1),
                ("data", "model", "pod"))
    cache = KV.init_kv_cache(2, 64, 2, 32, fmt="fp4_e2m1", packed=True)
    specs = shd.cache_spec({"groups": {"p0": jax.tree.map(
        lambda x: x[None], cache)}}, mesh)
    for name in ("k_codes", "k_scale", "v_codes", "v_scale"):
        spec = specs["groups"]["p0"][name].spec
        assert spec[2] == "model", (name, spec)   # lead + (B, S, KV, .)


def test_model_prefill_matches_stepped_decode():
    """End-to-end policy wiring: prefill writing the quantized cache and
    token-by-token DPA decode off it produce the same logits."""
    from repro.configs import get_config, reduce_config
    from repro.models import build_model
    cfg = reduce_config(get_config("qwen3-4b")).replace(
        policy="kv4_attn8_packed")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S0 = 2, 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S0), 0,
                              cfg.vocab_size)
    logits, _ = model.prefill(params, {"tokens": toks})
    caches = model.init_caches(B, S0 + 4)
    assert KV.is_quantized(
        jax.tree.leaves(caches, is_leaf=KV.is_quantized)[0])
    lg = None
    for t in range(S0):
        lg, caches = model.decode_step(
            params, {"tokens": toks[:, t:t + 1], "index": jnp.int32(t)},
            caches)
    np.testing.assert_allclose(np.asarray(lg[:, -1]),
                               np.asarray(logits[:, -1]),
                               rtol=1e-4, atol=1e-4)


# -----------------------------------------------------------------------------
# NaN / Inf propagation through the f32 softmax core
# -----------------------------------------------------------------------------

@pytest.mark.parametrize("fmt", ["fp16", "fp8_e4m3", "fp4_e2m1"])
def test_nan_in_q_poisons_only_its_row(fmt):
    """A NaN query row must yield an all-NaN output row and leave every
    other row finite — even for fp4, whose grid has no NaN encoding (the
    per-row absmax scale carries the NaN through the software exponent
    path)."""
    q, k, v = _qkv(29, B=1, H=2, Hkv=2, S=128, hd=16)
    qn = q.at[0, 0, 5, 3].set(jnp.nan)
    out = np.asarray(O.dpa_flash_attention(qn, k, v, fmt=fmt))
    assert np.isnan(out[0, 0, 5]).all()
    assert np.isfinite(np.delete(out[0, 0], 5, axis=0)).all()
    assert np.isfinite(out[0, 1]).all()


@pytest.mark.parametrize("fmt", ["fp8_e4m3", "fp4_e2m1"])
def test_nan_in_k_poisons_attending_rows(fmt):
    q, k, v = _qkv(31, B=1, H=2, Hkv=2, S=128, hd=16)
    kn = k.at[0, 0, 3, 2].set(jnp.nan)
    out = np.asarray(O.dpa_flash_attention(q, kn, v, fmt=fmt))
    assert np.isnan(out[0, 0, 3:]).all()       # causal: rows >= 3 see it
    assert np.isfinite(out[0, 0, :3]).all()


@pytest.mark.parametrize("fmt", ["fp8_e4m3", "fp4_e2m1"])
def test_inf_in_v_breaks_finiteness_downstream(fmt):
    """An Inf value row must surface as non-finite output for every query
    that attends it.  (Like the f32 reference, masked-out queries may
    also see NaN through the 0 x inf PV product — IEEE, not a bug — so
    only the attending-rows claim is pinned; the untouched head proves
    containment.)"""
    q, k, v = _qkv(37, B=1, H=2, Hkv=2, S=128, hd=16)
    vi = v.at[0, 0, 3, 2].set(jnp.inf)
    out = np.asarray(O.dpa_flash_attention(q, k, vi, fmt=fmt))
    assert not np.isfinite(out[0, 0, 3:]).all()
    assert np.isfinite(out[0, 1]).all()        # other kv-head unaffected
