"""Unit tests for the HLO collective parser + analytic cost census."""
import textwrap

from repro.configs import SHAPES, get_config
from repro.launch import analytic as A
from repro.launch import hlo_analysis as H


def test_collective_parser_basic():
    hlo = textwrap.dedent("""
    HloModule m

    ENTRY %main (p0: f32[16,64]) -> f32[16,64] {
      %p0 = f32[16,64]{1,0} parameter(0)
      %ag = f32[64,64]{1,0} all-gather(%p0), replica_groups={{0,1,2,3}}, dimensions={0}
      %ar = f32[16,64]{1,0} all-reduce(%p0), replica_groups={{0,1},{2,3}}, to_apply=%add
      %rs = f32[4,64]{1,0} reduce-scatter(%p0), replica_groups={{0,1,2,3}}, dimensions={0}
      %cp = f32[16,64]{1,0} collective-permute(%p0), source_target_pairs={{0,1}}
      ROOT %r = f32[16,64]{1,0} add(%ar, %cp)
    }
    """)
    totals, recs = H.collective_bytes(hlo)
    assert len(recs) == 4
    ag = 64 * 64 * 4
    assert abs(totals["all-gather"] - ag * 3 / 4) < 1
    ar = 16 * 64 * 4
    assert abs(totals["all-reduce"] - ar * 2 * 1 / 2) < 1
    rs = 4 * 64 * 4
    assert abs(totals["reduce-scatter"] - rs * 3) < 1
    assert totals["collective-permute"] == 16 * 64 * 4


def test_collective_parser_loop_multiplier():
    """A collective inside a while body counts trip_count times."""
    hlo = textwrap.dedent("""
    HloModule m

    %cond (s: (s32[], f32[8])) -> pred[] {
      %s = (s32[], f32[8]) parameter(0)
      %i = s32[] get-tuple-element(%s), index=0
      %n = s32[] constant(28)
      ROOT %lt = pred[] compare(%i, %n), direction=LT
    }

    %body (s: (s32[], f32[8])) -> (s32[], f32[8]) {
      %s = (s32[], f32[8]) parameter(0)
      %x = f32[8]{0} get-tuple-element(%s), index=1
      %ar = f32[8]{0} all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%add
      %i = s32[] get-tuple-element(%s), index=0
      ROOT %t = (s32[], f32[8]) tuple(%i, %ar)
    }

    ENTRY %main (p: (s32[], f32[8])) -> (s32[], f32[8]) {
      %p = (s32[], f32[8]) parameter(0)
      ROOT %w = (s32[], f32[8]) while(%p), condition=%cond, body=%body
    }
    """)
    totals, recs = H.collective_bytes(hlo)
    one = 8 * 4 * 2 * 3 / 4          # ring all-reduce of f32[8] over 4
    assert abs(totals["all-reduce"] - 28 * one) < 1e-6
    assert any(r.get("in_loop") == 28 for r in recs)


def test_iota_replica_groups():
    hlo = ("ENTRY %m (p: f32[4]) -> f32[4] {\n"
           " %p = f32[4]{0} parameter(0)\n"
           " %ar = f32[4]{0} all-reduce(%p), replica_groups=[16,16]<=[256],"
           " to_apply=%add\n ROOT %r = f32[4]{0} copy(%ar)\n}\n")
    totals, recs = H.collective_bytes(hlo)
    assert recs[0]["group"] == 16


def test_roofline_terms_dominance():
    rt = H.roofline_terms(flops=197e12, hbm_bytes=0, coll_bytes=0, n_chips=1)
    assert rt["dominant"] == "compute" and abs(rt["compute_s"] - 1.0) < 1e-9
    rt = H.roofline_terms(flops=0, hbm_bytes=819e9, coll_bytes=1e9,
                          n_chips=1)
    assert rt["dominant"] == "memory"
    rt = H.roofline_terms(flops=1e12, hbm_bytes=1e9, coll_bytes=500e9,
                          n_chips=256)
    assert rt["dominant"] == "collective"


def test_analytic_flops_scale_with_model():
    """Analytic census tracks 6ND within a small factor for dense LMs
    (extra = attention quadratic + remat + unembed)."""
    for arch in ("llama3.2-3b", "qwen2-72b", "deepseek-67b"):
        cfg = get_config(arch)
        sh = SHAPES["train_4k"]
        got = A.cell_flops_per_device(cfg, sh["seq"], sh["batch"], "train",
                                      256) * 256
        model = 6.0 * cfg.n_params * sh["seq"] * sh["batch"]
        ratio = got / model
        # remat=full gives 4/3 over the 6ND fwd+bwd; attention adds more
        assert 1.1 < ratio < 2.5, (arch, ratio)


def test_analytic_moe_uses_active_params():
    cfg = get_config("dbrx-132b")
    sh = SHAPES["train_4k"]
    got = A.cell_flops_per_device(cfg, sh["seq"], sh["batch"], "train",
                                  256) * 256
    dense_equiv = 6.0 * cfg.n_params * sh["seq"] * sh["batch"]
    active = 6.0 * cfg.n_active_params * sh["seq"] * sh["batch"]
    assert got < dense_equiv * 0.7          # far below dense
    assert got > active * 0.9               # at least the active math


def test_analytic_decode_memory_dominated_by_cache():
    cfg = get_config("qwen2-72b")
    sh = SHAPES["decode_32k"]
    b = A.cell_hbm_bytes_per_device(cfg, sh["seq"], sh["batch"], "decode",
                                    256)
    # bf16 cache: 80L * 128B * 32768 * 8kv * 128hd * 2(k,v) * 2B / 256
    cache = 80 * 128 * 32768 * 8 * 128 * 2 * 2 / 256
    assert b > cache, "cache read must be counted"
    assert b < cache * 2.5, "params should not dominate decode"


def test_model_flops_kinds():
    cfg = get_config("llama3.2-3b")
    tr = H.model_flops(cfg, SHAPES["train_4k"], "train")
    pf = H.model_flops(cfg, SHAPES["prefill_32k"], "prefill")
    de = H.model_flops(cfg, SHAPES["decode_32k"], "decode")
    assert tr == 6.0 * cfg.n_active_params * 4096 * 256
    assert pf == 2.0 * cfg.n_active_params * 32768 * 32
    assert de == 2.0 * cfg.n_active_params * 128
