"""End-to-end system behaviour: train-to-convergence, resume-exactness,
serve generation — the integration surface of all substrates."""
import numpy as np

import jax
import jax.numpy as jnp

from repro.data.pipeline import DataConfig, make_pipeline
from repro.distributed.step import make_serve_step, make_train_step
from repro.models import ModelConfig, build_model
from repro.optim import adamw
from repro.runtime.fault import Supervisor, SupervisorConfig


def _setup(policy="fp8_dpa"):
    cfg = ModelConfig("sys", "decoder", 2, 64, 4, 2, 128, 256,
                      policy=policy)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    state = {"params": params, "opt": adamw.init(params)}
    step = jax.jit(make_train_step(model, adamw.AdamWConfig(
        lr=3e-3, warmup_steps=5, total_steps=80)))
    pipe = make_pipeline(DataConfig(vocab_size=256, batch=8, seq=32))
    return cfg, model, state, step, pipe


def test_training_reduces_loss_under_dpa_policy():
    _, _, state, step, pipe = _setup()
    losses = []
    for i in range(80):
        state, m = step(state, pipe.batch(i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.6, (losses[0], losses[-1])
    assert np.isfinite(losses).all()


def test_supervised_run_with_failure_matches_clean_run(tmp_path):
    """Deterministic pipeline + checkpoint restart => a run with an
    injected failure reaches the SAME final state as a clean run."""
    _, _, state0, step, pipe = _setup("fp32")
    clean = dict(state0)
    for i in range(40):
        clean, _ = step(clean, pipe.batch(i))

    sup = Supervisor(SupervisorConfig(ckpt_dir=str(tmp_path), ckpt_every=10,
                                      async_save=False), state=state0)
    sup.inject_failure_at = 25
    faulty = sup.run(step, pipe.batch, 40)
    for a, b in zip(jax.tree.leaves(clean["params"]),
                    jax.tree.leaves(faulty["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_generation_roundtrip():
    cfg, model, state, step, pipe = _setup()
    for i in range(30):
        state, _ = step(state, pipe.batch(i))
    serve = jax.jit(make_serve_step(model), donate_argnums=(2,))
    caches = model.init_caches(2, 24)
    tok = jnp.ones((2, 1), jnp.int32)
    outs = []
    for t in range(24):
        nxt, caches = serve(state["params"],
                            {"tokens": tok, "index": jnp.int32(t)}, caches)
        tok = nxt[:, None]
        outs.append(nxt)
    seq = jnp.stack(outs, 1)
    assert seq.shape == (2, 24)
    assert bool((seq >= 0).all()) and bool((seq < cfg.vocab_size).all())
