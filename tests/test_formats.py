"""Format decode/encode vs ml_dtypes ground truth + quantization laws."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import formats as F
from repro.core.quantize import (dequantize_blockwise, fake_quant,
                                 quantize_blockwise)
from repro.core.quantize import dequantize as _deq
from repro.core.quantize import quantize as _quant

SMALL = [F.FP16, F.BF16, F.FP8_E4M3, F.FP8_E5M2, F.FP4_E2M1]


@pytest.mark.parametrize("fmt", SMALL, ids=lambda f: f.name)
def test_decode_matches_mldtypes_exhaustive(fmt):
    """Decode every code in the format; reconstruct and compare with the
    ml_dtypes value (NaN/inf flags included)."""
    codes = np.arange(1 << fmt.bits, dtype=np.uint32)
    vals = F.codes_to_np(codes, fmt).astype(np.float64)
    sign, mant, exp, is_zero, is_inf, is_nan = map(
        np.asarray, F.decode(codes, fmt))
    recon = ((-1.0) ** sign) * mant.astype(np.float64) \
        * np.exp2(exp.astype(np.float64) - fmt.man_bits)
    finite = ~(is_inf | is_nan)
    assert np.array_equal(recon[finite], vals[finite]), fmt.name
    assert np.array_equal(is_nan, np.isnan(vals)), fmt.name
    assert np.array_equal(is_inf, np.isinf(vals)), fmt.name
    assert np.array_equal(is_zero, (vals == 0) & ~np.isnan(vals)), fmt.name


@pytest.mark.parametrize("fmt", SMALL, ids=lambda f: f.name)
def test_max_finite_and_min_subnormal(fmt):
    codes = np.arange(1 << fmt.bits, dtype=np.uint32)
    vals = F.codes_to_np(codes, fmt).astype(np.float64)
    finite = vals[np.isfinite(vals)]
    assert finite.max() == fmt.max_finite
    pos = finite[finite > 0]
    assert pos.min() == fmt.min_subnormal


@pytest.mark.parametrize("trial", range(20))
def test_quant_dequant_error_bound(trial):
    """|x - qdq(x)| <= scale * ulp/2 elementwise for fp8 per-tensor
    (seeded randomized sweep over magnitudes up to 1e4, incl. tiny)."""
    rng = np.random.default_rng(1000 + trial)
    n = int(rng.integers(4, 65))
    mag = 10.0 ** rng.uniform(-4, 4)
    x = jnp.asarray(rng.uniform(-mag, mag, size=n).astype(np.float32))
    q, s = _quant(x, "fp8_e4m3")
    err = np.abs(np.asarray(_deq(q, s)) - np.asarray(x))
    scale = float(np.asarray(s).max())
    # fp8e4m3 relative ulp <= 2^-3; absolute bound at the scaled max
    bound = scale * F.FP8_E4M3.quant_target * (2.0 ** -3)
    assert err.max() <= bound + 1e-12


@pytest.mark.parametrize("fmt", ["fp8_e4m3", "fp4_e2m1", "fp16", "bf16"])
def test_fake_quant_identity_shape_grad(fmt):
    import jax
    x = jnp.linspace(-3, 3, 32).reshape(4, 8)
    y = fake_quant(x, fmt)
    assert y.shape == x.shape
    g = jax.grad(lambda t: fake_quant(t, fmt).sum())(x)
    # STE: gradient of identity
    assert np.allclose(np.asarray(g), 1.0)


def test_blockwise_roundtrip():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(8, 256)),
                    jnp.float32)
    q, s = quantize_blockwise(x, "fp8_e4m3", axis=1, block=64)
    y = dequantize_blockwise(q, s, axis=1, block=64)
    rel = np.abs(np.asarray(y) - np.asarray(x)).max() / np.abs(x).max()
    assert rel < 0.08


def test_packing_roundtrip():
    from repro.core import packing as P
    rng = np.random.default_rng(1)
    c = jnp.asarray(rng.integers(0, 16, (16, 32)), jnp.uint8)
    assert (P.unpack_fp4(P.pack_fp4(c)) == c).all()
    assert P.packed_nbytes(10, F.FP4_E2M1) == 5
    assert P.packed_nbytes(10, F.FP8_E4M3) == 10
