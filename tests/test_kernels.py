"""Pallas kernel validation: shape/dtype sweeps vs the ref.py oracles
(interpret mode on CPU — the kernel body itself executes)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import get_policy
from repro.kernels import dpa_matmul as dm
from repro.kernels import ops as O
from repro.kernels import ref
from repro.kernels.ops import _quant_operand

FMTS = ["fp8_e4m3", "fp4_e2m1", "fp16", "bf16"]


@pytest.mark.parametrize("fmt", FMTS)
@pytest.mark.parametrize("mkn", [(128, 128, 128), (256, 384, 128),
                                 (128, 512, 256)])
def test_dpa_matmul_vs_ref(fmt, mkn):
    M, K, N = mkn
    k1, k2 = jax.random.split(jax.random.PRNGKey(M + K + N))
    x = jax.random.normal(k1, (M, K), jnp.float32)
    w = jax.random.normal(k2, (K, N), jnp.float32)
    xq, sx = _quant_operand(x, fmt, -1)
    wq, sw = _quant_operand(w, fmt, 0)
    got = dm.dpa_matmul_prequant(xq, wq, sx, sw, fmt_x=fmt, fmt_w=fmt,
                                 interpret=True)
    want = ref.dpa_matmul_ref(xq, wq, sx, sw, fmt_x=fmt, fmt_w=fmt)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-4)


@pytest.mark.parametrize("fmt", FMTS)
def test_dpa_matmul_block_shapes(fmt):
    """Block-shape sweep: result must be block-shape independent."""
    x = jax.random.normal(jax.random.PRNGKey(0), (256, 256), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (256, 256), jnp.float32)
    xq, sx = _quant_operand(x, fmt, -1)
    wq, sw = _quant_operand(w, fmt, 0)
    outs = []
    for bm, bk, bn in [(128, 128, 128), (64, 256, 128), (256, 64, 64)]:
        outs.append(np.asarray(dm.dpa_matmul_prequant(
            xq, wq, sx, sw, fmt_x=fmt, fmt_w=fmt, bm=bm, bk=bk, bn=bn,
            interpret=True)))
    np.testing.assert_allclose(outs[0], outs[1], rtol=2e-5, atol=2e-4)
    np.testing.assert_allclose(outs[0], outs[2], rtol=2e-5, atol=2e-4)


@pytest.mark.parametrize("pol", ["fp8_dpa", "fp16_dpa", "fp4_dpa",
                                 "bf16_dpa"])
def test_dpa_matmul_policy_wrapper_padding(pol):
    """Non-aligned shapes route through padding and stay close to f32."""
    x = jax.random.normal(jax.random.PRNGKey(2), (100, 200), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(3), (200, 72), jnp.float32)
    y = O.dpa_matmul(x, w, get_policy(pol))
    want = x @ w
    rel = float(jnp.abs(y - want).max() / jnp.abs(want).max())
    tol = {"fp16_dpa": 0.002, "bf16_dpa": 0.02, "fp8_dpa": 0.1,
           "fp4_dpa": 0.35}[pol]
    assert rel < tol, (pol, rel)


@pytest.mark.parametrize("fmt", FMTS)
@pytest.mark.parametrize("mk", [(128, 64), (128, 1024), (256, 333)])
def test_quantize_rows_vs_ref(fmt, mk):
    M, K = mk
    x = jax.random.normal(jax.random.PRNGKey(M * K), (M, K), jnp.float32) * 5
    q, s = O.quantize_rows(x, fmt)
    qr, sr = ref.quantize_rows_ref(x, fmt=fmt)
    if fmt == "fp4_e2m1":
        assert np.array_equal(np.asarray(q), np.asarray(qr))
    else:
        assert np.array_equal(np.asarray(q, np.float32),
                              np.asarray(qr, np.float32))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)


def test_fp4_encode_matches_mldtypes():
    """Kernel arithmetic E2M1 encoder == ml_dtypes RNE cast."""
    import ml_dtypes
    from repro.kernels.quantize import _encode_fp4
    from repro.core.formats import np_to_codes, FP4_E2M1
    x = np.linspace(-7, 7, 4001).astype(np.float32)
    got = np.asarray(_encode_fp4(jnp.clip(jnp.asarray(x), -6, 6)))
    want = np_to_codes(x.astype(ml_dtypes.float4_e2m1fn), FP4_E2M1)
    assert np.array_equal(got, want.astype(np.uint8))


@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 64)])
@pytest.mark.parametrize("hq,hkv", [(8, 8), (8, 2), (4, 1)])
def test_flash_attention_vs_ref(causal, window, hq, hkv):
    k = jax.random.PRNGKey(hq * 10 + (window or 0))
    q = jax.random.normal(k, (2, hq, 256, 64), jnp.float32)
    kk = jax.random.normal(jax.random.PRNGKey(1), (2, hkv, 256, 64),
                           jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (2, hkv, 256, 64),
                          jnp.float32)
    got = O.flash_attention(q, kk, v, causal=causal, window=window)
    want = ref.flash_attention_ref(q, kk, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_decode_shape_kv_longer():
    """Sq < Skv (cache suffix attention during chunked prefill)."""
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 4, 128, 64),
                          jnp.float32)
    kk = jax.random.normal(jax.random.PRNGKey(1), (1, 4, 512, 64),
                           jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 4, 512, 64),
                          jnp.float32)
    got = O.flash_attention(q, kk, v, causal=True)
    want = ref.flash_attention_ref(q, kk, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_dpa_matmul_bf16_inputs():
    """Kernel accepts bf16 activations directly (mixed-precision train)."""
    x = jax.random.normal(jax.random.PRNGKey(4), (128, 128), jnp.bfloat16)
    w = jax.random.normal(jax.random.PRNGKey(5), (128, 128), jnp.float32)
    y = O.dpa_matmul(x, w, get_policy("fp8_dpa"))
    assert y.dtype == jnp.bfloat16 and bool(jnp.isfinite(
        y.astype(jnp.float32)).all())
