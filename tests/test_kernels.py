"""Pallas kernel validation: shape/dtype sweeps vs the ref.py oracles
(interpret mode on CPU — the kernel body itself executes)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import get_policy
from repro.kernels import dpa_matmul as dm
from repro.kernels import ops as O
from repro.kernels import ref
from repro.kernels.ops import _quant_operand

FMTS = ["fp8_e4m3", "fp4_e2m1", "fp16", "bf16"]


@pytest.mark.parametrize("fmt", FMTS)
@pytest.mark.parametrize("mkn", [(128, 128, 128), (256, 384, 128),
                                 (128, 512, 256)])
def test_dpa_matmul_vs_ref(fmt, mkn):
    M, K, N = mkn
    k1, k2 = jax.random.split(jax.random.PRNGKey(M + K + N))
    x = jax.random.normal(k1, (M, K), jnp.float32)
    w = jax.random.normal(k2, (K, N), jnp.float32)
    xq, sx = _quant_operand(x, fmt, -1)
    wq, sw = _quant_operand(w, fmt, 0)
    got = dm.dpa_matmul_prequant(xq, wq, sx, sw, fmt_x=fmt, fmt_w=fmt,
                                 interpret=True)
    want = ref.dpa_matmul_ref(xq, wq, sx, sw, fmt_x=fmt, fmt_w=fmt)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-4)


@pytest.mark.parametrize("fmt", FMTS)
def test_dpa_matmul_block_shapes(fmt):
    """Block-shape sweep: result must be block-shape independent."""
    x = jax.random.normal(jax.random.PRNGKey(0), (256, 256), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (256, 256), jnp.float32)
    xq, sx = _quant_operand(x, fmt, -1)
    wq, sw = _quant_operand(w, fmt, 0)
    outs = []
    for bm, bk, bn in [(128, 128, 128), (64, 256, 128), (256, 64, 64)]:
        outs.append(np.asarray(dm.dpa_matmul_prequant(
            xq, wq, sx, sw, fmt_x=fmt, fmt_w=fmt, bm=bm, bk=bk, bn=bn,
            interpret=True)))
    np.testing.assert_allclose(outs[0], outs[1], rtol=2e-5, atol=2e-4)
    np.testing.assert_allclose(outs[0], outs[2], rtol=2e-5, atol=2e-4)


@pytest.mark.parametrize("pol", ["fp8_dpa", "fp16_dpa", "fp4_dpa",
                                 "bf16_dpa"])
def test_dpa_matmul_policy_wrapper_padding(pol):
    """Non-aligned shapes route through padding and stay close to f32."""
    x = jax.random.normal(jax.random.PRNGKey(2), (100, 200), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(3), (200, 72), jnp.float32)
    y = O.dpa_matmul(x, w, get_policy(pol))
    want = x @ w
    rel = float(jnp.abs(y - want).max() / jnp.abs(want).max())
    tol = {"fp16_dpa": 0.002, "bf16_dpa": 0.02, "fp8_dpa": 0.1,
           "fp4_dpa": 0.35}[pol]
    assert rel < tol, (pol, rel)


# -----------------------------------------------------------------------------
# packed-operand and fused-quantize pipelines
# -----------------------------------------------------------------------------

BLOCKS = [(128, 128, 128), (64, 256, 128), (128, 64, 256), (256, 128, 64)]


@pytest.mark.parametrize("bm,bk,bn", BLOCKS)
@pytest.mark.parametrize("pack_x,pack_w", [(True, True), (True, False),
                                           (False, True)])
def test_packed_fp4_bit_identical_to_unpacked(pack_x, pack_w, bm, bk, bn):
    """The tentpole contract: packing is pure I/O layout.  Moving fp4
    operands as 2-codes-per-byte through the BlockSpec and unpacking
    nibbles in VMEM must be BIT-identical to the byte-per-code path,
    across square and non-square blocks."""
    from repro.core.packing import pack_fp4_axis
    M, K, N = 256, 512, 256
    x = jax.random.normal(jax.random.PRNGKey(10), (M, K), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(11), (K, N), jnp.float32)
    xq, sx = _quant_operand(x, "fp4_e2m1", -1)
    wq, sw = _quant_operand(w, "fp4_e2m1", 0)
    base = np.asarray(dm.dpa_matmul_prequant(
        xq, wq, sx, sw, fmt_x="fp4_e2m1", fmt_w="fp4_e2m1",
        bm=bm, bk=bk, bn=bn, interpret=True))
    got = np.asarray(dm.dpa_matmul_prequant(
        pack_fp4_axis(xq, 1) if pack_x else xq,
        pack_fp4_axis(wq, 0) if pack_w else wq,
        sx, sw, fmt_x="fp4_e2m1", fmt_w="fp4_e2m1", bm=bm, bk=bk, bn=bn,
        pack_x=pack_x, pack_w=pack_w, interpret=True))
    assert np.array_equal(got, base), (pack_x, pack_w, bm, bk, bn)


@pytest.mark.parametrize("fmt_x", ["fp8_e4m3", "fp4_e2m1", "fp16"])
@pytest.mark.parametrize("bm,bk,bn", BLOCKS)
def test_fused_quantize_matmul_vs_ref(fmt_x, bm, bk, bn):
    """Fused in-kernel quantization == the blockwise-quantize reference
    (per-(row, K-block) scales), across formats x block shapes."""
    M, K, N = 256, 512, 256
    x = jax.random.normal(jax.random.PRNGKey(20), (M, K), jnp.float32) * 3
    w = jax.random.normal(jax.random.PRNGKey(21), (K, N), jnp.float32)
    wq, sw = _quant_operand(w, "fp8_e4m3", 0)
    got = dm.dpa_matmul_fused(x, wq, sw, fmt_x=fmt_x, fmt_w="fp8_e4m3",
                              bm=bm, bk=bk, bn=bn, interpret=True)
    want = ref.dpa_matmul_fused_ref(x, wq, sw, fmt_x=fmt_x,
                                    fmt_w="fp8_e4m3", bk=bk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-4)


@pytest.mark.parametrize("bm,bk,bn", BLOCKS[:2])
def test_fused_packed_w_bit_identical(bm, bk, bn):
    """Packed weights through the FUSED kernel == unpacked weights through
    the fused kernel, bit for bit."""
    from repro.core.packing import pack_fp4_axis
    M, K, N = 128, 256, 128
    x = jax.random.normal(jax.random.PRNGKey(30), (M, K), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(31), (K, N), jnp.float32)
    wq, sw = _quant_operand(w, "fp4_e2m1", 0)
    base = np.asarray(dm.dpa_matmul_fused(
        x, wq, sw, fmt_x="fp8_e4m3", fmt_w="fp4_e2m1",
        bm=bm, bk=bk, bn=bn, interpret=True))
    got = np.asarray(dm.dpa_matmul_fused(
        x, pack_fp4_axis(wq, 0), sw, fmt_x="fp8_e4m3", fmt_w="fp4_e2m1",
        bm=bm, bk=bk, bn=bn, pack_w=True, interpret=True))
    assert np.array_equal(got, base)


@pytest.mark.parametrize("pol", ["fp4_dpa_packed", "fp4_dpa_fused",
                                 "fp8_dpa_fused", "w4a8_packed"])
def test_packed_fused_policy_wrapper(pol):
    """Policy-selected packed/fused paths survive padding on non-aligned
    shapes and stay close to the f32 answer."""
    x = jax.random.normal(jax.random.PRNGKey(40), (100, 200), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(41), (200, 72), jnp.float32)
    y = O.dpa_matmul(x, w, get_policy(pol))
    want = x @ w
    rel = float(jnp.abs(y - want).max() / jnp.abs(want).max())
    tol = {"fp4_dpa_packed": 0.35, "fp4_dpa_fused": 0.35,
           "fp8_dpa_fused": 0.1, "w4a8_packed": 0.35}[pol]
    assert rel < tol, (pol, rel)


def test_packed_policy_bit_identical_via_wrapper():
    """End-to-end `ops.dpa_matmul`: the packed preset reproduces the
    unpacked preset's result bit for bit (same formats, same scales)."""
    x = jax.random.normal(jax.random.PRNGKey(50), (128, 256), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(51), (256, 128), jnp.float32)
    unpacked = get_policy("fp4_dpa_packed").replace(packed=False)
    a = np.asarray(O.dpa_matmul(x, w, unpacked))
    b = np.asarray(O.dpa_matmul(x, w, get_policy("fp4_dpa_packed")))
    assert np.array_equal(a, b)


def test_quantize_pack_rows_matches_unpacked():
    """Fused quantize->pack kernel: packed bytes unpack to exactly the
    codes the unpacked quantize kernel emits; scales identical."""
    from repro.core.packing import unpack_fp4_axis
    x = jax.random.normal(jax.random.PRNGKey(60), (130, 64), jnp.float32)
    qp, sp = O.quantize_rows(x, "fp4_e2m1", pack=True)
    q, s = O.quantize_rows(x, "fp4_e2m1")
    assert qp.shape == (130, 32) and qp.dtype == jnp.uint8
    assert np.array_equal(np.asarray(unpack_fp4_axis(qp, 1)), np.asarray(q))
    np.testing.assert_array_equal(np.asarray(sp), np.asarray(s))


def test_operand_bytes_moved_ratios():
    """The paper's Table I bandwidth story: fp16/fp8/packed-fp4 operands
    move 2x/4x/8x fewer bytes than f32 through the interface."""
    from repro.core.packing import matmul_operand_bytes, operand_nbytes
    n = 1 << 20
    assert operand_nbytes(n, "fp16") * 2 == 4 * n
    assert operand_nbytes(n, "fp8_e4m3") * 4 == 4 * n
    assert operand_nbytes(n, "fp4_e2m1", packed=True) * 8 == 4 * n
    assert operand_nbytes(n, "fp4_e2m1", packed=False) * 4 == 4 * n
    for pol, ratio in (("fp16_dpa", 2.0), ("fp8_dpa", 4.0),
                       ("fp4_dpa_packed", 8.0)):
        got = matmul_operand_bytes(4096, 4096, 4096, pol)["reduction_vs_f32"]
        assert abs(got - ratio) / ratio < 0.02, (pol, got)


@pytest.mark.parametrize("fmt", FMTS)
@pytest.mark.parametrize("mk", [(128, 64), (128, 1024), (256, 333)])
def test_quantize_rows_vs_ref(fmt, mk):
    M, K = mk
    x = jax.random.normal(jax.random.PRNGKey(M * K), (M, K), jnp.float32) * 5
    q, s = O.quantize_rows(x, fmt)
    qr, sr = ref.quantize_rows_ref(x, fmt=fmt)
    if fmt == "fp4_e2m1":
        assert np.array_equal(np.asarray(q), np.asarray(qr))
    else:
        assert np.array_equal(np.asarray(q, np.float32),
                              np.asarray(qr, np.float32))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)


def test_fp4_encode_matches_mldtypes():
    """Kernel arithmetic E2M1 encoder == ml_dtypes RNE cast."""
    import ml_dtypes
    from repro.kernels.quantize import _encode_fp4
    from repro.core.formats import np_to_codes, FP4_E2M1
    x = np.linspace(-7, 7, 4001).astype(np.float32)
    got = np.asarray(_encode_fp4(jnp.clip(jnp.asarray(x), -6, 6)))
    want = np_to_codes(x.astype(ml_dtypes.float4_e2m1fn), FP4_E2M1)
    assert np.array_equal(got, want.astype(np.uint8))


@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 64)])
@pytest.mark.parametrize("hq,hkv", [(8, 8), (8, 2), (4, 1)])
def test_flash_attention_vs_ref(causal, window, hq, hkv):
    k = jax.random.PRNGKey(hq * 10 + (window or 0))
    q = jax.random.normal(k, (2, hq, 256, 64), jnp.float32)
    kk = jax.random.normal(jax.random.PRNGKey(1), (2, hkv, 256, 64),
                           jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (2, hkv, 256, 64),
                          jnp.float32)
    got = O.flash_attention(q, kk, v, causal=causal, window=window)
    want = ref.flash_attention_ref(q, kk, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_decode_shape_kv_longer():
    """Sq < Skv (cache suffix attention during chunked prefill)."""
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 4, 128, 64),
                          jnp.float32)
    kk = jax.random.normal(jax.random.PRNGKey(1), (1, 4, 512, 64),
                           jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 4, 512, 64),
                          jnp.float32)
    got = O.flash_attention(q, kk, v, causal=True)
    want = ref.flash_attention_ref(q, kk, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_dpa_matmul_bf16_inputs():
    """Kernel accepts bf16 activations directly (mixed-precision train)."""
    x = jax.random.normal(jax.random.PRNGKey(4), (128, 128), jnp.bfloat16)
    w = jax.random.normal(jax.random.PRNGKey(5), (128, 128), jnp.float32)
    y = O.dpa_matmul(x, w, get_policy("fp8_dpa"))
    assert y.dtype == jnp.bfloat16 and bool(jnp.isfinite(
        y.astype(jnp.float32)).all())
