"""Continuous-batching engine end-to-end: per-request greedy outputs
must equal the static-batch `launch.serve.generate` path, with cache
memory scaling by live tokens and pages evicted back to the free list.

The equality claim is exact (token-for-token), not approximate: paging
is pure relayout, the engine's prefill runs the same quantized-cache
path as the static driver, and the paged decode step is bit-identical to
the contiguous one (see `tests/test_paged_kv.py`), so greedy argmax must
agree even on random-init near-flat logits.  The static reference runs
at the engine's S_max so both paths mask/reduce over identical shapes.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduce_config
from repro.launch.engine import (Engine, EngineConfig, Request,
                                 synthetic_workload)
from repro.launch.serve import generate
from repro.models import build_model

POLICY = "kv4_attn8_packed"
ECFG = EngineConfig(page_size=8, n_pages=32, max_batch=3,
                    max_pages_per_req=4, token_budget=8, prefill_chunk=8)
# mixed prompt/output lengths: partial pages, multi-page prompts, more
# requests than decode slots (continuous batching, not one static batch)
LENS = [(9, 5), (14, 7), (5, 4), (20, 6), (11, 8)]


@pytest.fixture(scope="module")
def model_and_params():
    cfg = reduce_config(get_config("qwen3-4b")).replace(policy=POLICY)
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def _requests(vocab, seed=3):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, vocab, size=s0).astype(np.int32),
                    max_new=g)
            for i, (s0, g) in enumerate(LENS)]


@pytest.fixture(scope="module")
def served(model_and_params):
    model, params = model_and_params
    engine = Engine(model, params, ECFG)
    reqs = _requests(model.cfg.vocab_size)
    report = engine.run(reqs)
    return engine, report


def test_engine_matches_static_batch_per_request(model_and_params, served):
    model, params = model_and_params
    engine, _ = served
    for req in _requests(model.cfg.vocab_size):
        out = generate(model, params, jnp.asarray(req.prompt[None]),
                       req.max_new, ECFG.s_max)
        want = np.asarray(out)[0, req.n_prompt:]
        got = [r for r in engine.finished if r.rid == req.rid][0]
        assert np.array_equal(np.asarray(got.out_tokens), want), req.rid
        # and the full tokens() timeline matches the static layout
        assert np.array_equal(got.tokens(), np.asarray(out)[0])


def test_engine_finishes_and_evicts(served):
    engine, report = served
    assert report["n_requests"] == len(LENS)
    assert report["gen_tokens"] == sum(g for _, g in LENS)
    # eviction: every page returned to the free list, slots idle
    assert engine.alloc.in_use == 0
    assert all(s is None for s in engine.slots)
    assert engine.alloc.peak_in_use > 0
    assert np.all(engine._table == 0)          # all rows back to scratch


def test_engine_report_counts_live_tokens_not_b_smax(served):
    _, report = served
    # honest accounting: live <= paged (page granularity) < static layouts
    assert 0 < report["live_bytes"] <= report["paged_bytes"]
    assert report["paged_bytes"] < report["static_bytes"]
    assert report["static_bytes"] < report["static_f32_bytes"]
    assert 0.0 < report["page_util"] <= 1.0
    assert report["p50_latency_s"] <= report["p99_latency_s"]
    assert report["tokens_per_s"] > 0


def test_engine_poisson_open_loop(model_and_params):
    """Arrivals spread in time (open loop) still drain completely, with
    deterministic workload shapes from the seed."""
    model, params = model_and_params
    engine = Engine(model, params, ECFG)
    reqs = synthetic_workload(6, vocab=model.cfg.vocab_size, seed=1,
                              rate=200.0, prompt_range=(4, 12),
                              gen_range=(2, 5))
    assert all(reqs[i].arrival <= reqs[i + 1].arrival
               for i in range(len(reqs) - 1))
    report = engine.run(reqs)
    assert report["n_requests"] == 6
    assert engine.alloc.in_use == 0


def test_engine_queues_when_pool_is_tight(model_and_params):
    """A pool smaller than the aggregate demand forces waiting-queue
    admission control; everything still completes via page reuse."""
    model, params = model_and_params
    ecfg = EngineConfig(page_size=8, n_pages=8, max_batch=3,
                        max_pages_per_req=4, token_budget=8,
                        prefill_chunk=8)
    engine = Engine(model, params, ecfg)
    reqs = _requests(model.cfg.vocab_size)      # needs 15 pages total, has 7
    report = engine.run(reqs)
    assert report["n_requests"] == len(LENS)
    assert engine.alloc.peak_in_use <= 7


def test_prefill_baton_survives_same_tick_admission(model_and_params):
    """A partially-prefilled request must keep the (shared) staging cache
    until its prompt is fully staged.  Regression: a request admitted
    later in the *same tick* (after a finish freed a lower slot) used to
    tie on t_admit and steal the prefill baton by slot order,
    interleaving two prompts' rows in staging — silently corrupting both
    requests' outputs."""
    model, params = model_and_params
    ecfg = EngineConfig(page_size=8, n_pages=32, max_batch=2,
                        max_pages_per_req=4, token_budget=8,
                        prefill_chunk=8)
    engine = Engine(model, params, ecfg)
    rng = np.random.default_rng(7)
    V = model.cfg.vocab_size
    # X finishes fast, freeing slot 0 mid-tick while A (2.5 chunks) is
    # still prefilling; B then admits into slot 0 with A's t_admit
    x = Request(rid=0, prompt=rng.integers(0, V, 8).astype(np.int32),
                max_new=2)
    a = Request(rid=1, prompt=rng.integers(0, V, 20).astype(np.int32),
                max_new=4)
    b = Request(rid=2, prompt=rng.integers(0, V, 20).astype(np.int32),
                max_new=4)
    engine.submit(x)
    engine.step(0.0)
    engine.submit(a)
    engine.submit(b)
    now = 1.0
    while any(engine.slots) or engine.waiting:
        engine.step(now)
        now += 1.0
    for req in (x, a, b):
        out = generate(model, params, jnp.asarray(req.prompt[None]),
                       req.max_new, ecfg.s_max)
        want = np.asarray(out)[0, req.n_prompt:]
        assert np.array_equal(np.asarray(req.out_tokens), want), req.rid


def test_engine_report_is_strict_json(served):
    """The report must round-trip through strict JSON even at wall == 0
    (tokens_per_s reports 0.0, never inf/NaN — json.dumps(...,
    allow_nan=False) is what downstream harnesses hold us to)."""
    import json
    engine, _ = served
    for wall in (0.0, 1.0):
        rep = engine.report(wall)
        back = json.loads(json.dumps(rep, allow_nan=False))
        assert back == rep
    assert engine.report(0.0)["tokens_per_s"] == 0.0
    assert engine.report(1.0)["tokens_per_s"] > 0.0


def test_engine_rejects_raw_cache_policy(model_and_params):
    model, _ = model_and_params
    cfg = model.cfg.replace(policy="fp32")
    m2 = build_model(cfg)
    with pytest.raises(ValueError, match="fmt_kv"):
        Engine(m2, None, ECFG)


def test_engine_rejects_oversized_request(model_and_params, served):
    model, params = model_and_params
    engine, _ = served
    big = Request(rid=99, prompt=np.zeros(ECFG.s_max, np.int32), max_new=1)
    with pytest.raises(ValueError, match="S_max"):
        engine.submit(big)


def test_engine_rejects_misaligned_prefill_chunk(model_and_params):
    model, params = model_and_params
    with pytest.raises(ValueError, match="prefill_chunk"):
        Engine(model, params, EngineConfig(page_size=8, max_pages_per_req=4,
                                           prefill_chunk=7))
