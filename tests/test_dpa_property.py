"""DPA conformance suite: TransDot golden model vs the exact big-int oracle.

Seeded randomized property tests driving `dpa_codes` against
`core.oracle.dpa_exact` across every (fmt_ab, N) mode of Table I —
fp16/N=2, fp8_e4m3/N=4, fp4_e2m1/N=8, plus the scalar and fp16-accumulate
modes.  The contract (DESIGN.md §4): bit-exact vs the exact single-rounded
sum whenever cancellation does not dig below the accumulation window; a
bounded absolute error 2^(anchor - W + 3) otherwise; bit-exact always with
a wide window.  Dedicated cases cover RNE ties, signed zeros, subnormal
operands, and NaN/Inf propagation, plus the FPnew sequential-FMA baseline
semantics.
"""
import numpy as np
import pytest

from repro.core import dpa, formats as F, oracle
from repro.core.fpnew_ref import sequential_fma_codes

MODES = [("fp16", "fp32", 2), ("fp8_e4m3", "fp32", 4),
         ("fp4_e2m1", "fp32", 8), ("fp32", "fp32", 1),
         ("fp16", "fp16", 2), ("fp8_e4m3", "fp16", 4)]


def _rand_codes(rng, fmt, shape, specials=False):
    c = rng.integers(0, 1 << fmt.bits, size=shape).astype(np.uint32)
    if not specials and fmt.special != "none":
        # remap NaN/inf codes into finite space
        vals = F.codes_to_np(c, fmt).astype(np.float64)
        bad = ~np.isfinite(vals)
        c = np.where(bad, c & (fmt.man_mask >> 1), c)
    return c


def _assert_conformant(a, b, c, fmt_ab, fmt_acc, n, *, window_bits=None):
    """got == oracle bit-for-bit, except under the window-loss bound."""
    fa, fc = F.get_format(fmt_ab), F.get_format(fmt_acc)
    got = np.asarray(dpa.dpa_codes(a, b, c, fa, fc, window_bits))
    want = oracle.dpa_exact(a, b, c, fa, fc)
    gf = F.codes_to_np(got, fc).astype(np.float64)
    wf = F.codes_to_np(want, fc).astype(np.float64)
    mismatch = (got != want) & ~(np.isnan(gf) & np.isnan(wf))
    if mismatch.any():
        W = window_bits or dpa.default_window_bits(fc, n)
        av = F.codes_to_np(a, fa).astype(np.float64)
        bv = F.codes_to_np(b, fa).astype(np.float64)
        cv = F.codes_to_np(c, fc).astype(np.float64)
        mags = np.concatenate([np.abs(av * bv),
                               np.abs(cv)[:, None]], axis=1)
        anchor = np.log2(np.maximum(mags.max(axis=1), 1e-300)) + 1
        bound = 2.0 ** (anchor - W + 3)
        err = np.abs(gf - wf)
        bad = mismatch & ~(err <= bound)
        assert not bad.any(), (
            f"{bad.sum()} results outside the window bound; first bad lane: "
            f"a={av[bad][0]} b={bv[bad][0]} c={cv[bad][0]} "
            f"got={gf[bad][0]} want={wf[bad][0]}")


@pytest.mark.parametrize("fmt_ab,fmt_acc,n", MODES,
                         ids=[f"{a}x{n}to{c}" for a, c, n in MODES])
def test_bitexact_vs_oracle_random(fmt_ab, fmt_acc, n):
    """Random finite operands across the FULL code space (subnormals,
    extreme exponents included)."""
    fa, fc = F.get_format(fmt_ab), F.get_format(fmt_acc)
    rng = np.random.default_rng(42)
    trials = 1500
    a = _rand_codes(rng, fa, (trials, n))
    b = _rand_codes(rng, fa, (trials, n))
    c = _rand_codes(rng, fc, (trials,))
    _assert_conformant(a, b, c, fmt_ab, fmt_acc, n)


@pytest.mark.parametrize("fmt_ab,fmt_acc,n", MODES,
                         ids=[f"{a}x{n}to{c}" for a, c, n in MODES])
def test_subnormal_operands(fmt_ab, fmt_acc, n):
    """All-subnormal operand lanes (e_raw == 0): the alignment shifter's
    denormal corner.  Products are tiny so the window anchors low and the
    result must still be bit-exact."""
    fa, fc = F.get_format(fmt_ab), F.get_format(fmt_acc)
    rng = np.random.default_rng(7)
    trials = 600
    # codes with zero exponent field: sign x subnormal fraction
    sub = fa.man_mask + 1          # number of (sign-less) subnormal codes
    a = rng.integers(0, sub, size=(trials, n)).astype(np.uint32) \
        | (rng.integers(0, 2, size=(trials, n)).astype(np.uint32)
           << (fa.bits - 1))
    b = rng.integers(0, sub, size=(trials, n)).astype(np.uint32) \
        | (rng.integers(0, 2, size=(trials, n)).astype(np.uint32)
           << (fa.bits - 1))
    c = _rand_codes(rng, fc, (trials,))
    _assert_conformant(a, b, c, fmt_ab, fmt_acc, n)
    # and with a subnormal addend too
    csub = rng.integers(0, fc.man_mask + 1, size=trials).astype(np.uint32)
    _assert_conformant(a, b, csub, fmt_ab, fmt_acc, n)


@pytest.mark.parametrize("fmt_ab,fmt_acc,n", MODES[:3],
                         ids=[f"{a}x{n}" for a, c, n in MODES[:3]])
def test_rne_ties(fmt_ab, fmt_acc, n):
    """Engineered RNE tie cases: a large product plus a term that lands
    exactly half an ulp below the large term's grid.  The oracle computes
    the exact single-rounded answer, so bit-equality proves ties-to-even.

    Construction: a0*b0 = 1.0 (code of 1.0 squared), a1*b1 = +-2^-e with e
    chosen so the sum sits exactly between two fmt_acc values.  For fp32
    (p=24) 1.0 + 2^-25 is a tie -> rounds down to 1.0 (even); 1.5 + 2^-25
    is representable-adjacent; we sweep products of +-2^-k around p."""
    fa, fc = F.get_format(fmt_ab), F.get_format(fmt_acc)
    one = int(F.float_to_codes(np.array(1.0), fa)[()])
    lanes = []
    # powers of two representable in fmt_ab (normal range)
    pows = [2.0 ** k for k in range(fa.emin, fa.emax + 1)]
    for p2 in pows:
        for sign in (1.0, -1.0):
            a = [one] * n
            b = [one] * n
            # second term: sqrt-free tie generator — p2 * 1.0 product
            tie = int(F.float_to_codes(np.array(sign * p2), fa)[()])
            if n >= 2:
                a[1] = tie
                b[1] = one
            lanes.append((a, b))
    a = np.array([l[0] for l in lanes], np.uint32)
    b = np.array([l[1] for l in lanes], np.uint32)
    # addends at half-ulp offsets of 1.0 in fmt_acc: 2^-(p), 2^-(p+1)
    for k in (fc.precision, fc.precision + 1, fc.precision + 2):
        for cs in (1.0, -1.0):
            c_val = np.full(len(lanes), cs * 2.0 ** -k)
            c = F.float_to_codes(c_val, fc)
            _assert_conformant(a, b, c, fmt_ab, fmt_acc, n)


def test_rne_tie_to_even_explicit():
    """Pin the canonical fp32 ties: 1 + 2^-25 -> 1.0 (down to even) and
    (1 + 2^-23) + 2^-24 -> 1 + 2^-22 ulp step (up to even)."""
    fa, fc = F.FP16, F.FP32
    one16 = 0x3C00
    a = np.array([[one16, 0]], np.uint32)
    b = np.array([[one16, 0]], np.uint32)
    # c = 2^-25: exact sum 1 + 2^-25, tie -> 1.0
    c = F.float_to_codes(np.array([2.0 ** -25]), fc)
    got = np.asarray(dpa.dpa_codes(a, b, c, fa, fc))[0]
    assert got == 0x3F800000, hex(int(got))
    # c = 3 * 2^-25 = 2^-24 + 2^-25: tie between 1+2^-24... exact sum
    # 1 + 3*2^-25 lies between 1+2^-24 (ulp/2 above) -> nearest is 1+2^-23?
    # Use the oracle to avoid hand-rounding mistakes on this one.
    c = F.float_to_codes(np.array([3.0 * 2.0 ** -25]), fc)
    got = np.asarray(dpa.dpa_codes(a, b, c, fa, fc))
    want = oracle.dpa_exact(a, b, c, fa, fc)
    assert got[0] == want[0]


@pytest.mark.parametrize("fmt_ab,fmt_acc,n", MODES[:3],
                         ids=[f"{a}x{n}" for a, c, n in MODES[:3]])
def test_bitexact_wide_window(fmt_ab, fmt_acc, n):
    """With a 140-bit window the model must match the oracle everywhere,
    including engineered catastrophic cancellation."""
    fa, fc = F.get_format(fmt_ab), F.get_format(fmt_acc)
    rng = np.random.default_rng(7)
    a = _rand_codes(rng, fa, (800, n))
    b = _rand_codes(rng, fa, (800, n))
    # force pairwise cancellation: b1 = -b0, a1 = a0
    if n >= 2:
        b[:, 1] = b[:, 0] ^ (1 << (fa.bits - 1))
        a[:, 1] = a[:, 0]
    # c within a moderate range so (product span + c span) fits the wide
    # window — the full-code-space regime is covered (with the window
    # bound) by test_bitexact_vs_oracle_random
    c = F.float_to_codes(rng.normal(size=800) * 1e3, fc)
    got = np.asarray(dpa.dpa_codes(a, b, c, fa, fc, window_bits=140))
    want = oracle.dpa_exact(a, b, c, fa, fc)
    gf = F.codes_to_np(got, fc).astype(np.float64)
    wf = F.codes_to_np(want, fc).astype(np.float64)
    ok = (got == want) | (np.isnan(gf) & np.isnan(wf))
    assert ok.all(), f"{(~ok).sum()} mismatches with wide window"


@pytest.mark.parametrize("trial", range(6))
def test_fma_correctly_rounded_random(trial):
    """Scalar trans-precision FMA (N=1) is correctly rounded for random
    inputs across the full fp16 x fp16 + fp32 code space — the hardware
    3p+4 exactness property (seeded sweep, 6 x 500 lanes)."""
    rng = np.random.default_rng(5000 + trial)
    a = rng.integers(0, 1 << 16, size=(500, 1)).astype(np.uint32)
    b = rng.integers(0, 1 << 16, size=(500, 1)).astype(np.uint32)
    c = rng.integers(0, 1 << 32, size=500, dtype=np.uint64).astype(np.uint32)
    got = np.asarray(dpa.dpa_codes(a, b, c, F.FP16, F.FP32))
    want = oracle.dpa_exact(a, b, c, F.FP16, F.FP32)
    gf = F.codes_to_np(got, F.FP32).astype(np.float64)
    wf = F.codes_to_np(want, F.FP32).astype(np.float64)
    ok = (got == want) | (np.isnan(gf) & np.isnan(wf))
    assert ok.all(), f"{(~ok).sum()} scalar FMA mismatches"


def test_special_values():
    fa, fc = F.FP16, F.FP32
    inf = 0x7C00
    ninf = 0xFC00
    nan = 0x7E00
    one = 0x3C00
    zero = 0x0000
    cases = [
        # (a, b), c -> predicate on float result
        ([(inf, one), (one, one)], 0, lambda v: v == np.inf),
        ([(ninf, one), (one, one)], 0, lambda v: v == -np.inf),
        ([(inf, zero), (one, one)], 0, np.isnan),        # inf * 0
        ([(inf, one), (ninf, one)], 0, np.isnan),        # inf - inf
        ([(nan, one), (one, one)], 0, np.isnan),
        ([(one, one), (one, one)], 0x7F800000, lambda v: v == np.inf),
        ([(one, one), (one, one)], 0xFF800000, lambda v: v == -np.inf),
        ([(inf, one), (one, one)], 0xFF800000, np.isnan),
    ]
    for terms, c, pred in cases:
        a = np.array([[t[0] for t in terms]], np.uint32)
        b = np.array([[t[1] for t in terms]], np.uint32)
        out = np.asarray(dpa.dpa_codes(a, b, np.array([c], np.uint32),
                                       fa, fc))
        v = F.codes_to_np(out, fc).astype(np.float64)[0]
        assert pred(v), (terms, c, v)


def test_special_values_e5m2_and_fn_nan():
    """OCP specials: fp8-e5m2 has IEEE-like inf/NaN; fp8-e4m3 ("fn") has
    only the all-ones NaN and must saturate instead of overflowing."""
    # e5m2: inf * 1 -> inf through the N=4 datapath
    f8 = F.FP8_E5M2
    inf8 = int(F.np_to_codes(np.array(np.inf), f8)[()])
    one8 = int(F.np_to_codes(np.array(1.0), f8)[()])
    a = np.array([[inf8, one8, 0, 0]], np.uint32)
    b = np.array([[one8, one8, 0, 0]], np.uint32)
    out = np.asarray(dpa.dpa_codes(a, b, np.zeros(1, np.uint32), f8, F.FP32))
    assert F.codes_to_np(out, F.FP32)[0] == np.inf
    # e4m3 fn NaN in -> NaN out
    f8fn = F.FP8_E4M3
    nanfn = F.nan_code(f8fn)
    a = np.array([[nanfn, one8, 0, 0]], np.uint32)
    out = np.asarray(dpa.dpa_codes(a, b, np.zeros(1, np.uint32), f8fn,
                                   F.FP32))
    assert np.isnan(F.codes_to_np(out, F.FP32)[0])


def test_signed_zero():
    fa, fc = F.FP16, F.FP32
    nzero16 = 0x8000
    nzero32 = np.uint32(0x80000000)
    a = np.array([[nzero16, nzero16]], np.uint32)
    b = np.array([[0x3C00, 0x3C00]], np.uint32)   # -0 * 1 = -0 twice
    out = np.asarray(dpa.dpa_codes(a, b, np.array([nzero32]), fa, fc))[0]
    assert out == 0x80000000                       # all -0 -> -0
    out = np.asarray(dpa.dpa_codes(a, b, np.array([0], np.uint32),
                                   fa, fc))[0]
    assert out == 0                                # mixed signs -> +0


def test_signed_zero_all_modes():
    """Sum-of-zeros sign rule holds in every (fmt_ab, N) mode: all negative
    zeros -> -0, any positive zero in the mix -> +0."""
    for fmt_ab, fmt_acc, n in MODES:
        fa, fc = F.get_format(fmt_ab), F.get_format(fmt_acc)
        nz = 1 << (fa.bits - 1)                    # -0 in fmt_ab
        onec = int(F.float_to_codes(np.array(1.0), fa)[()])
        a = np.full((1, n), nz, np.uint32)
        b = np.full((1, n), onec, np.uint32)
        ncz = np.array([1 << (fc.bits - 1)], np.uint32)
        out = np.asarray(dpa.dpa_codes(a, b, ncz, fa, fc))[0]
        assert out == (1 << (fc.bits - 1)), (fmt_ab, fmt_acc, hex(int(out)))
        out = np.asarray(dpa.dpa_codes(a, b, np.zeros(1, np.uint32),
                                       fa, fc))[0]
        assert out == 0, (fmt_ab, fmt_acc, hex(int(out)))


def test_dpa_single_rounding_beats_sequential():
    """The paper's numerics motivation: DPA (one rounding) accumulates
    less error than FPnew sequential FMA (N roundings) on long dots."""
    rng = np.random.default_rng(3)
    n, trials = 4, 400
    fa, fc = F.FP8_E4M3, F.FP16     # coarse accumulate fmt shows the gap
    a = rng.normal(size=(trials, n))
    b = rng.normal(size=(trials, n))
    ac = F.float_to_codes(a, fa)
    bc = F.float_to_codes(b, fa)
    cc = np.zeros(trials, np.uint32)
    av = F.codes_to_np(ac, fa).astype(np.float64)
    bv = F.codes_to_np(bc, fa).astype(np.float64)
    exact = (av * bv).sum(1)
    got_dpa = F.codes_to_np(np.asarray(dpa.dpa_codes(ac, bc, cc, fa, fc)),
                            fc).astype(np.float64)
    got_seq = F.codes_to_np(np.asarray(sequential_fma_codes(ac, bc, cc,
                                                            fa, fc)),
                            fc).astype(np.float64)
    err_dpa = np.abs(got_dpa - exact).mean()
    err_seq = np.abs(got_seq - exact).mean()
    assert err_dpa <= err_seq * 1.001


def test_fp16_accumulate_mode():
    """Table I: FP16 accumulate output format."""
    rng = np.random.default_rng(5)
    a = rng.normal(size=(200, 2))
    out = dpa.dpa(a, a, np.zeros(200), "fp16", "fp16")
    assert np.isfinite(out).all() and (out >= 0).all()
