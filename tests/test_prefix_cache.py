"""Prefix-sharing copy-on-write paged KV cache conformance.

The load-bearing claim (the serving mirror of "paging is pure
relayout"): a prefix-hit request's greedy outputs are **bit-identical**
to the same request served cold — shared pages hold exactly the
codes/scales a cold prefill of the same tokens would have written, the
warm prefill materializes them back into staging unchanged, and
copy-on-write moves rows bit-for-bit.  Pinned across Table-I KV formats
(packed fp4 included) with divergence mid-page, so the packed-codes
relayout path is exercised where it could plausibly break.

Plus: radix-index unit behavior (match / insert / CoW tail / LRU
eviction) against a bare allocator, tick-by-tick allocator invariants
under the refcount protocol (shared pages never freed or re-handed-out
while referenced, CoW never mutates its source, no leak/double-free
across admit -> hit -> diverge -> evict), and eviction under pool
pressure.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import kvcache as KV
from repro.launch.engine import Engine, EngineConfig, Request
from repro.serving.prefix_cache import PrefixCache

PS = 8

# the Table-I KV formats the bit-identity claim is pinned across:
# packed fp4 (the engine default), fp8, fp16
POLICIES = ["kv4_attn8_packed", "attn_fp8_dpa", "attn_fp16_dpa"]


def _ecfg(**kw):
    base = dict(page_size=PS, n_pages=32, max_batch=3,
                max_pages_per_req=4, token_budget=8, prefill_chunk=8)
    base.update(kw)
    return EngineConfig(**base)


# -----------------------------------------------------------------------------
# radix index unit behavior (bare allocator, no engine)
# -----------------------------------------------------------------------------

def _cache(capacity=32):
    alloc = KV.PageAllocator(capacity)
    return PrefixCache(PS, alloc), alloc


def _toks(*blocks):
    """Concatenate per-page token blocks into one prompt array."""
    return np.concatenate([np.asarray(b, np.int32) for b in blocks])


def test_match_walks_full_pages_and_respects_limit():
    pc, alloc = _cache()
    prompt = _toks(range(0, 8), range(8, 16), range(16, 24))
    pages = alloc.alloc(3)
    assert pc.insert(prompt, pages) == 3
    assert all(alloc.refcount(p) == 2 for p in pages)   # owner + cache
    m = pc.match(prompt, limit=len(prompt))
    assert m.pages == pages and m.tokens == 24 and m.cow is None
    # the limit caps coverage: 23 tokens -> 2 full pages + a 7-row CoW
    m = pc.match(prompt, limit=23)
    assert m.pages == pages[:2] and m.cow == (pages[2], 7)
    assert m.tokens == 23
    # a foreign prompt misses entirely
    miss = pc.match(_toks(range(100, 124)), limit=24)
    assert miss.pages == [] and miss.cow is None and miss.tokens == 0


def test_match_finds_longest_cow_tail_among_siblings():
    """Divergence inside a block picks the sibling sharing the longest
    per-token common prefix as the CoW source."""
    pc, alloc = _cache()
    head = list(range(8))
    a = _toks(head, [1, 2, 3, 4, 5, 6, 7, 8])
    b = _toks(head, [1, 2, 9, 9, 9, 9, 9, 9])
    pa, pb = alloc.alloc(2)
    pc.insert(a, [pa, pa])          # page ids only matter per block
    pc.insert(b, [pa, pb])
    probe = _toks(head, [1, 2, 9, 9, 7, 7, 7, 7])   # 4 tokens with b's tail
    m = pc.match(probe, limit=16)
    assert m.pages == [pa]
    assert m.cow == (pb, 4) and m.tokens == 8 + 4


def test_insert_first_writer_wins_and_partial_tail_skipped():
    pc, alloc = _cache()
    prompt = _toks(range(8), range(8, 13))          # 13 tokens: 1 full page
    p = alloc.alloc(2)
    assert pc.insert(prompt, p) == 1                # tail block not indexed
    assert pc.n_pages == 1
    dup = alloc.alloc(2)
    assert pc.insert(prompt, dup) == 0              # existing node kept
    assert pc.match(prompt, limit=8).pages == [p[0]]
    assert alloc.refcount(dup[0]) == 1              # no cache ref taken


def test_lru_eviction_drops_coldest_leaf_and_pins_referenced():
    pc, alloc = _cache()
    cold = _toks(range(0, 8))
    warm = _toks(range(10, 18))
    pinned = _toks(range(20, 28))
    (p_cold,) = alloc.alloc(1)
    (p_warm,) = alloc.alloc(1)
    (p_pin,) = alloc.alloc(1)
    pc.insert(cold, [p_cold])
    pc.insert(warm, [p_warm])
    pc.insert(pinned, [p_pin])
    alloc.free([p_cold]); alloc.free([p_warm])      # owners exit
    # p_pin: owner stays -> refcount 2, not evictable
    pc.match(warm, limit=8)                         # touch warm
    assert pc.evict(1) == 1                         # drops cold, the LRU
    assert pc.match(cold, limit=8).tokens == 0
    assert pc.match(warm, limit=8).tokens == 8      # warm survived
    assert pc.evict(5) == 1                         # warm goes; pin stays
    assert pc.n_pages == 1
    assert alloc.refcount(p_pin) == 2
    # once the owner exits, the pin becomes evictable
    alloc.free([p_pin])
    assert pc.evict(1) == 1 and pc.n_pages == 0
    assert alloc.in_use == 0                        # everything drained


def test_eviction_drains_chains_deepest_first():
    pc, alloc = _cache()
    prompt = _toks(range(0, 8), range(8, 16), range(16, 24))
    pages = alloc.alloc(3)
    pc.insert(prompt, pages)
    alloc.free(pages)
    assert pc.evict(2) == 2
    # the surviving node is the root block (parents outlive children)
    m = pc.match(prompt, limit=24)
    assert m.pages == pages[:1]
    assert pc.drop_all() == 1
    assert alloc.in_use == 0


# -----------------------------------------------------------------------------
# engine integration: bit-identity warm vs cold, across KV formats
# -----------------------------------------------------------------------------

@pytest.fixture(scope="module")
def base():
    from repro.configs import get_config, reduce_config
    from repro.models import build_model
    cfg = reduce_config(get_config("qwen3-4b")).replace(policy=POLICIES[0])
    model = build_model(cfg)
    # params are policy-independent: one init serves every policy
    return cfg, model.init(jax.random.PRNGKey(0))


def _shared_prefix_requests(vocab, seed=7):
    """A (20 tokens), B (same first 12, diverges mid page 1 -> CoW),
    C (same first 16, diverges on the page boundary -> pure 2-page hit)."""
    rng = np.random.default_rng(seed)
    base_p = rng.integers(0, vocab, size=20).astype(np.int32)
    pb = base_p.copy(); pb[12:] = rng.integers(0, vocab, size=8)
    pc_ = base_p.copy(); pc_[16:] = rng.integers(0, vocab, size=4)
    return [Request(rid=0, prompt=base_p.copy(), max_new=5),
            Request(rid=1, prompt=pb, max_new=5),
            Request(rid=2, prompt=pc_, max_new=5)]


@pytest.mark.parametrize("policy", POLICIES)
def test_prefix_hit_outputs_bit_identical_to_cold(base, policy):
    """The pinned invariant: serve A then B (CoW mid-page) then C (full
    2-page hit) sequentially through one warm engine; every request's
    greedy tokens equal a cold engine's, bit for bit."""
    from repro.models import build_model
    cfg, params = base
    model = build_model(cfg.replace(policy=policy))
    warm = Engine(model, params, _ecfg(prefix_cache=True))
    cold = Engine(model, params, _ecfg())
    reqs = _shared_prefix_requests(cfg.vocab_size)
    for r in reqs:
        warm.run([r])                   # sequential: B and C hit A's pages
    for r in _shared_prefix_requests(cfg.vocab_size):
        cold.run([r])
    cold_out = {r.rid: list(r.out_tokens) for r in cold.finished}
    for r in warm.finished:
        assert list(r.out_tokens) == cold_out[r.rid], (r.rid, policy)
    # and the hits really happened: B saved 12 tokens (CoW), C saved 16
    assert warm.prefix_queries == 3 and warm.prefix_hits == 2
    assert warm.prefill_tokens_saved == 12 + 16
    assert warm.cow_copies == 1
    # all request pages freed; only the cache's residents remain
    assert warm.alloc.in_use == warm.prefix.n_pages > 0
    warm.prefix.drop_all()
    assert warm.alloc.in_use == 0


def test_prefix_report_keys_and_json(base):
    import json
    from repro.models import build_model
    cfg, params = base
    model = build_model(cfg)
    engine = Engine(model, params, _ecfg(prefix_cache=True))
    for r in _shared_prefix_requests(cfg.vocab_size):
        engine.run([r])
    rep = engine.report(1.0)
    assert rep["prefix_hit_rate"] == pytest.approx(2 / 3)
    assert rep["prefill_tokens_saved"] == 28
    assert rep["prefix_cow_copies"] == 1
    assert rep["resident_prefix_pages"] == engine.prefix.n_pages > 0
    assert rep["resident_prefix_bytes"] > 0
    json.loads(json.dumps(rep, allow_nan=False))
    from repro.launch.engine import format_report
    txt = format_report(rep, cfg.policy)
    assert "prefix:" in txt and "28 prefill tokens saved" in txt
    # reset clears counters but keeps the resident cache warm
    engine.reset_stats()
    assert engine.prefix_queries == 0 and engine.prefix.n_pages > 0
    assert "prefix_hit_rate" not in Engine(
        model, params, _ecfg()).report(1.0)     # off by default


# -----------------------------------------------------------------------------
# tick-by-tick allocator invariants under the refcount protocol
# -----------------------------------------------------------------------------

def _check_invariants(engine):
    alloc = engine.alloc
    live = [r for r in engine.slots if r is not None]
    assert alloc.reserved <= alloc.n_free
    assert alloc.in_use + alloc.n_free == alloc.capacity - 1
    # every page is held by exactly its holders: requests (uniquely per
    # request) + one cache ref per resident node
    holders = {}
    for r in live:
        assert len(set(r.pages)) == len(r.pages)
        for p in r.pages:
            holders[p] = holders.get(p, 0) + 1
    stack = list(engine.prefix.root.children.values())
    n_nodes = 0
    while stack:
        nd = stack.pop()
        stack.extend(nd.children.values())
        n_nodes += 1
        holders[nd.page] = holders.get(nd.page, 0) + 1
        # a cached page is never on the free list while referenced
        assert alloc.refcount(nd.page) >= 1
    assert n_nodes == engine.prefix.n_nodes
    for p, n in holders.items():
        assert alloc.refcount(p) == n, p


def test_tick_by_tick_invariants_across_hit_diverge_evict(base):
    """Drive admit -> hit -> mid-page divergence -> finish -> evict one
    scheduler tick at a time, checking after every tick that refcounts
    equal the true holder sets, reserved <= n_free, and shared pages
    never leak or double-free.  CoW source bytes are snapshotted before
    the diverging request runs and must be untouched after."""
    from repro.models import build_model
    cfg, params = base
    model = build_model(cfg)
    engine = Engine(model, params, _ecfg(prefix_cache=True))
    reqs = _shared_prefix_requests(cfg.vocab_size)

    def run_one(req):
        engine.submit(req)
        now = 0.0
        while engine.waiting or any(engine.slots):
            engine.step(now)
            _check_invariants(engine)
            now += 1.0

    run_one(reqs[0])
    shared_pages = [nd.page for nd in
                    _walk(engine.prefix.root)]
    snap = {k: np.asarray(engine.caches["groups"]["p0"][k][:, shared_pages])
            for k in KV.QUANT_KEYS}
    run_one(reqs[1])                             # CoW divergence mid-page
    for k in KV.QUANT_KEYS:
        now_ = np.asarray(engine.caches["groups"]["p0"][k][:, shared_pages])
        assert np.array_equal(now_, snap[k]), k  # source never mutated
    run_one(reqs[2])                             # pure full-page hit
    assert engine.cow_copies == 1
    assert engine.alloc.in_use == engine.prefix.n_pages
    engine.prefix.drop_all()
    assert engine.prefix.n_nodes == 0
    assert engine.alloc.in_use == 0 and engine.alloc.reserved == 0


def _walk(root):
    out, stack = [], list(root.children.values())
    while stack:
        nd = stack.pop()
        stack.extend(nd.children.values())
        out.append(nd)
    return out


def test_spec_mode_composes_with_prefix_cache(base):
    """Speculative decoding + prefix sharing: rollback never reclaims a
    shared page (the allocator would raise), outputs still match the
    plain warm engine, and everything drains."""
    from repro.launch.engine import SpecConfig
    from repro.models import build_model
    cfg, params = base
    model = build_model(cfg)
    plain = Engine(model, params, _ecfg(prefix_cache=True))
    spec = Engine(model, params,
                  _ecfg(prefix_cache=True, token_budget=16),
                  spec=SpecConfig(POLICIES[0], k=3))
    for r in _shared_prefix_requests(cfg.vocab_size):
        plain.run([r])
    for r in _shared_prefix_requests(cfg.vocab_size):
        spec.run([r])
    plain_out = {r.rid: list(r.out_tokens) for r in plain.finished}
    for r in spec.finished:
        assert list(r.out_tokens) == plain_out[r.rid], r.rid
    assert spec.prefix_hits == 2
    assert spec.alloc.reserved == 0
    spec.prefix.drop_all()
    assert spec.alloc.in_use == 0


def test_eviction_under_pool_pressure(base):
    """A pool too small to hold residents + a new request evicts cold
    prefixes instead of stalling; referenced pages survive."""
    from repro.models import build_model
    cfg, params = base
    model = build_model(cfg)
    # 9 usable pages; one 20-token resident (3 pages) + a 20-token
    # request needing ceil(25/8)=4 fresh pages on a miss
    engine = Engine(model, params, _ecfg(prefix_cache=True, n_pages=10))
    rng = np.random.default_rng(11)
    V = cfg.vocab_size
    a = Request(rid=0, prompt=rng.integers(0, V, 20).astype(np.int32),
                max_new=5)
    engine.run([a])
    assert engine.prefix.n_pages == 2            # 20 tokens: 2 full blocks
    # two unrelated requests need 4 pages each = 8 > 9 - 2 residents:
    # admission must evict at least one cold resident to fit both at once
    b = Request(rid=1, prompt=rng.integers(0, V, 20).astype(np.int32),
                max_new=5)
    c = Request(rid=2, prompt=rng.integers(0, V, 20).astype(np.int32),
                max_new=5)
    engine.run([b, c])
    assert len(engine.finished) == 3             # nothing stalled
    assert engine.prefix.n_pages < 2 + 2 + 2     # eviction really ran
    engine.prefix.drop_all()
    assert engine.alloc.in_use == 0
