"""Checkpoint atomicity/async + supervisor failure & straggler recovery."""
import os
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.runtime import checkpoint as ck
from repro.runtime.fault import Supervisor, SupervisorConfig


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (32, 16)),
                       "b": jnp.zeros((16,))},
            "opt": {"m": {"w": jnp.ones((32, 16)), "b": jnp.zeros((16,))},
                    "count": jnp.int32(5)}}


def test_save_restore_roundtrip(tmp_path):
    s = _state()
    ck.save(s, 42, str(tmp_path))
    assert ck.latest_step(str(tmp_path)) == 42
    r, step = ck.restore(str(tmp_path), s)
    assert step == 42
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(r)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_latest_pointer_advances_and_survives_partial(tmp_path):
    s = _state()
    ck.save(s, 1, str(tmp_path))
    ck.save(s, 2, str(tmp_path))
    assert ck.latest_step(str(tmp_path)) == 2
    # a crash mid-save leaves a .tmp dir that must be ignored
    os.makedirs(tmp_path / "step_00000003.tmp")
    assert ck.latest_step(str(tmp_path)) == 2
    r, step = ck.restore(str(tmp_path), s)
    assert step == 2


def test_async_saver(tmp_path):
    s = _state()
    saver = ck.AsyncSaver()
    saver.save(s, 10, str(tmp_path))
    saver.join()
    assert ck.latest_step(str(tmp_path)) == 10


def test_restore_shape_mismatch_raises(tmp_path):
    s = _state()
    ck.save(s, 0, str(tmp_path))
    bad = jax.tree.map(lambda x: jnp.zeros((3,) + x.shape, x.dtype), s)
    with pytest.raises(ValueError):
        ck.restore(str(tmp_path), bad)


def _counting_step(state, batch):
    return {**state, "n": state["n"] + 1}, {"loss": jnp.float32(0.0)}


def test_supervisor_failure_recovery_replays_exactly(tmp_path):
    state = {"n": jnp.int32(0)}
    sup = Supervisor(SupervisorConfig(ckpt_dir=str(tmp_path), ckpt_every=5,
                                      async_save=False), state=state)
    sup.inject_failure_at = 12
    seen = []
    out = sup.run(_counting_step, lambda s: {"step": s}, 20,
                  on_metrics=lambda s, m, dt: seen.append(s))
    # failure hits before step 12 runs -> restore step-9 ckpt -> replay 10..
    assert int(out["n"]) == 20
    assert sup.events[0][0] == "failure" and sup.events[1] == ("restored", 9)
    assert seen.count(10) == 2 and seen.count(11) == 2   # replayed
    assert seen.count(12) == 1 and seen.count(9) == 1    # pre-ckpt not


def test_supervisor_straggler_watchdog(tmp_path):
    calls = {"n": 0}

    def slow_step(state, batch):
        calls["n"] += 1
        if calls["n"] == 3:
            time.sleep(1.0)        # straggle once
        return state, {"loss": jnp.float32(0)}

    sup = Supervisor(SupervisorConfig(ckpt_dir=str(tmp_path), ckpt_every=2,
                                      step_deadline_s=0.5,
                                      async_save=False),
                     state={"n": jnp.int32(0)})
    sup.run(slow_step, lambda s: {}, 5)
    kinds = [e[0] for e in sup.events]
    assert "failure" in kinds                     # straggler detected
    assert sup.failures == 1


def test_supervisor_gives_up_after_max_failures(tmp_path):
    def bad_step(state, batch):
        raise RuntimeError("always broken")

    sup = Supervisor(SupervisorConfig(ckpt_dir=str(tmp_path),
                                      max_failures=3, async_save=False),
                     state={})
    with pytest.raises(RuntimeError):
        sup.run(bad_step, lambda s: {}, 5)
    assert sup.failures == 4


def test_data_pipeline_determinism_and_sharding():
    from repro.data.pipeline import DataConfig, make_pipeline
    cfg = DataConfig(vocab_size=128, batch=8, seq=16, seed=3)
    p1 = make_pipeline(cfg)
    p2 = make_pipeline(cfg)
    b1, b2 = p1.batch(7), p2.batch(7)
    assert np.array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    assert not np.array_equal(np.asarray(p1.batch(8)["tokens"]),
                              np.asarray(b1["tokens"]))
    # labels are next-token shifted
    s0 = make_pipeline(DataConfig(vocab_size=128, batch=2, seq=16, seed=0))
    b = s0.batch(0)
    assert b["tokens"].shape == b["labels"].shape == (2, 16)
    # shards see different data
    sa = make_pipeline(DataConfig(vocab_size=128, batch=8, seq=16,
                                  n_shards=2, shard=0))
    sb = make_pipeline(DataConfig(vocab_size=128, batch=8, seq=16,
                                  n_shards=2, shard=1))
    assert sa.batch(0)["tokens"].shape == (4, 16)
    assert not np.array_equal(np.asarray(sa.batch(0)["tokens"]),
                              np.asarray(sb.batch(0)["tokens"]))


def test_memmap_pipeline(tmp_path):
    from repro.data.pipeline import DataConfig, make_pipeline
    data = np.arange(10000, dtype=np.uint16) % 512
    f = tmp_path / "tokens.bin"
    data.tofile(str(f))
    cfg = DataConfig(vocab_size=512, batch=4, seq=32, kind="memmap",
                     path=str(f))
    p = make_pipeline(cfg)
    b = p.batch(0)
    assert b["tokens"].shape == (4, 32)
    assert np.array_equal(np.asarray(b["tokens"][:, 1:]),
                          np.asarray(b["labels"][:, :-1]))
