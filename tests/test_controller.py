"""Adaptive draft controller in isolation (`repro.runtime.controller`).

The controller is pure — (state, observation) -> (state, rung), no wall
clock, no RNG — so every property here is a plain function property:
hysteresis (no flapping inside the dead band), monotone demote/promote
on clean high/low-acceptance traces, dwell enforcement, and bit-exact
replay determinism.  The engine-side integration (per-rung batching,
reservations, output invariance) lives in tests/test_adaptive_engine.py.
"""
import pytest

from repro.core.policy import POLICIES
from repro.runtime import controller as C

LADDER = ("w4a4_kv4_attn4", "w4a8_kv4_attn8", "w16a16_kv4_attn16")


def _cfg(**kw):
    kw.setdefault("ladder", LADDER)
    return C.ControllerConfig(**kw)


# -- config validation ----------------------------------------------------

def test_config_rejects_unknown_rung():
    with pytest.raises(ValueError, match="not a policy preset"):
        _cfg(ladder=("w4a4_kv4_attn4", "no_such_policy"))


def test_config_rejects_empty_ladder():
    with pytest.raises(ValueError, match="at least one rung"):
        _cfg(ladder=())


def test_config_rejects_bad_thresholds():
    with pytest.raises(ValueError, match="promote_below < demote_above"):
        _cfg(demote_above=0.4, promote_below=0.6)
    with pytest.raises(ValueError, match="promote_below < demote_above"):
        _cfg(demote_above=0.5, promote_below=0.5)   # no dead band


def test_config_rejects_bad_ks():
    with pytest.raises(ValueError, match="entries for a"):
        _cfg(ks=(2, 3))                              # 2 ks, 3 rungs
    with pytest.raises(ValueError, match=">= 1"):
        _cfg(ks=(2, 0, 3))


def test_config_rejects_bad_dwell_alpha_start():
    with pytest.raises(ValueError, match="dwell"):
        _cfg(dwell=0)
    with pytest.raises(ValueError, match="ema_alpha"):
        _cfg(ema_alpha=0.0)
    with pytest.raises(ValueError, match="start rung"):
        _cfg(start=3)


def test_rung_ks_and_max_k():
    assert _cfg(k=5).rung_ks == (5, 5, 5)
    cfg = _cfg(ks=(4, 2, 1))
    assert cfg.rung_ks == (4, 2, 1)
    assert cfg.max_k == 4
    assert _cfg().start_rung == len(LADDER) - 1      # -1 = most precise
    assert _cfg(start=0).start_rung == 0


# -- monotone demote / promote --------------------------------------------

def test_demotes_to_cheapest_on_high_acceptance():
    cfg = _cfg(dwell=1)
    rungs = C.replay(cfg, [(4, 4)] * 6)              # perfect acceptance
    assert rungs[-1] == 0                            # reached the bottom
    assert rungs == sorted(rungs, reverse=True)      # monotone downward


def test_promotes_to_most_precise_on_low_acceptance():
    cfg = _cfg(dwell=1, start=0)
    rungs = C.replay(cfg, [(0, 4)] * 6)              # nothing accepted
    assert rungs[-1] == len(LADDER) - 1
    assert rungs == sorted(rungs)                    # monotone upward


def test_clamped_at_ladder_ends():
    cfg = _cfg(dwell=1, start=0)
    assert C.replay(cfg, [(4, 4)] * 10)[-1] == 0     # can't demote past 0
    cfg = _cfg(dwell=1)
    assert C.replay(cfg, [(0, 4)] * 10)[-1] == len(LADDER) - 1


# -- dwell ----------------------------------------------------------------

def test_dwell_blocks_early_switch():
    cfg = _cfg(dwell=3)
    rungs = C.replay(cfg, [(4, 4)] * 3)
    # rounds 1 and 2 sit inside the dwell; only round 3 may switch
    assert rungs[:2] == [cfg.start_rung] * 2
    assert rungs[2] == cfg.start_rung - 1


def test_dwell_clock_resets_on_switch():
    cfg = _cfg(dwell=2)
    rungs = C.replay(cfg, [(4, 4)] * 6)
    # a switch every `dwell` rounds, never faster
    switches = [i for i in range(1, len(rungs)) if rungs[i] != rungs[i - 1]]
    assert all(b - a >= cfg.dwell for a, b in zip(switches, switches[1:]))


# -- hysteresis: no flapping ----------------------------------------------

def test_dead_band_never_flaps():
    """An EMA wandering strictly inside (promote_below, demote_above)
    must never move the rung, however long the trace."""
    cfg = _cfg(demote_above=0.75, promote_below=0.45, dwell=1, start=1)
    # alternating 50% / 70% rates: every EMA value stays in (0.45, 0.75)
    trace = [(2, 4), (3, 4)] * 20
    rungs = C.replay(cfg, trace)
    assert set(rungs) == {1}
    state = C.init_state(cfg)
    for obs in trace:
        state, _ = C.step(cfg, state, *obs)
    assert state.switches == 0


def test_noisy_trace_bounded_switches():
    """A trace oscillating across both thresholds switches at most once
    per dwell window — hysteresis + dwell bound the flap rate even under
    adversarial noise."""
    cfg = _cfg(dwell=2)
    trace = [(4, 4), (0, 4)] * 12
    rungs = C.replay(cfg, trace)
    flips = sum(1 for a, b in zip(rungs, rungs[1:]) if a != b)
    assert flips <= len(trace) // cfg.dwell


# -- purity / replay determinism ------------------------------------------

def test_replay_is_deterministic():
    cfg = _cfg(dwell=2, ema_alpha=0.3)
    trace = [(i % 5, 4) for i in range(40)]
    assert C.replay(cfg, trace) == C.replay(cfg, trace)


def test_step_is_pure():
    """Stepping the same (cfg, state, obs) twice yields equal values —
    and never mutates the input state (frozen dataclass)."""
    cfg = _cfg()
    s0 = C.init_state(cfg)
    a = C.step(cfg, s0, 3, 4)
    b = C.step(cfg, s0, 3, 4)
    assert a == b
    assert s0 == C.init_state(cfg)
    with pytest.raises(Exception):
        s0.rung = 0


def test_step_rejects_empty_round():
    cfg = _cfg()
    with pytest.raises(ValueError, match="at least one"):
        C.step(cfg, C.init_state(cfg), 0, 0)


def test_ema_seeds_then_folds():
    cfg = _cfg(ema_alpha=0.5, dwell=10)              # dwell blocks switches
    s, _ = C.step(cfg, C.init_state(cfg), 4, 4)
    assert s.ema == 1.0                              # first round seeds
    s, _ = C.step(cfg, s, 0, 4)
    assert s.ema == pytest.approx(0.5)               # 0.5*0 + 0.5*1


# -- default ladders ------------------------------------------------------

def test_default_ladder_matches_cache_layout():
    for name, pol in POLICIES.items():
        if not pol.kv_quantized:
            continue
        ladder = C.default_ladder(name)
        assert len(ladder) >= 2                      # a real ladder
        for rung in ladder:
            rp = POLICIES[rung]
            assert (rp.fmt_kv, rp.kv_packed) == (pol.fmt_kv, pol.kv_packed)


def test_default_ladder_rejects_raw_f32_cache():
    with pytest.raises(ValueError, match="raw f32 cache"):
        C.default_ladder("fp32")
