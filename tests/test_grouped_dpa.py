"""Grouped (per-expert) DPA pipelines: kernel-vs-reference pins, exact
big-int oracle conformance, the grouped fake-quant regression, and the
engine's MoE serving bit-identity claim.

Layers covered, bottom-up:

  1. `dpa_grouped_matmul_prequant` vs `core.oracle.dpa_exact` — per
     output element, the kernel's f32-accumulated per-expert dot must
     equal the exact single-rounded sum whenever that sum is exactly
     representable in f32 (operands drawn with bounded exponent spread
     so f32 accumulation is exact), across the Table-I operand ladder
     and with nibble-packed fp4 expert stacks.
  2. The policy-driven pipelines vs the `xla_fake_quant` reference at
     the registered route tolerance, both grouped einsums.
  3. Per-expert slices of the grouped prequant pipeline vs the dense
     prequant pipeline — same quantization axes, bit-identical.
  4. `_gmm_fake_quant` regression: no pre-cast of f32 expert weights
     through the activation dtype (the double-rounding bug), and the
     per-channel granularity axes match the dense reference's.
  5. Engine MoE serving: greedy outputs bit-identical to the static
     `serve.generate` path with `prefill_chunk=1` (MoE expert capacity
     is chunk-local: C = f(chunk tokens), so only single-token prefill
     reproduces the static path's token-by-token routing exactly).
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import exec_plan, formats as F, oracle
from repro.core.packing import pack_fp4_axis
from repro.core.policy import get_policy
from repro.core.quantize import jnp_dtype
from repro.kernels import dpa_grouped_matmul as gm
from repro.kernels import ops as O

EQS = ("gti,gio->gto", "becd,edf->becf")


def _operands(eq, key=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(key))
    if eq == "gti,gio->gto":
        x = jax.random.normal(k1, (3, 24, 48), jnp.float32)
        w = jax.random.normal(k2, (3, 48, 40), jnp.float32) * 0.5
    else:
        x = jax.random.normal(k1, (2, 3, 4, 48), jnp.float32)
        w = jax.random.normal(k2, (3, 48, 40), jnp.float32) * 0.5
    return x, w


def _relerr(got, want):
    got, want = np.asarray(got, np.float64), np.asarray(want, np.float64)
    return np.linalg.norm(got - want) / max(np.linalg.norm(want), 1e-30)


# -----------------------------------------------------------------------------
# 1. exact big-int oracle conformance (test_dpa_property.py style)
# -----------------------------------------------------------------------------

# (fmt, K) with an exponent-field window narrow enough that every exact
# per-expert dot is representable in f32 — then f32 accumulation commits
# no rounding and the kernel must match `dpa_exact` bit-for-bit.
#   fp16: p=11 -> 22-bit products; one exponent value keeps K=4 sums
#         under 2^24.  fp8 e4m3: p=4 -> 8-bit products; a 4-wide raw-
#         exponent window spans <= 14 bits + 3 sum bits.  fp4 e2m1:
#         p=2 and the full grid spans ~13 bits — no restriction needed.
ORACLE_MODES = [("fp16", 4, (15, 15)), ("fp8_e4m3", 8, (6, 9)),
                ("fp4_e2m1", 8, None)]


def _windowed_codes(rng, fmt, shape, ewin):
    """Random sign/mantissa codes with the raw exponent field confined
    to `ewin` (inclusive); None = any non-special exponent."""
    f = F.get_format(fmt)
    lo, hi = ewin if ewin is not None else (0, f.exp_mask - 1)
    if fmt == "fp4_e2m1":                  # special == "none": full grid
        lo, hi = 0, f.exp_mask
    e = rng.integers(lo, hi + 1, size=shape)
    man = rng.integers(0, f.man_mask + 1, size=shape)
    sign = rng.integers(0, 2, size=shape) << (f.bits - 1)
    return (sign | (e << f.man_bits) | man).astype(np.uint32)


def _codes_to_operand(codes, fmt):
    """Codes -> the operand array the kernel ingests (uint8 codes for
    fp4; the native narrow jnp dtype otherwise — exact, values on grid)."""
    if fmt == "fp4_e2m1":
        return jnp.asarray(codes.astype(np.uint8))
    vals = F.codes_to_np(codes, F.get_format(fmt)).astype(np.float32)
    return jnp.asarray(vals).astype(jnp_dtype(fmt))


@pytest.mark.parametrize("fmt,K,ewin", ORACLE_MODES,
                         ids=[m[0] for m in ORACLE_MODES])
def test_grouped_kernel_bitexact_vs_oracle(fmt, K, ewin):
    E, M, N = 2, 8, 8
    rng = np.random.default_rng(17)
    xc = _windowed_codes(rng, fmt, (E, M, K), ewin)
    wc = _windowed_codes(rng, fmt, (E, K, N), ewin)
    out = gm.dpa_grouped_matmul_prequant(
        _codes_to_operand(xc, fmt), _codes_to_operand(wc, fmt),
        jnp.ones((E, M, 1), jnp.float32), jnp.ones((E, 1, N), jnp.float32),
        fmt_x=fmt, fmt_w=fmt, bm=M, bk=K, bn=N,
        pack_x=False, pack_w=False, interpret=True)
    a = np.broadcast_to(xc[:, :, None, :], (E, M, N, K)).reshape(-1, K)
    b = np.broadcast_to(wc.transpose(0, 2, 1)[:, None, :, :],
                        (E, M, N, K)).reshape(-1, K)
    fa = F.get_format(fmt)
    want = F.codes_to_np(
        oracle.dpa_exact(a, b, np.zeros(E * M * N, np.uint32), fa, F.FP32),
        F.FP32).astype(np.float64)
    got = np.asarray(out).reshape(-1).astype(np.float64)
    assert np.array_equal(got, want), (
        f"{(got != want).sum()}/{got.size} lanes off the exact sum")


def test_grouped_kernel_packed_fp4_bitexact_vs_oracle():
    """Nibble-packed fp4 expert stacks (the 8x residency claim) decode
    to the same codes: bit-equal to the oracle AND to the unpacked run."""
    fmt, E, M, K, N = "fp4_e2m1", 2, 8, 8, 8
    rng = np.random.default_rng(23)
    xc = _windowed_codes(rng, fmt, (E, M, K), None)
    wc = _windowed_codes(rng, fmt, (E, K, N), None)
    sx = jnp.ones((E, M, 1), jnp.float32)
    sw = jnp.ones((E, 1, N), jnp.float32)
    kw = dict(fmt_x=fmt, fmt_w=fmt, bm=M, bk=K, bn=N, interpret=True)
    plain = gm.dpa_grouped_matmul_prequant(
        _codes_to_operand(xc, fmt), _codes_to_operand(wc, fmt), sx, sw,
        pack_x=False, pack_w=False, **kw)
    packed = gm.dpa_grouped_matmul_prequant(
        pack_fp4_axis(jnp.asarray(xc.astype(np.uint8)), 2),
        pack_fp4_axis(jnp.asarray(wc.astype(np.uint8)), 1), sx, sw,
        pack_x=True, pack_w=True, **kw)
    assert np.array_equal(np.asarray(plain), np.asarray(packed))
    a = np.broadcast_to(xc[:, :, None, :], (E, M, N, K)).reshape(-1, K)
    b = np.broadcast_to(wc.transpose(0, 2, 1)[:, None, :, :],
                        (E, M, N, K)).reshape(-1, K)
    fa = F.get_format(fmt)
    want = F.codes_to_np(
        oracle.dpa_exact(a, b, np.zeros(E * M * N, np.uint32), fa, F.FP32),
        F.FP32).astype(np.float64)
    assert np.array_equal(
        np.asarray(packed).reshape(-1).astype(np.float64), want)


# -----------------------------------------------------------------------------
# 2. policy pipelines vs the xla_fake_quant reference
# -----------------------------------------------------------------------------

PIPE_PRESETS = ["fp8_dpa_fused", "fp4_dpa_packed", "fp4_dpa_fused",
                "w4a8_packed", "w8a8_kv8_attn8", "w4a8_kv4_attn8"]


@pytest.mark.parametrize("eq", EQS, ids=["gti", "becd"])
@pytest.mark.parametrize("preset", PIPE_PRESETS)
def test_grouped_pipeline_vs_fake_quant(eq, preset):
    """Both Pallas grouped pipelines within the registered route tol of
    the per-expert STE fake-quant reference, both supported einsums."""
    pol = get_policy(preset)
    x, w = _operands(eq)
    ref = exec_plan.route("grouped_matmul", "xla_fake_quant")
    want = ref.run(x, w, pol, eq=eq)
    for name, fn in (("pallas_grouped_fused", O.dpa_grouped_fused_pipeline),
                     ("pallas_grouped_prequant",
                      O.dpa_grouped_prequant_pipeline)):
        got = fn(x, w, pol, eq=eq, bm=8, bk=16, bn=16)
        tol = exec_plan.route("grouped_matmul", name).tol
        assert got.shape == want.shape
        assert _relerr(got, want) <= tol, (name, _relerr(got, want))


def test_grouped_prequant_matches_dense_per_expert():
    """The grouped prequant pipeline quantizes per-(expert row / expert
    output column) — exactly the dense pipeline's axes — so each expert
    slice is bit-identical to running the dense pipeline on it."""
    for preset in ("fp8_dpa_fused", "fp4_dpa_packed"):
        pol = get_policy(preset)
        x, w = _operands("gti,gio->gto", key=5)
        got = O.dpa_grouped_prequant_pipeline(x, w, pol, eq="gti,gio->gto",
                                              bm=8, bk=16, bn=16)
        for e in range(x.shape[0]):
            want = O.dpa_matmul_prequant_pipeline(x[e], w[e], pol,
                                                  bm=8, bk=16, bn=16)
            assert np.array_equal(np.asarray(got[e]), np.asarray(want)), \
                (preset, e)


def test_grouped_kernel_capacity_dropped_rows():
    """Capacity-dropped tokens are zero rows in the dispatch buffer: the
    fused kernel's per-(row, K-block) quantization makes every row
    independent, so zero rows yield exactly-zero outputs and live rows
    are bit-identical with or without dropped neighbors."""
    pol = get_policy("w4a8_kv4_attn8")
    x, w = _operands("gti,gio->gto", key=9)
    full = O.dpa_grouped_fused_pipeline(x, w, pol, eq="gti,gio->gto",
                                        bm=8, bk=16, bn=16)
    drop = np.zeros(x.shape[:2], bool)
    drop[0, 3:8] = drop[2, :4] = True
    xd = jnp.where(jnp.asarray(drop)[:, :, None], 0.0, x)
    got = O.dpa_grouped_fused_pipeline(xd, w, pol, eq="gti,gio->gto",
                                       bm=8, bk=16, bn=16)
    gotn, fulln = np.asarray(got), np.asarray(full)
    assert np.all(gotn[drop] == 0.0)
    assert np.array_equal(gotn[~drop], fulln[~drop])


# -----------------------------------------------------------------------------
# 3. the grouped fake-quant reference vs the dense one (regression)
# -----------------------------------------------------------------------------

def test_gmm_fake_quant_matches_dense_reference():
    """Regression for the grouped fake-quant reference: (a) f32 expert
    weights quantize on their own grid -- no pre-cast through the
    activation dtype (the double-rounding bug); (b) granularity axes
    match the dense `_mm_fake_quant` (weights per output column,
    activations per row), and the per-expert results agree with the
    dense route."""
    from repro.core.quantize import fake_quant
    gmm = exec_plan.route("grouped_matmul", "xla_fake_quant")
    mm = exec_plan.route("matmul", "xla_fake_quant")
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    w = jax.random.normal(k2, (3, 32, 24), jnp.float32) * 0.5
    pol = get_policy("fp8_dpa")

    def want(x, wts, p):
        # the dense reference's semantics, stacked: quantize w on its
        # own (f32) grid with the dense granularity axes, same einsum
        wq = fake_quant(wts, p.fmt_weights,
                        axis=1 if p.w_granularity == "per_channel"
                        else None)
        xq = fake_quant(x, p.fmt_acts,
                        axis=-1 if p.a_granularity == "per_channel"
                        else None)
        return jnp.einsum("gti,gio->gto", xq, wq,
                          preferred_element_type=jnp.float32).astype(x.dtype)

    # (a) bf16 activations, f32 weights: bit-identical to the intended
    # semantics, NOT to the pre-cast variant (quantizing bf16-rounded
    # weights shifts the per-channel scales)
    xb = jax.random.normal(k1, (3, 16, 32), jnp.float32).astype(jnp.bfloat16)
    got = gmm.run(xb, w, pol, eq="gti,gio->gto")
    assert np.array_equal(np.asarray(got, np.float32),
                          np.asarray(want(xb, w, pol), np.float32))
    buggy = want(xb, w.astype(xb.dtype).astype(jnp.float32), pol)
    assert not np.array_equal(np.asarray(got, np.float32),
                              np.asarray(buggy, np.float32))
    # (b) per-channel granularity on BOTH operands: every scale attaches
    # to an expert row/column, so each expert slice agrees with the
    # dense route run on it (batched einsum and per-slice dot may
    # associate f32 sums differently -> tight allclose, not bitwise)
    x = jax.random.normal(k1, (3, 16, 32), jnp.float32)
    polc = pol.replace(w_granularity="per_channel",
                       a_granularity="per_channel")
    g = np.asarray(gmm.run(x, w, polc, eq="gti,gio->gto"), np.float64)
    for e in range(3):
        d = np.asarray(mm.run(x[e], w[e], polc), np.float64)
        np.testing.assert_allclose(g[e], d, rtol=1e-5, atol=1e-5)
    # per-tensor granularity scales over the WHOLE stack (one absmax
    # across experts, like the dense route's one absmax per operand) —
    # pinned against the stacked semantics, not per-expert slices
    per_t = pol.replace(w_granularity="per_tensor",
                        a_granularity="per_tensor")
    assert np.array_equal(
        np.asarray(gmm.run(x, w, per_t, eq="gti,gio->gto")),
        np.asarray(want(x, w, per_t)))
    # and the granularity axes are live: per-channel != per-tensor
    assert not np.array_equal(
        np.asarray(gmm.run(x, w, pol, eq="gti,gio->gto")),
        np.asarray(gmm.run(x, w, per_t, eq="gti,gio->gto")))


# -----------------------------------------------------------------------------
# 4. engine MoE serving: bit-identity with the static path
# -----------------------------------------------------------------------------

MOE_POLICY = "w4a8_kv4_attn8"


@pytest.fixture(scope="module")
def moe_served():
    from repro.configs import get_config, reduce_config
    from repro.launch.engine import Engine, EngineConfig, Request
    from repro.models import build_model
    cfg = reduce_config(get_config("granite-moe-1b-a400m")).replace(
        policy=MOE_POLICY)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # prefill_chunk=1 is load-bearing: MoE expert capacity C = f(chunk
    # tokens) and token routing competes within a chunk, so only single-
    # token prefill reproduces serve.generate's token-by-token dispatch
    ecfg = EngineConfig(page_size=8, n_pages=32, max_batch=3,
                        max_pages_per_req=4, token_budget=8,
                        prefill_chunk=1)
    engine = Engine(model, params, ecfg)
    rng = np.random.default_rng(7)
    lens = [(6, 4), (9, 3), (5, 4)]
    reqs = [Request(rid=i, prompt=rng.integers(
                0, cfg.vocab_size, size=s0).astype(np.int32), max_new=g)
            for i, (s0, g) in enumerate(lens)]
    report = engine.run([dataclasses.replace(r) for r in reqs])
    return model, params, ecfg, reqs, engine, report


def test_engine_moe_bit_identical_to_static(moe_served):
    from repro.launch.serve import generate
    model, params, ecfg, reqs, engine, _ = moe_served
    for req in reqs:
        out = generate(model, params, jnp.asarray(req.prompt[None]),
                       req.max_new, ecfg.s_max)
        want = np.asarray(out)[0, req.n_prompt:]
        got = [r for r in engine.finished if r.rid == req.rid][0]
        assert np.array_equal(np.asarray(got.out_tokens), want), req.rid


def test_engine_moe_report_states_grouped_plan(moe_served):
    *_, report = moe_served
    assert report["moe_experts"] == 8 and report["moe_top_k"] == 2
    assert report["moe_grouped_route"] == "pallas_grouped_fused"
    assert report["moe_grouped_backend"] == "pallas"
    # packed fp4 expert weights: exactly 8x under f32 residency
    assert report["expert_w_reduction_vs_f32"] == pytest.approx(8.0)
    assert report["moe_grouped_bytes_per_step_layer"] > 0
