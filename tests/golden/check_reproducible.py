"""Golden-vector reproducibility check.

Regenerates the DPA golden vectors with the current JAX/ml_dtypes stack
and asserts bit-identity against the checked-in
`tests/golden/dpa_vectors.npz`.  A drift here means the golden *model*
(or a dependency's numerics) changed — exactly what the replay suite is
designed to catch before it silently re-baselines.

Called from two places (the single source of truth for the check):
  - CI's `golden` job:  PYTHONPATH=src python tests/golden/check_reproducible.py
  - the tier-1 suite:   tests/test_dpa_golden.py::test_golden_vectors_reproduce
"""
from __future__ import annotations

import os
import sys
import tempfile

import numpy as np

HERE = os.path.dirname(os.path.abspath(__file__))


def check() -> int:
    """Regenerate into a temp file, compare, return the array count."""
    sys.path.insert(0, HERE)
    import generate_dpa_vectors as g
    tmp = os.path.join(tempfile.mkdtemp(), "fresh.npz")
    g.main(tmp)
    a = np.load(os.path.join(HERE, "dpa_vectors.npz"))
    b = np.load(tmp)
    assert set(a.files) == set(b.files), "golden array set drifted"
    for name in a.files:
        assert np.array_equal(a[name], b[name]), f"{name} drifted"
    return len(a.files)


if __name__ == "__main__":
    n = check()
    print(f"{n} golden arrays reproduce bit-for-bit")
