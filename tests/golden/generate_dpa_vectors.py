"""Generate tests/golden/dpa_vectors.npz — pinned DPA conformance vectors.

Seeded random operand codes for every (fmt_ab, fmt_acc, N) mode of Table I
(finite lanes plus a specials-included batch for modes whose format has
specials), with outputs computed by BOTH the golden model
(`repro.core.dpa.dpa_codes`) and the exact big-int oracle
(`repro.core.oracle`).  The generator refuses to write vectors where the
two disagree outside the documented window bound, so the checked-in file
is known-conformant at generation time; `test_dpa_golden.py` then replays
it bit-for-bit, pinning the datapath against JAX / ml_dtypes version
drift.

Run from the repo root to regenerate (only needed when the DPA contract
itself changes — a diff in this file's output is a *numerics break*):

    PYTHONPATH=src python tests/golden/generate_dpa_vectors.py
"""
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..",
                                "src"))

from repro.core import dpa, formats as F, oracle  # noqa: E402

MODES = [("fp16", "fp32", 2), ("fp8_e4m3", "fp32", 4),
         ("fp4_e2m1", "fp32", 8), ("fp32", "fp32", 1),
         ("fp16", "fp16", 2), ("fp8_e4m3", "fp16", 4)]
LANES = 256
SEED = 20260801


def _finite_codes(rng, fmt, shape):
    c = rng.integers(0, 1 << fmt.bits, size=shape).astype(np.uint32)
    if fmt.special != "none":
        vals = F.codes_to_np(c, fmt).astype(np.float64)
        c = np.where(~np.isfinite(vals), c & (fmt.man_mask >> 1), c)
    return c


def _check_against_oracle(a, b, c, out, fa, fc, n, tag):
    want = oracle.dpa_exact(a, b, c, fa, fc)
    gf = F.codes_to_np(out, fc).astype(np.float64)
    wf = F.codes_to_np(want, fc).astype(np.float64)
    mism = (out != want) & ~(np.isnan(gf) & np.isnan(wf))
    if mism.any():
        W = dpa.default_window_bits(fc, n)
        av = F.codes_to_np(a, fa).astype(np.float64)
        bv = F.codes_to_np(b, fa).astype(np.float64)
        cv = F.codes_to_np(c, fc).astype(np.float64)
        mags = np.concatenate([np.abs(av * bv), np.abs(cv)[:, None]], 1)
        anchor = np.log2(np.maximum(mags.max(1), 1e-300)) + 1
        bad = mism & ~(np.abs(gf - wf) <= 2.0 ** (anchor - W + 3))
        assert not bad.any(), f"{tag}: {bad.sum()} lanes outside the bound"


def main(path):
    rng = np.random.default_rng(SEED)
    arrays = {}
    for fmt_ab, fmt_acc, n in MODES:
        fa, fc = F.get_format(fmt_ab), F.get_format(fmt_acc)
        batches = {"finite": (_finite_codes(rng, fa, (LANES, n)),
                              _finite_codes(rng, fa, (LANES, n)),
                              _finite_codes(rng, fc, (LANES,)))}
        if fa.special != "none" or fc.special != "none":
            batches["specials"] = (
                rng.integers(0, 1 << fa.bits, (LANES, n)).astype(np.uint32),
                rng.integers(0, 1 << fa.bits, (LANES, n)).astype(np.uint32),
                rng.integers(0, 1 << fc.bits,
                             (LANES,), dtype=np.uint64).astype(np.uint32))
        for kind, (a, b, c) in batches.items():
            out = np.asarray(dpa.dpa_codes(a, b, c, fa, fc),
                             dtype=np.uint32)
            tag = f"{fmt_ab}_x{n}_{fmt_acc}_{kind}"
            if kind == "finite":
                _check_against_oracle(a, b, c, out, fa, fc, n, tag)
            for name, arr in (("a", a), ("b", b), ("c", c), ("out", out)):
                arrays[f"{tag}__{name}"] = arr
    np.savez_compressed(path, **arrays)
    print(f"wrote {path}: {len(arrays)} arrays, "
          f"{os.path.getsize(path)} bytes")


if __name__ == "__main__":
    main(os.path.join(os.path.dirname(__file__), "dpa_vectors.npz"))
