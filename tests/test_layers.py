"""Layer-level invariants: recurrences, MoE dispatch, attention caches,
property tests on the mLSTM chunk decomposition."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig

CFG = ModelConfig("t", "decoder", 2, 32, 4, 2, 64, 128, chunk=8)


@pytest.mark.parametrize("seq", [8, 16, 24, 32])
def test_mlstm_chunk_invariance(seq):
    """Chunkwise-parallel result is chunk-size independent (the recurrence
    decomposition law)."""
    cfg = CFG.replace(n_kv_heads=4)
    k = jax.random.PRNGKey(seq)
    x = jax.random.normal(k, (2, seq, 32), jnp.float32)
    p = L.init_mlstm(k, cfg)
    outs = []
    for ck in (8, seq):
        y, _ = L.apply_mlstm(p, x, cfg.replace(chunk=ck))
        outs.append(np.asarray(y))
    np.testing.assert_allclose(outs[0], outs[1], rtol=2e-4, atol=2e-4)


def test_mlstm_state_carry_equals_full():
    """Running two halves with carried state == one full pass."""
    cfg = CFG.replace(n_kv_heads=4, chunk=8)
    k = jax.random.PRNGKey(0)
    x = jax.random.normal(k, (2, 32, 32), jnp.float32)
    p = L.init_mlstm(k, cfg)
    full, _ = L.apply_mlstm(p, x, cfg)
    y1, s = L.apply_mlstm(p, x[:, :16], cfg)
    y2, _ = L.apply_mlstm(p, x[:, 16:], cfg, state=s)
    np.testing.assert_allclose(np.asarray(full),
                               np.asarray(jnp.concatenate([y1, y2], 1)),
                               rtol=2e-4, atol=2e-4)


def test_rglru_state_carry_equals_full():
    cfg = CFG.replace(d_rnn=32)
    k = jax.random.PRNGKey(1)
    x = jax.random.normal(k, (2, 24, 32), jnp.float32)
    p = L.init_rglru(k, cfg)
    full, _ = L.apply_rglru(p, x, cfg)
    y1, s = L.apply_rglru(p, x[:, :12], cfg)
    y2, _ = L.apply_rglru(p, x[:, 12:], cfg, state=s)
    np.testing.assert_allclose(np.asarray(full),
                               np.asarray(jnp.concatenate([y1, y2], 1)),
                               rtol=1e-5, atol=1e-5)


def test_rglru_decay_bounded():
    """RG-LRU is a contraction: with zero input-gate path the state decays;
    |h| stays bounded for bounded inputs."""
    cfg = CFG.replace(d_rnn=32)
    k = jax.random.PRNGKey(2)
    p = L.init_rglru(k, cfg)
    x = jnp.ones((1, 256, 32), jnp.float32) * 10
    y, s = L.apply_rglru(p, x, cfg)
    assert bool(jnp.isfinite(y).all())
    assert float(jnp.abs(s["h"]).max()) < 1e3


def test_sliding_window_attention_matches_masked_full():
    """attn_local == full attention with a band mask."""
    cfg = CFG.replace(window=8)
    k = jax.random.PRNGKey(3)
    x = jax.random.normal(k, (2, 32, 32), jnp.float32)
    p = L.init_attention(k, cfg)
    y_win, _ = L.apply_attention(p, x, cfg, window=8)
    # reference: full attention then band-masked probs
    q = (x @ p["wq"]["w"]).reshape(2, 32, 4, 8)
    kk = (x @ p["wk"]["w"]).reshape(2, 32, 2, 8)
    vv = (x @ p["wv"]["w"]).reshape(2, 32, 2, 8)
    q = L.rope(q, jnp.arange(32), cfg.rope_theta)
    kk = L.rope(kk, jnp.arange(32), cfg.rope_theta)
    kh = jnp.repeat(kk, 2, 2)
    vh = jnp.repeat(vv, 2, 2)
    lg = jnp.einsum("bshd,bthd->bhst", q, kh) / np.sqrt(8)
    i, j = np.arange(32)[:, None], np.arange(32)[None, :]
    mask = (j <= i) & (j > i - 8)
    lg = jnp.where(jnp.asarray(mask)[None, None], lg, -1e30)
    ref = jnp.einsum("bhst,bthd->bshd", jax.nn.softmax(lg, -1), vh)
    ref = ref.reshape(2, 32, 32) @ p["wo"]["w"]
    np.testing.assert_allclose(np.asarray(y_win), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)


def test_moe_capacity_drop_and_combine_weights():
    """Tokens over capacity are dropped (output contribution zero); gate
    weights are renormalized over the selected top-k."""
    cfg = CFG.replace(n_experts=4, top_k=2, capacity_factor=1.0)
    k = jax.random.PRNGKey(4)
    p = L.init_moe(k, cfg)
    x = jax.random.normal(k, (2, 16, 32), jnp.float32)
    y, aux = L.apply_moe(p, x, cfg)
    assert y.shape == x.shape and bool(jnp.isfinite(y).all())
    assert float(aux) > 0
    # huge capacity == no drops; tiny capacity -> smaller output norm
    y_full, _ = L.apply_moe(p, x, cfg.replace(capacity_factor=8.0))
    y_tiny, _ = L.apply_moe(p, x, cfg.replace(capacity_factor=0.05))
    assert float(jnp.linalg.norm(y_tiny)) < float(jnp.linalg.norm(y_full))


def test_moe_uniform_router_is_lossless_at_high_capacity():
    """With capacity >> need, every token's contribution equals the gate-
    weighted sum of its experts applied to it (dense check, small)."""
    cfg = CFG.replace(n_experts=4, top_k=2, capacity_factor=8.0)
    k = jax.random.PRNGKey(5)
    p = L.init_moe(k, cfg)
    x = jax.random.normal(k, (1, 8, 32), jnp.float32)
    y, _ = L.apply_moe(p, x, cfg)
    # dense reference
    logits = x @ p["router"]["w"]
    probs = jax.nn.softmax(logits, -1)
    w, idx = jax.lax.top_k(probs, 2)
    w = w / w.sum(-1, keepdims=True)
    ref = jnp.zeros_like(x)
    for e in range(4):
        g = jax.nn.silu(x @ p["wg"]["w"][e])
        u = x @ p["wu"]["w"][e]
        o = (g * u) @ p["wd"]["w"][e]
        we = jnp.sum(jnp.where(idx == e, w, 0.0), -1)
        ref = ref + we[..., None] * o
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=5e-3,
                               atol=5e-4)


def test_window_cache_decode_matches_prefill_then_step():
    """Prefill builds a window cache; continuing decode matches the
    full-sequence computation step by step."""
    cfg = CFG.replace(window=8)
    k = jax.random.PRNGKey(6)
    x = jax.random.normal(k, (2, 24, 32), jnp.float32)
    p = L.init_attention(k, cfg)
    full, _ = L.apply_attention(p, x, cfg, window=8)
    # prefill 16
    cache = {"k": jnp.zeros((2, 8, 2, 8)), "v": jnp.zeros((2, 8, 2, 8))}
    y0, cache = L.apply_attention(p, x[:, :16], cfg, window=8, cache=cache,
                                  cache_mode="window")
    np.testing.assert_allclose(np.asarray(y0), np.asarray(full[:, :16]),
                               rtol=1e-4, atol=1e-5)
    for t in range(16, 24):
        yt, cache = L.apply_attention(p, x[:, t:t + 1], cfg, offset=t,
                                      cache=cache, cache_mode="window")
        np.testing.assert_allclose(np.asarray(yt[:, 0]),
                                   np.asarray(full[:, t]), rtol=1e-4,
                                   atol=1e-5)


def test_chunked_attention_matches_full():
    cfg = CFG.replace(attn_chunk=8)
    k = jax.random.PRNGKey(7)
    x = jax.random.normal(k, (2, 32, 32), jnp.float32)
    p = L.init_attention(k, cfg)
    y_chunk, _ = L.apply_attention(p, x, cfg)
    y_full, _ = L.apply_attention(p, x, cfg.replace(attn_chunk=0))
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_full),
                               rtol=1e-5, atol=1e-6)


def test_slstm_stabilizer_no_overflow():
    """Exponential gating with the m-stabilizer must survive large gate
    pre-activations."""
    cfg = CFG
    k = jax.random.PRNGKey(8)
    p = L.init_slstm(k, cfg)
    x = jax.random.normal(k, (2, 64, 32), jnp.float32) * 20
    y, s = L.apply_slstm(p, x, cfg)
    assert bool(jnp.isfinite(y).all())
