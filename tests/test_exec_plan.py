"""Execution-plan layer conformance: route table, resolver, equivalence.

Three claims pinned here:

  1. The resolver is deterministic and total: same (op, policy, shapes)
     -> same route, every op has a reference fallback, and a resolution
     failure names each candidate's predicate bits.
  2. Every registered route is *reachable* — some (preset, shape-class)
     selects it.  A route nothing selects is dead weight (the
     `tools/plan_table.py` CI check enforces the test-coverage side).
  3. Every route is pinned to its reference fallback at the registered
     tolerance — bit-identical (tol 0) for pure-relayout routes like the
     paged-decode block-table kernel, bounded-error for routes whose
     scale granularity legitimately differs (kernel per-row/per-block
     scales vs the fake-quant reference's per-tensor activations).
"""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import exec_plan
from repro.core import kvcache as KV
from repro.core.policy import get_policy
from repro.core.quantize import cast_to

PAGED_PRESETS = ["attn_fp16_dpa", "kv8_attn_f32", "kv4_attn8_packed",
                 "attn_fp4_packed"]


def _rel_err(a, b):
    a, b = np.asarray(a, np.float64), np.asarray(b, np.float64)
    return float(np.max(np.abs(a - b)) / max(1e-6, np.max(np.abs(b))))


# -----------------------------------------------------------------------------
# shape-class samples per op: (ctx, run_args, run_kwargs) builders
# -----------------------------------------------------------------------------

def _matmul_cases():
    """(preset, native_weights) sweep covering every matmul route."""
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    x = jax.random.normal(ks[0], (8, 32))
    wf = jax.random.normal(ks[1], (32, 24)) * 0.5
    cases = []
    for preset in ["fp32", "fp16_dpa", "fp8_dpa", "w4a8", "fp8_dpa_fused",
                   "fp4_dpa_packed", "fp4_dpa_fused", "w4a8_packed"]:
        cases.append((preset, x, wf, wf))
    wq = cast_to(wf, "fp8_e4m3")                 # pre-quantized serving
    cases.append(("w8a16", x, wq, wf))
    return cases


def _attn_inputs(seed=1, sq=16, skv=16, b=2, h=4, kv=2, hd=16):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, sq, h, hd))
    k = jax.random.normal(ks[1], (b, skv, kv, hd))
    v = jax.random.normal(ks[2], (b, skv, kv, hd))
    return q, k, v


def _flash_cases():
    """(preset, ctx-overrides) sweep covering every flash_attn route."""
    return [
        ("fp32", dict(use_flash=True)),           # pallas_f32_flash
        ("attn_fp8_dpa", dict(use_flash=True)),   # pallas_dpa_flash
        ("attn_fp16_dpa", dict(use_flash=False)),  # xla_dpa_attn
        ("attn_fp8_dpa", dict(use_flash=True, has_valid=True)),  # masked dpa
        ("fp32", dict(use_flash=False)),          # xla_ref_attn
    ]


def _paged_cache(pol, lengths, ps=8, n_kv=2, hd=16, seed=3):
    """Paged cache via the shared relayout fixture, lengths crossing
    page boundaries."""
    B = len(lengths)
    S = max(-(-n // ps) for n in lengths) * ps
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    k = jax.random.normal(ks[0], (B, S, n_kv, hd))
    v = jax.random.normal(ks[1], (B, S, n_kv, hd))
    ref = KV.update_kv_cache(
        KV.init_kv_cache(B, S, n_kv, hd, fmt=pol.fmt_kv,
                         packed=pol.kv_packed),
        k, v, 0, fmt=pol.fmt_kv, packed=pol.kv_packed)
    return KV.paged_from_contiguous(ref, lengths, page_size=ps)


# -----------------------------------------------------------------------------
# 1. resolver determinism / totality / introspection
# -----------------------------------------------------------------------------

def test_resolver_deterministic():
    pol = get_policy("fp8_dpa_fused")
    ctx = dict(w_dtype="float32")
    first = exec_plan.resolve("matmul", pol, **ctx)
    for _ in range(3):
        assert exec_plan.resolve("matmul", pol, **ctx) is first
    assert first.name == "pallas_fused"
    # candidate order is (priority desc, name) — stable across calls
    names = [e.name for e in exec_plan.candidates("matmul")]
    assert names == [e.name for e in exec_plan.candidates("matmul")]
    prios = [e.priority for e in exec_plan.candidates("matmul")]
    assert prios == sorted(prios, reverse=True)


def test_every_op_has_reference_fallback():
    for op in exec_plan.ops():
        refs = [e for e in exec_plan.candidates(op) if e.reference is None]
        assert refs, op
        # routes that declare a reference point at a registered one
        for e in exec_plan.candidates(op):
            if e.reference is not None:
                assert exec_plan.reference_entry(e) is not None, (op, e.name)


def test_duplicate_registration_rejected():
    with pytest.raises(ValueError, match="registered twice"):
        exec_plan.register("matmul", "xla_f32", backend="xla",
                           run=lambda *a, **k: None)


def test_unresolvable_names_predicates():
    with pytest.raises(exec_plan.PlanError, match="kv_quantized"):
        exec_plan.resolve("paged_decode", "fp16_dpa")


def test_describe_reports_predicates_and_bytes():
    d = exec_plan.describe("paged_decode", "kv4_attn8_packed", page_size=8,
                           max_pages=4, kv_heads=2, hd=16)
    assert d["op"] == "paged_decode"
    assert d["route"] == "pallas_block_table"
    assert d["predicates"] == {"kv_quantized": True, "not_disabled": True}
    assert d["bytes_moved"] > 0
    assert set(d["candidates"]) == {"pallas_block_table", "jnp_gather",
                                    "paged_decode_sharded"}
    # the gather fallback re-materializes the view: strictly more bytes
    gather = exec_plan.route("paged_decode", "jnp_gather")
    assert gather.bytes_moved(
        get_policy("kv4_attn8_packed"),
        dict(page_size=8, max_pages=4, kv_heads=2, hd=16)) > d["bytes_moved"]


def test_paged_kernel_env_kill_switch(monkeypatch):
    monkeypatch.setenv("REPRO_PAGED_KERNEL", "0")
    e = exec_plan.resolve("paged_decode", "kv4_attn8_packed")
    assert e.name == "jnp_gather"


# -----------------------------------------------------------------------------
# 2. every registered route is reachable by some (preset, shape-class)
# -----------------------------------------------------------------------------

def test_every_route_reachable(monkeypatch):
    seen = {op: set() for op in exec_plan.ops()}
    for preset, x, w, _ in _matmul_cases():
        e = exec_plan.resolve("matmul", preset, w_dtype=str(w.dtype))
        seen["matmul"].add(e.name)
        e = exec_plan.resolve("grouped_matmul", preset,
                              w_dtype=str(w.dtype), eq="gti,gio->gto")
        seen["grouped_matmul"].add(e.name)
    for preset, ctx in _flash_cases():
        e = exec_plan.resolve("flash_attn", preset,
                              **dict(dict(sq=16, skv=16), **ctx))
        seen["flash_attn"].add(e.name)
    seen["decode_attn"].add(
        exec_plan.resolve("decode_attn", "kv8_attn_f32", s_ctx=32).name)
    seen["paged_decode"].add(
        exec_plan.resolve("paged_decode", "kv4_attn8_packed").name)
    monkeypatch.setenv("REPRO_PAGED_KERNEL", "0")
    seen["paged_decode"].add(
        exec_plan.resolve("paged_decode", "kv4_attn8_packed").name)
    monkeypatch.delenv("REPRO_PAGED_KERNEL")
    for fmt, pack in [("fp8_e4m3", False), ("fp4_e2m1", True)]:
        seen["quantize_pack"].add(
            exec_plan.resolve("quantize_pack", None, fmt=fmt, pack=pack).name)
    seen["quantize_pack"].add("xla_quantize")   # reference, pinned below
    # multi-device contexts select the sharded serving routes and the
    # wire-compressed allreduce (executed by the multi-device CI lane)
    seen["paged_decode"].add(
        exec_plan.resolve("paged_decode", "kv4_attn8_packed",
                          n_devices=8).name)
    seen["verify_attn"].add(
        exec_plan.resolve("verify_attn", "kv4_attn8_packed", sq=4,
                          n_devices=8).name)
    seen["allreduce"].add(
        exec_plan.resolve("allreduce", None, wire_fmt="fp8_e4m3",
                          n_devices=8).name)
    for op in exec_plan.ops():
        registered = {e.name for e in exec_plan.candidates(op)}
        missing = registered - seen[op]
        # reference fallbacks may only be reachable as references —
        # they are still exercised by the equivalence sweep below
        refs = {e.name for e in exec_plan.candidates(op)
                if e.reference is None}
        assert missing <= refs, (op, missing)


# -----------------------------------------------------------------------------
# 3. every route pinned to its reference fallback
# -----------------------------------------------------------------------------

def test_route_pinned_to_reference():
    """Sweep (op, preset, shape-class); wherever the resolved route has a
    reference fallback, outputs agree within the registered tolerance."""
    checked = 0
    for preset, x, w, wf in _matmul_cases():
        pol = get_policy(preset)
        e = exec_plan.resolve("matmul", pol, w_dtype=str(w.dtype))
        ref = exec_plan.reference_entry(e)
        if ref is None:
            continue
        got = e.run(x, w, pol)
        want = ref.run(x, wf, pol)
        assert _rel_err(got, want) <= e.tol, (preset, e.name, _rel_err(got, want))
        checked += 1
        eg = exec_plan.resolve("grouped_matmul", pol, w_dtype=str(w.dtype))
        refg = exec_plan.reference_entry(eg)
        if refg is not None:
            got = eg.run(x[None], w[None], pol, eq="gti,gio->gto")
            want = refg.run(x[None], wf[None], pol, eq="gti,gio->gto")
            assert _rel_err(got, want) <= eg.tol, (preset, eg.name)
            checked += 1
    q, k, v = _attn_inputs()
    for preset, ctx in _flash_cases():
        pol = get_policy(preset)
        full = dict(sq=q.shape[1], skv=k.shape[1], **ctx)
        e = exec_plan.resolve("flash_attn", pol, **full)
        ref = exec_plan.reference_entry(e)
        if ref is None:
            continue
        kw = dict(policy=pol, causal=True, window=None, offset=0,
                  valid=None, scale=q.shape[-1] ** -0.5, kv_on_grid=False)
        got, want = e.run(q, k, v, **kw), ref.run(q, k, v, **kw)
        assert _rel_err(got, want) <= e.tol, (preset, e.name, _rel_err(got, want))
        checked += 1
    ks = jax.random.split(jax.random.PRNGKey(7), 2)
    x2 = jax.random.normal(ks[0], (9, 32))
    for fmt, pack in [("fp16", False), ("fp8_e4m3", False),
                      ("fp4_e2m1", False), ("fp4_e2m1", True)]:
        e = exec_plan.resolve("quantize_pack", None, fmt=fmt, pack=pack)
        ref = exec_plan.reference_entry(e)
        if ref is None:
            continue
        gq, gs = e.run(x2, fmt=fmt, pack=pack, bm=128)
        wq, ws = ref.run(x2, fmt=fmt, pack=pack, bm=128)
        # codes land on the same grid points; scales may differ by the
        # kernel-vs-XLA fusion ulp the registered tol pins
        assert np.array_equal(np.asarray(gq, np.float32)
                              if gq.dtype != jnp.uint8 else np.asarray(gq),
                              np.asarray(wq, np.float32)
                              if wq.dtype != jnp.uint8 else np.asarray(wq))
        np.testing.assert_allclose(np.asarray(gs), np.asarray(ws),
                                   rtol=e.tol)
        checked += 1
    assert checked >= 10


@pytest.mark.parametrize("pol_name", PAGED_PRESETS)
def test_paged_decode_kernel_bit_identical(pol_name):
    """The block-table Pallas kernel == the jnp gather fallback, bit for
    bit, across every Table-I KV format — packed fp4 included, at odd
    lengths whose live rows cross page boundaries mid-page."""
    pol = get_policy(pol_name)
    lengths = [13, 5, 17]                   # odd: partial tail pages
    cache = _paged_cache(pol, lengths)
    B, hd = len(lengths), 16
    q = jax.random.normal(jax.random.PRNGKey(9), (B, 1, 4, hd))
    positions = jnp.asarray([n - 1 for n in lengths], jnp.int32)
    kernel = exec_plan.route("paged_decode", "pallas_block_table")
    gather = exec_plan.route("paged_decode", "jnp_gather")
    assert kernel.tol == 0.0 and kernel.reference == "jnp_gather"
    got = kernel.run(q, cache, positions, policy=pol, scale=hd ** -0.5)
    want = gather.run(q, cache, positions, policy=pol, scale=hd ** -0.5)
    assert got.dtype == want.dtype and got.shape == want.shape
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_paged_decode_kernel_masks_mid_page_positions():
    """Positions below the live length mask the tail — kernel and
    fallback agree at every position inside a page, not just the last."""
    pol = get_policy("kv4_attn8_packed")
    cache = _paged_cache(pol, [17, 17])
    q = jax.random.normal(jax.random.PRNGKey(11), (2, 1, 4, 16))
    kernel = exec_plan.route("paged_decode", "pallas_block_table")
    gather = exec_plan.route("paged_decode", "jnp_gather")
    for positions in ([0, 16], [7, 8], [15, 3]):
        pos = jnp.asarray(positions, jnp.int32)
        got = kernel.run(q, cache, pos, policy=pol, scale=16 ** -0.5)
        want = gather.run(q, cache, pos, policy=pol, scale=16 ** -0.5)
        assert np.array_equal(np.asarray(got), np.asarray(want)), positions


def test_selection_pin_table():
    """The scattered gates this layer replaced, as explicit expectations."""
    pins = [
        ("matmul", "fp32", dict(w_dtype="float32"), "xla_f32"),
        ("matmul", "fp8_dpa", dict(w_dtype="float32"), "xla_fake_quant"),
        ("matmul", "fp8_dpa", dict(w_dtype="float8_e4m3fn"),
         "xla_native_narrow"),
        ("matmul", "fp8_dpa_fused", dict(w_dtype="float32"), "pallas_fused"),
        ("matmul", "fp4_dpa_packed", dict(w_dtype="float32"),
         "pallas_prequant"),
        ("flash_attn", "fp32", dict(sq=16, skv=16, use_flash=True),
         "pallas_f32_flash"),
        ("flash_attn", "attn_fp8_dpa", dict(sq=16, skv=16, use_flash=True),
         "pallas_dpa_flash"),
        ("flash_attn", "attn_fp8_dpa",
         dict(sq=16, skv=16, use_flash=True, kv_on_grid=True),
         "xla_dpa_attn"),
        ("flash_attn", "attn_fp8_dpa", dict(sq=1, skv=16, use_flash=True),
         "xla_dpa_attn"),
        ("flash_attn", "fp32", dict(sq=1, skv=16, use_flash=True),
         "xla_ref_attn"),
        ("paged_decode", "kv4_attn8_packed", {}, "pallas_block_table"),
        ("paged_decode", "kv4_attn8_packed", dict(n_devices=8),
         "paged_decode_sharded"),
        ("verify_attn", "kv4_attn8_packed", dict(sq=4), "jnp_gather"),
        ("verify_attn", "kv4_attn8_packed", dict(sq=4, n_devices=8),
         "verify_attn_sharded"),
        ("allreduce", None, dict(wire_fmt="fp8_e4m3", n_devices=8),
         "wire_compressed"),
        ("allreduce", None, dict(n_devices=1), "xla_psum_f32"),
        ("unembed", None, {}, "xla_tied_table"),
        ("quantize_pack", None, dict(fmt="fp4_e2m1", pack=True),
         "pallas_quantize_pack"),
    ]
    for op, pol, ctx, want in pins:
        assert exec_plan.resolve(op, pol, **ctx).name == want, (op, pol, ctx)


def test_quantize_pack_rejects_non_fp4_pack():
    with pytest.raises(exec_plan.PlanError):
        exec_plan.resolve("quantize_pack", None, fmt="fp8_e4m3", pack=True)


def test_env_kill_switch_restored():
    """Paranoia: the monkeypatched kill switch really is off again."""
    assert os.environ.get("REPRO_PAGED_KERNEL", "1") != "0"
    e = exec_plan.resolve("paged_decode", "kv4_attn8_packed")
    assert e.name == "pallas_block_table"


def test_hlo_plan_routes_states_kernels():
    """launch.hlo_analysis.plan_routes names the kernel each op runs."""
    from repro.launch.hlo_analysis import plan_routes
    routes = plan_routes("w4a8_kv4_attn8")
    assert routes["matmul"]["route"] == "pallas_fused"
    assert routes["paged_decode"]["route"] == "pallas_block_table"
    assert routes["decode_attn"]["route"] == "xla_dpa_decode"
    # a raw-f32-cache policy has no paged route — reported as None
    assert plan_routes("fp16_dpa")["paged_decode"] is None
    assert plan_routes("fp16_dpa")["matmul"]["route"] == "xla_fake_quant"
