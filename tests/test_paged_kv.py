"""Paged KV cache conformance: paged-vs-contiguous bit-identity across
every Table-I KV format (packed fp4 included, at odd lengths crossing
page boundaries), allocator reuse/eviction invariants, and the paged
decode attention path vs the contiguous one.

The load-bearing claim: paging is *pure relayout*.  A page pool + block
table must hold codes and scales bit-identical to the contiguous cache
it replaces, whether rows arrive token-by-token (`paged_write_token`,
the decode path) or as a prefill scatter (`write_prefill_rows`), and the
attention consuming them (`dpa_paged_decode_attn`) must reproduce the
contiguous `dpa_decode_attn` bit-for-bit when the gathered view matches
the contiguous context length.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import kvcache as KV

# (fmt, packed): every KV format the policy table exposes
KV_FORMATS = [("fp16", False), ("bf16", False), ("fp8_e4m3", False),
              ("fp4_e2m1", False), ("fp4_e2m1", True)]
PS = 8                       # page size: small, so lengths cross pages
# odd lengths: mid-page tail, single partial page, >2 pages + 1 row
LENGTHS = [13, 5, 17]


def _fmt_id(p):
    return f"{p[0]}{'_packed' if p[1] else ''}"


def _raw_kv(seed, B, S, n_kv=2, hd=16):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    k = jax.random.normal(ks[0], (B, S, n_kv, hd))
    v = jax.random.normal(ks[1], (B, S, n_kv, hd))
    return k, v


def _alloc_tables(lengths, max_pages, capacity):
    alloc = KV.PageAllocator(capacity)
    table = np.full((len(lengths), max_pages), KV.SCRATCH_PAGE, np.int32)
    pages = []
    for b, L in enumerate(lengths):
        ids = alloc.alloc(-(-L // PS))
        pages.append(ids)
        table[b, :len(ids)] = ids
    return alloc, table, pages


def _assert_rows_equal(view, ref, lengths):
    for b, L in enumerate(lengths):
        for key in KV.QUANT_KEYS:
            got, want = np.asarray(view[key][b, :L]), np.asarray(ref[key][b, :L])
            assert got.dtype == want.dtype
            assert np.array_equal(got, want), (key, b)


# -----------------------------------------------------------------------------
# bit-identity: token writes and prefill scatter vs the contiguous cache
# -----------------------------------------------------------------------------

@pytest.mark.parametrize("fmt,packed", KV_FORMATS, ids=map(_fmt_id, KV_FORMATS))
def test_paged_token_writes_bit_identical(fmt, packed):
    """Token-by-token paged writes == contiguous update_kv_cache, for
    mixed lengths whose partial tails land mid-page."""
    B, n_kv, hd, max_pages = len(LENGTHS), 2, 16, 3
    k, v = _raw_kv(0, B, max_pages * PS, n_kv, hd)
    ref = KV.update_kv_cache(
        KV.init_kv_cache(B, max_pages * PS, n_kv, hd, fmt=fmt, packed=packed),
        k, v, 0, fmt=fmt, packed=packed)
    _, table, _ = _alloc_tables(LENGTHS, max_pages, capacity=16)
    cache = dict(KV.init_paged_kv_cache(16, PS, n_kv, hd, fmt=fmt,
                                        packed=packed),
                 block_table=jnp.asarray(table))
    for t in range(max(LENGTHS)):
        live = np.array([t < L for L in LENGTHS])
        # idle rows write position 0 of their (scratch) table row — the
        # engine's fixed-shape step; live data must be untouched by it
        tbl = np.where(live[:, None], table, KV.SCRATCH_PAGE).astype(np.int32)
        step = dict(cache, block_table=jnp.asarray(tbl))
        step = KV.paged_write_token(step, k[:, t:t + 1], v[:, t:t + 1],
                                    jnp.asarray(np.where(live, t, 0)),
                                    fmt=fmt, packed=packed)
        cache = dict(step, block_table=jnp.asarray(table))
    _assert_rows_equal(KV.gather_paged_kv(cache), ref, LENGTHS)


@pytest.mark.parametrize("fmt,packed", KV_FORMATS, ids=map(_fmt_id, KV_FORMATS))
def test_multi_token_write_equals_stepped_writes(fmt, packed):
    """`paged_write_tokens` over an S_new window == S_new sequential
    `paged_write_token` calls, bit for bit — rows quantize independently
    (per-row absmax over head_dim), so the speculative draft/verify
    window writes exactly what stepped decode would have written, even
    when the window straddles a page boundary."""
    B, n_kv, hd, max_pages, s_new = len(LENGTHS), 2, 16, 4, 5
    starts = [L - 2 for L in LENGTHS]           # windows cross boundaries
    k, v = _raw_kv(4, B, s_new, n_kv, hd)
    _, table, _ = _alloc_tables([L + s_new for L in LENGTHS], max_pages,
                                capacity=16)
    base = dict(KV.init_paged_kv_cache(16, PS, n_kv, hd, fmt=fmt,
                                       packed=packed),
                block_table=jnp.asarray(table))
    multi = KV.paged_write_tokens(base, k, v, jnp.asarray(starts, jnp.int32),
                                  fmt=fmt, packed=packed)
    stepped = base
    for t in range(s_new):
        stepped = KV.paged_write_token(
            stepped, k[:, t:t + 1], v[:, t:t + 1],
            jnp.asarray([s + t for s in starts], jnp.int32),
            fmt=fmt, packed=packed)
    for key in KV.QUANT_KEYS:
        assert np.array_equal(np.asarray(multi[key]),
                              np.asarray(stepped[key])), key


@pytest.mark.parametrize("fmt,packed", KV_FORMATS, ids=map(_fmt_id, KV_FORMATS))
def test_prefill_scatter_bit_identical(fmt, packed):
    """write_prefill_rows (whole pages + partial tail) == the contiguous
    staging rows it copies."""
    B, n_kv, hd, max_pages = len(LENGTHS), 2, 16, 3
    k, v = _raw_kv(1, B, max_pages * PS, n_kv, hd)
    ref = KV.update_kv_cache(
        KV.init_kv_cache(B, max_pages * PS, n_kv, hd, fmt=fmt, packed=packed),
        k, v, 0, fmt=fmt, packed=packed)
    _, table, pages = _alloc_tables(LENGTHS, max_pages, capacity=16)
    cache = dict(KV.init_paged_kv_cache(16, PS, n_kv, hd, fmt=fmt,
                                        packed=packed),
                 block_table=jnp.asarray(table))
    for b, L in enumerate(LENGTHS):
        rows = {key: ref[key][b] for key in KV.QUANT_KEYS}
        cache = KV.write_prefill_rows(cache, rows, pages[b], L)
    _assert_rows_equal(KV.gather_paged_kv(cache), ref, LENGTHS)


def test_write_prefill_rows_rejects_short_page_list():
    cache = KV.init_paged_kv_cache(4, PS, 2, 16, fmt="fp16")
    rows = {key: jnp.zeros((2 * PS,) + cache[key].shape[2:],
                           cache[key].dtype) for key in KV.QUANT_KEYS}
    with pytest.raises(ValueError, match="pages"):
        KV.write_prefill_rows(cache, rows, [1], PS + 1)


def test_gather_view_shape_and_scratch_tail():
    """The gathered view is (B, max_pages*page, ...) and tail slots past a
    request's pages read the scratch page (zeros here) — maskable, never
    out of bounds."""
    n_kv, hd = 2, 16
    cache = dict(KV.init_paged_kv_cache(8, PS, n_kv, hd, fmt="fp8_e4m3"),
                 block_table=jnp.asarray([[1, KV.SCRATCH_PAGE]], np.int32))
    k, v = _raw_kv(2, 1, PS, n_kv, hd)
    rows = KV.quantize_kv(k[0], fmt="fp8_e4m3")
    cache = KV.write_prefill_rows(
        cache, {"k_codes": rows[0], "k_scale": rows[1],
                "v_codes": rows[0], "v_scale": rows[1]}, [1], PS)
    view = KV.gather_paged_kv(cache)
    assert view["k_codes"].shape == (1, 2 * PS, n_kv, hd)
    assert np.all(np.asarray(view["k_scale"][0, PS:]) == 0.0)


# -----------------------------------------------------------------------------
# paged decode attention vs the contiguous decode path
# -----------------------------------------------------------------------------

@pytest.mark.parametrize("pol_name", ["attn_fp16_dpa", "kv4_attn8_packed"])
def test_paged_decode_attn_matches_contiguous(pol_name):
    """dpa_paged_decode_attn == dpa_decode_attn bit-for-bit when the
    gathered view length equals the contiguous S_ctx (same shapes, same
    reductions), at per-request positions."""
    from repro.core import get_policy
    from repro.models.decode_attn import dpa_decode_attn, dpa_paged_decode_attn
    pol = get_policy(pol_name)
    B, H, n_kv, hd, n_pg = 3, 4, 2, 16, 4
    S = n_pg * PS
    k, v = _raw_kv(3, B, S, n_kv, hd)
    q = jax.random.normal(jax.random.PRNGKey(9), (B, 1, H, hd))
    ref = KV.update_kv_cache(
        KV.init_kv_cache(B, S, n_kv, hd, fmt=pol.fmt_kv,
                         packed=pol.kv_packed),
        k, v, 0, fmt=pol.fmt_kv, packed=pol.kv_packed)
    cache = KV.paged_from_contiguous(ref, [S] * B, page_size=PS)
    positions = jnp.asarray([5, S - 1, 12], jnp.int32)
    got = dpa_paged_decode_attn(q, cache, positions, fmt=pol.fmt_attn,
                                fmt_kv=pol.fmt_kv, kv_packed=pol.kv_packed,
                                scale=hd ** -0.5)
    for b in range(B):
        want = dpa_decode_attn(q[b:b + 1],
                               {key: ref[key][b:b + 1]
                                for key in KV.QUANT_KEYS},
                               int(positions[b]), fmt=pol.fmt_attn,
                               fmt_kv=pol.fmt_kv, kv_packed=pol.kv_packed,
                               scale=hd ** -0.5)
        assert np.array_equal(np.asarray(got[b]), np.asarray(want[0])), b


# -----------------------------------------------------------------------------
# allocator invariants
# -----------------------------------------------------------------------------

def test_allocator_reserves_scratch_and_exhausts():
    a = KV.PageAllocator(5)
    assert a.n_free == 4                       # page 0 reserved
    got = a.alloc(4)
    assert KV.SCRATCH_PAGE not in got and len(set(got)) == 4
    assert not a.can_alloc(1)
    with pytest.raises(MemoryError):
        a.alloc(1)


def test_allocator_free_list_reuse():
    """Eviction returns pages for reuse (LIFO: the hottest pages first)."""
    a = KV.PageAllocator(8)
    first = a.alloc(3)
    a.free(first)
    assert a.in_use == 0 and a.n_free == 7
    again = a.alloc(3)
    assert again == first[::-1]                # LIFO reuse order
    assert a.peak_in_use == 3                  # peak survives the evict


def test_allocator_rejects_double_and_scratch_free():
    a = KV.PageAllocator(4)
    pages = a.alloc(2)
    a.free(pages[:1])
    with pytest.raises(ValueError, match="double free"):
        a.free(pages[:1])
    with pytest.raises(ValueError, match="scratch"):
        a.free([KV.SCRATCH_PAGE])
    with pytest.raises(ValueError):
        KV.PageAllocator(1)


def test_allocator_utilization():
    a = KV.PageAllocator(11)
    a.alloc(5)
    assert a.utilization() == 0.5
    assert a.peak_in_use == 5


def test_allocator_refcount_lifecycle():
    """free() is a decref: a shared page survives every free but the
    last, then returns to the free list exactly once."""
    a = KV.PageAllocator(8)
    (p,) = a.alloc(1)
    assert a.refcount(p) == 1 and not a.is_shared(p)
    a.incref([p])
    a.incref([p])
    assert a.refcount(p) == 3 and a.is_shared(p)
    a.free([p])
    a.free([p])
    assert a.in_use == 1                       # still held once
    assert a.refcount(p) == 1
    a.free([p])
    assert a.in_use == 0 and a.refcount(p) == 0
    with pytest.raises(ValueError, match="double free"):
        a.free([p])
    with pytest.raises(ValueError, match="not in use"):
        a.incref([p])


def test_allocator_shared_page_never_rehanded_out():
    """While any holder remains, a shared page never reappears from
    alloc() — the prefix cache's never-freed-while-referenced contract."""
    a = KV.PageAllocator(6)
    (p,) = a.alloc(1)
    a.incref([p])                              # second holder
    a.free([p])                                # first holder exits
    assert p not in a.alloc(4)                 # the whole rest of the pool
    with pytest.raises(MemoryError):
        a.alloc(1)


def test_allocator_rollback_refuses_shared_pages():
    """Speculative rollback (free to_reserved=True) may only reclaim
    exclusively-owned pages; a shared prefix page inside the rollback
    set is an accounting bug and must raise, not silently corrupt."""
    a = KV.PageAllocator(8)
    a.reserve(2)
    pages = a.alloc(2, reserved=True)
    a.incref(pages[:1])
    with pytest.raises(ValueError, match="shared"):
        a.free(pages[:1], to_reserved=True)
    a.free(pages[1:], to_reserved=True)        # exclusive page: fine
    assert a.reserved == 1


def test_paged_from_contiguous_empty_and_single():
    """Empty workloads are legal: an all-scratch table over a minimal
    pool, not a max() crash; a single request round-trips exactly."""
    ref = KV.init_kv_cache(0, 2 * PS, 2, 16, fmt="fp8_e4m3")
    cache = KV.paged_from_contiguous(ref, [], page_size=PS)
    assert cache["block_table"].shape[0] == 0
    assert cache["block_table"].shape[1] >= 1
    k, v = _raw_kv(5, 1, 2 * PS, 2, 16)
    one = KV.update_kv_cache(KV.init_kv_cache(1, 2 * PS, 2, 16,
                                              fmt="fp8_e4m3"),
                             k, v, 0, fmt="fp8_e4m3")
    paged = KV.paged_from_contiguous(one, [2 * PS], page_size=PS)
    _assert_rows_equal(KV.gather_paged_kv(paged), one, [2 * PS])


@pytest.mark.parametrize("fmt,packed", [("fp8_e4m3", False),
                                        ("fp4_e2m1", True)],
                         ids=["fp8", "fp4_packed"])
def test_prefill_scatter_start_skips_prefix_pages(fmt, packed):
    """write_prefill_rows(start=m) leaves every row before m untouched —
    full prefix pages are never written (shared-page safety) and a CoW
    page keeps its copied head rows — while rows from m on land
    bit-identical to a start=0 scatter."""
    n_kv, hd, L, start = 2, 16, 2 * PS + 3, PS + 5   # mid-page divergence
    k, v = _raw_kv(6, 1, 3 * PS, n_kv, hd)
    ref = KV.update_kv_cache(
        KV.init_kv_cache(1, 3 * PS, n_kv, hd, fmt=fmt, packed=packed),
        k, v, 0, fmt=fmt, packed=packed)
    rows = {key: ref[key][0] for key in KV.QUANT_KEYS}
    _, table, pages = _alloc_tables([L], 3, capacity=8)
    base = dict(KV.init_paged_kv_cache(8, PS, n_kv, hd, fmt=fmt,
                                       packed=packed),
                block_table=jnp.asarray(table))
    # poison the pool so "untouched" is observable
    poisoned = {key: jnp.ones_like(base[key]) for key in KV.QUANT_KEYS}
    part = KV.write_prefill_rows(dict(base, **poisoned), rows, pages[0], L,
                                 start=start)
    full = KV.write_prefill_rows(base, rows, pages[0], L)
    pids = pages[0]
    for key in KV.QUANT_KEYS:
        got = np.asarray(part[key])
        # page 0 entirely before `start`: still poison
        assert np.all(got[pids[0]] == 1), key
        # page 1 rows before the in-page offset: still poison
        assert np.all(got[pids[1], :start - PS] == 1), key
        # everything from `start` up to `length` matches the full scatter
        want = np.asarray(full[key])
        assert np.array_equal(got[pids[1], start - PS:],
                              want[pids[1], start - PS:]), key
        assert np.array_equal(got[pids[2], :L - 2 * PS],
                              want[pids[2], :L - 2 * PS]), key
    with pytest.raises(ValueError, match="start"):
        KV.write_prefill_rows(base, rows, pages[0], L, start=L + 1)


# -----------------------------------------------------------------------------
# byte accounting: live tokens, not B x S_max
# -----------------------------------------------------------------------------

@pytest.mark.parametrize("fmt,packed", [("fp8_e4m3", False),
                                        ("fp4_e2m1", True)],
                         ids=["fp8", "fp4_packed"])
def test_paged_bytes_scale_with_live_tokens(fmt, packed):
    n_kv, hd, B, s_max = 2, 64, 8, 256
    live, pages_used = 300, -(-300 // PS)
    nb = KV.paged_kv_cache_nbytes(live, pages_used, PS, n_kv, hd,
                                  fmt=fmt, packed=packed)
    static = KV.kv_cache_nbytes(B, s_max, n_kv, hd, fmt=fmt, packed=packed)
    assert nb["live"] <= nb["paged"]           # page-granularity overhead
    assert nb["paged"] < static["total"]       # << the B x S_max layout
    # live bytes are exactly per-row bytes x live rows
    per_row = KV.kv_cache_nbytes(1, 1, n_kv, hd, fmt=fmt,
                                 packed=packed)["total"]
    assert nb["live"] == per_row * live
