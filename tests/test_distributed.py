"""Distributed semantics on 8 virtual CPU devices (subprocess: the device
count must be fixed before jax initializes, and other tests need 1)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(body: str) -> dict:
    """Run `body` in a subprocess with 8 host devices; it must print a
    single JSON line prefixed RESULT:."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import jax, jax.numpy as jnp
        import numpy as np
    """) + textwrap.dedent(body)
    env = dict(os.environ,
               PYTHONPATH=os.path.join(_REPO, "src"),
               XLA_FLAGS="")
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    for line in out.stdout.splitlines():
        if line.startswith("RESULT:"):
            return json.loads(line[len("RESULT:"):])
    raise AssertionError(f"no RESULT line in: {out.stdout[-2000:]}")


def test_sharded_train_step_matches_single_device():
    """pjit 4x2 mesh train step == single-device step (same seed)."""
    r = _run("""
        from repro.models import ModelConfig, build_model
        from repro.distributed.step import make_train_step
        from repro.distributed import sharding as shd
        from repro.optim import adamw
        from repro.launch.mesh import make_host_mesh

        cfg = ModelConfig("t", "decoder", 2, 64, 4, 2, 128, 256)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, 256),
                 "labels": jax.random.randint(jax.random.PRNGKey(2), (8, 32), 0, 256)}
        step = make_train_step(model, adamw.AdamWConfig(lr=1e-3, total_steps=10))
        state0 = {"params": params, "opt": adamw.init(params)}
        s_ref, m_ref = jax.jit(step)(state0, batch)

        mesh = make_host_mesh(n_data=4, n_model=2)
        with mesh:
            sh = {"params": shd.make_param_shardings(params, mesh),
                  "opt": {"m": shd.make_param_shardings(params, mesh),
                          "v": shd.make_param_shardings(params, mesh),
                          "count": jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())}}
            state = jax.device_put({"params": params, "opt": adamw.init(params)}, sh)
            bsh = shd.batch_spec(batch, mesh)
            s_d, m_d = jax.jit(step, in_shardings=(sh, bsh))(state, jax.device_put(batch, bsh))
        dl = max(float(jnp.abs(a - b).max()) for a, b in
                 zip(jax.tree.leaves(s_ref["params"]), jax.tree.leaves(s_d["params"])))
        print("RESULT:" + json.dumps({"loss_ref": float(m_ref["loss"]),
                                      "loss_d": float(m_d["loss"]),
                                      "param_diff": dl}))
    """)
    assert abs(r["loss_ref"] - r["loss_d"]) < 1e-4, r
    assert r["param_diff"] < 1e-4, r


def test_compressed_allreduce_error_feedback():
    """fp8 EF all-reduce over shard_map: (a) single-round error bounded,
    (b) error feedback makes the *average over rounds* converge to the
    true mean gradient."""
    r = _run("""
        from functools import partial
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.distributed.collectives import ef_compress_allreduce

        at = getattr(jax.sharding, "AxisType", None)
        mesh = jax.make_mesh((8,), ("data",),
                             **({"axis_types": (at.Auto,)} if at else {}))
        g = jax.random.normal(jax.random.PRNGKey(0), (8, 1024), jnp.float32)
        true_mean = jnp.mean(g, axis=0)

        @partial(shard_map, mesh=mesh, in_specs=(P("data"), P("data")),
                 out_specs=(P("data"), P("data")))
        def reduce_once(gs, es):
            m, e = ef_compress_allreduce(gs[0], es[0], "data")
            return m[None], e[None]

        err_state = jnp.zeros_like(g)
        acc = jnp.zeros_like(true_mean)
        rounds = 30
        for _ in range(rounds):
            mean8, err_state = reduce_once(g, err_state)
            acc = acc + mean8[0]
        single = reduce_once(g, jnp.zeros_like(g))[0][0]
        rel1 = float(jnp.abs(single - true_mean).max() / jnp.abs(true_mean).max())
        relN = float(jnp.abs(acc / rounds - true_mean).max() / jnp.abs(true_mean).max())
        print("RESULT:" + json.dumps({"rel_single": rel1, "rel_avg": relN}))
    """)
    assert r["rel_single"] < 0.08, r          # one fp8 round: ~fp8 eps
    assert r["rel_avg"] < r["rel_single"] / 2, r   # EF cancels bias over rounds


def test_sharding_specs_divisibility_guards():
    r = _run("""
        from repro.distributed import sharding as shd
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh(n_data=4, n_model=2)
        # batch=1 must fall back to replication; batch=8 shards
        import jax
        specs = shd.batch_spec({"tokens": jax.ShapeDtypeStruct((1, 16), jnp.int32),
                                "big": jax.ShapeDtypeStruct((8, 16), jnp.int32)}, mesh)
        s1 = specs["tokens"].spec
        s8 = specs["big"].spec
        # odd head dim must not shard on model
        p = shd.param_spec([], jax.ShapeDtypeStruct((64, 7), jnp.float32), mesh)
        print("RESULT:" + json.dumps({"b1": str(s1), "b8": str(s8), "odd": str(p)}))
    """)
    assert "None" in r["b1"] or r["b1"] == "PartitionSpec()", r
    assert "data" in r["b8"], r
    assert "model" not in r["odd"], r


def test_elastic_checkpoint_restore_new_mesh():
    """Save under a 4x2 mesh, restore under 2x4 — elastic scaling."""
    r = _run("""
        import tempfile
        from repro.models import ModelConfig, build_model
        from repro.distributed import sharding as shd
        from repro.runtime import checkpoint as ck
        from repro.launch.mesh import make_host_mesh

        cfg = ModelConfig("t", "decoder", 2, 64, 4, 2, 128, 256)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        mesh1 = make_host_mesh(n_data=4, n_model=2)
        with mesh1:
            p1 = jax.device_put(params, shd.make_param_shardings(params, mesh1))
        d = tempfile.mkdtemp()
        ck.save(p1, 7, d)
        mesh2 = make_host_mesh(n_data=2, n_model=4)
        with mesh2:
            p2, step = ck.restore(d, params,
                                  shardings=shd.make_param_shardings(params, mesh2))
        diff = max(float(jnp.abs(a - b).max()) for a, b in
                   zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
        nshards = len(set(str(x.sharding) for x in jax.tree.leaves(p2)))
        print("RESULT:" + json.dumps({"step": step, "diff": diff}))
    """)
    assert r["step"] == 7 and r["diff"] == 0.0, r


@pytest.mark.slow
def test_dryrun_machinery_on_8_devices():
    """The dry-run lower+compile path itself, scaled to an 8-chip mesh
    stand-in via a reduced arch (full 512-dev sweep runs via
    `python -m repro.launch.dryrun --all`, recorded in EXPERIMENTS.md)."""
    r = _run("""
        from repro.configs import get_config, reduce_config
        from repro.distributed import sharding as shd
        from repro.distributed.step import make_train_step
        from repro.launch.mesh import make_host_mesh
        from repro.launch import hlo_analysis as H
        from repro.models import build_model
        from repro.optim import adamw

        cfg = reduce_config(get_config("llama3.2-3b")).replace(remat="full")
        model = build_model(cfg)
        mesh = make_host_mesh(n_data=4, n_model=2)
        params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        state = {"params": params, "opt": jax.eval_shape(adamw.init, params)}
        batch = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
        with mesh:
            sh = {"params": shd.make_param_shardings(state["params"], mesh),
                  "opt": {"m": shd.make_param_shardings(state["opt"]["m"], mesh),
                          "v": shd.make_param_shardings(state["opt"]["v"], mesh),
                          "count": jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())}}
            bsh = shd.batch_spec(batch, mesh)
            step = make_train_step(model, adamw.AdamWConfig())
            compiled = jax.jit(step, in_shardings=(sh, bsh)).lower(state, batch).compile()
        coll, recs = H.collective_bytes(compiled.as_text())
        mem = compiled.memory_analysis()
        print("RESULT:" + json.dumps({
            "coll_total": sum(coll.values()),
            "n_coll": len(recs),
            "temp": getattr(mem, "temp_size_in_bytes", -1)}))
    """)
    assert r["coll_total"] > 0 and r["n_coll"] > 0, r
    assert r["temp"] > 0, r


def test_compressed_train_step_routes_allreduce_through_plan():
    """make_compressed_train_step on an 8-way DP mesh: the exec-plan
    ``allreduce`` op serves the gradient collective.  With fmt_name=None
    the f32 psum reference route reproduces the single-device step to
    float-reassociation tolerance; with the fp8 wire route the loss
    stays close and the error-feedback state is live (nonzero)."""
    r = _run("""
        from repro.distributed.step import (init_err_state,
                                            make_compressed_train_step,
                                            make_train_step)
        from repro.launch.mesh import make_host_mesh
        from repro.models import ModelConfig, build_model
        from repro.optim import adamw

        cfg = ModelConfig("t", "decoder", 2, 64, 4, 2, 128, 256)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        batch = {"tokens": jax.random.randint(
                     jax.random.PRNGKey(1), (8, 32), 0, 256),
                 "labels": jax.random.randint(
                     jax.random.PRNGKey(2), (8, 32), 0, 256)}
        ocfg = adamw.AdamWConfig(lr=1e-3, total_steps=10)
        ref_step = make_train_step(model, ocfg)
        s_ref, m_ref = jax.jit(ref_step)(
            {"params": params, "opt": adamw.init(params)}, batch)

        mesh = make_host_mesh(n_data=8, n_model=1)
        out = {}
        for fmt in (None, "fp8_e4m3"):
            step = make_compressed_train_step(model, ocfg, mesh,
                                              fmt_name=fmt)
            state = {"params": params, "opt": adamw.init(params),
                     "err": init_err_state(params, 8)}
            with mesh:
                s_d, m_d = jax.jit(step)(state, batch)
            dl = max(float(jnp.abs(a - b).max()) for a, b in
                     zip(jax.tree.leaves(s_ref["params"]),
                         jax.tree.leaves(s_d["params"])))
            err_mag = max(float(jnp.abs(e).max())
                          for e in jax.tree.leaves(s_d["err"]))
            key = fmt or "psum"
            out[key] = {"loss": float(m_d["loss"]),
                        "param_diff": dl, "err_mag": err_mag}
        out["loss_ref"] = float(m_ref["loss"])
        print("RESULT:" + json.dumps(out))
    """)
    assert abs(r["psum"]["loss"] - r["loss_ref"]) < 1e-4, r
    assert r["psum"]["param_diff"] < 1e-4, r
    assert r["psum"]["err_mag"] == 0.0, r
    assert abs(r["fp8_e4m3"]["loss"] - r["loss_ref"]) < 1e-3, r
    # fp8 wire: one update's drift is bounded by the lr (the residual
    # feeds back next step), and the residual itself is live
    assert r["fp8_e4m3"]["param_diff"] < 5e-3, r
    assert r["fp8_e4m3"]["err_mag"] > 0.0, r
