"""Property tests: TransDot golden model vs the exact big-int oracle.

The contract (DESIGN.md §4): bit-exact vs the exact single-rounded sum
whenever cancellation does not dig below the accumulation window; a
bounded absolute error 2^(anchor - W + 3) otherwise; bit-exact always
with a wide window.  Plus IEEE special-value propagation and the FPnew
sequential-FMA baseline semantics.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import dpa, formats as F, oracle
from repro.core.fpnew_ref import sequential_fma_codes

MODES = [("fp16", "fp32", 2), ("fp8_e4m3", "fp32", 4),
         ("fp4_e2m1", "fp32", 8), ("fp32", "fp32", 1),
         ("fp16", "fp16", 2), ("fp8_e4m3", "fp16", 4)]


def _rand_codes(rng, fmt, shape, specials=False):
    c = rng.integers(0, 1 << fmt.bits, size=shape).astype(np.uint32)
    if not specials and fmt.special != "none":
        # remap NaN/inf codes into finite space
        vals = F.codes_to_np(c, fmt).astype(np.float64)
        bad = ~np.isfinite(vals)
        c = np.where(bad, c & (fmt.man_mask >> 1), c)
    return c


@pytest.mark.parametrize("fmt_ab,fmt_acc,n", MODES,
                         ids=[f"{a}x{n}to{c}" for a, c, n in MODES])
def test_bitexact_vs_oracle_random(fmt_ab, fmt_acc, n):
    """Random finite operands across the FULL code space (subnormals,
    extreme exponents included): windowed result must be bit-exact except
    for deep cancellation, which must obey the window error bound."""
    fa, fc = F.get_format(fmt_ab), F.get_format(fmt_acc)
    rng = np.random.default_rng(42)
    trials = 1500
    a = _rand_codes(rng, fa, (trials, n))
    b = _rand_codes(rng, fa, (trials, n))
    c = _rand_codes(rng, fc, (trials,))
    got = np.asarray(dpa.dpa_codes(a, b, c, fa, fc))
    want = oracle.dpa_exact(a, b, c, fa, fc)
    gf = F.codes_to_np(got, fc).astype(np.float64)
    wf = F.codes_to_np(want, fc).astype(np.float64)
    mismatch = (got != want) & ~(np.isnan(gf) & np.isnan(wf))
    if mismatch.any():
        # allowed only under the window-loss bound
        W = dpa.default_window_bits(fc, n)
        av = F.codes_to_np(a, fa).astype(np.float64)
        bv = F.codes_to_np(b, fa).astype(np.float64)
        cv = F.codes_to_np(c, fc).astype(np.float64)
        mags = np.concatenate([np.abs(av * bv),
                               np.abs(cv)[:, None]], axis=1)
        anchor = np.log2(np.maximum(mags.max(axis=1), 1e-300)) + 1
        bound = 2.0 ** (anchor - W + 3)
        err = np.abs(gf - wf)
        bad = mismatch & ~(err <= bound)
        assert not bad.any(), (
            f"{bad.sum()} results outside window bound; "
            f"first: a={av[bad][0] if bad.any() else None}")


@pytest.mark.parametrize("fmt_ab,fmt_acc,n", MODES[:3],
                         ids=[f"{a}x{n}" for a, c, n in MODES[:3]])
def test_bitexact_wide_window(fmt_ab, fmt_acc, n):
    """With a 140-bit window the model must match the oracle everywhere,
    including engineered catastrophic cancellation."""
    fa, fc = F.get_format(fmt_ab), F.get_format(fmt_acc)
    rng = np.random.default_rng(7)
    a = _rand_codes(rng, fa, (800, n))
    b = _rand_codes(rng, fa, (800, n))
    # force pairwise cancellation: b1 = -b0, a1 = a0
    if n >= 2:
        b[:, 1] = b[:, 0] ^ (1 << (fa.bits - 1))
        a[:, 1] = a[:, 0]
    # c within a moderate range so (product span + c span) fits the wide
    # window — the full-code-space regime is covered (with the window
    # bound) by test_bitexact_vs_oracle_random
    c = F.float_to_codes(rng.normal(size=800) * 1e3, fc)
    got = np.asarray(dpa.dpa_codes(a, b, c, fa, fc, window_bits=140))
    want = oracle.dpa_exact(a, b, c, fa, fc)
    gf = F.codes_to_np(got, fc).astype(np.float64)
    wf = F.codes_to_np(want, fc).astype(np.float64)
    ok = (got == want) | (np.isnan(gf) & np.isnan(wf))
    assert ok.all(), f"{(~ok).sum()} mismatches with wide window"


@given(st.integers(0, 2 ** 16 - 1), st.integers(0, 2 ** 16 - 1),
       st.integers(0, 2 ** 32 - 1))
@settings(max_examples=300, deadline=None)
def test_fma_correctly_rounded_hypothesis(ac, bc, cc):
    """Scalar trans-precision FMA (N=1) is correctly rounded for ALL
    inputs — the hardware 3p+4 exactness property."""
    a = np.array([[ac]], np.uint32)
    b = np.array([[bc]], np.uint32)
    c = np.array([cc], np.uint32)
    got = np.asarray(dpa.dpa_codes(a, b, c, F.FP16, F.FP32))
    want = oracle.dpa_exact(a, b, c, F.FP16, F.FP32)
    gf = F.codes_to_np(got, F.FP32).astype(np.float64)
    wf = F.codes_to_np(want, F.FP32).astype(np.float64)
    assert (got == want).all() or (np.isnan(gf) & np.isnan(wf)).all()


def test_special_values():
    fa, fc = F.FP16, F.FP32
    inf = 0x7C00
    ninf = 0xFC00
    nan = 0x7E00
    one = 0x3C00
    zero = 0x0000
    cases = [
        # (a, b), c -> predicate on float result
        ([(inf, one), (one, one)], 0, lambda v: v == np.inf),
        ([(ninf, one), (one, one)], 0, lambda v: v == -np.inf),
        ([(inf, zero), (one, one)], 0, np.isnan),        # inf * 0
        ([(inf, one), (ninf, one)], 0, np.isnan),        # inf - inf
        ([(nan, one), (one, one)], 0, np.isnan),
        ([(one, one), (one, one)], 0x7F800000, lambda v: v == np.inf),
        ([(one, one), (one, one)], 0xFF800000, lambda v: v == -np.inf),
        ([(inf, one), (one, one)], 0xFF800000, np.isnan),
    ]
    for terms, c, pred in cases:
        a = np.array([[t[0] for t in terms]], np.uint32)
        b = np.array([[t[1] for t in terms]], np.uint32)
        out = np.asarray(dpa.dpa_codes(a, b, np.array([c], np.uint32),
                                       fa, fc))
        v = F.codes_to_np(out, fc).astype(np.float64)[0]
        assert pred(v), (terms, c, v)


def test_signed_zero():
    fa, fc = F.FP16, F.FP32
    nzero16 = 0x8000
    nzero32 = np.uint32(0x80000000)
    a = np.array([[nzero16, nzero16]], np.uint32)
    b = np.array([[0x3C00, 0x3C00]], np.uint32)   # -0 * 1 = -0 twice
    out = np.asarray(dpa.dpa_codes(a, b, np.array([nzero32]), fa, fc))[0]
    assert out == 0x80000000                       # all -0 -> -0
    out = np.asarray(dpa.dpa_codes(a, b, np.array([0], np.uint32),
                                   fa, fc))[0]
    assert out == 0                                # mixed signs -> +0


def test_dpa_single_rounding_beats_sequential():
    """The paper's numerics motivation: DPA (one rounding) accumulates
    less error than FPnew sequential FMA (N roundings) on long dots."""
    rng = np.random.default_rng(3)
    n, trials = 4, 400
    fa, fc = F.FP8_E4M3, F.FP16     # coarse accumulate fmt shows the gap
    a = rng.normal(size=(trials, n))
    b = rng.normal(size=(trials, n))
    ac = F.float_to_codes(a, fa)
    bc = F.float_to_codes(b, fa)
    cc = np.zeros(trials, np.uint32)
    av = F.codes_to_np(ac, fa).astype(np.float64)
    bv = F.codes_to_np(bc, fa).astype(np.float64)
    exact = (av * bv).sum(1)
    got_dpa = F.codes_to_np(np.asarray(dpa.dpa_codes(ac, bc, cc, fa, fc)),
                            fc).astype(np.float64)
    got_seq = F.codes_to_np(np.asarray(sequential_fma_codes(ac, bc, cc,
                                                            fa, fc)),
                            fc).astype(np.float64)
    err_dpa = np.abs(got_dpa - exact).mean()
    err_seq = np.abs(got_seq - exact).mean()
    assert err_dpa <= err_seq * 1.001


def test_fp16_accumulate_mode():
    """Table I: FP16 accumulate output format."""
    rng = np.random.default_rng(5)
    a = rng.normal(size=(200, 2))
    out = dpa.dpa(a, a, np.zeros(200), "fp16", "fp16")
    assert np.isfinite(out).all() and (out >= 0).all()
