"""Golden-vector replay: the DPA datapath pinned bit-for-bit.

`tests/golden/dpa_vectors.npz` holds seeded operand codes and golden-model
outputs for every (fmt_ab, fmt_acc, N) mode (generated — and verified
against the exact big-int oracle — by `tests/golden/
generate_dpa_vectors.py`).  Replaying them catches silent numerics drift
from JAX / ml_dtypes / XLA upgrades that the property suite, which
regenerates both sides on every run, structurally cannot: if the model and
its test inputs drift *together*, only a pinned file notices.

A mismatch here is a numerics break in `repro.core.dpa` (or an intended
contract change — in which case regenerate the vectors and flag the diff
in review).
"""
import os

import numpy as np
import pytest

from repro.core import dpa, formats as F

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "dpa_vectors.npz")
MODES = [("fp16", "fp32", 2), ("fp8_e4m3", "fp32", 4),
         ("fp4_e2m1", "fp32", 8), ("fp32", "fp32", 1),
         ("fp16", "fp16", 2), ("fp8_e4m3", "fp16", 4)]


@pytest.fixture(scope="module")
def vectors():
    assert os.path.exists(GOLDEN), (
        f"{GOLDEN} missing — run PYTHONPATH=src python "
        f"tests/golden/generate_dpa_vectors.py")
    return np.load(GOLDEN)


def _replay(vectors, tag, fmt_ab, fmt_acc):
    a = vectors[f"{tag}__a"]
    b = vectors[f"{tag}__b"]
    c = vectors[f"{tag}__c"]
    want = vectors[f"{tag}__out"]
    got = np.asarray(dpa.dpa_codes(a, b, c, F.get_format(fmt_ab),
                                   F.get_format(fmt_acc)))
    mism = got != want
    assert not mism.any(), (
        f"{tag}: {mism.sum()}/{mism.size} lanes drifted from the golden "
        f"vectors; first: a={a[mism][0]} b={b[mism][0]} "
        f"c={c[mism.reshape(c.shape)][0] if c.shape == mism.shape else '?'} "
        f"got={hex(int(got[mism][0]))} want={hex(int(want[mism][0]))}")


@pytest.mark.parametrize("fmt_ab,fmt_acc,n", MODES,
                         ids=[f"{a}x{n}to{c}" for a, c, n in MODES])
def test_golden_replay_finite(vectors, fmt_ab, fmt_acc, n):
    _replay(vectors, f"{fmt_ab}_x{n}_{fmt_acc}_finite", fmt_ab, fmt_acc)


@pytest.mark.parametrize("fmt_ab,fmt_acc,n", MODES,
                         ids=[f"{a}x{n}to{c}" for a, c, n in MODES])
def test_golden_replay_specials(vectors, fmt_ab, fmt_acc, n):
    """Full-code-space batches (NaN/Inf codes included) replay bit-for-bit
    — NaN encodings are pinned too, not just NaN-ness."""
    tag = f"{fmt_ab}_x{n}_{fmt_acc}_specials"
    if f"{tag}__a" not in vectors:
        pytest.skip("mode has no specials batch")
    _replay(vectors, tag, fmt_ab, fmt_acc)


def test_golden_file_covers_all_modes(vectors):
    names = set(vectors.files)
    for fmt_ab, fmt_acc, n in MODES:
        assert f"{fmt_ab}_x{n}_{fmt_acc}_finite__out" in names, (fmt_ab, n)


def test_golden_vectors_reproduce():
    """Regenerating the vectors with the current stack is bit-identical
    to the checked-in npz (shared with CI's golden job —
    `tests/golden/check_reproducible.py` is the single implementation)."""
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "golden"))
    import check_reproducible
    assert check_reproducible.check() > 0
