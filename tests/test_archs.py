"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step on CPU, asserting output shapes + no NaNs, plus a
prefill->decode consistency probe."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_archs, reduce_config
from repro.distributed.step import make_train_step
from repro.models import build_model
from repro.optim import adamw

B, S = 2, 32


def _batch(cfg, key):
    b = {}
    if cfg.family == "encdec":
        b["frames"] = jax.random.normal(key, (B, 8, cfg.d_model),
                                        jnp.float32)
        b["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    elif cfg.frontend == "stub":
        b["embeddings"] = jax.random.normal(key, (B, S, cfg.d_model),
                                            jnp.float32)
    else:
        b["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    b["labels"] = jax.random.randint(jax.random.fold_in(key, 1), (B, S),
                                     0, cfg.vocab_size)
    return b


@pytest.mark.parametrize("arch", list_archs())
def test_arch_smoke_train_step(arch):
    cfg = reduce_config(get_config(arch))
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = _batch(cfg, key)

    logits, aux = model.train_logits(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size), arch
    assert bool(jnp.isfinite(logits).all()), arch

    state = {"params": params, "opt": adamw.init(params)}
    step = jax.jit(make_train_step(model, adamw.AdamWConfig(lr=1e-3,
                                                            total_steps=10)))
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"])), arch
    assert np.isfinite(float(metrics["grad_norm"])), arch
    # params actually changed
    delta = sum(float(jnp.abs(a - b).sum()) for a, b in zip(
        jax.tree.leaves(state["params"]), jax.tree.leaves(params)))
    assert delta > 0, arch


@pytest.mark.parametrize("arch", list_archs())
def test_arch_smoke_decode_step(arch):
    cfg = reduce_config(get_config(arch))
    model = build_model(cfg)
    key = jax.random.PRNGKey(1)
    params = model.init(key)
    caches = model.init_caches(B, 16)
    batch = {"tokens": jnp.ones((B, 1), jnp.int32), "index": jnp.int32(3)}
    if cfg.family == "encdec":
        batch["enc_out"] = jnp.zeros((B, 8, cfg.d_model), jnp.float32)
    logits, caches2 = model.decode_step(params, batch, caches)
    assert logits.shape == (B, 1, cfg.vocab_size), arch
    assert bool(jnp.isfinite(logits).all()), arch
    # caches structurally preserved
    assert jax.tree.structure(caches) == jax.tree.structure(caches2), arch


@pytest.mark.parametrize("arch", ["qwen2-72b", "recurrentgemma-9b",
                                  "xlstm-1.3b", "granite-moe-1b-a400m"])
def test_decode_matches_train(arch):
    """Teacher-forced decode must reproduce the train-time logits."""
    # policy=fp32: dynamic per-tensor activation scales legitimately
    # differ between full-sequence and single-token batches, so the
    # cache/state equivalence is tested on the unquantized path.
    # capacity_factor=8: MoE capacity drops hit full sequences but never
    # single-token decode — also a legitimate train/serve asymmetry.
    cfg = reduce_config(get_config(arch)).replace(policy="fp32",
                                                  capacity_factor=8.0)
    if cfg.frontend == "stub" or cfg.family == "encdec":
        pytest.skip("token-in archs only")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, 16), 0,
                              cfg.vocab_size)
    full, _ = model.train_logits(params, {"tokens": toks})
    caches = model.init_caches(B, 16)
    errs = []
    for t in range(16):
        lg, caches = model.decode_step(
            params, {"tokens": toks[:, t:t + 1], "index": jnp.int32(t)},
            caches)
        errs.append(float(jnp.abs(lg[:, 0] - full[:, t]).max()))
    assert max(errs) < 2e-4, (arch, max(errs))


def test_exact_paper_configs_structural():
    """Full (non-reduced) configs build their param STRUCTURE (eval_shape
    only) with the exact assigned dimensions."""
    expect = {
        "qwen2-72b": dict(n_layers=80, d_model=8192, n_heads=64,
                          n_kv_heads=8, d_ff=29568, vocab_size=152064),
        "deepseek-67b": dict(n_layers=95, d_model=8192, d_ff=22016,
                             vocab_size=102400),
        "qwen3-4b": dict(n_layers=36, d_model=2560, qk_norm=True),
        "llama3.2-3b": dict(n_layers=28, d_model=3072, n_heads=24),
        "pixtral-12b": dict(n_layers=40, d_model=5120, d_ff=14336),
        "whisper-medium": dict(n_layers=24, d_model=1024, d_ff=4096,
                               vocab_size=51865),
        "recurrentgemma-9b": dict(n_layers=38, d_model=4096, window=2048),
        "granite-moe-1b-a400m": dict(n_experts=32, top_k=8, d_ff=512),
        "dbrx-132b": dict(n_experts=16, top_k=4, d_model=6144),
        "xlstm-1.3b": dict(n_layers=48, d_model=2048, d_ff=0),
    }
    for arch, fields in expect.items():
        cfg = get_config(arch)
        for k, v in fields.items():
            assert getattr(cfg, k) == v, (arch, k)
        model = build_model(cfg)
        shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        n = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes))
        # within 2% of the config-level estimate
        assert abs(n - cfg.n_params) / cfg.n_params < 0.02, (
            arch, n, cfg.n_params)
