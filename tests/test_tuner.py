"""Autotuner selection-invariance + measurement-DB contracts.

The tuner (`repro.runtime.tuner`) may only *reorder* the exec-plan's
resolution among routes whose reference pins already pass — a tuned DB
is a performance table, never a numerics change.  Pinned here:

  1. Selection invariance: for every config the sweep could measure,
     forcing it through a DB yields outputs within the forced route's
     pinned tolerance of the family reference; for the bit-pinned ops
     (paged_decode, verify_attn) and the greedy engine (spec + prefix
     paths), tuning on vs off is bit-identical.
  2. Every failure mode of the consult degrades to the static prior:
     unknown routes, out-of-family records, env-ineligible routes,
     corrupt DBs — warn, never crash, never change numerics.
  3. The measurement DB: content hashes are key-order/whitespace
     stable, measured configs are skipped on re-run, hash-sharding
     partitions the space exactly once, corrupt records are dropped.
  4. The bytes-moved models the tuner uses as its untuned prior match
     the actual arrays the routes move (anti-drift).
  5. `synthetic_workload` is seed-deterministic — the engine-level
     cutouts depend on it for reproducible measurements.
"""
import dataclasses
import json
import os

import numpy as np
import pytest

import jax

from repro.core import exec_plan
from repro.core import kvcache as KV
from repro.core.policy import get_policy
from repro.runtime import tuner


@pytest.fixture(autouse=True)
def _tuner_isolation(monkeypatch):
    """Each test starts with no tuned DB wired and cold tuner caches."""
    monkeypatch.delenv("REPRO_TUNED_DB", raising=False)
    monkeypatch.delenv("REPRO_TUNED", raising=False)
    tuner.clear_caches()
    yield
    tuner.clear_caches()


def _cfg(op, policy, cls, route, knobs=None):
    return {"op": op, "policy": policy,
            "policy_key": tuner.policy_key(get_policy(policy)),
            "shape_class": cls, "route": route, "knobs": dict(knobs or {}),
            **tuner.env_fingerprint()}


def _write_db(path, cfgs, us=1.0):
    records = {tuner.config_hash(c): {**c, "us": us, "reps": 1}
               for c in cfgs}
    tuner.save_db(str(path), {"version": 1, "meta": {},
                              "records": records})
    return str(path)


def _rel_err(a, b):
    a, b = np.asarray(a, np.float64), np.asarray(b, np.float64)
    return float(np.max(np.abs(a - b)) / max(1e-6, np.max(np.abs(b))))


def _leaves(out):
    return [np.asarray(x, np.float64)
            for x in jax.tree_util.tree_leaves(out)]


# -----------------------------------------------------------------------------
# 1. selection invariance
# -----------------------------------------------------------------------------

def _forceable_configs():
    """One config per (op, class, route, knob-combo) of the smoke space
    under each op's first CI policy (the full sweep repeats per
    policy; one policy per op keeps the property test tractable)."""
    return [c for c in tuner.enumerate_space(smoke=True)
            if c["op"] != tuner.ENGINE_OP
            and c["policy"] == tuner.OP_POLICIES[c["op"]][0]]


def test_tuned_only_reorders_within_reference_family(tmp_path, monkeypatch):
    """Force every measurable config through a single-record DB: the
    resolution must pick exactly that route, and its output must sit
    within the route's pinned tolerance of the family reference — i.e.
    any tuned table keeps the plan's numerics contract."""
    configs = _forceable_configs()
    assert configs, "smoke space is empty?"
    for cfg in configs:
        sc = tuner.shape_class(cfg["op"], cfg["shape_class"])
        pol = get_policy(cfg["policy"])
        db = _write_db(tmp_path / "force.json", [cfg])
        monkeypatch.setenv("REPRO_TUNED_DB", db)
        tuner.clear_caches()
        entry = exec_plan.resolve(cfg["op"], pol, **sc.rep)
        assert entry.name == cfg["route"], cfg
        assert entry.tuned and entry.tuned_class == cfg["shape_class"]
        base = exec_plan.route(cfg["op"], cfg["route"])
        ref = exec_plan.reference_entry(base) or base
        args, kwargs = tuner._cutout(cfg["op"], cfg["shape_class"], pol)
        got = _leaves(entry.run(*args, **kwargs))
        want = _leaves(ref.run(*args, **kwargs))
        assert len(got) == len(want), cfg
        for g, w in zip(got, want):
            if base.tol == 0.0:
                assert np.array_equal(g, w), cfg
            else:
                assert _rel_err(g, w) <= base.tol + 5e-6, cfg


@pytest.mark.parametrize("op,cls", [("paged_decode", "paged_single"),
                                    ("verify_attn", "verify_paged")])
def test_bit_pinned_ops_identical_tuned_vs_off(op, cls, tmp_path,
                                               monkeypatch):
    """The bit-pinned ops: any in-family tuned selection is
    bit-identical to the untuned prior's output, not merely close."""
    pol = get_policy("kv4_attn8_packed")
    sc = tuner.shape_class(op, cls)
    args, kwargs = tuner._cutout(op, cls, pol)
    static = exec_plan.resolve(op, pol, **sc.rep)
    want = np.asarray(static.run(*args, **kwargs))
    db = _write_db(tmp_path / "db.json",
                   [_cfg(op, "kv4_attn8_packed", cls, "jnp_gather")])
    monkeypatch.setenv("REPRO_TUNED_DB", db)
    tuner.clear_caches()
    entry = exec_plan.resolve(op, pol, **sc.rep)
    assert entry.tuned and entry.name == "jnp_gather"
    assert np.array_equal(np.asarray(entry.run(*args, **kwargs)), want)


def test_engine_greedy_bit_identical_tuned_vs_off(tmp_path, monkeypatch):
    """Greedy engine outputs token-for-token equal with tuning on vs
    off, across the decode + speculative-verify + prefix-cache paths —
    an adversarial DB can only pick bit-pinned alternatives there."""
    from repro.configs import get_config, reduce_config
    from repro.launch.engine import (Engine, EngineConfig, SpecConfig,
                                     synthetic_workload)
    from repro.models import build_model

    cfg = reduce_config(get_config("qwen3-4b")).replace(
        policy="kv4_attn8_packed")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ecfg = EngineConfig(page_size=8, n_pages=48, max_batch=3,
                        max_pages_per_req=6, token_budget=16,
                        prefill_chunk=8, prefix_cache=True)
    spec = SpecConfig("w4a4_kv4_attn4", k=2)

    def serve(db_path):
        if db_path:
            monkeypatch.setenv("REPRO_TUNED_DB", db_path)
        else:
            monkeypatch.delenv("REPRO_TUNED_DB", raising=False)
        tuner.clear_caches()
        engine = Engine(model, params, ecfg, spec=spec)
        engine.run(synthetic_workload(
            5, vocab=cfg.vocab_size, seed=0, prompt_range=(8, 20),
            gen_range=(4, 8), shared_prefix=8))
        return {r.rid: list(r.out_tokens) for r in engine.finished}

    baseline = serve(None)
    # the adversarial table: push both paged ops onto their references
    db = _write_db(tmp_path / "adv.json", [
        _cfg("paged_decode", "kv4_attn8_packed", "paged_single",
             "jnp_gather"),
        _cfg("verify_attn", "kv4_attn8_packed", "verify_paged",
             "jnp_gather")])
    tuned = serve(db)
    assert tuned == baseline
    assert baseline, "no requests finished?"


def test_tuned_resolve_deterministic_and_describe(tmp_path, monkeypatch):
    pol = get_policy("kv4_attn8_packed")
    sc = tuner.shape_class("paged_decode", "paged_single")
    prior = exec_plan.resolve("paged_decode", pol, **sc.rep)
    assert not prior.tuned
    assert prior.describe(pol, sc.rep)["selection"] == "prior"
    db = _write_db(tmp_path / "db.json",
                   [_cfg("paged_decode", "kv4_attn8_packed",
                         "paged_single", "jnp_gather")])
    monkeypatch.setenv("REPRO_TUNED_DB", db)
    tuner.clear_caches()
    first = exec_plan.resolve("paged_decode", pol, **sc.rep)
    for _ in range(3):        # identical object, not merely equal
        assert exec_plan.resolve("paged_decode", pol, **sc.rep) is first
    d = exec_plan.describe("paged_decode", pol, **sc.rep)
    assert d["selection"] == "tuned"
    assert d["shape_class"] == "paged_single"
    assert d["tuned_knobs"] == {}
    # kill switch restores the prior without touching the DB
    monkeypatch.setenv("REPRO_TUNED", "0")
    assert exec_plan.resolve("paged_decode", pol, **sc.rep) is prior


def test_tuned_ineligible_route_falls_back(tmp_path, monkeypatch):
    """A tuned route the live env disables (REPRO_PAGED_KERNEL=0) must
    fall back to the prior — and come back once re-enabled."""
    pol = get_policy("kv4_attn8_packed")
    sc = tuner.shape_class("paged_decode", "paged_single")
    db = _write_db(tmp_path / "db.json",
                   [_cfg("paged_decode", "kv4_attn8_packed",
                         "paged_single", "pallas_block_table")])
    monkeypatch.setenv("REPRO_TUNED_DB", db)
    monkeypatch.setenv("REPRO_PAGED_KERNEL", "0")
    tuner.clear_caches()
    assert exec_plan.resolve("paged_decode", pol, **sc.rep).name \
        == "jnp_gather"
    monkeypatch.delenv("REPRO_PAGED_KERNEL")
    entry = exec_plan.resolve("paged_decode", pol, **sc.rep)
    assert entry.name == "pallas_block_table" and entry.tuned


def test_tuned_unknown_route_warns_and_falls_back(tmp_path, monkeypatch):
    pol = get_policy("kv4_attn8_packed")
    sc = tuner.shape_class("paged_decode", "paged_single")
    db = _write_db(tmp_path / "db.json",
                   [_cfg("paged_decode", "kv4_attn8_packed",
                         "paged_single", "no_such_kernel")])
    monkeypatch.setenv("REPRO_TUNED_DB", db)
    tuner.clear_caches()
    with pytest.warns(UserWarning, match="unknown route"):
        entry = exec_plan.resolve("paged_decode", pol, **sc.rep)
    assert entry.name == "pallas_block_table" and not entry.tuned


def test_out_of_family_record_never_selected(tmp_path, monkeypatch):
    """xla_f32 is eligible under any policy but shares no reference
    with the DPA family — a DB naming it must not flip an fp8 resolve
    onto the unquantized path."""
    pol = get_policy("fp8_dpa_fused")
    sc = tuner.shape_class("matmul", "gemm_decode")
    db = _write_db(tmp_path / "db.json",
                   [_cfg("matmul", "fp8_dpa_fused", "gemm_decode",
                         "xla_f32")])
    monkeypatch.setenv("REPRO_TUNED_DB", db)
    tuner.clear_caches()
    with pytest.warns(UserWarning, match="reference family"):
        entry = exec_plan.resolve("matmul", pol, **sc.rep)
    assert entry.name == "pallas_fused" and not entry.tuned


# -----------------------------------------------------------------------------
# 2. measurement DB
# -----------------------------------------------------------------------------

def test_config_hash_stable_across_key_order_and_whitespace():
    cfg = _cfg("matmul", "fp8_dpa_fused", "gemm_decode", "pallas_fused",
               {"bm": 32, "bk": 64})
    h = tuner.config_hash(cfg)
    shuffled = {k: cfg[k] for k in reversed(list(cfg))}
    assert tuner.config_hash(shuffled) == h
    assert tuner.config_hash(json.dumps(shuffled, indent=4)) == h
    assert tuner.config_hash(
        dict(cfg, knobs={"bk": 64, "bm": 32})) == h
    # ... and sensitive to what it must be sensitive to
    assert tuner.config_hash(dict(cfg, knobs={"bm": 64})) != h
    assert tuner.config_hash(dict(cfg, route="pallas_prequant")) != h
    assert tuner.config_hash(dict(cfg, jax_version="other")) != h


def test_sweep_skips_measured_and_shards_cover_space_once(tmp_path):
    """Two-shard sweep over the quantize_pack slice: the shards measure
    disjoint halves summing to the space, and a re-run measures 0."""
    db = str(tmp_path / "sweep.json")
    space = tuner.enumerate_space(smoke=True, ops=["quantize_pack"])
    hashes = [tuner.config_hash(c) for c in space]
    assert len(set(hashes)) == len(hashes)
    for n in (2, 3, 5):        # partition: every config in exactly one shard
        assert sorted(h for i in range(n) for h in hashes
                      if tuner.shard_of(h, n) == i) == sorted(hashes)
    s0 = tuner.run_sweep(db, smoke=True, shard=(0, 2), reps=1,
                         ops=["quantize_pack"])
    s1 = tuner.run_sweep(db, smoke=True, shard=(1, 2), reps=1,
                         ops=["quantize_pack"])
    assert s0["measured"] + s1["measured"] == len(space)
    assert s0["measured"] == s1["other_shard"]
    missing = tuner.missing_configs(db, smoke=True)
    assert not any(c["op"] == "quantize_pack" for c in missing)
    again = tuner.run_sweep(db, smoke=True, shard=(0, 1), reps=1,
                            ops=["quantize_pack"])
    assert again["measured"] == 0
    assert again["skipped"] == len(space)


def test_corrupt_and_partial_db_entries_ignored(tmp_path, monkeypatch):
    pol = get_policy("kv4_attn8_packed")
    sc = tuner.shape_class("paged_decode", "paged_single")
    good = _cfg("paged_decode", "kv4_attn8_packed", "paged_single",
                "jnp_gather")
    records = {
        tuner.config_hash(good): {**good, "us": 1.0, "reps": 1},
        "deadbeefdeadbeef": {"op": "paged_decode"},          # partial
        "feedfacefeedface": "not even a dict",               # corrupt
        "0123456789abcdef": {**good, "us": -3.0},            # bad value
    }
    path = tmp_path / "dirty.json"
    path.write_text(json.dumps({"version": 1, "records": records}))
    with pytest.warns(UserWarning, match="corrupt/partial"):
        db = tuner.load_db(str(path))
    assert list(db["records"]) == [tuner.config_hash(good)]
    monkeypatch.setenv("REPRO_TUNED_DB", str(path))
    tuner.clear_caches()
    entry = exec_plan.resolve("paged_decode", pol, **sc.rep)
    assert entry.tuned and entry.name == "jnp_gather"
    # a DB that is not JSON at all: warn, resolve on the prior
    path.write_text("{definitely not json")
    tuner.clear_caches()
    with pytest.warns(UserWarning, match="unreadable"):
        entry = exec_plan.resolve("paged_decode", pol, **sc.rep)
    assert not entry.tuned


def test_save_db_is_atomic_and_loadable(tmp_path):
    path = tmp_path / "nested" / "db.json"
    tuner.save_db(str(path), {"meta": {"backend": "cpu"},
                              "records": {"ab": {
                                  "op": "matmul", "policy_key": "x",
                                  "shape_class": "c", "route": "r",
                                  "us": 2.0}}})
    db = tuner.load_db(str(path))
    assert db["records"]["ab"]["us"] == 2.0
    assert not os.path.exists(str(path) + ".tmp")


# -----------------------------------------------------------------------------
# 3. plan-table contract checks (tools/plan_table.py --check)
# -----------------------------------------------------------------------------

def _plan_table():
    import importlib.util
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    spec = importlib.util.spec_from_file_location(
        "plan_table", os.path.join(root, "tools", "plan_table.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_plan_table_flags_undeclared_and_ungridded_knobs():
    pt = _plan_table()

    def run_with_knob(x, *, bm=128):
        return x

    entry = exec_plan.PlanEntry(
        op="fake", name="r", backend="xla", run=run_with_knob,
        predicate=lambda policy, ctx: {})
    errs = pt._knob_errors(entry)
    assert any("does not declare" in e for e in errs)
    declared = dataclasses.replace(entry, knobs=("bm",))
    assert pt._knob_errors(declared) == []
    ungridded = dataclasses.replace(entry, knobs=("no_such_knob",))
    assert any("no grid" in e for e in pt._knob_errors(ungridded))


def test_plan_table_flags_stale_tuned_defaults(tmp_path, monkeypatch):
    pt = _plan_table()
    d = tmp_path / "benchmarks" / "tuned"
    d.mkdir(parents=True)
    bad = _cfg("paged_decode", "kv4_attn8_packed", "paged_single",
               "route_that_got_deleted")
    (d / "stale.json").write_text(json.dumps(
        {"version": 1,
         "records": {tuner.config_hash(bad): {**bad, "us": 1.0}}}))
    monkeypatch.setattr(pt, "ROOT", str(tmp_path))
    errs = pt._tuned_defaults_errors()
    assert any("nonexistent route" in e for e in errs)
    # a hand-edited record whose key no longer matches its content
    good = _cfg("paged_decode", "kv4_attn8_packed", "paged_single",
                "jnp_gather")
    (d / "stale.json").write_text(json.dumps(
        {"version": 1, "records": {"0" * 16: {**good, "us": 1.0}}}))
    errs = pt._tuned_defaults_errors()
    assert any("content hash" in e for e in errs)


def test_shipped_tuned_defaults_are_valid():
    """The DBs under benchmarks/tuned/ pass the CI integrity check and
    cover the whole smoke space (the tune --smoke lane's contract)."""
    pt = _plan_table()
    assert pt._tuned_defaults_errors() == []
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    db = os.path.join(root, "benchmarks", "tuned", "ci_default.json")
    assert os.path.exists(db)
    assert tuner.missing_configs(db, smoke=True) == []


# -----------------------------------------------------------------------------
# 4. bytes-model anti-drift
# -----------------------------------------------------------------------------

def _view_bytes(cache):
    view = KV.gather_paged_kv(cache)
    return sum(np.asarray(view[k]).nbytes for k in KV.QUANT_KEYS)


def _pool_bytes(cache):
    return sum(np.asarray(cache[k]).nbytes for k in KV.QUANT_KEYS)


def _paged_fixture(pol, B=2, ps=8, mp=3, n_kv=2, hd=16):
    S = mp * ps
    ks = jax.random.split(jax.random.PRNGKey(7), 2)
    k = jax.random.normal(ks[0], (B, S, n_kv, hd))
    v = jax.random.normal(ks[1], (B, S, n_kv, hd))
    ref = KV.update_kv_cache(
        KV.init_kv_cache(B, S, n_kv, hd, fmt=pol.fmt_kv,
                         packed=pol.kv_packed),
        k, v, 0, fmt=pol.fmt_kv, packed=pol.kv_packed)
    cache = KV.paged_from_contiguous(ref, [S] * B, page_size=ps)
    ctx = dict(batch=B, page_size=ps, max_pages=mp, kv_heads=n_kv, hd=hd,
               n_pages=int(cache["k_codes"].shape[0]))
    return cache, ref, ctx


def _matmul_actual(pol, m, k, n):
    from repro.core.packing import pack_fp4_axis
    from repro.kernels.ops import _quant_operand
    ks = jax.random.split(jax.random.PRNGKey(11), 2)
    x = jax.random.normal(ks[0], (m, k))
    w = jax.random.normal(ks[1], (k, n))
    xq, _ = _quant_operand(x, pol.fmt_acts, axis_scale=-1)
    wq, _ = _quant_operand(w, pol.fmt_weights, axis_scale=0)
    if pol.packed and pol.fmt_acts == "fp4_e2m1":
        xq = pack_fp4_axis(xq, 1)
    if pol.packed and pol.fmt_weights == "fp4_e2m1":
        wq = pack_fp4_axis(wq, 0)
    return np.asarray(xq).nbytes + np.asarray(wq).nbytes


@pytest.mark.parametrize("preset", ["fp8_dpa_fused", "fp4_dpa_packed"])
def test_bytes_model_matmul_matches_nbytes(preset):
    pol = get_policy(preset)
    m, k, n = 32, 64, 48
    ctx = dict(m=m, k=k, n=n)
    actual = _matmul_actual(pol, m, k, n)
    for route in ("pallas_fused", "pallas_prequant"):
        model = exec_plan.route("matmul", route).bytes_moved(pol, ctx)
        assert 0.5 <= model / actual <= 2.0, (route, model, actual)


def _grouped_actual(pol, e, m, k, n, *, packed):
    from repro.core.packing import pack_fp4_axis
    from repro.kernels.ops import _quant_operand
    ks = jax.random.split(jax.random.PRNGKey(12), 2)
    x = jax.random.normal(ks[0], (e, m, k))
    w = jax.random.normal(ks[1], (e, k, n))
    xq, _ = _quant_operand(x, pol.fmt_acts, axis_scale=-1)
    wq, _ = _quant_operand(w, pol.fmt_weights, axis_scale=1)
    if packed and pol.packed and pol.fmt_acts == "fp4_e2m1":
        xq = pack_fp4_axis(xq, 2)
    if packed and pol.packed and pol.fmt_weights == "fp4_e2m1":
        wq = pack_fp4_axis(wq, 1)
    return np.asarray(xq).nbytes + np.asarray(wq).nbytes


@pytest.mark.parametrize("preset", ["fp8_dpa_fused", "fp4_dpa_packed"])
def test_bytes_model_grouped_matmul_matches_nbytes(preset):
    """Declared grouped bytes vs the real quantized (and, for the kernel
    routes, packed) operand stacks' nbytes — within 2x, every grouped
    route that declares a model."""
    pol = get_policy(preset)
    e, m, k, n = 4, 16, 64, 48
    ctx = dict(e=e, m=m, k=k, n=n, eq="gti,gio->gto",
               w_dtype="float32")
    actual = _grouped_actual(pol, e, m, k, n, packed=True)
    for route in ("pallas_grouped_fused", "pallas_grouped_prequant"):
        model = exec_plan.route("grouped_matmul", route).bytes_moved(pol,
                                                                     ctx)
        assert 0.5 <= model / actual <= 2.0, (route, model, actual)
    # the wide routes traverse both stacks at f32 width
    wide = 4 * (e * m * k + e * k * n)
    for route in ("xla_fake_quant", "xla_f32"):
        model = exec_plan.route("grouped_matmul", route).bytes_moved(pol,
                                                                     ctx)
        assert 0.5 <= model / wide <= 2.0, (route, model, wide)
    # native-narrow: format width, never packed
    narrow = _grouped_actual(pol, e, m, k, n, packed=False)
    model = exec_plan.route("grouped_matmul",
                            "xla_native_narrow").bytes_moved(pol, ctx)
    assert 0.5 <= model / narrow <= 2.0, (model, narrow)


def test_bytes_model_paged_ops_match_nbytes():
    """The paged-op models = (declared pass count) x (view rows at the
    cache's format width): recompute against the real gathered-view and
    pool arrays' nbytes."""
    pol = get_policy("kv4_attn8_packed")
    cache, _, ctx = _paged_fixture(pol)
    view = _view_bytes(cache)
    pool = _pool_bytes(cache)
    cases = [
        ("paged_decode", "pallas_block_table", dict(ctx), 1 * view),
        ("paged_decode", "jnp_gather", dict(ctx), 3 * view),
        ("verify_attn", "jnp_gather", dict(ctx, sq=4), (3 + 2 * 4) * view),
        ("paged_decode", "paged_decode_sharded", dict(ctx, n_devices=2),
         3 * view + pool // 2),
        ("verify_attn", "verify_attn_sharded",
         dict(ctx, sq=4, n_devices=2), (3 + 2 * 4) * view + pool // 2),
    ]
    for op, route, c, actual in cases:
        model = exec_plan.route(op, route).bytes_moved(pol, c)
        assert 0.5 <= model / actual <= 2.0, (op, route, model, actual)


def test_bytes_model_decode_attn_matches_nbytes():
    pol = get_policy("kv4_attn8_packed")
    B, S, n_kv, hd = 2, 16, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(9), 2)
    ref = KV.update_kv_cache(
        KV.init_kv_cache(B, S, n_kv, hd, fmt=pol.fmt_kv,
                         packed=pol.kv_packed),
        jax.random.normal(ks[0], (B, S, n_kv, hd)),
        jax.random.normal(ks[1], (B, S, n_kv, hd)),
        0, fmt=pol.fmt_kv, packed=pol.kv_packed)
    actual = sum(np.asarray(ref[k]).nbytes for k in KV.QUANT_KEYS)
    model = exec_plan.route("decode_attn", "xla_dpa_decode").bytes_moved(
        pol, dict(batch=B, s_ctx=S, kv_heads=n_kv, hd=hd))
    assert 0.5 <= model / actual <= 2.0, (model, actual)


def test_bytes_model_allreduce_and_unembed_match_nbytes():
    from repro.distributed.collectives import quantize_for_wire
    pol = get_policy("fp32")
    size = 4096
    grad = jax.random.normal(jax.random.PRNGKey(13), (size,))
    q, scale = quantize_for_wire(grad, "fp8_e4m3")
    actual_wire = np.asarray(q).nbytes + np.asarray(scale).nbytes
    model = exec_plan.route("allreduce", "wire_compressed").bytes_moved(
        pol, dict(size=size, wire_fmt="fp8_e4m3", n_devices=2))
    assert 0.5 <= model / actual_wire <= 2.0
    model = exec_plan.route("allreduce", "xla_psum_f32").bytes_moved(
        pol, dict(size=size))
    assert model == np.asarray(grad, np.float32).nbytes
    # unembed: 4 bytes per f32 logit
    B, S, D, V = 1, 4, 32, 64
    x = jax.random.normal(jax.random.PRNGKey(17), (B, S, D))
    table = jax.random.normal(jax.random.PRNGKey(19), (V, D))
    out = exec_plan.route("unembed", "xla_tied_table").run(x, table, pol)
    model = exec_plan.route("unembed", "xla_tied_table").bytes_moved(
        pol, dict(size=S * V))
    assert model == np.asarray(out).nbytes / B


def test_every_bytes_model_covered():
    """Anti-drift completeness: a new route with a bytes model must be
    added to the coverage above (this test names the current set)."""
    have = {(e.op, e.name) for op in exec_plan.ops()
            for e in exec_plan.candidates(op) if e.bytes_moved}
    covered = {("matmul", "pallas_fused"), ("matmul", "pallas_prequant"),
               ("grouped_matmul", "pallas_grouped_fused"),
               ("grouped_matmul", "pallas_grouped_prequant"),
               ("grouped_matmul", "xla_native_narrow"),
               ("grouped_matmul", "xla_fake_quant"),
               ("grouped_matmul", "xla_f32"),
               ("decode_attn", "xla_dpa_decode"),
               ("paged_decode", "pallas_block_table"),
               ("paged_decode", "jnp_gather"),
               ("paged_decode", "paged_decode_sharded"),
               ("verify_attn", "jnp_gather"),
               ("verify_attn", "verify_attn_sharded"),
               ("allreduce", "wire_compressed"),
               ("allreduce", "xla_psum_f32"),
               ("unembed", "xla_tied_table")}
    assert have == covered, have.symmetric_difference(covered)


# -----------------------------------------------------------------------------
# 5. workload seed determinism (the engine cutout's substrate)
# -----------------------------------------------------------------------------

def test_synthetic_workload_seed_determinism():
    from repro.launch.engine import synthetic_workload
    kw = dict(vocab=211, rate=2.0, prompt_range=(4, 12), gen_range=(2, 6),
              shared_prefix=4)
    a = synthetic_workload(8, seed=5, **kw)
    b = synthetic_workload(8, seed=5, **kw)
    assert len(a) == len(b) == 8
    for ra, rb in zip(a, b):
        assert ra.rid == rb.rid
        assert np.array_equal(ra.prompt, rb.prompt)
        assert ra.max_new == rb.max_new
        assert ra.arrival == rb.arrival
    c = synthetic_workload(8, seed=6, **kw)
    assert any(not np.array_equal(ra.prompt, rc.prompt)
               or ra.arrival != rc.arrival for ra, rc in zip(a, c))
