"""Sampler conformance: batch-composition independence + edge cases.

The serving sampler's two contracts:

  1. Per-request determinism — a token draw depends only on (seed,
     request id, token index, role), never on which other requests share
     the batch, so continuous batching cannot change a request's output.
  2. Greedy anchor — temperature 0 is raw-logits argmax bit-for-bit
     (the speculative-decoding exactness story hangs off this).

Plus the filter edge cases: top-p mass landing exactly on a cumulative
step, top-k=1, ties at the k-th logit, and NaN/-inf masked vocabularies.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.serving import sampler as S
from repro.serving.sampler import SamplerConfig


def _logits(seed=0, b=4, v=64):
    return jax.random.normal(jax.random.PRNGKey(seed), (b, v)) * 3.0


# -----------------------------------------------------------------------------
# per-request determinism: independent of batch composition
# -----------------------------------------------------------------------------

def test_sample_independent_of_batch_composition():
    """Row (rid, position) draws the same token whether it sits alone,
    first, last, or among different neighbors."""
    cfg = SamplerConfig(temperature=0.7, top_k=16, top_p=0.95, seed=5)
    logits = _logits(1, b=5, v=128)
    rids = jnp.asarray([3, 9, 4, 7, 11], jnp.int32)
    pos = jnp.asarray([2, 17, 5, 9, 1], jnp.int32)
    full = S.sample_tokens(logits, rids, pos, cfg)
    # alone
    for i in range(5):
        alone = S.sample_tokens(logits[i:i + 1], rids[i:i + 1],
                                pos[i:i + 1], cfg)
        assert int(alone[0]) == int(full[i]), i
    # permuted batch
    perm = jnp.asarray([4, 2, 0, 3, 1])
    shuffled = S.sample_tokens(logits[perm], rids[perm], pos[perm], cfg)
    assert np.array_equal(np.asarray(shuffled), np.asarray(full)[perm])


def test_streams_differ_across_rid_position_role():
    """Distinct (rid, position, role) tuples give distinct keys (a
    sanity check that the folds all participate)."""
    keys = {tuple(np.asarray(S.request_key(0, r, p, role)))
            for r in range(4) for p in range(4)
            for role in (S.ROLE_SAMPLE, S.ROLE_DRAFT, S.ROLE_ACCEPT,
                         S.ROLE_RESIDUAL)}
    assert len(keys) == 4 * 4 * 4


def test_seed_changes_tokens():
    logits = _logits(2, b=8, v=256)
    rids = jnp.arange(8, dtype=jnp.int32)
    pos = jnp.full((8,), 3, jnp.int32)
    a = S.sample_tokens(logits, rids, pos, SamplerConfig(temperature=1.0,
                                                         seed=0))
    b = S.sample_tokens(logits, rids, pos, SamplerConfig(temperature=1.0,
                                                         seed=1))
    assert not np.array_equal(np.asarray(a), np.asarray(b))


# -----------------------------------------------------------------------------
# greedy anchor
# -----------------------------------------------------------------------------

def test_temperature_zero_is_argmax_bit_for_bit():
    logits = _logits(3, b=6, v=300)
    rids = jnp.arange(6, dtype=jnp.int32)
    pos = jnp.arange(6, dtype=jnp.int32)
    got = S.sample_tokens(logits, rids, pos, SamplerConfig())
    want = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_tiny_temperature_peaked_logits_matches_greedy():
    """A strongly peaked distribution at low temperature samples the
    argmax with overwhelming probability — sanity for the t -> 0 limit."""
    logits = jnp.zeros((4, 32)).at[:, 7].set(50.0)
    rids = jnp.arange(4, dtype=jnp.int32)
    pos = jnp.arange(4, dtype=jnp.int32)
    got = S.sample_tokens(logits, rids, pos,
                          SamplerConfig(temperature=0.1, seed=3))
    assert np.all(np.asarray(got) == 7)


# -----------------------------------------------------------------------------
# filter edge cases
# -----------------------------------------------------------------------------

def test_top_k_one_keeps_only_argmax():
    cfg = SamplerConfig(temperature=1.0, top_k=1, seed=0)
    logits = _logits(4, b=3, v=50)
    probs = S.sample_probs(logits, cfg)
    am = np.asarray(jnp.argmax(logits, -1))
    p = np.asarray(probs)
    for i in range(3):
        assert p[i, am[i]] == pytest.approx(1.0)
        assert np.count_nonzero(p[i]) == 1
    toks = S.sample_tokens(logits, jnp.arange(3, dtype=jnp.int32),
                           jnp.arange(3, dtype=jnp.int32), cfg)
    assert np.array_equal(np.asarray(toks), am)


def test_top_k_ties_at_kth_value_all_kept():
    """Ties at the k-th largest logit are all kept (deterministic mask,
    no arbitrary index-order cut)."""
    logits = jnp.asarray([[4.0, 3.0, 3.0, 1.0, 0.0]])
    probs = S.sample_probs(logits, SamplerConfig(temperature=1.0, top_k=2))
    assert np.count_nonzero(np.asarray(probs)) == 3      # 4.0 + both 3.0s


def test_top_p_exactly_at_cumulative_step():
    """p landing exactly on a cumulative-mass boundary keeps exactly
    that prefix: probs (.5, .3, .2), p=0.8 -> the .2 token is cut."""
    logits = jnp.log(jnp.asarray([[0.5, 0.3, 0.2]]))
    probs = np.asarray(S.sample_probs(
        logits, SamplerConfig(temperature=1.0, top_p=0.8)))
    np.testing.assert_allclose(probs[0], [0.625, 0.375, 0.0], atol=1e-6)
    # nudging p past the boundary readmits the third token
    probs = np.asarray(S.sample_probs(
        logits, SamplerConfig(temperature=1.0, top_p=0.81)))
    assert probs[0, 2] > 0


def test_top_p_always_keeps_one_token():
    logits = jnp.asarray([[10.0, -5.0, -5.0, -5.0]])
    probs = np.asarray(S.sample_probs(
        logits, SamplerConfig(temperature=1.0, top_p=0.01)))
    assert probs[0, 0] == pytest.approx(1.0)


def test_all_masked_but_one_with_nan_and_inf():
    """NaN logits are masked; a vocabulary with one finite entry always
    samples it, greedy or not."""
    row = jnp.asarray([[-jnp.inf, jnp.nan, 2.5, -jnp.inf, jnp.nan]])
    rids = jnp.zeros((1,), jnp.int32)
    pos = jnp.zeros((1,), jnp.int32)
    for cfg in (SamplerConfig(),                       # greedy
                SamplerConfig(temperature=1.0, seed=2),
                SamplerConfig(temperature=0.5, top_k=3, top_p=0.9)):
        tok = S.sample_tokens(row, rids, pos, cfg)
        assert int(tok[0]) == 2, cfg
    probs = np.asarray(S.sample_probs(row, SamplerConfig(temperature=1.0)))
    np.testing.assert_allclose(probs[0], [0, 0, 1, 0, 0], atol=1e-7)


def test_top_k_at_or_above_vocab_disables_filter():
    """top_k == V and top_k > V keep every token — identical to top_k=0
    — instead of a static out-of-range sort index (crash)."""
    logits = _logits(7, b=3, v=16)
    off = S.sample_probs(logits, SamplerConfig(temperature=1.0, top_k=0))
    for k in (16, 17, 1000):
        got = S.sample_probs(logits,
                             SamplerConfig(temperature=1.0, top_k=k))
        assert np.array_equal(np.asarray(got), np.asarray(off)), k
    toks = S.sample_tokens(logits, jnp.arange(3, dtype=jnp.int32),
                           jnp.arange(3, dtype=jnp.int32),
                           SamplerConfig(temperature=0.8, top_k=16, seed=4))
    want = S.sample_tokens(logits, jnp.arange(3, dtype=jnp.int32),
                           jnp.arange(3, dtype=jnp.int32),
                           SamplerConfig(temperature=0.8, top_k=0, seed=4))
    assert np.array_equal(np.asarray(toks), np.asarray(want))


def test_all_nan_row_survives_every_filter():
    """A fully-dead row (every logit NaN/-inf) degenerates to token 0 —
    matching greedy's argmax-of-all-(-inf) — with finite one-hot probs,
    never NaN probabilities or an undefined categorical."""
    rows = jnp.stack([jnp.full((8,), jnp.nan),
                      jnp.full((8,), -jnp.inf),
                      jnp.zeros((8,)).at[5].set(3.0)])   # control row
    rids = jnp.zeros((3,), jnp.int32)
    pos = jnp.zeros((3,), jnp.int32)
    for cfg in (SamplerConfig(),                          # greedy
                SamplerConfig(temperature=1.0, seed=9),
                SamplerConfig(temperature=0.7, top_k=4, top_p=0.9),
                SamplerConfig(temperature=1.0, top_p=0.5)):
        toks = np.asarray(S.sample_tokens(rows, rids, pos, cfg))
        assert toks[0] == 0 and toks[1] == 0, cfg
        probs = np.asarray(S.sample_probs(rows, cfg))
        assert np.all(np.isfinite(probs)), cfg
        np.testing.assert_allclose(probs[0], np.eye(8)[0], atol=1e-7)
        np.testing.assert_allclose(probs[1], np.eye(8)[0], atol=1e-7)
        assert probs[2, 5] > 0                            # control intact


def test_config_validation():
    with pytest.raises(ValueError, match="temperature"):
        SamplerConfig(temperature=-0.1)
    with pytest.raises(ValueError, match="top_p"):
        SamplerConfig(top_p=0.0)
    with pytest.raises(ValueError, match="top_k"):
        SamplerConfig(top_k=-1)


def test_sample_probs_matches_categorical_frequencies():
    """The probs the rejection sampler compares are the distribution the
    categorical draw actually follows (coarse chi-square-free check)."""
    cfg = SamplerConfig(temperature=1.0, top_k=4, seed=11)
    logits = jnp.asarray([3.0, 2.0, 1.0, 0.5, -1.0, -2.0])
    probs = np.asarray(S.sample_probs(logits, cfg))
    draws = np.asarray(jax.vmap(
        lambda i: S.sample_tokens(logits[None], jnp.asarray([0]),
                                  i[None].astype(jnp.int32), cfg)[0])(
        jnp.arange(4000)))
    freq = np.bincount(draws, minlength=6) / 4000
    assert freq[4] == 0 and freq[5] == 0                 # top-k cut
    np.testing.assert_allclose(freq[:4], probs[:4], atol=0.03)
