"""The paper's headline claims must fall out of the hardware model."""
from repro.hwmodel import area as A
from repro.hwmodel import energy as E
from repro.hwmodel import throughput as T
from repro.hwmodel import timing as TM


def test_shifter_mux_counts():
    # §II-B1 closed forms
    assert A.barrel_shifter_muxes(128) == 128 * 7
    assert A.reconfig_extra_muxes(128) == 5 * 128 / 8 + 3 * 7 - 5


def test_shifter_overheads_match_paper():
    assert abs(A.reconfig_overhead(128) - 0.107) < 0.001
    assert abs(A.reconfig_overhead(64) - 0.138) < 0.001
    assert abs(A.multilane_overhead(128) - 0.785) < 0.005
    assert abs(A.multilane_overhead(64) - 0.750) < 0.001


def test_throughput_table1():
    """Table I/II: 1/2/4/8-way modes, 2..16 GFLOP/s at 1 GHz."""
    expect = {"fp32_fma_scalar": 2, "fp16_fma_simd": 4, "fp16_dpa_fp32": 4,
              "fp8_fma_simd": 8, "fp8_dpa_fp32": 8, "fp4_dpa_fp32": 16}
    for name, gf in expect.items():
        assert T.gflops(T.MODE_BY_NAME[name]) == gf, name


def test_dpa_throughput_gain_vs_fpnew():
    """Abstract: 2x FP16, 4x FP8, 8x FP4 throughput via DPA."""
    for name, gain in [("fp16_dpa_fp32", 2), ("fp8_dpa_fp32", 4),
                       ("fp4_dpa_fp32", 8)]:
        m = T.MODE_BY_NAME[name]
        assert T.gflops(m) / T.gflops(m, "fpnew") == gain, name


def test_area_efficiency_headline():
    """Abstract: 1.46x FP16 DPA, 2.92x FP8 DPA area efficiency at the
    mean +37.3% area cost."""
    assert abs(A.TRANSDOT_AREA_RATIO_MEAN - 1.373) < 1e-9
    eff16 = T.area_efficiency(T.MODE_BY_NAME["fp16_dpa_fp32"])
    eff8 = T.area_efficiency(T.MODE_BY_NAME["fp8_dpa_fp32"])
    assert abs(eff16 - 1.46) < 0.01
    assert abs(eff8 - 2.92) < 0.01


def test_area_efficiency_ranges():
    """Fig. 7a ranges: FP16 1.28-1.52; FP8 upper 3.04 (the paper's printed
    lower bound 1.56 is inconsistent with its own +56.8% worst-case area —
    our model gives 2.55 = 4/1.568; see EXPERIMENTS.md §Paper-claims)."""
    lo16, hi16 = T.area_efficiency_range(T.MODE_BY_NAME["fp16_dpa_fp32"])
    lo8, hi8 = T.area_efficiency_range(T.MODE_BY_NAME["fp8_dpa_fp32"])
    assert abs(lo16 - 1.28) < 0.01 and abs(hi16 - 1.52) < 0.01
    assert abs(hi8 - 3.04) < 0.01
    assert abs(lo8 - 2.55) < 0.01


def test_merged_simd_saving():
    """§III-C: merged-SIMD TransDot is -9.44% vs FPnew."""
    assert abs(A.MERGED_SIMD_AREA_RATIO - (1 - 0.0944)) < 1e-9


def test_table2_energy():
    assert E.ENERGY_PJ_PER_FLOP["fp32_fma_scalar"] == 3.75
    assert E.ENERGY_PJ_PER_FLOP["fp4_dpa_fp32"] == 0.41
    assert abs(E.efficiency_vs_fp32("fp4_dpa_fp32") - 3.75 / 0.41) < 1e-9
    # DPA never costs more energy than same-format SIMD
    assert E.ENERGY_PJ_PER_FLOP["fp16_dpa_fp32"] <= \
        E.ENERGY_PJ_PER_FLOP["fp16_fma_simd"]


def test_fig6b_multiplier_anchors():
    assert TM.multiplier_min_delay("transdot", pipelined=False) == 1.38
    assert TM.multiplier_min_delay("separated", pipelined=False) == 1.50
    td = TM.multiplier_area(1.6, "transdot", pipelined=False)
    sep = TM.multiplier_area(1.6, "separated", pipelined=False)
    assert abs(1 - td / sep - 0.154) < 1e-6
    td = TM.multiplier_area(1.0, "transdot", pipelined=True)
    sep = TM.multiplier_area(1.0, "separated", pipelined=True)
    assert abs(1 - td / sep - 0.158) < 1e-6


def test_fig6a_shifter_behaviour():
    # converges to baseline above 400ps
    for d in (420, 500, 800):
        assert TM.shifter_area(d, "reconfig") == TM.shifter_area(d, "single")
    # multi-lane stays 35.8%..67.2% larger
    for d in (200, 300, 500, 800):
        r = TM.shifter_area(d, "multilane") / TM.shifter_area(d, "single")
        assert 1.35 <= r <= 1.68, (d, r)
    # tight targets push reconfig toward multi-lane
    r300 = TM.shifter_area(300, "reconfig") / TM.shifter_area(300, "single")
    assert 1.0 < r300 < TM.shifter_area(300, "multilane") / \
        TM.shifter_area(300, "single")


def test_layout_and_breakdown_shares():
    assert abs(sum(A.TRANSDOT_LAYOUT.values()) - 1.0) < 1e-9
    assert abs(sum(A.FPNEW_BREAKDOWN.values()) - 1.0) < 1e-9
    assert A.TRANSDOT_LAYOUT["fp4_dp2"] == 0.039      # Fig 7b: FP4 3.9%
    assert A.FPNEW_BREAKDOWN["mantissa_multiplier"] == 0.30
    sh = (A.FPNEW_BREAKDOWN["alignment_shifter"]
          + A.FPNEW_BREAKDOWN["normalization_shifter"])
    assert 0.15 <= sh <= 0.20                          # §II-B1 "15-20%"


def test_peak_scaling_for_roofline():
    assert T.peak_flops_scale("fp8_e4m3") == 2.0
    assert T.peak_flops_scale("fp4_e2m1") == 4.0
    assert T.peak_flops_scale("bf16") == 1.0
