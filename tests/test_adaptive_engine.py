"""Adaptive trans-precision drafting: engine-side conformance.

The load-bearing claims (`repro.launch.engine` + `repro.runtime.
controller` together):

  1. Greedy adaptive output is token-for-token identical to the plain
     (non-speculative) engine AND to static-draft spec engines, across
     serving presets — whichever rung drafts, verify-and-accept emits
     the serving policy's argmax tokens.
  2. That identity survives an *adversarial* controller that switches
     rungs every round (the controller seam is behavioural only, never
     numerical).
  3. Sampled adaptive mode is deterministic under a fixed seed and
     drains cleanly.
  4. The global `acceptance_rate` is the drafted-token-weighted
     aggregate of the per-rung rates, and equals the static scalar for
     a one-rung ladder.
  5. Reservation accounting holds tick-by-tick across forced rung
     switches mid-request with per-rung draft lengths: reservations are
     priced at the ladder-wide max k, so no switch can OOM or leak.
  6. `synthetic_workload(mixed=...)` is byte-identical to the old
     stream at the default, deterministic, and actually heterogeneous
     when enabled.
"""
import dataclasses

import numpy as np
import pytest

import jax

from repro.launch.engine import (DECODE, Engine, EngineConfig, Request,
                                 synthetic_workload)
from repro.runtime import controller as C
from repro.serving import SamplerConfig, SpecConfig

ECFG = EngineConfig(page_size=8, n_pages=32, max_batch=3,
                    max_pages_per_req=6, token_budget=16, prefill_chunk=8)
LENS = [(9, 5), (14, 7), (5, 4)]
K = 3
SAMPLED = SamplerConfig(temperature=0.8, top_k=16, top_p=0.95, seed=7)

# serving presets spanning both default-ladder cache layouts
PRESETS = ["kv4_attn8_packed", "kv8_attn_f32"]


@pytest.fixture(scope="module")
def base():
    from repro.configs import get_config, reduce_config
    from repro.models import build_model
    cfg = reduce_config(get_config("qwen3-4b"))
    model = build_model(cfg.replace(policy=PRESETS[0]))
    # params are policy-independent: one init serves every preset
    return cfg, build_model, model.init(jax.random.PRNGKey(0))


def _requests(vocab, seed=3):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, vocab, size=s0).astype(np.int32),
                    max_new=g)
            for i, (s0, g) in enumerate(LENS)]


def _outputs(engine):
    return {r.rid: list(r.out_tokens) for r in engine.finished}


def _run(engine, vocab):
    for r in _requests(vocab):
        engine.submit(r)
    now = 0.0
    while engine.waiting or any(engine.slots):
        engine.step(now)
        now += 0.01
    return _outputs(engine)


def _adaptive_cfg(preset, **kw):
    kw.setdefault("k", K)
    kw.setdefault("start", 0)
    kw.setdefault("dwell", 1)
    return C.ControllerConfig(C.default_ladder(preset), **kw)


def _every_round_cycler(cfg, state, accepted, drafted):
    """Adversarial controller: hop to the next rung every single round,
    ignoring the acceptance signal entirely."""
    nxt = (state.rung + 1) % len(cfg.ladder)
    return dataclasses.replace(state, rung=nxt,
                               switches=state.switches + 1), nxt


def _check_alloc_invariants(engine):
    from repro.core import kvcache as KV
    alloc = engine.alloc
    live = [r for r in engine.slots if r is not None]
    assert alloc.in_use == sum(len(r.pages) for r in live)
    assert alloc.reserved == sum(r.reserved_left for r in live)
    assert alloc.reserved <= alloc.n_free
    assert alloc.in_use + alloc.n_free == alloc.capacity - 1
    for r in live:
        row = engine._table[r.slot]
        if r.state == DECODE:
            assert list(row[:len(r.pages)]) == r.pages
            assert np.all(row[len(r.pages):] == KV.SCRATCH_PAGE)
        else:
            assert np.all(row == KV.SCRATCH_PAGE)


# -----------------------------------------------------------------------------
# 1 + 2. greedy identity: adaptive == plain == static draft, incl. adversarial
# -----------------------------------------------------------------------------

@pytest.mark.parametrize("preset", PRESETS)
def test_greedy_adaptive_matches_plain_and_static(base, preset):
    cfg, build_model, params = base
    model = build_model(cfg.replace(policy=preset))
    want = _run(Engine(model, params, ECFG), cfg.vocab_size)

    acfg = _adaptive_cfg(preset)
    eng = Engine(model, params, ECFG, adaptive=acfg)
    assert _run(eng, cfg.vocab_size) == want
    # the ladder actually moved (start=0 + imperfect fp4 acceptance)
    assert eng.spec_rounds > 0

    static = Engine(model, params, ECFG,
                    spec=SpecConfig(acfg.ladder[0], K))
    assert _run(static, cfg.vocab_size) == want


@pytest.mark.parametrize("preset", PRESETS)
def test_greedy_adaptive_adversarial_every_round_switch(base, preset):
    """An every-round-switching controller exercises every rung's draft
    view mid-request — and the emitted tokens still match the plain
    engine exactly (rung choice is a performance decision, never an
    output decision)."""
    cfg, build_model, params = base
    model = build_model(cfg.replace(policy=preset))
    want = _run(Engine(model, params, ECFG), cfg.vocab_size)

    eng = Engine(model, params, ECFG, adaptive=_adaptive_cfg(preset))
    eng._ctrl_step = _every_round_cycler
    assert _run(eng, cfg.vocab_size) == want
    assert eng.ctrl_switches > 0
    # more than one rung really drafted
    assert sum(1 for n in eng.rung_rounds if n > 0) > 1


# -----------------------------------------------------------------------------
# 3. sampled mode: deterministic under a fixed seed, drains cleanly
# -----------------------------------------------------------------------------

def test_sampled_adaptive_deterministic_and_drains(base):
    cfg, build_model, params = base
    model = build_model(cfg.replace(policy=PRESETS[0]))
    acfg = _adaptive_cfg(PRESETS[0])
    runs = []
    for _ in range(2):
        eng = Engine(model, params, ECFG, sampler=SAMPLED, adaptive=acfg)
        runs.append(_run(eng, cfg.vocab_size))
        assert len(eng.finished) == len(LENS)
        assert not any(eng.slots) and not eng.waiting
        assert eng.alloc.in_use == 0 and eng.alloc.reserved == 0
        for r in eng.finished:
            assert len(r.out_tokens) <= r.max_new
    assert runs[0] == runs[1]


# -----------------------------------------------------------------------------
# 4. acceptance_rate: rung-weighted aggregate, == static scalar for 1 rung
# -----------------------------------------------------------------------------

def test_acceptance_rate_is_rung_weighted_aggregate(base):
    cfg, build_model, params = base
    model = build_model(cfg.replace(policy=PRESETS[0]))
    eng = Engine(model, params, ECFG, adaptive=_adaptive_cfg(PRESETS[0]))
    eng._ctrl_step = _every_round_cycler       # spread rounds over rungs
    _run(eng, cfg.vocab_size)
    rep = eng.report(wall=1.0)
    rungs = rep["adaptive_rungs"]
    drafted = sum(r["drafted"] for r in rungs)
    accepted = sum(r["accepted"] for r in rungs)
    assert drafted == eng.drafted and accepted == eng.drafts_accepted
    assert rep["acceptance_rate"] == pytest.approx(accepted / drafted)
    # per-rung rates recompose into the global through drafted weights
    agg = sum(r["acceptance_rate"] * r["drafted"] for r in rungs) / drafted
    assert rep["acceptance_rate"] == pytest.approx(agg)
    assert rep["adaptive_switches"] == eng.ctrl_switches > 0
    assert sum(r["rounds"] for r in rungs) == rep["spec_rounds"]
    ws = [r["wall_share"] for r in rungs if r["rounds"] > 0]
    assert sum(ws) == pytest.approx(1.0)


def test_one_rung_ladder_equals_static_spec_scalar(base):
    """A degenerate one-rung ladder IS static drafting: same tokens,
    same acceptance scalar — the aggregate reduces to the old number."""
    cfg, build_model, params = base
    model = build_model(cfg.replace(policy=PRESETS[0]))
    draft = "w4a4_kv4_attn4"
    static = Engine(model, params, ECFG, spec=SpecConfig(draft, K))
    want = _run(static, cfg.vocab_size)
    srep = static.report(wall=1.0)

    one = C.ControllerConfig((draft,), k=K)
    eng = Engine(model, params, ECFG, adaptive=one)
    assert _run(eng, cfg.vocab_size) == want
    rep = eng.report(wall=1.0)
    assert rep["acceptance_rate"] == srep["acceptance_rate"]
    assert rep["adaptive_switches"] == 0
    assert rep["adaptive_rungs"][0]["acceptance_rate"] == \
        srep["acceptance_rate"]


# -----------------------------------------------------------------------------
# 5. reservations: ladder-wide max k, tick-by-tick across forced switches
# -----------------------------------------------------------------------------

def test_reservation_accounting_across_forced_switches(base):
    """Per-rung draft lengths (ks=(3,1,2)) under an every-round rung
    cycler: every tick the allocator balances — committed pages match
    live block tables, reservations cover the remainder — because
    admission priced the ladder-wide max k, not the current rung's."""
    cfg, build_model, params = base
    model = build_model(cfg.replace(policy=PRESETS[0]))
    acfg = C.ControllerConfig(C.default_ladder(PRESETS[0]), ks=(3, 1, 2))
    eng = Engine(model, params, ECFG, adaptive=acfg)
    eng._ctrl_step = _every_round_cycler
    assert eng._spec_k == 3                    # max over (3, 1, 2)
    for r in _requests(cfg.vocab_size):
        eng.submit(r)
    now, switched = 0.0, False
    while eng.waiting or any(eng.slots):
        eng.step(now)
        now += 0.01
        _check_alloc_invariants(eng)
        switched = switched or eng.ctrl_switches > 0
    assert switched
    assert eng.alloc.in_use == 0 and eng.alloc.reserved == 0
    assert len(eng.finished) == len(LENS)


def test_submit_guard_prices_ladder_max_k(base):
    cfg, build_model, params = base
    model = build_model(cfg.replace(policy=PRESETS[0]))
    acfg = C.ControllerConfig(C.default_ladder(PRESETS[0]), ks=(1, 1, 9))
    eng = Engine(model, params, ECFG, adaptive=acfg)
    # s_max = 48; 30 + 10 + max_k(9) = 49 > 48 must be refused up front,
    # even though the *start* rung's k=1 would fit — a later promotion
    # to the k=9 rung could otherwise overflow the block table
    bad = Request(rid=0, prompt=np.zeros(30, np.int32), max_new=10)
    with pytest.raises(ValueError, match="draft window"):
        eng.submit(bad)


# -----------------------------------------------------------------------------
# 6. synthetic_workload mixed= knob
# -----------------------------------------------------------------------------

def test_workload_mixed_default_byte_identical():
    a = synthetic_workload(8, vocab=97, seed=5, shared_prefix=2)
    b = synthetic_workload(8, vocab=97, seed=5, shared_prefix=2, mixed=0.0)
    for ra, rb in zip(a, b):
        assert np.array_equal(ra.prompt, rb.prompt)
        assert (ra.max_new, ra.arrival) == (rb.max_new, rb.arrival)


def test_workload_mixed_deterministic_and_heterogeneous():
    kw = dict(vocab=97, seed=5, prompt_range=(8, 16), gen_range=(4, 8),
              mixed=0.5)
    a = synthetic_workload(16, **kw)
    b = synthetic_workload(16, **kw)
    for ra, rb in zip(a, b):
        assert np.array_equal(ra.prompt, rb.prompt)
        assert ra.max_new == rb.max_new
    longs = [r for r in a if r.n_prompt > 16]
    shorts = [r for r in a if r.n_prompt <= 16]
    assert longs and shorts                     # actually mixed
    for r in longs:                             # the long class is 2-4x
        assert 32 <= r.n_prompt <= 64
        assert 16 <= r.max_new <= 32


def test_workload_mixed_short_requests_ride_base_stream():
    """Long-class draws come only from the forked stream, so the short
    requests of a mixed workload are exactly the head of the unmixed
    workload's request sequence."""
    kw = dict(vocab=97, seed=5, prompt_range=(8, 16), gen_range=(4, 8))
    plain = synthetic_workload(16, **kw)
    mixed = synthetic_workload(16, mixed=0.5, **kw)
    shorts = [r for r in mixed if r.n_prompt <= 16]
    assert shorts
    for rs, rp in zip(shorts, plain):
        assert np.array_equal(rs.prompt, rp.prompt)
        assert rs.max_new == rp.max_new
