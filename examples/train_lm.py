"""End-to-end training driver example.

Default: a ~10M-param llama-family model for 200 steps on CPU (finishes
in minutes, loss visibly decreases, checkpoints + fault-supervisor on).
`--full` switches to a ~100M-param config (same code path; budget ~1h on
CPU, minutes on one accelerator host).

  PYTHONPATH=src python examples/train_lm.py [--full] [--steps N]
"""
import sys

from repro.launch.train import main

if __name__ == "__main__":
    full = "--full" in sys.argv
    steps = "400" if full else "200"
    if "--steps" in sys.argv:
        steps = sys.argv[sys.argv.index("--steps") + 1]
    args = ["--arch", "llama3.2-3b", "--reduced",
            "--steps", steps, "--batch", "8", "--seq", "256",
            "--policy", "fp8_dpa", "--vocab", "2048",
            "--ckpt-dir", "/tmp/repro_train_lm"]
    if full:
        # ~100M params: widen the reduced config via the same driver
        args += ["--n-model", "1"]
        import repro.configs.base as base
        _orig = base.reduce_config

        def bigger(cfg):
            return _orig(cfg).replace(n_layers=12, d_model=768, n_heads=12,
                                      n_kv_heads=4, head_dim=64, d_ff=2048,
                                      vocab_size=8192)
        base.reduce_config = bigger
    main(args)
