"""MoE serving walkthrough: the fused quantize->pack->grouped-DPA expert
pipeline behind the continuous-batching engine.

Dense serving moves every weight for every token; an MoE layer routes
each token to top-k of E experts, so the *resident expert stack* — not
the per-token compute — dominates weight bytes.  This demo serves a
reduced granite-moe config (8 experts, top-2) through `launch.engine`
and shows the three claims:

  1. the expert contraction runs the grouped-DPA Pallas route
     (`pallas_grouped_fused`): per-expert (M,K)x(K,N) tiles, packed-fp4
     expert weights, activations quantized to fp8 in the kernel
     prologue — the report names the route and its bytes/step;
  2. expert weights at the grouped route's operand interface are
     exactly 8x smaller than the f32 expert residency the seed paid
     (fp8 preset: exactly 4x);
  3. numerics are unchanged: greedy engine outputs are bit-identical,
     per request, to the static `serve.generate` path.  MoE expert
     capacity is *chunk-local* (C grows with tokens routed together),
     so the engine runs `prefill_chunk=1` to reproduce the static
     path's token-by-token routing exactly.

Run: PYTHONPATH=src python examples/moe_serving.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduce_config
from repro.launch.engine import (Engine, EngineConfig, format_report,
                                 synthetic_workload)
from repro.launch.serve import generate
from repro.models import build_model


def main():
    # packed-fp4 expert/linear weights + fused fp8 activations, fp8 DPA
    # attention over a packed-fp4 KV cache (the full serving preset)
    cfg = reduce_config(get_config("granite-moe-1b-a400m")).replace(
        policy="w4a8_kv4_attn8")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"model: {cfg.name} reduced — {cfg.n_experts} experts "
          f"top-{cfg.top_k}, {cfg.n_layers} layers, policy {cfg.policy}")

    # prefill_chunk=1: MoE capacity C = f(chunk tokens), so single-token
    # prefill is what keeps the engine bit-identical to the static path
    ecfg = EngineConfig(page_size=8, n_pages=48, max_batch=4,
                        max_pages_per_req=6, token_budget=16,
                        prefill_chunk=1)
    reqs = synthetic_workload(6, vocab=cfg.vocab_size, seed=0,
                              prompt_range=(6, 16), gen_range=(3, 8))
    print("workload:", ", ".join(f"#{r.rid} {r.n_prompt}+{r.max_new}"
                                 for r in reqs))
    engine = Engine(model, params, ecfg)
    rep = engine.run(reqs)
    print()
    print(format_report(rep, cfg.policy))

    # claim 1: the grouped route actually served the experts
    assert rep["moe_grouped_route"] == "pallas_grouped_fused", rep
    # claim 2: expert-weight bytes at format width, exactly 8x under f32
    red = rep["expert_w_reduction_vs_f32"]
    print(f"\nexpert weights: {rep['expert_w_bytes'] / 1e6:.3f} MB packed "
          f"fp4 vs {rep['expert_w_bytes_f32'] / 1e6:.3f} MB f32 "
          f"({red:.1f}x smaller)")
    assert abs(red - 8.0) < 1e-6, red

    # claim 3: engine output == static path, per request
    print("\nper-request greedy outputs vs the static-batch path:")
    for req in sorted(engine.finished, key=lambda r: r.rid)[:3]:
        out = generate(model, params, jnp.asarray(req.prompt[None]),
                       req.max_new, ecfg.s_max)
        want = np.asarray(out)[0, req.n_prompt:]
        same = np.array_equal(np.asarray(req.out_tokens), want)
        print(f"  req {req.rid} ({req.n_prompt}+{req.max_new} tokens): "
              f"{'bit-identical' if same else 'MISMATCH'} "
              f"{req.out_tokens[:6]}")
        assert same, (req.rid, req.out_tokens, want.tolist())


if __name__ == "__main__":
    main()
