"""Quantize -> pack -> DPA: the operand-bandwidth pipeline, end to end.

TransDot's Table I argument is that trans-precision operands saturate a
fixed-width operand interface: FP16 moves 2 bytes/code, FP8 one, FP4 half
a byte — so the same wires feed 2x/4x/8x more dot-product terms than f32.
In the jax_pallas reproduction that interface is HBM->VMEM bandwidth.
This example walks the whole software face of that story:

  1. quantize+pack the activations in ONE fused Pallas kernel
     (`quantize_rows(pack=True)`: absmax -> E2M1 cast -> nibble pack),
  2. run the packed-operand DPA matmul (nibbles unpacked in VMEM — the
     BlockSpec moved half the fp4 bytes),
  3. run the fully fused variant (quantization inside the matmul
     prologue: the quantized activation never touches HBM at all),
  4. account the operand bytes per policy and check the 2x/4x/8x ratios,
  5. prove packing is free: packed and unpacked results are bit-identical.

Run:  PYTHONPATH=src python examples/packed_dpa_pipeline.py
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.core import get_policy
from repro.core.packing import matmul_operand_bytes, pack_fp4_axis
from repro.kernels import dpa_matmul as dm
from repro.kernels import ops as O
from repro.kernels.ops import _quant_operand

M, K, N = 256, 512, 256
x = jax.random.normal(jax.random.PRNGKey(0), (M, K), jnp.float32)
w = jax.random.normal(jax.random.PRNGKey(1), (K, N), jnp.float32)
f32 = np.asarray(x @ w)


def rel_err(y):
    return float(np.abs(np.asarray(y) - f32).max() / np.abs(f32).max())


# -- 1. fused quantize+pack kernel -------------------------------------------
qx_packed, sx = O.quantize_rows(x, "fp4_e2m1", pack=True)
print(f"quantize+pack: x {x.shape} f32 ({x.size * 4} B) -> "
      f"{qx_packed.shape} uint8 ({qx_packed.size} B packed codes)")

# -- 2. packed-operand DPA matmul --------------------------------------------
wq, sw = _quant_operand(w, "fp4_e2m1", 0)
y_packed = dm.dpa_matmul_prequant(
    qx_packed, pack_fp4_axis(wq, 0), sx, sw, fmt_x="fp4_e2m1",
    fmt_w="fp4_e2m1", pack_x=True, pack_w=True)
print(f"packed DPA matmul:   rel err vs f32 = {rel_err(y_packed):.3f} "
      "(fp4 operands: quantization error, not packing error)")

# -- 3. fully fused variant (policy-driven) ----------------------------------
y_fused = O.dpa_matmul(x, w, get_policy("fp4_dpa_fused"))
print(f"fused-quant matmul:  rel err vs f32 = {rel_err(y_fused):.3f} "
      "(per-(row,K-block) scales, no quantized-x HBM round-trip)")

# -- 4. bytes moved through the operand interface ----------------------------
print(f"\noperand bytes for the {M}x{K}x{N} matmul "
      "(quantized operands + scales):")
print(f"  {'policy':16s} {'bytes':>10s} {'vs f32':>8s}")
for pol in ("fp16_dpa", "fp8_dpa", "fp4_dpa_packed"):
    b = matmul_operand_bytes(M, K, N, pol)
    print(f"  {pol:16s} {b['total']:10d} "
          f"{b['reduction_vs_f32']:7.2f}x")
print("  (expected ~2x / ~4x / ~8x — Table I's operand-bandwidth story)")

# -- 5. packing is pure layout: bit-identity ---------------------------------
# same quantizer kernel, unpacked layout (byte per code) on both sides
xq, sx2 = O.quantize_rows(x, "fp4_e2m1")
y_unpacked = dm.dpa_matmul_prequant(xq, wq, sx2, sw, fmt_x="fp4_e2m1",
                                    fmt_w="fp4_e2m1")
bit_identical = np.array_equal(np.asarray(y_packed), np.asarray(y_unpacked))
print(f"\npacked == unpacked bit-for-bit: {bit_identical}")
assert bit_identical
