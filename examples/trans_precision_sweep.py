"""Trans-precision sweep: train the same model under every DPA policy.

Reproduces the paper's motivation at the system level: lower-precision
operands buy throughput (modeled via Table I/II) at bounded quality cost
— because accumulation stays FP32 (the DPA contract), even FP4 operands
train stably.

Run:  PYTHONPATH=src python examples/trans_precision_sweep.py
"""
import time

import jax

from repro.data.pipeline import DataConfig, make_pipeline
from repro.distributed.step import make_train_step
from repro.hwmodel.energy import ENERGY_PJ_PER_FLOP
from repro.hwmodel.throughput import MODE_BY_NAME, gflops
from repro.models import ModelConfig, build_model
from repro.optim import adamw

POLICY_TO_MODE = {"fp32": "fp32_fma_scalar", "fp16_dpa": "fp16_dpa_fp32",
                  "fp8_dpa": "fp8_dpa_fp32", "fp4_dpa": "fp4_dpa_fp32"}
STEPS = 120


def run(policy: str):
    cfg = ModelConfig("sweep", "decoder", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                      policy=policy)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    state = {"params": params, "opt": adamw.init(params)}
    step = jax.jit(make_train_step(model, adamw.AdamWConfig(
        lr=3e-3, warmup_steps=10, total_steps=STEPS)))
    pipe = make_pipeline(DataConfig(vocab_size=256, batch=8, seq=32, seed=1))
    t0 = time.monotonic()
    losses = []
    for i in range(STEPS):
        state, m = step(state, pipe.batch(i))
        losses.append(float(m["loss"]))
    wall = time.monotonic() - t0
    return sum(losses[-10:]) / 10, wall


print(f"{'policy':10s} {'final loss':>10s} {'FPU GF/s':>9s} {'pJ/FLOP':>8s}"
      f" {'cpu s':>6s}")
base = None
for policy in ("fp32", "fp16_dpa", "fp8_dpa", "fp4_dpa"):
    loss, wall = run(policy)
    base = base or loss
    mode = MODE_BY_NAME[POLICY_TO_MODE[policy]]
    print(f"{policy:10s} {loss:10.3f} {gflops(mode):9.0f} "
          f"{ENERGY_PJ_PER_FLOP[POLICY_TO_MODE[policy]]:8.2f} {wall:6.1f}"
          + ("   <- baseline" if policy == "fp32" else
             f"   (+{loss - base:.3f} loss, "
             f"{gflops(mode) / 2:.0f}x FPU throughput)"))
print("\nAccumulation stays FP32 in every mode — the paper's stability "
      "contract; operand format is a pure throughput/quality dial.")
