"""Batched serving example: greedy decode with KV caches under the fp8
DPA policy (weights ride the narrow wires, accumulation stays FP32).

  PYTHONPATH=src python examples/serve_batch.py
"""
from repro.launch.serve import main

if __name__ == "__main__":
    main(["--arch", "qwen3-4b", "--reduced", "--batch", "4",
          "--prompt-len", "16", "--gen", "16", "--policy", "fp8_dpa"])
