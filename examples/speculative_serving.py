"""Self-speculative serving walkthrough: draft in fp4, verify exactly.

TransDot's reconfigurable datapath runs the *same weights* at
fp16/fp8/fp4 operand width with 2x/4x/8x DPA throughput (Table I).
Speculative decoding turns that trans-precision range into a serving
win without touching output quality:

  1. draft  — k tokens per request under `w4a4_kv4_attn4` (fp4-grid
     linears AND attention: the 8-term DPA route end to end);
  2. verify — ONE batched pass under the serving policy scores all k+1
     positions through the `verify_attn` exec-plan route, each row
     bit-identical to a plain decode step at that position;
  3. accept — greedy prefix-match (or full rejection sampling when a
     temperature is set), so outputs are EXACTLY the serving policy's —
     the demo asserts token-for-token identity against the plain engine.

Both policies share one packed-fp4 page pool; the verify pass rewrites
every draft-touched row with serving-policy codes, and pages holding
only rejected rows roll back to the request's reservation
(`core.kvcache.PageAllocator`).

Run: PYTHONPATH=src python examples/speculative_serving.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

import jax

from repro.configs import get_config, reduce_config
from repro.launch.engine import (Engine, EngineConfig, SamplerConfig,
                                 SpecConfig, format_report,
                                 synthetic_workload)
from repro.models import build_model

DRAFT, VERIFY, K = "w4a4_kv4_attn4", "kv4_attn8_packed", 3


def main():
    cfg = reduce_config(get_config("qwen3-4b")).replace(policy=VERIFY)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ecfg = EngineConfig(page_size=8, n_pages=48, max_batch=4,
                        max_pages_per_req=6, token_budget=16,
                        prefill_chunk=8)
    reqs = synthetic_workload(8, vocab=cfg.vocab_size, seed=0,
                              prompt_range=(6, 24), gen_range=(4, 10))
    print(f"draft {K} tokens/round under {DRAFT} (8-term fp4 DPA), "
          f"verify under {VERIFY}\n")

    plain = Engine(model, params, ecfg)
    plain.run(reqs)

    spec = Engine(model, params, ecfg, spec=SpecConfig(DRAFT, k=K))
    rep = spec.run(synthetic_workload(8, vocab=cfg.vocab_size, seed=0,
                                      prompt_range=(6, 24),
                                      gen_range=(4, 10)))
    print(format_report(rep, VERIFY))

    # the exactness claim: greedy speculative == plain engine, per request
    print("\nper-request outputs vs the plain (non-speculative) engine:")
    for want in sorted(plain.finished, key=lambda r: r.rid)[:5]:
        got = [r for r in spec.finished if r.rid == want.rid][0]
        same = got.out_tokens == want.out_tokens
        print(f"  req {want.rid}: "
              f"{'token-for-token identical' if same else 'MISMATCH'} "
              f"{got.out_tokens[:6]}")
        assert same, (want.rid, got.out_tokens, want.out_tokens)

    # sampled mode: same distribution as the target, keyed per request
    smp = SamplerConfig(temperature=0.8, top_k=16, top_p=0.95, seed=7)
    sampled = Engine(model, params, ecfg, sampler=smp,
                     spec=SpecConfig(DRAFT, k=K))
    rep = sampled.run(synthetic_workload(8, vocab=cfg.vocab_size, seed=0,
                                         prompt_range=(6, 24),
                                         gen_range=(4, 10)))
    print(f"\nsampled (T={smp.temperature}, top-k {smp.top_k}, top-p "
          f"{smp.top_p}): acceptance {rep['acceptance_rate']:.0%}, "
          f"{rep['eff_tokens_per_round']:.2f} effective tokens/round "
          f"(rejection sampling keeps the output distribution exactly "
          f"the serving policy's)")
    # sampled mode rejects (and rolls back) hardest — check both engines
    for eng in (spec, sampled):
        assert eng.alloc.in_use == 0 and eng.alloc.reserved == 0
    print("\nallocator drained clean: no leaked pages, reservations "
          "balanced after every rollback")


if __name__ == "__main__":
    main()
