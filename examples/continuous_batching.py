"""Continuous-batching walkthrough: the paged quantized KV cache serving
mixed-length traffic.

The static serving path (`examples/quantized_kv_serving.py`) holds a
(B, S_max) cache — every request pays for the longest one.  This demo
serves an open-loop Poisson workload of mixed prompt/output lengths
through `repro.launch.engine` instead, and shows the three claims that
make it a serving system rather than a demo loop:

  1. cache memory scales with *live tokens*, not B x S_max — the report
     prices the cache from actual per-request lengths, with the page
     allocator's utilization alongside;
  2. requests of different lengths share one batched decode step
     (per-request positions, block-table reads), admitted and evicted
     continuously as pages free up;
  3. numerics are unchanged: the engine's greedy outputs are
     bit-identical, per request, to the static path serving the same
     prompt alone (paging is pure relayout + the same DPA contract).

Run: PYTHONPATH=src python examples/continuous_batching.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduce_config
from repro.launch.engine import (Engine, EngineConfig, format_report,
                                 synthetic_workload)
from repro.launch.serve import generate
from repro.models import build_model


def main():
    cfg = reduce_config(get_config("qwen3-4b")).replace(
        policy="kv4_attn8_packed")    # fp8 attention over a packed-fp4 cache
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    ecfg = EngineConfig(page_size=8, n_pages=48, max_batch=4,
                        max_pages_per_req=6, token_budget=16,
                        prefill_chunk=8)
    print(f"engine: {ecfg.max_batch} decode slots, "
          f"{ecfg.n_pages - 1} pages x {ecfg.page_size} tokens "
          f"(S_max {ecfg.s_max}/request), policy {cfg.policy}")

    # open-loop Poisson traffic: mixed lengths, arrivals spread in time
    reqs = synthetic_workload(10, vocab=cfg.vocab_size, seed=0, rate=100.0,
                              prompt_range=(6, 30), gen_range=(3, 10))
    print("workload:", ", ".join(f"#{r.rid} {r.n_prompt}+{r.max_new}"
                                 for r in reqs))
    engine = Engine(model, params, ecfg)
    rep = engine.run(reqs)
    print()
    print(format_report(rep, cfg.policy))

    # the numerics claim: engine output == static path, per request
    print("\nper-request greedy outputs vs the static-batch path:")
    for req in sorted(engine.finished, key=lambda r: r.rid)[:4]:
        out = generate(model, params, jnp.asarray(req.prompt[None]),
                       req.max_new, ecfg.s_max)
        want = np.asarray(out)[0, req.n_prompt:]
        same = np.array_equal(np.asarray(req.out_tokens), want)
        print(f"  req {req.rid} ({req.n_prompt}+{req.max_new} tokens): "
              f"{'bit-identical' if same else 'MISMATCH'} "
              f"{req.out_tokens[:6]}")
        assert same, (req.rid, req.out_tokens, want.tolist())

    # the memory claim, restated as a single number
    saved = rep["static_f32_bytes"] / rep["paged_bytes"]
    print(f"\npeak cache memory: {rep['paged_bytes'] / 1e6:.3f} MB of pages"
          f" vs {rep['static_f32_bytes'] / 1e6:.3f} MB static f32 "
          f"(B x S_max) — {saved:.1f}x smaller (format width x paging)")


if __name__ == "__main__":
    main()
