"""Quickstart: the TransDot DPA contract in 60 lines.

1. bit-accurate golden-model DPA (the FPU datapath),
2. the same contract as a training policy on a small LM,
3. a few optimization steps with the full production stack.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

import jax
import jax.numpy as jnp

# --- 1. the FPU: 4-term FP8 dot product accumulated into FP32 -----------
from repro.core import dpa

a = np.array([[1.5, -2.0, 0.25, 3.0]])
b = np.array([[2.0, 0.5, -4.0, 1.0]])
c = np.array([10.0])
out = dpa.dpa(a, b, c, "fp8_e4m3", "fp32")
print(f"DPA fp8x4->fp32: {a[0]} . {b[0]} + {c[0]} = {out[0]}")
assert out[0] == (a * b).sum() + c[0]          # exact here: fp32 is wide

# paper Table I throughput contract
from repro.hwmodel import throughput as T
m = T.MODE_BY_NAME["fp8_dpa_fp32"]
print(f"fp8 DPA: {T.gflops(m):.0f} GFLOP/s vs FPnew "
      f"{T.gflops(m, 'fpnew'):.0f} — {T.area_efficiency(m):.2f}x "
      "throughput/area (paper: 2.92x)")

# --- 2. the same contract as a model policy ------------------------------
from repro.core import apply_linear, init_linear, get_policy

k = jax.random.PRNGKey(0)
layer = init_linear(k, 256, 128)
x = jax.random.normal(k, (4, 256), jnp.float32)
y32 = apply_linear(layer, x, get_policy("fp32"))
y8 = apply_linear(layer, x, get_policy("fp8_dpa"))
rel = float(jnp.abs(y8 - y32).max() / jnp.abs(y32).max())
print(f"DPALinear fp8_dpa vs fp32: rel err {rel:.4f} (operands fp8, "
      "accumulation fp32)")

# --- 3. train a tiny LM under the policy ---------------------------------
from repro.data.pipeline import DataConfig, make_pipeline
from repro.distributed.step import make_train_step
from repro.models import ModelConfig, build_model
from repro.optim import adamw

cfg = ModelConfig("quickstart", "decoder", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                  policy="fp8_dpa")
model = build_model(cfg)
params = model.init(k)
state = {"params": params, "opt": adamw.init(params)}
step = jax.jit(make_train_step(model, adamw.AdamWConfig(lr=3e-3,
                                                        total_steps=60)))
pipe = make_pipeline(DataConfig(vocab_size=256, batch=8, seq=32))
for i in range(60):
    state, metrics = step(state, pipe.batch(i))
    if i % 20 == 0:
        print(f"step {i:3d}  loss {float(metrics['loss']):.3f}")
print(f"final loss {float(metrics['loss']):.3f} — trained under the "
      "fp8-DPA execution contract")
