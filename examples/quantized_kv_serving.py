"""Quantized-KV serving walkthrough: the DPA attention path end to end.

Serves a reduced qwen3-4b under three policies — the seed f32 datapath,
fp8 DPA attention (attn_fp8_dpa), and the trans-precision sweet spot
kv4_attn8_packed (fp8 attention arithmetic over a packed-fp4 KV cache) —
and shows the three claims that make the path production-shaped:

  1. the KV cache shrinks 3.9x / 7.5x (bytes streamed per decode step);
  2. greedy generations track the f32 path (same weights, narrower
     attention operands);
  3. prefill-then-decode is self-consistent: the cache a prompt writes is
     the cache decode reads, codes and scales included.

Run: PYTHONPATH=src python examples/quantized_kv_serving.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

import jax
import numpy as np

from repro.configs import get_config, reduce_config
from repro.core.kvcache import is_quantized, kv_cache_nbytes
from repro.core.policy import get_policy
from repro.launch.serve import generate, report_kv_cache
from repro.models import build_model


def main():
    base = reduce_config(get_config("qwen3-4b"))
    B, S0, GEN = 2, 12, 8
    prompt = jax.random.randint(jax.random.PRNGKey(1), (B, S0), 0,
                                base.vocab_size)

    outs = {}
    for pol in ("fp32", "attn_fp8_dpa", "kv4_attn8_packed"):
        cfg = base.replace(policy=pol)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))   # same weights each run
        print(f"\n=== policy {pol} ===")
        print(report_kv_cache(cfg, B, S0 + GEN))
        caches = model.init_caches(B, S0 + GEN)
        leaf = jax.tree.leaves(caches, is_leaf=is_quantized)[0]
        print("cache layout:", "codes+scales (quantized)"
              if is_quantized(leaf) else "raw k/v")
        toks = generate(model, params, prompt, GEN, S0 + GEN)
        outs[pol] = np.asarray(toks)
        print("greedy tokens:", outs[pol][0, S0:].tolist())

    agree8 = (outs["fp32"][:, S0:] == outs["attn_fp8_dpa"][:, S0:]).mean()
    agree4 = (outs["fp32"][:, S0:] == outs["kv4_attn8_packed"][:, S0:]).mean()
    print(f"\ngreedy agreement vs f32: attn_fp8_dpa {agree8:.0%}, "
          f"kv4_attn8_packed {agree4:.0%} "
          "(random init -> flat logits; trained weights agree far more)")

    # the bandwidth table the policies buy, at a serving-scale shape
    print("\nKV-cache bytes per decode sweep (B=8, S=4096, KV=8, hd=128):")
    for pol in ("attn_fp16_dpa", "attn_fp8_dpa", "kv4_attn8_packed"):
        p = get_policy(pol)
        nb = kv_cache_nbytes(8, 4096, 8, 128, fmt=p.fmt_kv,
                             packed=p.kv_packed)
        print(f"  {pol:18s} {nb['total'] / 2**20:8.1f} MiB  "
              f"({nb['reduction_vs_f32']:.2f}x fewer than f32's "
              f"{nb['f32_total'] / 2**20:.1f} MiB)")


if __name__ == "__main__":
    main()
