"""Print the execution-plan routing table; fail on unexercised routes.

Every `core.exec_plan` route registers the tier-1 tests that exercise it
(`PlanEntry.tests`).  This tool renders the full table — op, route,
backend, priority, reference fallback + pinned tolerance, and the tests
— and verifies the coverage claim holds on disk:

  - every registered route names at least one test;
  - every named test file exists, and a ``file::name`` entry names a
    test function actually defined in that file (parametrized variants
    match by prefix);
  - every tunable knob a route's ``run`` exposes is declared
    (`PlanEntry.knobs`) and has a grid in the tuner's config space
    (`repro.runtime.tuner.KNOB_GRID`), and every record in a shipped
    tuned-defaults DB (`benchmarks/tuned/*.json`) names a live route +
    shape-class, carries only declared knobs, and hashes to its own key;
  - every default draft-precision ladder (`repro.runtime.controller.
    DEFAULT_LADDERS`) is servable: for every KV-quantized serving preset
    the engine can pair a ladder with, each rung passes
    `validate_policy_pair` and resolves a ``paged_decode`` route — a bad
    ladder entry fails CI here, not the first adaptive request at
    runtime;
  - every route whose predicate requires ``n_devices > 1`` (the sharded
    serving routes, the wire-compressed allreduce) names at least one
    test in the multi-device suite (`tests/test_distributed.py` /
    `tests/test_tp_*.py`), which the CI multidevice job runs under
    `XLA_FLAGS=--xla_force_host_platform_device_count=8` — a sharded
    route pinned only by single-device tests would never actually cross
    a device boundary in CI.

Run by the CI docs job (alongside `tools/check_docs.py`), so registering
a kernel route without pinning it to a test fails CI the same way a
dangling doc link does.

Usage: python tools/plan_table.py [--check]   (--check: no table, just
the coverage verdict; default prints both)
"""
from __future__ import annotations

import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))


def _test_exists(ref: str) -> bool:
    """'tests/foo.py' or 'tests/foo.py::test_name' resolves on disk."""
    path, _, name = ref.partition("::")
    full = os.path.join(ROOT, path)
    if not os.path.isfile(full):
        return False
    if not name:
        return True
    with open(full, encoding="utf-8") as f:
        text = f.read()
    return re.search(rf"^def {re.escape(name)}\b", text, re.M) is not None


def _is_multidevice_test(ref: str) -> bool:
    path = ref.partition("::")[0]
    base = os.path.basename(path)
    return base == "test_distributed.py" or base.startswith("test_tp_")


def _requires_multidevice(entry) -> bool:
    """True when the route's predicate gates on n_devices > 1: eligible
    in an 8-device context but not a 1-device one, everything else held
    permissive."""
    from repro.core.policy import get_policy
    pol = get_policy("kv4_attn8_packed")
    base = dict(wire_fmt="fp8_e4m3", sq=4)
    try:
        one = entry.predicate(pol, dict(base, n_devices=1))
        many = entry.predicate(pol, dict(base, n_devices=8))
    except Exception:
        return False
    return all(many.values()) and not all(one.values()) and one != many


def _knob_errors(entry) -> list:
    """The tuner-contract checks for one route: every knob-named kwarg
    the run signature exposes must be declared in `entry.knobs`, and
    every declared knob must have a grid in the tuner's config space —
    otherwise the sweep silently never measures it (or `tuned_entry`
    silently drops it) and the tuned table lies."""
    import inspect

    from repro.runtime import tuner
    errs = []
    try:
        params = inspect.signature(entry.run).parameters
    except (TypeError, ValueError):
        params = {}
    exposed = {n for n, p in params.items()
               if n in tuner.KNOB_GRID and p.kind in (
                   inspect.Parameter.KEYWORD_ONLY,
                   inspect.Parameter.POSITIONAL_OR_KEYWORD)}
    for knob in sorted(exposed - set(entry.knobs)):
        errs.append(f"{entry.op}/{entry.name}: run() exposes tunable "
                    f"knob {knob!r} but the route does not declare it "
                    "(knobs=...)")
    for knob in entry.knobs:
        if knob not in tuner.KNOB_GRID:
            errs.append(f"{entry.op}/{entry.name}: declared knob "
                        f"{knob!r} has no grid in tuner.KNOB_GRID — "
                        "the sweep can never measure it")
        elif knob not in exposed:
            errs.append(f"{entry.op}/{entry.name}: declares knob "
                        f"{knob!r} that run() does not accept")
    return errs


def _tuned_defaults_errors() -> list:
    """Validate every shipped tuned-defaults DB under benchmarks/tuned/:
    records must name live routes/shape-classes, carry only declared
    knobs, and hash to their own key (integrity — a hand-edited record
    that no sweep produced fails here)."""
    import glob
    import json

    from repro.core import exec_plan
    from repro.runtime import tuner
    errs = []
    for path in sorted(glob.glob(os.path.join(ROOT, "benchmarks", "tuned",
                                              "*.json"))):
        rel = os.path.relpath(path, ROOT)
        try:
            with open(path) as f:
                raw = json.load(f)
        except (OSError, json.JSONDecodeError) as exc:
            errs.append(f"{rel}: unreadable ({exc})")
            continue
        for h, rec in (raw.get("records") or {}).items():
            where = f"{rel}[{h}]"
            if not isinstance(rec, dict) or "op" not in rec \
                    or "route" not in rec:
                errs.append(f"{where}: malformed record")
                continue
            knobs = set(rec.get("knobs") or {})
            if rec["op"] == tuner.ENGINE_OP:
                extra = knobs - set(tuner.ENGINE_KNOB_GRID)
                if extra:
                    errs.append(f"{where}: unknown engine knob(s) "
                                f"{sorted(extra)}")
            else:
                try:
                    entry = exec_plan.route(rec["op"], rec["route"])
                except exec_plan.PlanError:
                    errs.append(f"{where}: references nonexistent route "
                                f"{rec['op']}/{rec['route']}")
                    continue
                if rec.get("shape_class") not in {
                        sc.name for sc in tuner.SHAPE_CLASSES
                        if sc.op == rec["op"]}:
                    errs.append(f"{where}: unknown shape class "
                                f"{rec.get('shape_class')!r} for "
                                f"{rec['op']}")
                extra = knobs - set(entry.knobs)
                if extra:
                    errs.append(f"{where}: knob(s) {sorted(extra)} not "
                                f"declared by {rec['op']}/{rec['route']}")
            try:
                if tuner.config_hash(rec) != h:
                    errs.append(f"{where}: key does not match the "
                                "record's content hash")
            except KeyError as exc:
                errs.append(f"{where}: missing hash field {exc}")
    return errs


def _ladder_errors() -> list:
    """Audit the adaptive draft ladders: every serving preset with a
    quantized KV cache must map to a default ladder whose every rung (a)
    shares the serving cache layout (`validate_policy_pair`) and (b)
    resolves a ``paged_decode`` route at an engine-shaped context — the
    two things Engine construction would otherwise discover at runtime."""
    from repro.core import exec_plan
    from repro.core.policy import POLICIES
    from repro.runtime import controller
    from repro.serving.spec_decode import validate_policy_pair
    ctx = dict(batch=4, page_size=8, max_pages=4, kv_heads=2, hd=16,
               n_pages=32, n_devices=1)
    errs = []
    for serve_name, serve_pol in sorted(POLICIES.items()):
        if not serve_pol.kv_quantized:
            continue
        try:
            ladder = controller.default_ladder(serve_name)
        except ValueError as exc:
            errs.append(f"ladder[{serve_name}]: no default ladder "
                        f"({exc})")
            continue
        for rung in ladder:
            try:
                rpol = validate_policy_pair(rung, serve_pol)
            except ValueError as exc:
                errs.append(f"ladder[{serve_name}]/{rung}: cache layout "
                            f"mismatch ({exc})")
                continue
            try:
                exec_plan.resolve("paged_decode", rpol, **ctx)
            except exec_plan.PlanError as exc:
                errs.append(f"ladder[{serve_name}]/{rung}: no "
                            f"paged_decode route ({exc})")
    return errs


def collect():
    from repro.core import exec_plan
    rows, errors = [], []
    for op in exec_plan.ops():
        for e in exec_plan.candidates(op):
            rows.append(e)
            if not e.tests:
                errors.append(f"{op}/{e.name}: no tier-1 test registered")
            for t in e.tests:
                if not _test_exists(t):
                    errors.append(f"{op}/{e.name}: test {t!r} not found")
            if e.tests and _requires_multidevice(e) \
                    and not any(_is_multidevice_test(t) for t in e.tests):
                errors.append(
                    f"{op}/{e.name}: predicate requires n_devices > 1 but "
                    "no named test is in the multi-device suite "
                    "(tests/test_distributed.py or tests/test_tp_*.py)")
            errors.extend(_knob_errors(e))
    errors.extend(_tuned_defaults_errors())
    errors.extend(_ladder_errors())
    return rows, errors


def render(rows) -> str:
    head = f"{'op':<15} {'route':<22} {'backend':<7} {'prio':>4} " \
           f"{'reference (tol)':<26} tests"
    lines = [head, "-" * len(head)]
    for e in rows:
        ref = f"{e.reference} ({e.tol:g})" if e.reference else "— (is ref)"
        tests = ", ".join(t.split("/")[-1] for t in e.tests) or "NONE"
        lines.append(f"{e.op:<15} {e.name:<22} {e.backend:<7} "
                     f"{e.priority:>4} {ref:<26} {tests}")
    return "\n".join(lines)


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    rows, errors = collect()
    if "--check" not in argv:
        print(render(rows))
        print()
    if errors:
        print(f"plan table check: {len(errors)} problem(s)")
        for err in errors:
            print(f"  FAIL {err}")
        return 1
    print(f"plan table check: {len(rows)} routes, all named tests exist")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
