"""Run the measurement-driven autotuner sweep (`repro.runtime.tuner`).

Benchmarks every (op, policy, shape-class, route, knob) config of the
tuner's space as an isolated cutout and persists the results in a JSON
measurement database — content-hash keyed, so re-runs skip what is
already measured and the sweep shards across workers with no
coordination:

    # worker i of n, each measuring a disjoint hash-partitioned slice
    python tools/tune.py --db tuned.json --shard 0/2 &
    python tools/tune.py --db tuned.json.1 --shard 1/2
    # (separate DB files per concurrent worker; merge with --merge)

    # the CI lane: small grids, then assert the space is fully measured
    python tools/tune.py --db benchmarks/tuned/ci_default.json --smoke
    python tools/tune.py --db benchmarks/tuned/ci_default.json --smoke \
        --verify

Serving picks the DB up via ``REPRO_TUNED_DB=<path>`` (kill switch
``REPRO_TUNED=0``); `exec_plan.describe()` then reports ``tuned`` vs
``prior`` per resolution.  See docs/tuning.md.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(ROOT, "src"))


def _parse_shard(text: str):
    try:
        i, n = text.split("/")
        i, n = int(i), int(n)
    except ValueError:
        raise argparse.ArgumentTypeError(
            f"shard must look like i/n, got {text!r}")
    if not (n >= 1 and 0 <= i < n):
        raise argparse.ArgumentTypeError(f"bad shard {text!r}")
    return i, n


def _verify(db_path: str, smoke: bool) -> int:
    """Exit nonzero unless the (smoke) space is fully measured and the
    tuned consult resolves deterministically for every CI key."""
    from repro.core import exec_plan
    from repro.core.policy import get_policy
    from repro.runtime import tuner

    missing = tuner.missing_configs(db_path, smoke=smoke)
    if missing:
        print(f"tune --verify: {len(missing)} unmeasured config(s)")
        for cfg in missing[:10]:
            print(f"  MISSING {cfg['op']}/{cfg['route']} "
                  f"{cfg['shape_class']} {cfg['knobs']}")
        return 1
    os.environ["REPRO_TUNED_DB"] = db_path
    tuner.clear_caches()
    checked = 0
    for sc in tuner.SHAPE_CLASSES:
        for preset in tuner.OP_POLICIES.get(sc.op, ()):
            pol = get_policy(preset)
            first = exec_plan.resolve(sc.op, pol, **sc.rep)
            again = exec_plan.resolve(sc.op, pol, **sc.rep)
            if first is not again:
                print(f"tune --verify: nondeterministic resolve for "
                      f"{sc.op}/{sc.name} under {preset}")
                return 1
            d = first.describe(pol, sc.rep)
            print(f"  {sc.op:<14} {sc.name:<14} {preset:<16} -> "
                  f"{first.name} [{d['selection']}] "
                  f"knobs={d.get('tuned_knobs', {})}")
            checked += 1
    eng = tuner.best_engine_knobs(db_path)
    print(f"  engine         {tuner.ENGINE_SHAPE_CLASS:<14} "
          f"{tuner.ENGINE_POLICY:<16} -> best knobs {eng}")
    print(f"tune --verify: OK ({checked} keys, space fully measured)")
    return 0


def _merge(dst: str, sources) -> int:
    from repro.runtime import tuner
    db = tuner.load_db(dst)
    added = 0
    for src in sources:
        other = tuner.load_db(src)
        for h, rec in other["records"].items():
            if h not in db["records"]:
                db["records"][h] = rec
                added += 1
        if other["meta"]:
            db["meta"] = other["meta"]
    tuner.save_db(dst, db)
    print(f"merged {added} new record(s) into {dst} "
          f"({len(db['records'])} total)")
    return 0


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--db", required=True, help="measurement DB path")
    p.add_argument("--smoke", action="store_true",
                   help="small CI grids (subset of the full space)")
    p.add_argument("--shard", type=_parse_shard, default=(0, 1),
                   metavar="i/n", help="measure shard i of n (by hash)")
    p.add_argument("--reps", type=int, default=3,
                   help="timed repetitions per cutout")
    p.add_argument("--ops", nargs="*", default=None,
                   help="restrict to these ops (default: all)")
    p.add_argument("--policies", nargs="*", default=None,
                   help="restrict to these policy presets")
    p.add_argument("--verify", action="store_true",
                   help="no sweep: assert the space is fully measured "
                        "and the tuned consult is deterministic")
    p.add_argument("--merge", nargs="*", default=None, metavar="SRC",
                   help="no sweep: merge SRC DBs into --db")
    args = p.parse_args(argv)

    if args.merge is not None:
        return _merge(args.db, args.merge)
    if args.verify:
        return _verify(args.db, args.smoke)

    from repro.runtime import tuner

    def progress(cfg, us):
        print(f"  {cfg['op']:<14} {cfg['shape_class']:<14} "
              f"{cfg['route']:<22} {json.dumps(cfg['knobs']):<32} "
              f"{us:10.1f} us")

    stats = tuner.run_sweep(args.db, smoke=args.smoke, shard=args.shard,
                            reps=args.reps, ops=args.ops,
                            policies=args.policies, progress=progress)
    print(f"sweep: {stats['measured']} measured, {stats['skipped']} "
          f"already in DB, {stats['other_shard']} on other shards "
          f"(space: {stats['total']})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
