"""Markdown link checker for README.md + docs/ (the CI `docs` job).

Dependency-free: walks `[text](target)` links in the checked files and
verifies

  - relative file targets exist (README.md, docs/*.md, code paths);
  - intra-repo `#anchor` fragments resolve to a heading in the target
    markdown file (GitHub slug rules: lowercase, spaces -> dashes,
    punctuation dropped);
  - backtick-quoted `src/...` / `tests/...` / `benchmarks/...` path
    mentions in the docs point at real files — docs that name code must
    not rot.

http(s) links are not fetched (CI should not depend on the network);
they are only checked for obvious malformation.

Usage: python tools/check_docs.py [files...]   (default: README.md docs/*.md)
"""
from __future__ import annotations

import glob
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

LINK_RE = re.compile(r"(?<!!)\[[^\]]+\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.+?)\s*$", re.M)
# `path`-style code mentions that should exist on disk (plain files only)
CODE_PATH_RE = re.compile(
    r"`((?:src|tests|benchmarks|examples|docs|tools)/[A-Za-z0-9_/.-]+"
    r"\.(?:py|md|json|yml|npz))`")


def slugify(heading: str) -> str:
    """GitHub's anchor slug: strip markdown/punctuation, dash the spaces."""
    h = re.sub(r"[`*_]", "", heading).strip().lower()
    h = re.sub(r"[^\w\- ]", "", h)
    return h.replace(" ", "-")


def heading_slugs(path: str) -> set:
    with open(path, encoding="utf-8") as f:
        text = f.read()
    slugs, seen = set(), {}
    for m in HEADING_RE.finditer(text):
        s = slugify(m.group(1))
        n = seen.get(s, 0)
        seen[s] = n + 1
        slugs.add(s if n == 0 else f"{s}-{n}")   # duplicate headings
    return slugs


def check_file(path: str) -> list:
    errors = []
    rel = os.path.relpath(path, ROOT)
    with open(path, encoding="utf-8") as f:
        text = f.read()
    for m in LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        frag = None
        if "#" in target:
            target, frag = target.split("#", 1)
        dest = path if not target else os.path.normpath(
            os.path.join(os.path.dirname(path), target))
        if target and not os.path.exists(dest):
            errors.append(f"{rel}: broken link -> {m.group(1)}")
            continue
        if frag and dest.endswith(".md"):
            if slugify(frag) not in heading_slugs(dest):
                errors.append(f"{rel}: missing anchor -> {m.group(1)}")
    for m in CODE_PATH_RE.finditer(text):
        if not os.path.exists(os.path.join(ROOT, m.group(1))):
            errors.append(f"{rel}: code path does not exist -> `{m.group(1)}`")
    return errors


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    files = argv or ([os.path.join(ROOT, "README.md")]
                     + sorted(glob.glob(os.path.join(ROOT, "docs", "*.md"))))
    errors = []
    for path in files:
        errors.extend(check_file(path))
    if errors:
        print(f"check_docs: {len(errors)} problem(s)")
        for e in errors:
            print(f"  FAIL {e}")
        return 1
    print(f"check_docs: {len(files)} files clean")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
