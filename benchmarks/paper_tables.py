"""Reproductions of every paper table/figure, from the hardware model and
the golden datapath.  Each function returns a list of CSV rows
(name, us_per_call, derived)."""
from __future__ import annotations

import time

import numpy as np

from repro.core import dpa, formats as F
from repro.core.fpnew_ref import sequential_fma_codes
from repro.hwmodel import area as A
from repro.hwmodel import energy as E
from repro.hwmodel import throughput as T
from repro.hwmodel import timing as TM

_FMT = {"fp32": F.FP32, "fp16": F.FP16, "fp8_e4m3": F.FP8_E4M3,
        "fp4_e2m1": F.FP4_E2M1}


def _time(fn, *args, reps=3):
    fn(*args)                      # compile / warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    np.asarray(out)
    return (time.perf_counter() - t0) / reps * 1e6


def table1_modes():
    """Table I: every supported mode executes on the golden datapath;
    derived = ops/issue (the DPA term count)."""
    rows = []
    rng = np.random.default_rng(0)
    lanes = 4096
    for m in T.MODES:
        fa = _FMT[m.fmt]
        fc = _FMT[m.acc_fmt if m.kind != "dpa" else
                  ("fp32" if "fp32" in m.name else "fp16")]
        n = m.ways if m.kind == "dpa" else 1
        a = F.float_to_codes(rng.normal(size=(lanes, n)), fa)
        b = F.float_to_codes(rng.normal(size=(lanes, n)), fa)
        c = F.float_to_codes(rng.normal(size=(lanes,)), fc)
        us = _time(lambda: np.asarray(dpa.dpa_codes_jit(
            a, b, c, fmt_ab=fa.name, fmt_acc=fc.name)))
        rows.append((f"table1/{m.name}", us, f"macs_per_issue={n}"))
    return rows


def fig3_breakdown():
    return [(f"fig3/{k}", 0.0, f"share={v:.2f}")
            for k, v in A.FPNEW_BREAKDOWN.items()]


def fig6a_shifter():
    rows = []
    for d in (200, 250, 300, 350, 400, 500, 650, 800):
        s = TM.shifter_area(d, "single")
        r = TM.shifter_area(d, "reconfig")
        ml = TM.shifter_area(d, "multilane")
        rows.append((f"fig6a/delay_{d}ps", 0.0,
                     f"reconfig/base={r/s:.3f};multilane/base={ml/s:.3f}"))
    rows.append(("fig6a/mux_overhead_n128", 0.0,
                 f"{A.reconfig_overhead(128):.3f} (paper 0.107)"))
    rows.append(("fig6a/mux_overhead_n64", 0.0,
                 f"{A.reconfig_overhead(64):.3f} (paper 0.138)"))
    return rows


def fig6b_multiplier():
    rows = []
    for pipe in (False, True):
        tag = "pipe" if pipe else "comb"
        anchor = 1.0 if pipe else 1.6
        td = TM.multiplier_area(anchor, "transdot", pipelined=pipe)
        sep = TM.multiplier_area(anchor, "separated", pipelined=pipe)
        rows.append((f"fig6b/{tag}_saving_at_{anchor}ns", 0.0,
                     f"{1 - td/sep:.3f} (paper {'0.158' if pipe else '0.154'})"))
        rows.append((f"fig6b/{tag}_min_delay", 0.0,
                     f"transdot={TM.multiplier_min_delay('transdot', pipelined=pipe)}ns;"
                     f"separated={TM.multiplier_min_delay('separated', pipelined=pipe)}ns"))
    return rows


def fig7a_area_efficiency():
    rows = [("fig7a/area_ratio_mean", 0.0,
             f"{A.TRANSDOT_AREA_RATIO_MEAN:.3f} (paper +37.3%)"),
            ("fig7a/merged_simd_ratio", 0.0,
             f"{A.MERGED_SIMD_AREA_RATIO:.4f} (paper -9.44%)")]
    for name in ("fp16_dpa_fp32", "fp8_dpa_fp32", "fp4_dpa_fp32"):
        m = T.MODE_BY_NAME[name]
        lo, hi = T.area_efficiency_range(m)
        rows.append((f"fig7a/eff_{name}", 0.0,
                     f"mean={T.area_efficiency(m):.2f};range=[{lo:.2f},{hi:.2f}]"))
    return rows


def table2_perf_energy():
    rows = []
    for m in T.MODES:
        rows.append((f"table2/{m.name}", 0.0,
                     f"lat={T.latency_cycles(m)}cyc;"
                     f"perf={T.gflops(m):.0f}GFLOPs;"
                     f"energy={E.ENERGY_PJ_PER_FLOP[m.name]}pJ"))
    return rows


def fig1_throughput_motivation():
    """Fig. 1: trans-precision FMA vs DPA throughput, FPnew vs TransDot."""
    rows = []
    for name in ("fp8_fma_scalar", "fp8_fma_simd", "fp8_dpa_fp32"):
        m = T.MODE_BY_NAME[name]
        rows.append((f"fig1/{name}", 0.0,
                     f"fpnew={T.gflops(m, 'fpnew'):.0f};"
                     f"transdot={T.gflops(m):.0f}GFLOPs"))
    return rows


def numerics_dpa_vs_sequential():
    """The paper's numerics motivation quantified: accumulated |error| of
    DPA single rounding vs FPnew per-term rounding, exact-sum reference."""
    rows = []
    rng = np.random.default_rng(1)
    for fmt, n, acc in (("fp16", 2, "fp16"), ("fp8_e4m3", 4, "fp16"),
                        ("fp8_e4m3", 4, "fp32"), ("fp4_e2m1", 8, "fp32")):
        fa, fc = F.get_format(fmt), F.get_format(acc)
        trials = 2000
        a = rng.normal(size=(trials, n))
        b = rng.normal(size=(trials, n))
        ac, bc = F.float_to_codes(a, fa), F.float_to_codes(b, fa)
        cc = np.zeros(trials, np.uint32)
        av = F.codes_to_np(ac, fa).astype(np.float64)
        bv = F.codes_to_np(bc, fa).astype(np.float64)
        exact = (av * bv).sum(1)
        t0 = time.perf_counter()
        got_d = F.codes_to_np(np.asarray(dpa.dpa_codes(ac, bc, cc, fa, fc)),
                              fc).astype(np.float64)
        us = (time.perf_counter() - t0) * 1e6
        got_s = F.codes_to_np(
            np.asarray(sequential_fma_codes(ac, bc, cc, fa, fc)),
            fc).astype(np.float64)
        e_d = np.abs(got_d - exact).mean()
        e_s = np.abs(got_s - exact).mean()
        rows.append((f"numerics/{fmt}x{n}_to_{acc}", us,
                     f"dpa_err={e_d:.2e};seq_err={e_s:.2e};"
                     f"improvement={e_s/max(e_d,1e-300):.2f}x"))
    return rows


def numerics_deep_chain():
    """GEMM-reduction view: a K-length dot executed as K/N chained DPA
    issues vs K chained FMAs (both FP32-accumulated, both rounding once
    per issue).  DPA's K/N-fold fewer roundings is the paper's stability
    story at the workload level."""
    rows = []
    rng = np.random.default_rng(2)
    fa = F.FP8_E4M3
    n = 4
    for fc, K in ((F.FP32, 1024), (F.FP16, 64), (F.FP16, 256),
                  (F.FP16, 1024)):
        trials = 256
        a = rng.normal(size=(trials, K))
        b = rng.normal(size=(trials, K))
        ac, bc = F.float_to_codes(a, fa), F.float_to_codes(b, fa)
        av = F.codes_to_np(ac, fa).astype(np.float64)
        bv = F.codes_to_np(bc, fa).astype(np.float64)
        exact = (av * bv).sum(1)
        acc_d = np.zeros(trials, np.uint32)
        for i in range(0, K, n):       # chained 4-term DPA issues
            acc_d = np.asarray(dpa.dpa_codes(ac[:, i:i + n], bc[:, i:i + n],
                                             acc_d, fa, fc))
        acc_s = np.zeros(trials, np.uint32)
        acc_s = np.asarray(sequential_fma_codes(ac, bc, acc_s, fa, fc))
        e_d = np.abs(F.codes_to_np(acc_d, fc).astype(np.float64)
                     - exact).mean()
        e_s = np.abs(F.codes_to_np(acc_s, fc).astype(np.float64)
                     - exact).mean()
        rel_d = e_d / np.abs(exact).mean()
        rel_s = e_s / np.abs(exact).mean()
        rows.append((f"numerics_chain/fp8x4_K{K}_to_{fc.name}", 0.0,
                     f"dpa_rel={rel_d:.2e};fma_rel={rel_s:.2e};"
                     f"rounds={K//n}v{K}"))
    return rows


ALL = [table1_modes, fig1_throughput_motivation, fig3_breakdown,
       fig6a_shifter, fig6b_multiplier, fig7a_area_efficiency,
       table2_perf_energy, numerics_dpa_vs_sequential,
       numerics_deep_chain]
