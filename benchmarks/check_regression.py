"""CI benchmark-regression gate.

Compares a ``benchmarks/run.py --smoke --json`` dump against the
checked-in `benchmarks/baseline.json` and exits non-zero on regression.

Two kinds of checks, both over the ``key=<float>x`` metrics a row's
derived string carries:

  value+rtol : deterministic quantities (operand / KV-cache bytes-moved
               reductions) — tight, these are modeled bytes, not wall
               clock, so any drift is a real contract change.
  min / max  : sanity tripwires on CPU wall-clock *ratios* (DPA kernel vs
               f32 kernel) — deliberately loose; CI machines are noisy,
               but a 20x blowup means someone broke the kernel path.

A row's baseline entry is one spec or a list of specs (a row's derived
string can carry several ``key=VALx`` metrics — e.g. the engine row pins
both its static-cache and f32-cache byte ratios).

Usage: python benchmarks/check_regression.py bench.json \
           [--baseline benchmarks/baseline.json]
"""
from __future__ import annotations

import json
import os
import sys


def load_rows(path: str) -> dict:
    with open(path) as f:
        rows = json.load(f)
    return {r["name"]: r for r in rows}


def check(current: dict, baseline: dict) -> list:
    failures = []
    for name, specs in baseline["metrics"].items():
        row = current.get(name)
        if row is None:
            failures.append(f"{name}: missing from benchmark output")
            continue
        # a row may pin several derived metrics (a list of specs)
        for spec in specs if isinstance(specs, list) else [specs]:
            failures.extend(_check_spec(name, spec, row))
    return failures


def _check_spec(name: str, spec: dict, row: dict) -> list:
    failures = []
    key = spec["key"]
    got = row.get("metrics", {}).get(key)
    if got is None:
        failures.append(f"{name}: derived metric {key!r} not reported "
                        f"(derived={row.get('derived')!r})")
        return failures
    if "value" in spec:
        want, rtol = spec["value"], spec.get("rtol", 0.05)
        if abs(got - want) > rtol * abs(want):
            failures.append(f"{name}: {key}={got:.3f} drifted from "
                            f"baseline {want:.3f} (rtol {rtol})")
    if "min" in spec and got < spec["min"]:
        failures.append(f"{name}: {key}={got:.3f} < floor {spec['min']}")
    if "max" in spec and got > spec["max"]:
        failures.append(f"{name}: {key}={got:.3f} > ceiling "
                        f"{spec['max']}")
    return failures


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if not argv:
        print(__doc__)
        return 2
    cur_path = argv[0]
    base_path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                             "baseline.json")
    if "--baseline" in argv:
        base_path = argv[argv.index("--baseline") + 1]
    current = load_rows(cur_path)
    with open(base_path) as f:
        baseline = json.load(f)
    failures = check(current, baseline)
    n = len(baseline["metrics"])
    if failures:
        print(f"benchmark regression gate: {len(failures)}/{n} FAILED")
        for f_ in failures:
            print(f"  FAIL {f_}")
        return 1
    print(f"benchmark regression gate: {n} metrics within tolerance")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
