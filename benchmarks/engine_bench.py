"""Serving-engine benchmarks: paged-cache memory + engine decode rate.

The continuous-batching face of the bandwidth story.  A static (B,
S_max) cache prices every request at the longest sequence; the paged
cache prices them at live tokens (page-granular).  Rows report

  sw/paged_kv_live_bytes     : deterministic — bytes a mixed-length
                               workload's pages hold vs the static
                               (B, S_max) cache at the same format
                               (live_vs_static) and vs the f32 seed
                               cache (vs_f32_static).  The regression
                               gate pins both (modeled bytes, any drift
                               is a contract change).
  sw/engine_decode_tokens    : wall-clock of the engine serving a small
                               mixed workload end to end (reduced
                               qwen3-4b, kv4_attn8_packed) + derived
                               decode tokens/s — a loose CPU tripwire,
                               not a TPU number.
"""
from __future__ import annotations

from repro.core import get_policy
from repro.core.kvcache import kv_cache_nbytes, paged_kv_cache_nbytes

# a serving-ish mixed-length snapshot: 8 slots, S_max = 1024, live
# lengths in whole pages so live == paged (the honest comparison)
PAGE, N_SLOTS, MAX_PAGES = 64, 8, 16
LIVE_LENS = (1024, 512, 256, 128, 896, 384, 640, 64)


def paged_cache_bytes():
    """Deterministic: paged live bytes vs the static layouts."""
    pol = get_policy("kv4_attn8_packed")
    n_kv, hd = 8, 128
    live = sum(LIVE_LENS)
    pages = sum(-(-n // PAGE) for n in LIVE_LENS)
    nb = paged_kv_cache_nbytes(live, pages, PAGE, n_kv, hd,
                               fmt=pol.fmt_kv, packed=pol.kv_packed)
    static = kv_cache_nbytes(N_SLOTS, MAX_PAGES * PAGE, n_kv, hd,
                             fmt=pol.fmt_kv, packed=pol.kv_packed)
    return [("sw/paged_kv_live_bytes", float(nb["paged"]),
             f"live_vs_static={static['total'] / nb['paged']:.2f}x "
             f"vs_f32_static={static['f32_total'] / nb['paged']:.2f}x")]


def engine_decode_rate():
    """End-to-end engine wall clock on a small mixed workload."""
    import time

    import jax

    from repro.configs import get_config, reduce_config
    from repro.launch.engine import Engine, EngineConfig, synthetic_workload
    from repro.models import build_model

    cfg = reduce_config(get_config("qwen3-4b")).replace(
        policy="kv4_attn8_packed")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ecfg = EngineConfig(page_size=8, n_pages=48, max_batch=4,
                        max_pages_per_req=6, token_budget=16,
                        prefill_chunk=8)
    reqs = synthetic_workload(6, vocab=cfg.vocab_size, seed=0,
                              prompt_range=(8, 24), gen_range=(4, 10))
    # warm-up run compiles prefill + decode; the timed run reuses them
    engine = Engine(model, params, ecfg)
    engine.run(synthetic_workload(2, vocab=cfg.vocab_size, seed=1,
                                  prompt_range=(8, 24), gen_range=(4, 10)))
    engine.reset_stats()
    t0 = time.perf_counter()
    rep = engine.run(reqs)
    us = (time.perf_counter() - t0) * 1e6
    return [("sw/engine_decode_tokens", us,
             f"tokens_per_s={rep['tokens_per_s']:.1f} "
             f"page_util={rep['page_util']:.2f}x")]


ALL = [paged_cache_bytes, engine_decode_rate]
SMOKE = [paged_cache_bytes, engine_decode_rate]
