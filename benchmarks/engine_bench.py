"""Serving-engine benchmarks: paged-cache memory + engine decode rate.

The continuous-batching face of the bandwidth story.  A static (B,
S_max) cache prices every request at the longest sequence; the paged
cache prices them at live tokens (page-granular).  Rows report

  sw/paged_kv_live_bytes     : deterministic — bytes a mixed-length
                               workload's pages hold vs the static
                               (B, S_max) cache at the same format
                               (live_vs_static) and vs the f32 seed
                               cache (vs_f32_static).  The regression
                               gate pins both (modeled bytes, any drift
                               is a contract change).
  sw/engine_decode_tokens    : wall-clock of the engine serving a small
                               mixed workload end to end (reduced
                               qwen3-4b, kv4_attn8_packed) + derived
                               decode tokens/s — a loose CPU tripwire,
                               not a TPU number.
  engine/paged_decode_kernel_vs_gather :
                               the two `paged_decode` exec-plan routes
                               head to head — block-table Pallas kernel
                               vs the jnp gather fallback — on one
                               batched decode step.  bytes_saved (the
                               gather's HBM view re-materialization the
                               kernel never pays, modeled) is pinned
                               tight; the wall-clock ratio and decode
                               tokens/s are loose CPU-interpret
                               tripwires.
  engine/spec_decode         : self-speculative decoding (greedy) on the
                               same workload.  acceptance_self (a
                               self-draft, draft == verify policy) is
                               pinned EXACTLY 1.0 — the k draft steps
                               and the batched verify are the same
                               computation, so any miss means the
                               multi-token verify path drifted from
                               stepped decode.  acceptance/eff_tokens of
                               the real all-fp4 draft and the spec-vs-
                               plain wall ratio are loose tripwires
                               (random-init weights; CPU, where drafts
                               cost the same as verifies — the
                               throughput win needs the 8x fp4 DPA
                               rate the hwmodel prices).
  engine/adaptive_spec       : the acceptance-feedback draft controller
                               (`repro.runtime.controller`) on mixed
                               traffic vs each static draft rung.
                               switches is pinned >= 1 (the ladder
                               really moves) and round_eff_vs_worst >=
                               1 (per draft+verify round, adaptive
                               emits at least as much as the worst
                               static rung — deterministic, unlike the
                               tokens/s wall tripwire).
"""
from __future__ import annotations

from repro.core import get_policy
from repro.core.kvcache import kv_cache_nbytes, paged_kv_cache_nbytes

# a serving-ish mixed-length snapshot: 8 slots, S_max = 1024, live
# lengths in whole pages so live == paged (the honest comparison)
PAGE, N_SLOTS, MAX_PAGES = 64, 8, 16
LIVE_LENS = (1024, 512, 256, 128, 896, 384, 640, 64)


def paged_cache_bytes():
    """Deterministic: paged live bytes vs the static layouts."""
    pol = get_policy("kv4_attn8_packed")
    n_kv, hd = 8, 128
    live = sum(LIVE_LENS)
    pages = sum(-(-n // PAGE) for n in LIVE_LENS)
    nb = paged_kv_cache_nbytes(live, pages, PAGE, n_kv, hd,
                               fmt=pol.fmt_kv, packed=pol.kv_packed)
    static = kv_cache_nbytes(N_SLOTS, MAX_PAGES * PAGE, n_kv, hd,
                             fmt=pol.fmt_kv, packed=pol.kv_packed)
    return [("sw/paged_kv_live_bytes", float(nb["paged"]),
             f"live_vs_static={static['total'] / nb['paged']:.2f}x "
             f"vs_f32_static={static['f32_total'] / nb['paged']:.2f}x")]


def engine_decode_rate():
    """End-to-end engine wall clock on a small mixed workload."""
    import time

    import jax

    from repro.configs import get_config, reduce_config
    from repro.launch.engine import Engine, EngineConfig, synthetic_workload
    from repro.models import build_model

    cfg = reduce_config(get_config("qwen3-4b")).replace(
        policy="kv4_attn8_packed")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ecfg = EngineConfig(page_size=8, n_pages=48, max_batch=4,
                        max_pages_per_req=6, token_budget=16,
                        prefill_chunk=8)
    reqs = synthetic_workload(6, vocab=cfg.vocab_size, seed=0,
                              prompt_range=(8, 24), gen_range=(4, 10))
    # warm-up run compiles prefill + decode; the timed run reuses them
    engine = Engine(model, params, ecfg)
    engine.run(synthetic_workload(2, vocab=cfg.vocab_size, seed=1,
                                  prompt_range=(8, 24), gen_range=(4, 10)))
    engine.reset_stats()
    t0 = time.perf_counter()
    rep = engine.run(reqs)
    us = (time.perf_counter() - t0) * 1e6
    return [("sw/engine_decode_tokens", us,
             f"tokens_per_s={rep['tokens_per_s']:.1f} "
             f"page_util={rep['page_util']:.2f}x")]


def paged_decode_kernel_vs_gather():
    """One batched decode step through both `paged_decode` routes."""
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import exec_plan
    from repro.core import kvcache as KV

    pol = get_policy("kv4_attn8_packed")
    B, H, n_kv, hd, ps, max_pages = 4, 8, 4, 64, 16, 4
    S = max_pages * ps
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    k = jax.random.normal(ks[0], (B, S, n_kv, hd))
    v = jax.random.normal(ks[1], (B, S, n_kv, hd))
    q = jax.random.normal(ks[2], (B, 1, H, hd))
    ref = KV.update_kv_cache(
        KV.init_kv_cache(B, S, n_kv, hd, fmt=pol.fmt_kv,
                         packed=pol.kv_packed),
        k, v, 0, fmt=pol.fmt_kv, packed=pol.kv_packed)
    cache = KV.paged_from_contiguous(ref, [S] * B, page_size=ps)
    positions = jnp.asarray([S - 1] * B, jnp.int32)

    ctx = dict(batch=B, page_size=ps, max_pages=max_pages, kv_heads=n_kv,
               hd=hd)
    kernel = exec_plan.route("paged_decode", "pallas_block_table")
    gather = exec_plan.route("paged_decode", "jnp_gather")

    def timed(entry, reps=3):
        entry.run(q, cache, positions, policy=pol,
                  scale=hd ** -0.5).block_until_ready()   # compile
        t0 = time.perf_counter()
        for _ in range(reps):
            out = entry.run(q, cache, positions, policy=pol,
                            scale=hd ** -0.5)
        out.block_until_ready()
        return (time.perf_counter() - t0) / reps * 1e6

    us_k, us_g = timed(kernel), timed(gather)
    # bytes_saved derived from *actual array sizes*, independent of the
    # registry's bytes_moved model (which the gate would otherwise just
    # re-derive): the gather route reads the view's pages, writes the
    # re-materialized view, then attention reads it back; the kernel
    # streams exactly one pass of codes+scales through the block table.
    view = KV.gather_paged_kv(cache)
    view_b = sum(np.asarray(view[key]).nbytes for key in KV.QUANT_KEYS)
    gather_bytes = 3 * view_b
    saved = gather_bytes / kernel.bytes_moved(pol, ctx)
    return [("engine/paged_decode_kernel_vs_gather", us_k,
             f"bytes_saved={saved:.2f}x "
             f"kernel_vs_gather={us_k / us_g:.2f}x "
             f"tokens_per_s={B / (us_k / 1e6):.1f}")]


def spec_decode():
    """Speculative vs plain greedy decode on one mixed workload."""
    import time

    import jax

    from repro.configs import get_config, reduce_config
    from repro.launch.engine import Engine, EngineConfig, SpecConfig, \
        synthetic_workload
    from repro.models import build_model

    cfg = reduce_config(get_config("qwen3-4b")).replace(
        policy="kv4_attn8_packed")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ecfg = EngineConfig(page_size=8, n_pages=48, max_batch=4,
                        max_pages_per_req=6, token_budget=32,
                        prefill_chunk=8)
    k = 3

    def run(spec, seed=0):
        engine = Engine(model, params, ecfg, spec=spec)
        # warm-up compiles draft/verify/decode; the timed run reuses them
        engine.run(synthetic_workload(2, vocab=cfg.vocab_size, seed=1,
                                      prompt_range=(8, 24),
                                      gen_range=(4, 10)))
        engine.reset_stats()
        reqs = synthetic_workload(6, vocab=cfg.vocab_size, seed=seed,
                                  prompt_range=(8, 24), gen_range=(4, 10))
        t0 = time.perf_counter()
        rep = engine.run(reqs)
        return (time.perf_counter() - t0) * 1e6, rep

    us_plain, _ = run(None)
    us_spec, rep = run(SpecConfig("w4a4_kv4_attn4", k=k))
    _, rep_self = run(SpecConfig("kv4_attn8_packed", k=k))
    return [("engine/spec_decode", us_spec,
             f"acceptance_self={rep_self['acceptance_rate']:.3f}x "
             f"acceptance_fp4={rep['acceptance_rate']:.2f}x "
             f"eff_tokens_per_round={rep['eff_tokens_per_round']:.2f}x "
             f"spec_vs_plain={us_spec / us_plain:.2f}x "
             f"tokens_per_s={rep['tokens_per_s']:.1f}")]


def adaptive_spec():
    """Adaptive trans-precision drafting vs each static draft rung on
    mixed (heterogeneous) traffic.

    The controller starts on the cheapest rung (fp4) and walks the
    ladder on acceptance feedback; random-init weights keep fp4
    acceptance low, so the run provably switches (switches is pinned
    >= 1).  round_eff_vs_worst — adaptive emitted-tokens-per-round over
    the *worst* static rung's — is the headline tripwire: every rung
    runs the same draft k, so a round is a fixed unit of draft+verify
    work and the ratio is deterministic (wall clocks under Pallas
    interpret mode are far too noisy to gate on).  It is
    penalty-inclusive: rung-grouped ticks fragment the batch into one
    round per live rung, and those smaller rounds drag the adaptive
    numerator down.  Per unit of draft+verify work the controller must
    still emit at least as much as pinning the worst rung for the whole
    workload.  tokens_per_s stays a loose wall-clock CPU tripwire."""
    import time

    import jax

    from repro.configs import get_config, reduce_config
    from repro.launch.engine import Engine, EngineConfig, SpecConfig, \
        synthetic_workload
    from repro.models import build_model
    from repro.runtime.controller import ControllerConfig, default_ladder

    cfg = reduce_config(get_config("qwen3-4b")).replace(
        policy="kv4_attn8_packed")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # S_max = 128: mixed traffic stretches prompts to 4x16=64 and gens
    # to 4x8=32; the pool holds 4 such requests plus scratch
    ecfg = EngineConfig(page_size=8, n_pages=96, max_batch=4,
                        max_pages_per_req=16, token_budget=32,
                        prefill_chunk=8)
    k, ladder = 2, default_ladder(cfg.policy)

    def workload(seed):
        return synthetic_workload(6, vocab=cfg.vocab_size, seed=seed,
                                  prompt_range=(8, 16), gen_range=(4, 8),
                                  mixed=0.3)

    def run(**kw):
        engine = Engine(model, params, ecfg, **kw)
        engine.run(workload(seed=1))     # warm-up compiles every view
        engine.reset_stats()
        reqs = workload(seed=0)
        t0 = time.perf_counter()
        rep = engine.run(reqs)
        return (time.perf_counter() - t0) * 1e6, rep

    static_eff = {name: run(spec=SpecConfig(name, k=k))[1]
                  ["eff_tokens_per_round"] for name in ladder}
    acfg = ControllerConfig(ladder, k=k, start=0, dwell=1)
    us_adapt, rep = run(adaptive=acfg)
    worst = min(static_eff.values())
    return [("engine/adaptive_spec", us_adapt,
             f"round_eff_vs_worst={rep['eff_tokens_per_round'] / worst:.2f}x "
             f"switches={float(rep['adaptive_switches']):.0f}x "
             f"acceptance={rep['acceptance_rate']:.2f}x "
             f"eff_tokens_per_round={rep['eff_tokens_per_round']:.2f}x "
             f"tokens_per_s={rep['tokens_per_s']:.1f}")]


def prefix_cache():
    """Prefix-sharing on a shared-system-prompt workload.

    Every request carries the same 16-token preamble; served one at a
    time through a warm engine, every request after the first hits the
    radix index.  hit_rate and prefill_saved are deterministic (token
    accounting, no wall clock) and pinned by the regression gate; the
    derived tokens/s is a loose CPU tripwire."""
    import time

    import jax

    from repro.configs import get_config, reduce_config
    from repro.launch.engine import Engine, EngineConfig, synthetic_workload
    from repro.models import build_model

    cfg = reduce_config(get_config("qwen3-4b")).replace(
        policy="kv4_attn8_packed")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ecfg = EngineConfig(page_size=8, n_pages=64, max_batch=4,
                        max_pages_per_req=8, token_budget=16,
                        prefill_chunk=8, prefix_cache=True)
    engine = Engine(model, params, ecfg)

    def workload(seed):
        return synthetic_workload(6, vocab=cfg.vocab_size, seed=seed,
                                  prompt_range=(4, 12), gen_range=(4, 8),
                                  shared_prefix=16)

    # warm-up compiles prefill/decode AND seeds the resident prefix,
    # then drop it: the timed run measures cold-index -> warm-index
    engine.run(workload(seed=1))
    engine.prefix.drop_all()
    engine.reset_stats()
    reqs = workload(seed=0)
    t0 = time.perf_counter()
    for r in reqs:                       # sequential: later reqs hit
        engine.run([r])
    us = (time.perf_counter() - t0) * 1e6
    rep = engine.report((time.perf_counter() - t0))
    return [("engine/prefix_cache", us,
             f"hit_rate={rep['prefix_hit_rate']:.3f}x "
             f"prefill_saved={float(rep['prefill_tokens_saved']):.1f}x "
             f"cow_copies={float(rep['prefix_cow_copies']):.1f}x "
             f"tokens_per_s={rep['tokens_per_s']:.1f}")]


def tp_collective_bytes():
    """Bytes on the tensor-parallel wire, measured from the actual
    arrays (``.nbytes``), not the bytes model.

      wire_fp16 / wire_fp8 : f32 payload bytes vs the codes + scale
          `quantize_for_wire` actually ships for a (256, 1024) f32 slab
          — the wire contract of the serving/training collectives
          (Table-I widths: ~2x / ~4x under an f32 wire).
      kv_pool_wire : f32 KV pool bytes per layer vs the packed-fp4
          codes+scales a TP shard all-gathers per decode step (reduced
          qwen3-4b, kv4_attn8_packed — the same arrays `Engine.report`
          prices as tp_wire_bytes_per_step_layer).
      tokens_per_s : the engine serving with tp=8 *requested* — on the
          single-device bench job this exercises the replicate-not-
          crash fallback end to end; a loose CPU tripwire.
    """
    import time

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, reduce_config
    from repro.core.kvcache import QUANT_KEYS
    from repro.distributed.collectives import quantize_for_wire
    from repro.launch.engine import Engine, EngineConfig, synthetic_workload
    from repro.models import build_model

    x = jax.random.normal(jax.random.PRNGKey(0), (256, 1024), jnp.float32)
    wire = {}
    for fmt in ("fp16", "fp8_e4m3"):
        q, s = quantize_for_wire(x, fmt)
        wire[fmt] = x.nbytes / (q.nbytes + s.nbytes)

    cfg = reduce_config(get_config("qwen3-4b")).replace(
        policy="kv4_attn8_packed")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ecfg = EngineConfig(page_size=8, n_pages=48, max_batch=4,
                        max_pages_per_req=6, token_budget=16,
                        prefill_chunk=8, tp=8)
    engine = Engine(model, params, ecfg)
    g = engine.caches["groups"]["p0"]
    pool_layer = sum(int(g[k].nbytes)
                     for k in QUANT_KEYS) // engine._n_groups
    f32_layer = 2 * 4 * (ecfg.n_pages * ecfg.page_size
                         * cfg.n_kv_heads * cfg.hd)
    # warm-up compiles prefill + decode; the timed run reuses them
    engine.run(synthetic_workload(2, vocab=cfg.vocab_size, seed=1,
                                  prompt_range=(8, 24), gen_range=(4, 10)))
    engine.reset_stats()
    t0 = time.perf_counter()
    rep = engine.run(synthetic_workload(4, vocab=cfg.vocab_size, seed=0,
                                        prompt_range=(8, 24),
                                        gen_range=(4, 10)))
    us = (time.perf_counter() - t0) * 1e6
    return [("engine/tp_collective_bytes", us,
             f"wire_fp16={wire['fp16']:.3f}x "
             f"wire_fp8={wire['fp8_e4m3']:.3f}x "
             f"kv_pool_wire={f32_layer / pool_layer:.3f}x "
             f"tokens_per_s={rep['tokens_per_s']:.1f}")]


def moe_grouped_dpa():
    """MoE serving through the fused quantize->pack->grouped-DPA expert
    pipeline (reduced granite-moe, 8 experts top-2).

      expert_w_red_fp8 / expert_w_red_fp4 : expert-weight bytes at the
          grouped route's operand interface vs the f32 expert residency
          the seed paid — deterministic byte accounting from the engine
          report (fp8 preset exactly 4x, packed-fp4 preset exactly 8x),
          pinned tight by the regression gate.
      operand_red_fp4 : grouped-matmul operand bytes per decode step
          (packed fp4 weights + fp8 activations) vs both stacks at f32
          width — the route's bytes model, deterministic.
      tokens_per_s : the engine end to end under the packed preset — a
          loose CPU-interpret tripwire, not a TPU number.
    """
    import time

    import jax

    from repro.configs import get_config, reduce_config
    from repro.launch.engine import Engine, EngineConfig, synthetic_workload
    from repro.models import build_model

    base = reduce_config(get_config("granite-moe-1b-a400m"))
    ecfg = EngineConfig(page_size=8, n_pages=48, max_batch=4,
                        max_pages_per_req=6, token_budget=16,
                        prefill_chunk=8)

    def serve(policy, seed=0):
        cfg = base.replace(policy=policy)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        engine = Engine(model, params, ecfg)
        # warm-up compiles prefill + decode; the timed run reuses them
        engine.run(synthetic_workload(2, vocab=cfg.vocab_size, seed=1,
                                      prompt_range=(8, 16),
                                      gen_range=(4, 8)))
        engine.reset_stats()
        reqs = synthetic_workload(4, vocab=cfg.vocab_size, seed=seed,
                                  prompt_range=(8, 16), gen_range=(4, 8))
        t0 = time.perf_counter()
        rep = engine.run(reqs)
        return (time.perf_counter() - t0) * 1e6, rep

    _, rep8 = serve("w8a8_kv8_attn8")
    us, rep4 = serve("w4a8_kv4_attn8")
    ctx = dict(rep4)
    wide = 4.0  # f32 bytes per element, both operand stacks
    mk = ecfg.max_batch * (int(base.capacity_factor * base.top_k
                               / base.n_experts) + 1)
    emk = base.n_experts * mk * base.d_model
    ekn = base.n_experts * base.d_model * base.d_ff
    operand_red = wide * (emk + ekn) / ctx["moe_grouped_bytes_per_step_layer"]
    return [("engine/moe_grouped_dpa", us,
             f"expert_w_red_fp8={rep8['expert_w_reduction_vs_f32']:.2f}x "
             f"expert_w_red_fp4={rep4['expert_w_reduction_vs_f32']:.2f}x "
             f"operand_red_fp4={operand_red:.2f}x "
             f"tokens_per_s={rep4['tokens_per_s']:.1f}")]


def tuned_vs_static():
    """Tuned resolution vs static priority, over the shipped CI DB.

      db_ratio : min over the shipped DB's (op, policy, shape-class)
          keys of us(static-priority config) / us(tuned selection).
          >= 1.0 *by construction* — the tuned selection is the argmin
          over a measured pool that always contains the static config
          (every knob grid includes the defaults) — so the gate pins
          the invariant: a tuned table never selects a measured-slower
          config on any CI shape-class.  keys counts the shape-classes
          covered (drops mean the smoke sweep lost coverage).
      tuned_vs_static : live re-measure of the shape-class where the DB
          disagrees with priority order the most, resolved tuned vs
          static — a loose CPU tripwire that the consult actually
          changes what runs.
    """
    import os
    import time

    import jax

    from repro.core import exec_plan
    from repro.runtime import tuner

    db_path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "tuned", "ci_default.json")
    db = tuner.load_db(db_path)
    keys = sorted({(r["op"], r["policy"], r["shape_class"])
                   for r in db["records"].values()
                   if r["op"] != tuner.ENGINE_OP})
    ratios = {}
    for op, preset, cls in keys:
        pol = get_policy(preset)
        sc = tuner.shape_class(op, cls)
        static = exec_plan.resolve(op, pol, **sc.rep)
        pool = [r for r in db["records"].values()
                if (r["op"], r["policy"], r["shape_class"])
                == (op, preset, cls)]
        static_rec = [r for r in pool if r["route"] == static.name
                      and not r.get("knobs")]
        best = tuner._best_record(db, op, tuner.policy_key(pol), cls)
        if static_rec and best:
            ratios[(op, preset, cls)] = static_rec[0]["us"] / best["us"]
    db_ratio = min(ratios.values())
    # live tripwire at the key the DB reorders hardest
    op, preset, cls = max(ratios, key=ratios.get)
    pol = get_policy(preset)
    sc = tuner.shape_class(op, cls)
    args, kwargs = tuner._cutout(op, cls, pol)

    def timed(entry, reps=3):
        jax.block_until_ready(entry.run(*args, **kwargs))   # compile
        t0 = time.perf_counter()
        for _ in range(reps):
            out = entry.run(*args, **kwargs)
        jax.block_until_ready(out)
        return (time.perf_counter() - t0) / reps * 1e6

    prior = exec_plan.resolve(op, pol, **sc.rep)
    saved = os.environ.get("REPRO_TUNED_DB")
    try:
        os.environ["REPRO_TUNED_DB"] = db_path
        tuner.clear_caches()
        tuned = exec_plan.resolve(op, pol, **sc.rep)
        us_tuned, us_static = timed(tuned), timed(prior)
    finally:
        if saved is None:
            os.environ.pop("REPRO_TUNED_DB", None)
        else:
            os.environ["REPRO_TUNED_DB"] = saved
        tuner.clear_caches()
    return [("engine/tuned_vs_static", us_tuned,
             f"db_ratio={db_ratio:.3f}x keys={float(len(ratios)):.0f}x "
             f"tuned_vs_static={us_static / us_tuned:.2f}x")]


ALL = [paged_cache_bytes, engine_decode_rate, paged_decode_kernel_vs_gather,
       spec_decode, adaptive_spec, prefix_cache, tp_collective_bytes,
       moe_grouped_dpa, tuned_vs_static]
SMOKE = [paged_cache_bytes, engine_decode_rate, paged_decode_kernel_vs_gather,
         spec_decode, adaptive_spec, prefix_cache, tp_collective_bytes,
         moe_grouped_dpa, tuned_vs_static]
