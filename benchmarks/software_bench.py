"""Software-path benchmarks: kernels, policies, end-to-end steps.

CPU wall-times are *relative* signals (the TPU target is modeled by the
roofline); what these benches pin down is the policy overhead structure
(quantize cost vs matmul cost) and the end-to-end step viability.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduce_config
from repro.core import get_policy
from repro.data.pipeline import DataConfig, make_pipeline
from repro.distributed.step import make_train_step
from repro.models import build_model
from repro.optim import adamw


def _time(fn, reps=5):
    out = fn()
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def dpa_dot_policies():
    """fake-quant DPA dot cost by policy vs plain f32 (jit, CPU)."""
    from repro.core.linear import dpa_dot
    rows = []
    x = jax.random.normal(jax.random.PRNGKey(0), (512, 1024), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (1024, 1024), jnp.float32)
    base = None
    for pol in ("fp32", "bf16_dpa", "fp16_dpa", "fp8_dpa", "fp4_dpa"):
        p = get_policy(pol)
        f = jax.jit(lambda x, w, p=p: dpa_dot(x, w, p))
        us = _time(lambda: f(x, w))
        base = base or us
        rows.append((f"sw/dpa_dot_{pol}", us, f"vs_fp32={us/base:.2f}x"))
    return rows


def packed_pipeline():
    """The quantize->pack->DPA operand-bandwidth story (paper Table I).

    Reports, per operand format, the bytes an (M,K)x(K,N) matmul moves
    through the fixed-width interface (quantized operands + scales) and
    the reduction vs f32 — expected 2x/4x/8x for fp16/fp8/packed-fp4 —
    plus interpret-mode wall-times for the packed and fused kernel paths
    (relative signals; the bytes are the modeled TPU quantity)."""
    from repro.core.packing import matmul_operand_bytes
    from repro.kernels import ops as O
    rows = []
    M, K, N = 256, 512, 256
    x = jax.random.normal(jax.random.PRNGKey(0), (M, K), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (K, N), jnp.float32)
    for pol in ("fp16_dpa", "fp8_dpa", "fp4_dpa_packed"):
        b = matmul_operand_bytes(M, K, N, pol)
        rows.append((f"sw/operand_bytes_{pol}", float(b["total"]),
                     f"reduction_vs_f32={b['reduction_vs_f32']:.2f}x"))
    for pol in ("fp4_dpa_packed", "fp4_dpa_fused", "fp8_dpa_fused"):
        us = _time(lambda pol=pol: O.dpa_matmul(x, w, get_policy(pol)),
                   reps=2)
        rows.append((f"sw/pallas_dpa_matmul_{pol}_interpret", us,
                     "packed/fused kernel path"))
    us = _time(lambda: O.quantize_rows(x, "fp4_e2m1", pack=True), reps=2)
    rows.append(("sw/pallas_quantize_pack_rows_interpret", us,
                 "fused absmax+cast+nibble-pack"))
    return rows


def pallas_kernels():
    rows = []
    from repro.kernels import ops as O
    x = jax.random.normal(jax.random.PRNGKey(0), (256, 512), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (512, 256), jnp.float32)
    pol = get_policy("fp8_dpa")
    us = _time(lambda: O.dpa_matmul(x, w, pol), reps=2)
    rows.append(("sw/pallas_dpa_matmul_interpret", us,
                 "interpret-mode (TPU target: MXU fp8)"))
    us = _time(lambda: O.quantize_rows(x, "fp8_e4m3"), reps=2)
    rows.append(("sw/pallas_quantize_rows_interpret", us, ""))
    q = jax.random.normal(jax.random.PRNGKey(2), (1, 8, 256, 64))
    kv = jax.random.normal(jax.random.PRNGKey(3), (1, 2, 256, 64))
    us = _time(lambda: O.flash_attention(q, kv, kv), reps=2)
    rows.append(("sw/pallas_flash_attention_interpret", us, "gqa 8:2"))
    return rows


def e2e_train_step():
    """Reduced-config train step by family (jit, CPU)."""
    rows = []
    for arch in ("llama3.2-3b", "granite-moe-1b-a400m",
                 "recurrentgemma-9b", "xlstm-1.3b"):
        cfg = reduce_config(get_config(arch))
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        state = {"params": params, "opt": adamw.init(params)}
        pipe = make_pipeline(DataConfig(
            vocab_size=cfg.vocab_size, batch=4, seq=64,
            frontend=cfg.frontend, d_model=cfg.d_model,
            frames=16 if cfg.family == "encdec" else 0))
        step = jax.jit(make_train_step(model, adamw.AdamWConfig()))
        batch = pipe.batch(0)
        state, _ = step(state, batch)          # compile
        t0 = time.perf_counter()
        for i in range(3):
            state, m = step(state, pipe.batch(i + 1))
        jax.block_until_ready(state)
        us = (time.perf_counter() - t0) / 3 * 1e6
        rows.append((f"sw/train_step_{arch}", us,
                     f"loss={float(m['loss']):.3f}"))
    return rows


def e2e_decode_step():
    rows = []
    cfg = reduce_config(get_config("qwen3-4b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    from repro.distributed.step import make_serve_step
    serve = jax.jit(make_serve_step(model), donate_argnums=(2,))
    caches = model.init_caches(8, 128)
    batch = {"tokens": jnp.ones((8, 1), jnp.int32), "index": jnp.int32(5)}
    tok, caches = serve(params, batch, caches)   # compile
    t0 = time.perf_counter()
    for i in range(10):
        tok, caches = serve(params, {"tokens": tok[:, None],
                                     "index": jnp.int32(6 + i)}, caches)
    jax.block_until_ready(tok)
    us = (time.perf_counter() - t0) / 10 * 1e6
    rows.append(("sw/decode_step_qwen3-4b-reduced", us, "batch=8 ctx=128"))
    return rows


ALL = [dpa_dot_policies, packed_pipeline, pallas_kernels, e2e_train_step,
       e2e_decode_step]
SMOKE = [dpa_dot_policies, packed_pipeline]
