"""Attention-path benchmarks: KV-cache bytes moved + DPA attention cost.

The serving-side face of the paper's bandwidth story, applied to the
hottest path: every decode step streams the whole KV cache, so the cache
byte reduction IS the per-token HBM saving.  Rows report

  sw/attn_kv_bytes_<policy>     : bytes one layer's K+V cache moves per
                                  decode sweep (codes + scales), with the
                                  reduction vs the seed f32 cache —
                                  2x/~3.9x/~7.5x for fp16/fp8/packed fp4.
  sw/attn_decode_<policy>       : jit wall-time of one quantized-cache
                                  DPA decode step + derived tokens/s
                                  (CPU-relative signal).
  sw/pallas_dpa_attention_*     : interpret-mode DPA flash-attention
                                  kernel wall vs the f32 flash kernel
                                  (sanity tripwire, not a TPU number).

The deterministic byte ratios are what the CI regression gate
(`benchmarks/check_regression.py` vs `benchmarks/baseline.json`) pins.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import get_policy
from repro.core.kvcache import (dequantize_cache, init_kv_cache,
                                kv_cache_nbytes, update_kv_cache)


def _time(fn, reps=3):
    out = fn()
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def kv_cache_bytes():
    """Deterministic: cache bytes per policy at a serving-ish shape."""
    rows = []
    B, S, KV, hd = 8, 1024, 8, 128
    for pol_name in ("attn_fp16_dpa", "attn_fp8_dpa", "kv4_attn8_packed"):
        pol = get_policy(pol_name)
        nb = kv_cache_nbytes(B, S, KV, hd, fmt=pol.fmt_kv,
                             packed=pol.kv_packed)
        rows.append((f"sw/attn_kv_bytes_{pol_name}", float(nb["total"]),
                     f"reduction_vs_f32={nb['reduction_vs_f32']:.2f}x"))
    return rows


def dpa_attention_kernels():
    """Interpret-mode DPA flash attention vs the f32 flash kernel."""
    from repro.kernels import ops as O
    rows = []
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 4, 256, 64))
    kv = jax.random.normal(jax.random.PRNGKey(1), (1, 2, 256, 64))
    base = _time(lambda: O.flash_attention(q, kv, kv), reps=2)
    rows.append(("sw/pallas_flash_attention_f32_interpret", base, "gqa 4:2"))
    for fmt, kvf in (("fp16", None), ("fp8_e4m3", None),
                     ("fp8_e4m3", "fp4_e2m1")):
        tag = fmt if kvf is None else f"{fmt}_kv4"
        us = _time(lambda fmt=fmt, kvf=kvf: O.dpa_flash_attention(
            q, kv, kv, fmt=fmt, fmt_kv=kvf), reps=2)
        rows.append((f"sw/pallas_dpa_attention_{tag}_interpret", us,
                     f"vs_f32_kernel={us / base:.2f}x"))
    return rows


def decode_step_tokens():
    """Jit'd single-token DPA decode against a quantized cache: wall time
    and tokens/s per policy, f32 jnp attention as the baseline."""
    from repro.models.decode_attn import dpa_decode_attn
    rows = []
    B, S, H, KV, hd = 8, 1024, 8, 8, 128
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, 1, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32)

    @jax.jit
    def f32_step(q, k, v):
        # takes q/k/v as arguments — a zero-arg closure would let XLA
        # constant-fold the whole computation and time a cached buffer
        logits = jnp.einsum("bqhd,bshd->bhqs", q, k) * hd ** -0.5
        p = jax.nn.softmax(logits, axis=-1)
        return jnp.einsum("bhqs,bshd->bqhd", p, v)

    base = _time(lambda: f32_step(q, k, v))
    rows.append(("sw/attn_decode_f32", base,
                 f"tokens_per_s={B / (base / 1e6):.0f}"))
    for pol_name in ("attn_fp8_dpa", "kv4_attn8_packed"):
        pol = get_policy(pol_name)
        cache = init_kv_cache(B, S, KV, hd, fmt=pol.fmt_kv,
                              packed=pol.kv_packed)
        cache = update_kv_cache(cache, k, v, 0, fmt=pol.fmt_kv,
                                packed=pol.kv_packed)
        step = jax.jit(lambda q, c, pol=pol: dpa_decode_attn(
            q, c, S - 1, fmt=pol.fmt_attn, fmt_kv=pol.fmt_kv,
            kv_packed=pol.kv_packed, scale=hd ** -0.5))
        us = _time(lambda: step(q, cache))
        rows.append((f"sw/attn_decode_{pol_name}", us,
                     f"tokens_per_s={B / (us / 1e6):.0f}"))
    # cache round-trip cost (quantize+write+dequant): the VMEM-side work
    pol = get_policy("kv4_attn8_packed")
    rt = jax.jit(lambda k, v: dequantize_cache(
        update_kv_cache(init_kv_cache(B, S, KV, hd, fmt=pol.fmt_kv,
                                      packed=pol.kv_packed),
                        k, v, 0, fmt=pol.fmt_kv, packed=pol.kv_packed),
        fmt=pol.fmt_kv, packed=pol.kv_packed))
    us = _time(lambda: rt(k, v))
    rows.append(("sw/kv_cache_roundtrip_kv4_packed", us,
                 "quantize+pack+write+dequant"))
    return rows


ALL = [kv_cache_bytes, dpa_attention_kernels, decode_step_tokens]
SMOKE = [kv_cache_bytes, dpa_attention_kernels, decode_step_tokens]
