"""Benchmark driver: one function per paper table/figure + software
benches.  Prints ``name,us_per_call,derived`` CSV.

Flags: --paper-only (skip software benches), --smoke (CI gate: the fast
software subset only — policy dots + the packed/fused operand-bandwidth
pipeline; no paper figures, no e2e train/decode steps).
"""
from __future__ import annotations

import os
import sys

# allow `python benchmarks/run.py` from anywhere: the repo root (for the
# `benchmarks` package) and src/ (for `repro`) both go on sys.path
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _ROOT)
sys.path.insert(0, os.path.join(_ROOT, "src"))


def main() -> None:
    from benchmarks import paper_tables, software_bench
    if "--smoke" in sys.argv:
        suites = list(software_bench.SMOKE)
    else:
        suites = list(paper_tables.ALL)
        if "--paper-only" not in sys.argv:
            suites += list(software_bench.ALL)
    print("name,us_per_call,derived")
    failures = []
    for fn in suites:
        try:
            for name, us, derived in fn():
                print(f"{name},{us:.1f},{derived}")
        except Exception as e:                      # pragma: no cover
            failures.append((fn.__name__, repr(e)))
            print(f"{fn.__name__},ERROR,{e!r}")
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
