"""Benchmark driver: one function per paper table/figure + software
benches.  Prints ``name,us_per_call,derived`` CSV."""
from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import paper_tables, software_bench
    suites = list(paper_tables.ALL)
    if "--paper-only" not in sys.argv:
        suites += list(software_bench.ALL)
    print("name,us_per_call,derived")
    failures = []
    for fn in suites:
        try:
            for name, us, derived in fn():
                print(f"{name},{us:.1f},{derived}")
        except Exception as e:                      # pragma: no cover
            failures.append((fn.__name__, repr(e)))
            print(f"{fn.__name__},ERROR,{e!r}")
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
