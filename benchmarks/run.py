"""Benchmark driver: one function per paper table/figure + software
benches.  Prints ``name,us_per_call,derived`` CSV.

Flags:
  --paper-only : skip software benches.
  --smoke      : CI gate subset — policy dots, the packed/fused
                 operand-bandwidth pipeline, the DPA-attention /
                 KV-cache suite, and the paged-cache serving engine;
                 no paper figures, no e2e train steps.
  --json PATH  : also dump rows as JSON (name/us_per_call/derived plus
                 any parsed ``key=<float>x`` derived metrics) — the
                 artifact `benchmarks/check_regression.py` gates on.
"""
from __future__ import annotations

import json
import os
import re
import sys

# allow `python benchmarks/run.py` from anywhere: the repo root (for the
# `benchmarks` package) and src/ (for `repro`) both go on sys.path
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _ROOT)
sys.path.insert(0, os.path.join(_ROOT, "src"))

_DERIVED_RE = re.compile(r"([A-Za-z0-9_]+)=([-+0-9.eE]+)x?")


def parse_derived(derived: str) -> dict:
    """``key=VALx`` tokens in a derived string -> {key: float}."""
    return {k: float(v) for k, v in _DERIVED_RE.findall(derived)}


def main() -> None:
    from benchmarks import (attention_bench, engine_bench, paper_tables,
                            software_bench)
    json_path = None
    if "--json" in sys.argv:
        i = sys.argv.index("--json") + 1
        if i >= len(sys.argv) or sys.argv[i].startswith("--"):
            raise SystemExit("--json needs an output path, e.g. "
                             "--json bench.json")
        json_path = sys.argv[i]
    if "--smoke" in sys.argv:
        suites = (list(software_bench.SMOKE) + list(attention_bench.SMOKE)
                  + list(engine_bench.SMOKE))
    else:
        suites = list(paper_tables.ALL)
        if "--paper-only" not in sys.argv:
            suites += (list(software_bench.ALL) + list(attention_bench.ALL)
                       + list(engine_bench.ALL))
    print("name,us_per_call,derived")
    rows = []
    failures = []
    for fn in suites:
        try:
            for name, us, derived in fn():
                print(f"{name},{us:.1f},{derived}")
                rows.append({"name": name, "us_per_call": us,
                             "derived": derived,
                             "metrics": parse_derived(derived)})
        except Exception as e:                      # pragma: no cover
            failures.append((fn.__name__, repr(e)))
            print(f"{fn.__name__},ERROR,{e!r}")
    if json_path:
        with open(json_path, "w") as f:
            json.dump(rows, f, indent=1)
        print(f"# wrote {len(rows)} rows to {json_path}", file=sys.stderr)
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
