"""Sharded flash-decoding: shard-local KV-cache update + partial softmax.

Auto-SPMD cannot see that a decode step's cache update touches one
sequence shard, nor that attention against a sequence-sharded cache only
needs (max, denom, weighted-V) per shard — it all-gathers the cache every
layer (measured: 2 x S_shard x KV x hd gathers/layer, 80 GB/step on
dbrx-132b decode; EXPERIMENTS.md §Perf).  This module is the manual
version: a shard_map over the "model" axis that

  1. writes k/v into the *owning* shard only (branchless in-range mask),
  2. computes local logits + local (max, exp-sum, exp-weighted V),
  3. combines across shards with three tiny collectives
     (B*H + B*H + B*H*hd floats — ~1e4x less wire than the gather).

The "data"/"pod" axes stay automatic, so the same code serves any DP
layout.  Used by layers.apply_attention when cfg.flash_decode is set and
the ambient mesh carries a "model" axis.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def _local_update(cache, new, offset, axis_name):
    """Write `new` (B,1,KV,hd) at global position `offset` into this
    device's sequence shard of `cache` (B, S_loc, KV, hd)."""
    idx = jax.lax.axis_index(axis_name)
    s_loc = cache.shape[1]
    local_off = offset - idx * s_loc
    in_range = (local_off >= 0) & (local_off < s_loc)
    off_c = jnp.clip(local_off, 0, s_loc - 1)
    z = jnp.zeros((), jnp.int32)
    written = jax.lax.dynamic_update_slice(
        cache, new.astype(cache.dtype),
        (z, off_c.astype(jnp.int32), z, z))
    return jnp.where(in_range, written, cache)


def _flash_decode_body(q, k_new, v_new, kc, vc, offset, *, axis_name,
                       scale):
    """Per-shard body.  q: (B,1,H,hd); kc/vc: (B,S_loc,KV,hd) local shard.
    Returns (out (B,1,H,hd), kc', vc')."""
    B, _, H, hd = q.shape
    s_loc = kc.shape[1]
    KV = kc.shape[2]
    g = H // KV
    idx = jax.lax.axis_index(axis_name)

    kc = _local_update(kc, k_new, offset, axis_name)
    vc = _local_update(vc, v_new, offset, axis_name)

    kh = jnp.repeat(kc.astype(q.dtype), g, axis=2)       # (B,S_loc,H,hd)
    vh = jnp.repeat(vc.astype(q.dtype), g, axis=2)
    logits = jnp.einsum("bqhd,bshd->bhqs", q, kh,
                        preferred_element_type=jnp.float32) * scale
    pos = idx * s_loc + jnp.arange(s_loc)
    valid = pos <= offset                                 # causal
    logits = jnp.where(valid[None, None, None, :], logits, -1e30)

    m_loc = jnp.max(logits, axis=-1)                      # (B,H,1)
    m_glob = jax.lax.pmax(m_loc, axis_name)
    p = jnp.exp(logits - m_glob[..., None])
    den = jax.lax.psum(jnp.sum(p, axis=-1), axis_name)    # (B,H,1)
    num = jnp.einsum("bhqs,bshd->bqhd", p.astype(q.dtype), vh,
                     preferred_element_type=jnp.float32)
    num = jax.lax.psum(num, axis_name)                    # (B,1,H,hd)
    out = num / jnp.maximum(den, 1e-30).transpose(0, 2, 1)[..., None]
    return out.astype(q.dtype), kc, vc


def flash_decode(q, k_new, v_new, k_cache, v_cache, offset, mesh,
                 *, scale):
    """shard_map wrapper: caches sequence-sharded on "model", everything
    else under auto SPMD."""
    axis = "model"
    body = partial(_flash_decode_body, axis_name=axis, scale=scale)
    in_specs = (P(), P(), P(), P(None, axis, None, None),
                P(None, axis, None, None), P())
    out_specs = (P(), P(None, axis, None, None),
                 P(None, axis, None, None))
    if hasattr(jax, "shard_map"):
        fn = jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, axis_names={axis},
                           check_vma=False)
    else:   # jax 0.4.x: the experimental API, check_rep instead of vma.
            # No auto= for the other mesh axes: partial-manual shard_map
            # on 0.4.x lowers to a PartitionId op XLA's SPMD partitioner
            # rejects ("PartitionId instruction is not supported").  All-
            # manual with replicated P() specs is numerically equivalent
            # here (test_flash_decode_sharded_matches_train pins it).
        from jax.experimental.shard_map import shard_map as _shard_map
        fn = _shard_map(body, mesh=mesh, in_specs=in_specs,
                        out_specs=out_specs, check_rep=False)
    return fn(q, k_new, v_new, k_cache, v_cache,
              jnp.asarray(offset, jnp.int32))
