"""Decode-side attention: DPA-quantized paths + sharded flash-decoding.

DPA attention (`dpa_attention` / `dpa_decode_attn`)
---------------------------------------------------
The jnp face of the DPA attention contract (kernel face:
`repro.kernels.flash_attention.dpa_flash_attention`; spec:
`repro.kernels.ref.dpa_flash_attention_ref`): QK^T and PV accumulate in
f32 over operands absmax-quantized onto a Table-I format grid, and the
softmax max/denominator stay f32.  These run under plain XLA, so they
serve every shape the Pallas kernel's block constraints exclude (and all
decode steps, where Sq == 1).  `dpa_paged_decode_attn` is the serving-
engine variant: same contract, but K/V codes are read through a block
table over the paged cache (`core.kvcache` paged layout) with a
per-request causal mask, so one batched step serves requests of mixed
lengths.  They define the *semantics* of the path;
the *bandwidth* claim belongs to the kernel's kv_quant mode, whose
BlockSpec moves cache codes+scales HBM->VMEM and widens in the prologue
— here the dequantized K/V is an ordinary XLA f32 intermediate (the HBM
saving on the XLA path is the cache's at-rest footprint, not the
per-step traffic).

Everything here is registered as `core.exec_plan` routes by
`repro.kernels.registry`: `dpa_attention`/`sdpa_reference` are the
masked fallbacks of the ``flash_attn`` op, `dpa_decode_attn` is the
``decode_attn`` reference, and `dpa_paged_decode_attn` is the
``paged_decode/jnp_gather`` reference the block-table Pallas kernel
(`kernels.flash_attention.paged_decode_attention`) is pinned
bit-identical against.

Sharded flash-decoding (`flash_decode`)
---------------------------------------
Shard-local KV-cache update + partial softmax.

Auto-SPMD cannot see that a decode step's cache update touches one
sequence shard, nor that attention against a sequence-sharded cache only
needs (max, denom, weighted-V) per shard — it all-gathers the cache every
layer (measured: 2 x S_shard x KV x hd gathers/layer, 80 GB/step on
dbrx-132b decode; EXPERIMENTS.md §Perf).  This module is the manual
version: a shard_map over the "model" axis that

  1. writes k/v into the *owning* shard only (branchless in-range mask),
  2. computes local logits + local (max, exp-sum, exp-weighted V),
  3. combines across shards with three tiny collectives
     (B*H + B*H + B*H*hd floats — ~1e4x less wire than the gather).

The "data"/"pod" axes stay automatic, so the same code serves any DP
layout.  Used by layers.apply_attention when cfg.flash_decode is set and
the ambient mesh carries a "model" axis.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.quantize import quant_rows_grid


def build_sdpa_mask(sq: int, skv: int, offset, causal: bool, window,
                    valid=None):
    """(Sq, Skv) bool attention mask shared by the masked XLA routes.

    offset: index of q position 0 within the kv timeline; window: local
    attention width (> 0); valid: optional (Skv,) extra key-slot mask
    (sliding caches)."""
    qpos = offset + jnp.arange(sq)[:, None]
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask = mask & (kpos <= qpos)
    if window is not None and window > 0:
        mask = mask & (kpos > qpos - window)
    if valid is not None:
        mask = mask & valid[None, :]
    return mask


def sdpa_reference(q, k, v, mask, *, scale):
    """The seed f32 attention datapath (any shape, GQA expansion).

    q: (B,Sq,H,hd); k/v: (B,Skv,KV,hd); mask broadcastable to
    (B,H,Sq,Skv).  f32 logits/softmax over compute-dtype operands — the
    `flash_attn/xla_ref_attn` route every DPA attention mode is judged
    against."""
    g = q.shape[2] // k.shape[2]
    kh = jnp.repeat(k, g, axis=2)     # (B, Skv, H, hd) — GQA expansion
    vh = jnp.repeat(v, g, axis=2)
    logits = jnp.einsum("bshd,bthd->bhst", q, kh,
                        preferred_element_type=jnp.float32)
    logits = logits * scale
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhst,bthd->bshd", probs, vh)


def dpa_attention(q, k, v, mask, *, fmt: str, fmt_kv=None, scale,
                  kv_on_grid: bool = False):
    """DPA attention over grouped K/V (XLA path, any shape).

    q: (B,Sq,H,hd); k/v: (B,Skv,KV,hd) with H a multiple of KV; mask
    broadcastable to (B,H,Sq,Skv).  With `kv_on_grid`, k/v already carry
    dequantized KV-cache values (grid * scale) and are consumed as-is;
    otherwise they are per-row quantized onto fmt_kv's grid here
    (bit-identical to a cache round-trip, so prefill and decode agree).
    Quantization happens *before* the GQA expansion — repeated heads
    share a row's scale, so expanding first would just redo identical
    absmax/encode work g times.  Matches `ref.dpa_flash_attention_ref`
    with a single key block (global max).
    """
    B, Sq, H, hd = q.shape
    g = H // k.shape[2]
    qg, qs = quant_rows_grid(q, fmt)                   # (B,Sq,H,hd/1)
    if kv_on_grid:
        k_eff = k.astype(jnp.float32)
        v_eff = v.astype(jnp.float32)
    else:
        kf = fmt_kv or fmt
        kg, ks = quant_rows_grid(k, kf)
        vg, vs = quant_rows_grid(v, kf)
        k_eff, v_eff = kg * ks, vg * vs
    if g > 1:
        k_eff = jnp.repeat(k_eff, g, axis=2)           # (B,Skv,H,hd)
        v_eff = jnp.repeat(v_eff, g, axis=2)
    logits = jnp.einsum("bshd,bthd->bhst", qg, k_eff,
                        preferred_element_type=jnp.float32)
    logits = logits * qs.transpose(0, 2, 1, 3) * scale
    logits = jnp.where(mask, logits, -1e30)
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)                            # f32 softmax core
    pg, ps = quant_rows_grid(p, fmt)
    den = jnp.sum(pg, axis=-1, keepdims=True) * ps     # f32 denominator
    num = jnp.einsum("bhst,bthd->bshd", pg, v_eff,
                     preferred_element_type=jnp.float32)
    num = num * ps.transpose(0, 2, 1, 3)
    out = num / jnp.maximum(den, 1e-30).transpose(0, 2, 1, 3)
    return out.astype(q.dtype)


def dpa_decode_attn(q, cache, offset, *, fmt: str, fmt_kv: str,
                    kv_packed: bool, scale):
    """One decode step against a quantized KV cache.

    q: (B,1,H,hd) (already rope'd); cache: `repro.core.kvcache` pytree
    (B,S_ctx,KV,...).  The cache rows are widened in the prologue
    (codes * per-row scale) and both matmuls accumulate f32 over
    fmt-grid operands; causal masking via `offset`.
    """
    from repro.core.kvcache import dequantize_cache
    k, v = dequantize_cache(cache, fmt=fmt_kv, packed=kv_packed)
    s_ctx = k.shape[1]
    valid = jnp.arange(s_ctx) <= jnp.asarray(offset, jnp.int32)
    mask = valid[None, None, None, :]
    return dpa_attention(q, k, v, mask, fmt=fmt, scale=scale,
                         kv_on_grid=True)


def dpa_paged_decode_attn(q, cache, positions, *, fmt: str, fmt_kv: str,
                          kv_packed: bool, scale):
    """One decode step against a *paged* quantized KV cache.

    q: (B,1,H,hd) (already rope'd at per-request positions); cache: paged
    `repro.core.kvcache` pytree (page pool + (B, max_pages) block table);
    positions: (B,) i32 — request b's current token index.  The block
    table gathers each request's pages into timeline order (pure relayout,
    bit-identical codes/scales to a contiguous cache), the prologue widens
    them (codes * per-row scale), and both matmuls accumulate f32 over
    fmt-grid operands — the same contract as `dpa_decode_attn`, with the
    causal mask per request: row b attends key slots <= positions[b]
    (slots past a request's live length come from scratch/stale pages and
    are masked off here)."""
    from repro.core.kvcache import dequantize_kv, gather_paged_kv
    view = gather_paged_kv(cache)
    k = dequantize_kv(view["k_codes"], view["k_scale"], fmt=fmt_kv,
                      packed=kv_packed)
    v = dequantize_kv(view["v_codes"], view["v_scale"], fmt=fmt_kv,
                      packed=kv_packed)
    s_view = k.shape[1]
    pos = jnp.asarray(positions, jnp.int32)
    valid = jnp.arange(s_view)[None, :] <= pos[:, None]     # (B, S_view)
    mask = valid[:, None, None, :]
    return dpa_attention(q, k, v, mask, fmt=fmt, scale=scale,
                         kv_on_grid=True)


def dpa_paged_verify_attn(q, cache, positions, *, fmt: str, fmt_kv: str,
                          kv_packed: bool, scale):
    """Speculative-verify attention: S_q causal query tokens per request
    against the *paged* quantized KV cache.

    q: (B, S_q, H, hd) — a request's last accepted token followed by its
    draft tokens, already rope'd at per-request positions; cache: paged
    `repro.core.kvcache` pytree whose pools already hold the query rows
    (written by `paged_write_tokens`); positions: (B,) i32 — the absolute
    timeline index of query row 0.  Same contract as
    `dpa_paged_decode_attn`, generalized to S_q > 1 with a per-request
    *causal* mask: query row i of request b attends key slots <=
    positions[b] + i — exactly the chunked-prefill masking, applied to
    the block-table view — and row i reproduces BIT-FOR-BIT what a
    single-token decode step at position positions[b] + i would compute.
    That bit-identity is what makes greedy speculative decoding exact
    (`serving.spec_decode`): the verify pass's attention outputs ARE the
    plain decode path's.

    The exactness is engineered, not assumed: the (B, S_q) query axis
    folds into the batch axis, so every einsum in `dpa_attention` sees
    exactly the S_q == 1 decode shapes and XLA lowers the identical
    per-element reduction (an (S_q, S_kv) logits matmul would pick a
    different gemm tiling and drift by ulps — enough to flip a greedy
    argmax on near-tied logits).  The price is the gathered view
    repeated per query row, S_q x the decode step's HBM traffic — the
    verify pass amortizes it over k+1 scored tokens
    (`tests/test_spec_decode.py::test_verify_attn_matches_stepped_
    paged_decode` pins the bit-identity)."""
    from repro.core.kvcache import dequantize_kv, gather_paged_kv
    B, sq, H, hd = q.shape
    view = gather_paged_kv(cache)
    k = dequantize_kv(view["k_codes"], view["k_scale"], fmt=fmt_kv,
                      packed=kv_packed)
    v = dequantize_kv(view["v_codes"], view["v_scale"], fmt=fmt_kv,
                      packed=kv_packed)
    s_view = k.shape[1]
    pos = jnp.asarray(positions, jnp.int32)[:, None] \
        + jnp.arange(sq, dtype=jnp.int32)[None]             # (B, S_q)
    pos_r = pos.reshape(B * sq)
    valid = jnp.arange(s_view)[None, :] <= pos_r[:, None]   # (B*S_q, S_view)
    mask = valid[:, None, None, :]
    out = dpa_attention(q.reshape(B * sq, 1, H, hd),
                        jnp.repeat(k, sq, axis=0),
                        jnp.repeat(v, sq, axis=0), mask, fmt=fmt,
                        scale=scale, kv_on_grid=True)
    return out.reshape(B, sq, H, hd)


def _local_update(cache, new, offset, axis_name):
    """Write `new` (B,1,KV,hd) at global position `offset` into this
    device's sequence shard of `cache` (B, S_loc, KV, hd)."""
    idx = jax.lax.axis_index(axis_name)
    s_loc = cache.shape[1]
    local_off = offset - idx * s_loc
    in_range = (local_off >= 0) & (local_off < s_loc)
    off_c = jnp.clip(local_off, 0, s_loc - 1)
    z = jnp.zeros((), jnp.int32)
    written = jax.lax.dynamic_update_slice(
        cache, new.astype(cache.dtype),
        (z, off_c.astype(jnp.int32), z, z))
    return jnp.where(in_range, written, cache)


def _flash_decode_body(q, k_new, v_new, kc, vc, offset, *, axis_name,
                       scale):
    """Per-shard body.  q: (B,1,H,hd); kc/vc: (B,S_loc,KV,hd) local shard.
    Returns (out (B,1,H,hd), kc', vc')."""
    B, _, H, hd = q.shape
    s_loc = kc.shape[1]
    KV = kc.shape[2]
    g = H // KV
    idx = jax.lax.axis_index(axis_name)

    kc = _local_update(kc, k_new, offset, axis_name)
    vc = _local_update(vc, v_new, offset, axis_name)

    kh = jnp.repeat(kc.astype(q.dtype), g, axis=2)       # (B,S_loc,H,hd)
    vh = jnp.repeat(vc.astype(q.dtype), g, axis=2)
    logits = jnp.einsum("bqhd,bshd->bhqs", q, kh,
                        preferred_element_type=jnp.float32) * scale
    pos = idx * s_loc + jnp.arange(s_loc)
    valid = pos <= offset                                 # causal
    logits = jnp.where(valid[None, None, None, :], logits, -1e30)

    m_loc = jnp.max(logits, axis=-1)                      # (B,H,1)
    m_glob = jax.lax.pmax(m_loc, axis_name)
    p = jnp.exp(logits - m_glob[..., None])
    den = jax.lax.psum(jnp.sum(p, axis=-1), axis_name)    # (B,H,1)
    num = jnp.einsum("bhqs,bshd->bqhd", p.astype(q.dtype), vh,
                     preferred_element_type=jnp.float32)
    num = jax.lax.psum(num, axis_name)                    # (B,1,H,hd)
    out = num / jnp.maximum(den, 1e-30).transpose(0, 2, 1)[..., None]
    return out.astype(q.dtype), kc, vc


def flash_decode(q, k_new, v_new, k_cache, v_cache, offset, mesh,
                 *, scale):
    """shard_map wrapper: caches sequence-sharded on "model", everything
    else under auto SPMD."""
    axis = "model"
    body = partial(_flash_decode_body, axis_name=axis, scale=scale)
    in_specs = (P(), P(), P(), P(None, axis, None, None),
                P(None, axis, None, None), P())
    out_specs = (P(), P(None, axis, None, None),
                 P(None, axis, None, None))
    if hasattr(jax, "shard_map"):
        fn = jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, axis_names={axis},
                           check_vma=False)
    else:   # jax 0.4.x: the experimental API, check_rep instead of vma.
            # No auto= for the other mesh axes: partial-manual shard_map
            # on 0.4.x lowers to a PartitionId op XLA's SPMD partitioner
            # rejects ("PartitionId instruction is not supported").  All-
            # manual with replicated P() specs is numerically equivalent
            # here (test_flash_decode_sharded_matches_train pins it).
        from jax.experimental.shard_map import shard_map as _shard_map
        fn = _shard_map(body, mesh=mesh, in_specs=in_specs,
                        out_specs=out_specs, check_rep=False)
    return fn(q, k_new, v_new, k_cache, v_cache,
              jnp.asarray(offset, jnp.int32))
