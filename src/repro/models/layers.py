"""Layer library for the model zoo.

Every projection routes through `repro.core.linear.apply_linear` — the
DPA execution contract — so the paper's technique is a first-class policy
on all ten architectures.  Layers are functional: init_* returns a params
pytree, apply_* consumes it.  Decode paths carry explicit caches/states.

Policy-mode kernel selection never happens here: every attention/matmul
path asks `core.exec_plan.resolve(op, policy, **shape_ctx)` which route
serves it (routes + predicates live in `repro.kernels.registry`), so
this module carries no policy-mode branching and no lazy kernel
imports.  The one inline gate left is the sharded `flash_decode` fast
path in `apply_attention` — a *mesh-topology* selection (ambient mesh +
raw-cache structure), not a policy mode, so it stays outside the plan
table.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core import exec_plan
from repro.core import kvcache as KV
from repro.core.linear import apply_linear, dpa_grouped_dot, init_linear
from repro.core.policy import get_policy
from repro.distributed import tp
from repro.distributed.sharding import _ambient_mesh, maybe_shard
from repro.models.decode_attn import flash_decode

# -----------------------------------------------------------------------------
# norms
# -----------------------------------------------------------------------------

def init_norm(d: int, kind: str = "rmsnorm"):
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def apply_norm(params, x, *, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    if "bias" in params:  # layernorm
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * params["scale"] + params["bias"]
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * params["scale"]
    return y.astype(x.dtype)


# -----------------------------------------------------------------------------
# rotary position embedding
# -----------------------------------------------------------------------------

def rope(x, positions, theta: float):
    """x: (B, S, H, hd), positions: (S,) int32 — or (B, S) for per-request
    timelines (the continuous-batching decode step, where each batch slot
    sits at its own position).  The 2D path computes the identical
    angle-per-position values, just broadcast per batch row."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32)
                    / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, half)
    if ang.ndim == 3:                                       # (B, S, half)
        cos = jnp.cos(ang)[:, :, None, :]
        sin = jnp.sin(ang)[:, :, None, :]
    else:
        cos = jnp.cos(ang)[None, :, None, :]
        sin = jnp.sin(ang)[None, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -----------------------------------------------------------------------------
# attention (GQA, optional qk-norm / bias / sliding window / cross / cache)
# -----------------------------------------------------------------------------

def init_attention(key, cfg):
    d, hd = cfg.d_model, cfg.hd
    ks = jax.random.split(key, 6)
    p = {
        "wq": init_linear(ks[0], d, cfg.n_heads * hd, bias=cfg.qkv_bias),
        "wk": init_linear(ks[1], d, cfg.n_kv_heads * hd, bias=cfg.qkv_bias),
        "wv": init_linear(ks[2], d, cfg.n_kv_heads * hd, bias=cfg.qkv_bias),
        "wo": init_linear(ks[3], cfg.n_heads * hd, d),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_norm(hd)
        p["k_norm"] = init_norm(hd)
    return p


def _sdpa(q, k, v, *, causal, window, offset, valid=None, use_flash=False,
          q_chunk=0, policy=None, kv_on_grid=False):
    """q: (B,Sq,H,hd); k/v: (B,Skv,KV,hd) -> (B,Sq,H,hd).

    offset: index of q position 0 within the kv timeline.
    valid: optional (Skv,) bool — extra key-slot mask (sliding caches).
    q_chunk: scan over query blocks so the (Sq,Skv) score matrix never
    materializes whole — the XLA-native flash-attention memory shape.
    policy: when its attention bits are set, QK^T and PV run the DPA
    contract (f32 accumulation over fmt_attn-grid operands, f32 softmax
    core); the plan layer resolves whether the Pallas flash kernel or a
    masked jnp route serves this call.
    kv_on_grid: k/v already carry dequantized KV-cache values — skip the
    per-row fake-quant (re-quantizing grid values would double-round).
    """
    B, Sq, H, hd = q.shape
    policy = get_policy(policy if policy is not None else "fp32")
    entry = exec_plan.resolve(
        "flash_attn", policy, sq=Sq, skv=k.shape[1], use_flash=use_flash,
        has_valid=valid is not None, kv_on_grid=kv_on_grid)
    if (entry.backend != "pallas" and q_chunk and Sq > q_chunk
            and Sq % q_chunk == 0 and valid is None):
        @jax.checkpoint
        def chunk(i):
            # checkpointed: the (q_chunk, Skv) logits are recomputed in
            # backward instead of being saved for every chunk (saving them
            # re-materializes the full S^2 matrix the chunking avoids)
            qs = jax.lax.dynamic_slice_in_dim(q, i * q_chunk, q_chunk, 1)
            return _sdpa(qs, k, v, causal=causal, window=window,
                         offset=offset + i * q_chunk, policy=policy,
                         kv_on_grid=kv_on_grid)
        out = jax.lax.map(chunk, jnp.arange(Sq // q_chunk))
        return jnp.moveaxis(out, 0, 1).reshape(B, Sq, H, hd)
    return entry.run(q, k, v, policy=policy, causal=causal, window=window,
                     offset=offset, valid=valid, scale=hd ** -0.5,
                     kv_on_grid=kv_on_grid)


def apply_attention(params, x, cfg, *, offset=0, cache=None, cross_kv=None,
                    window=None, causal=True, use_rope=True,
                    cache_mode: str = "full"):
    """Returns (y, new_cache).

    cache_mode "full":   cache {"k","v": (B, S_ctx, KV, hd)}; k/v written at
                         `offset`, causal mask handles unfilled tail.
    cache_mode "window": sliding cache of length W kept in time order (shift
                         left + append on decode; last-W slice on prefill);
                         unfilled leading slots masked via `offset`.
    """
    policy = get_policy(cfg.policy)
    B, Sq, _ = x.shape
    hd = cfg.hd
    q = maybe_shard(apply_linear(params["wq"], x, policy),
                    "data", None, "model").reshape(B, Sq, cfg.n_heads, hd)
    q = maybe_shard(q, "data", None, "model", None)
    if cross_kv is not None:
        k, v = cross_kv["k"], cross_kv["v"]
    else:
        k = maybe_shard(apply_linear(params["wk"], x, policy),
                        "data", None, "model").reshape(B, Sq,
                                                       cfg.n_kv_heads, hd)
        v = maybe_shard(apply_linear(params["wv"], x, policy),
                        "data", None, "model").reshape(B, Sq,
                                                       cfg.n_kv_heads, hd)
    if "q_norm" in params:
        q = apply_norm(params["q_norm"], q, eps=cfg.norm_eps)
        k = apply_norm(params["k_norm"], k, eps=cfg.norm_eps) \
            if cross_kv is None else k
    if use_rope and cross_kv is None:
        if jnp.ndim(offset) == 1:   # per-request timelines (paged decode)
            pos = jnp.asarray(offset)[:, None] + jnp.arange(Sq)[None]
        else:
            pos = offset + jnp.arange(Sq)
        q = rope(q, pos, cfg.rope_theta)
        k = rope(k, pos, cfg.rope_theta)

    new_cache = cache
    valid = None
    kv_on_grid = False
    sdpa_offset = offset
    sdpa_causal = causal and cross_kv is None
    sdpa_window = window
    # flash_decode serves raw caches only: a kv-quantized policy takes
    # the DPA quantized-cache decode path below instead (the shard-local
    # partial-softmax combine does not speak codes+scales yet — see
    # ModelConfig.flash_decode)
    if (cache is not None and cross_kv is None and Sq == 1
            and cache_mode == "full" and cfg.flash_decode
            and "k" in cache):
        mesh = _ambient_mesh()
        S_ctx = cache["k"].shape[1]
        if (mesh is not None and "model" in mesh.axis_names
                and S_ctx % mesh.shape["model"] == 0):
            y, kc, vc = flash_decode(q, k, v, cache["k"], cache["v"],
                                     offset, mesh, scale=hd ** -0.5)
            y = maybe_shard(y.reshape(B, Sq, cfg.n_heads * hd),
                            "data", None, "model")
            y = apply_linear(params["wo"], y, policy)
            return maybe_shard(y, "data", "model", None), {"k": kc, "v": vc}
    if cache is not None and cross_kv is None and "block_table" in cache:
        # paged quantized KV cache (the continuous-batching engine's
        # layout): `offset` is a (B,) vector of per-request positions.
        # New tokens quantize into the request's pages, attention reads
        # codes through the block table — same prologue-dequant contract
        # as the contiguous branch below, bit-identical values.  Sq == 1
        # is the decode step; Sq > 1 is the speculative verify window
        # (the request's last accepted token + its draft tokens), scored
        # with per-request causal masks via the ``verify_attn`` route —
        # prefill still runs against a contiguous staging cache, see
        # launch.engine
        new_cache = KV.paged_write_tokens(cache, k, v, offset,
                                          fmt=policy.fmt_kv,
                                          packed=policy.kv_packed)
        plan_ctx = dict(batch=B, page_size=cache["k_codes"].shape[1],
                        max_pages=cache["block_table"].shape[1],
                        kv_heads=cfg.n_kv_heads, hd=hd,
                        n_pages=cache["k_codes"].shape[0],
                        n_devices=tp.axis_size())
        if Sq == 1:
            entry = exec_plan.resolve("paged_decode", policy, **plan_ctx)
        else:
            entry = exec_plan.resolve("verify_attn", policy, sq=Sq,
                                      **plan_ctx)
        y = entry.run(q, new_cache, offset, policy=policy, scale=hd ** -0.5)
        y = maybe_shard(y.reshape(B, Sq, cfg.n_heads * hd),
                        "data", None, "model")
        y = apply_linear(params["wo"], y, policy)
        return maybe_shard(y, "data", "model", None), new_cache
    if cache is not None and cross_kv is None and "k_codes" in cache:
        # quantized KV cache (full mode): new rows quantize into the
        # format-width cache; attention consumes dequantized-in-prologue
        # values, so prefill and decode see identical numerics
        new_cache = KV.update_kv_cache(cache, k, v, offset,
                                       fmt=policy.fmt_kv,
                                       packed=policy.kv_packed)
        if Sq == 1:
            # decode: DPA QK^T / PV straight off the quantized cache
            entry = exec_plan.resolve(
                "decode_attn", policy, batch=B,
                s_ctx=new_cache["k_codes"].shape[1],
                kv_heads=cfg.n_kv_heads, hd=hd)
            y = entry.run(q, new_cache, offset, policy=policy,
                          scale=hd ** -0.5)
            y = maybe_shard(y.reshape(B, Sq, cfg.n_heads * hd),
                            "data", None, "model")
            y = apply_linear(params["wo"], y, policy)
            return maybe_shard(y, "data", "model", None), new_cache
        k, v = KV.dequantize_cache(new_cache, fmt=policy.fmt_kv,
                                   packed=policy.kv_packed)
        kv_on_grid = True
    elif cache is not None and cross_kv is None:
        W = cache["k"].shape[1]
        cdt = cache["k"].dtype
        if cache_mode == "window":
            if Sq == 1:   # decode: shift left, append current
                kc = jnp.roll(cache["k"], -1, axis=1).at[:, -1].set(
                    k[:, 0].astype(cdt))
                vc = jnp.roll(cache["v"], -1, axis=1).at[:, -1].set(
                    v[:, 0].astype(cdt))
                # slot s holds position offset - (W-1-s); valid iff >= 0
                filled = jnp.minimum(offset + 1, W)
                valid = jnp.arange(W) >= (W - filled)
                sdpa_causal = False
                sdpa_window = None
                sdpa_offset = 0
            else:         # prefill: keep last W in order (left-pad zeros)
                pad = max(0, W - Sq)
                kc = jnp.pad(k[:, -W:], ((0, 0), (pad, 0), (0, 0), (0, 0))
                             ).astype(cdt)
                vc = jnp.pad(v[:, -W:], ((0, 0), (pad, 0), (0, 0), (0, 0))
                             ).astype(cdt)
            new_cache = {"k": kc, "v": vc}
            if Sq == 1:
                k, v = kc.astype(x.dtype), vc.astype(x.dtype)
        else:
            z = jnp.zeros((), jnp.int32)
            off = jnp.asarray(offset, jnp.int32)
            kc = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cdt), (z, off, z, z))
            vc = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cdt), (z, off, z, z))
            new_cache = {"k": kc, "v": vc}
            k, v = kc.astype(x.dtype), vc.astype(x.dtype)
    y = _sdpa(q, k, v, causal=sdpa_causal, window=sdpa_window,
              offset=sdpa_offset if (cache is not None or Sq > 1) else 0,
              valid=valid, use_flash=cfg.use_flash,
              q_chunk=cfg.attn_chunk, policy=policy,
              kv_on_grid=kv_on_grid)
    y = maybe_shard(y.reshape(B, Sq, cfg.n_heads * hd),
                    "data", None, "model")
    y = apply_linear(params["wo"], y, policy)
    return maybe_shard(y, "data", "model", None), new_cache


# -----------------------------------------------------------------------------
# MLP (SwiGLU / GELU)
# -----------------------------------------------------------------------------

def init_mlp(key, cfg, d_ff=None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.act == "silu":
        return {"wg": init_linear(ks[0], d, f), "wu": init_linear(ks[1], d, f),
                "wd": init_linear(ks[2], f, d)}
    return {"wu": init_linear(ks[0], d, f, bias=True),
            "wd": init_linear(ks[1], f, d, bias=True)}


def apply_mlp(params, x, cfg):
    policy = get_policy(cfg.policy)
    if "wg" in params:
        g = maybe_shard(apply_linear(params["wg"], x, policy),
                        "data", None, "model")
        u = maybe_shard(apply_linear(params["wu"], x, policy),
                        "data", None, "model")
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    else:
        u = maybe_shard(apply_linear(params["wu"], x, policy),
                        "data", None, "model")
        h = jax.nn.gelu(u.astype(jnp.float32)).astype(x.dtype)
    return maybe_shard(apply_linear(params["wd"], h, cfg.policy),
                       "data", "model", None)


# -----------------------------------------------------------------------------
# MoE: top-k routing with sort-based capacity dispatch (EP-shardable)
# -----------------------------------------------------------------------------

def init_moe(key, cfg):
    from repro.core.linear import init_grouped_linear
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    p = {"router": init_linear(ks[0], d, E)}
    if cfg.act == "silu":
        p["wg"] = init_grouped_linear(ks[1], E, d, f)
        p["wu"] = init_grouped_linear(ks[2], E, d, f)
        p["wd"] = init_grouped_linear(ks[3], E, f, d)
    else:
        p["wu"] = init_grouped_linear(ks[1], E, d, f)
        p["wd"] = init_grouped_linear(ks[2], E, f, d)
    return p


def apply_moe(params, x, cfg):
    """x: (B, S, d) -> (y, aux_loss).

    GShard-style *group-local* dispatch: each batch row routes its own S
    tokens into an (E, C, d) buffer (C = cf*S*K/E), so the sort/scatter
    is local to the row and SPMD keeps all dispatch data-parallel on the
    batch axis; only the grouped expert einsum (E on the "model" axis)
    communicates — this is what keeps the MoE memory/collective footprint
    sane at 256+ chips (no global (T,E,C) tensors, no global sort)."""
    policy = get_policy(cfg.policy)
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    C = int(cfg.capacity_factor * S * K / E) + 1

    logits = apply_linear(params["router"], x.astype(jnp.float32), "fp32")
    probs = jax.nn.softmax(logits, axis=-1)                      # (B, S, E)
    gate_w, gate_i = jax.lax.top_k(probs, K)                     # (B, S, K)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    # aux load-balancing loss (Switch style), computed globally
    density = jnp.mean(
        jax.nn.one_hot(gate_i[..., 0], E, dtype=jnp.float32), (0, 1))
    density_prob = jnp.mean(probs, axis=(0, 1))
    aux = jnp.sum(density * density_prob) * E * cfg.router_aux_coef

    def dispatch_row(xt, ge, gw):
        """xt (S,d), ge/gw (S,K) -> (buf (E,C,d), combine metadata)."""
        flat_e = ge.reshape(-1)                                  # (S*K,)
        order = jnp.argsort(flat_e)
        sorted_e = flat_e[order]
        counts = jnp.bincount(sorted_e, length=E)
        start = jnp.cumsum(counts) - counts
        pos = jnp.arange(S * K) - start[sorted_e]
        keep = pos < C
        tok = order // K
        pos_c = jnp.where(keep, pos, 0)
        buf = jnp.zeros((E, C, d), xt.dtype)
        buf = buf.at[sorted_e, pos_c].add(
            jnp.where(keep[:, None], xt[tok], 0).astype(xt.dtype))
        return buf, (sorted_e, pos_c, keep, tok, gw.reshape(-1)[order])

    buf, meta = jax.vmap(dispatch_row)(x, gate_i, gate_w)        # (B,E,C,d)
    buf = maybe_shard(buf, "data", "model", None, None)

    def expert_mm(name, z):
        return dpa_grouped_dot(z, params[name]["w"], policy,
                               eq="becd,edf->becf")

    if "wg" in params:
        h = jax.nn.silu(expert_mm("wg", buf).astype(jnp.float32)
                        ).astype(x.dtype) * expert_mm("wu", buf)
    else:
        h = jax.nn.gelu(expert_mm("wu", buf).astype(jnp.float32)
                        ).astype(x.dtype)
    out_buf = expert_mm("wd", h)                                 # (B,E,C,d)

    def combine_row(ob, m):
        sorted_e, pos_c, keep, tok, w = m
        g = ob[sorted_e, pos_c]                                  # (S*K, d)
        g = jnp.where(keep[:, None], g, 0)
        return jnp.zeros((S, d), x.dtype).at[tok].add(
            (g.astype(jnp.float32) * w[:, None]).astype(x.dtype))

    y = jax.vmap(combine_row)(out_buf, meta)
    return maybe_shard(y, "data", "model", None), aux


# -----------------------------------------------------------------------------
# RG-LRU recurrent block (RecurrentGemma / Griffin)
# -----------------------------------------------------------------------------

def init_rglru(key, cfg):
    d = cfg.d_model
    dr = cfg.d_rnn or d
    ks = jax.random.split(key, 6)
    # Lambda init so that a = exp(-c*softplus(L)*sigmoid(r)) starts near 0.9..0.999
    lam = jnp.log(jnp.expm1(jnp.linspace(0.9, 4.0, dr)))  # softplus^-1
    return {
        "wx": init_linear(ks[0], d, dr),
        "wgate": init_linear(ks[1], d, dr),
        "conv": jax.random.normal(ks[2], (cfg.conv_width, dr), jnp.float32)
                * (cfg.conv_width * dr) ** -0.5,
        "w_ig": init_linear(ks[3], d, dr),     # input gate
        "lam": lam.astype(jnp.float32),
        "wo": init_linear(ks[4], dr, d),
    }


_RG_C = 8.0


def _rglru_coeffs(params, x, cfg, policy):
    """-> (a, bx) with h_t = a_t * h_{t-1} + bx_t, all (B, S, dr)."""
    xb = maybe_shard(apply_linear(params["wx"], x, policy),
                     "data", None, "model")
    gate = apply_linear(params["wgate"], x, policy).astype(jnp.float32)
    igate = apply_linear(params["w_ig"], x, policy).astype(jnp.float32)
    log_a = -_RG_C * jax.nn.softplus(params["lam"]) * jax.nn.sigmoid(gate)
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    bx = mult * jax.nn.sigmoid(igate) * xb.astype(jnp.float32)
    return a, bx


def _conv1d(x, w, state=None):
    """Causal depthwise conv: x (B,S,dr), w (cw, dr).  state: (B, cw-1, dr)."""
    cw = w.shape[0]
    if state is None:
        pad = jnp.zeros(x.shape[:1] + (cw - 1,) + x.shape[2:], x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(cw))
    new_state = xp[:, -(cw - 1):] if cw > 1 else None
    return out.astype(x.dtype), new_state


def apply_rglru(params, x, cfg, *, state=None):
    """x: (B,S,d) -> (y, new_state).  state: {"h": (B,dr), "conv": ...}."""
    policy = get_policy(cfg.policy)
    xc, conv_state = _conv1d(x, params["conv"],
                             None if state is None else state["conv"])
    a, bx = _rglru_coeffs(params, xc, cfg, policy)
    h0 = None if state is None else state["h"]
    if x.shape[1] == 1 and h0 is not None:        # decode step
        h = a[:, 0] * h0 + bx[:, 0]
        hs = h[:, None]
    else:
        if h0 is not None:
            bx = bx.at[:, 0].add(a[:, 0] * h0)
        # associative scan: (a2,b2) o (a1,b1) = (a1*a2, a2*b1 + b2)
        def comb(c1, c2):
            return (c1[0] * c2[0], c2[0] * c1[1] + c2[1])
        aa, hs = jax.lax.associative_scan(comb, (a, bx), axis=1)
        h = hs[:, -1]
    y = apply_linear(params["wo"], hs.astype(x.dtype), policy)
    new_state = {"h": h, "conv": conv_state}
    return y, new_state


# -----------------------------------------------------------------------------
# xLSTM: chunkwise-parallel mLSTM + sequential sLSTM
# -----------------------------------------------------------------------------

def init_mlstm(key, cfg):
    d, hd = cfg.d_model, cfg.hd
    H = cfg.n_heads
    ks = jax.random.split(key, 7)
    return {
        "wq": init_linear(ks[0], d, H * hd),
        "wk": init_linear(ks[1], d, H * hd),
        "wv": init_linear(ks[2], d, H * hd),
        "wi": init_linear(ks[3], d, H),    # input gate (exp)
        "wf": init_linear(ks[4], d, H),    # forget gate
        "wo_gate": init_linear(ks[5], d, H * hd),
        "wo": init_linear(ks[6], H * hd, d),
    }


def _mlstm_chunk_scan(q, k, v, log_f, log_i, state, hd_scale):
    """Chunkwise-parallel stabilized mLSTM.

    q,k,v: (B, N, Ck, H, hd); log_f/log_i: (B, N, Ck, H).
    state: (C0: (B,H,hd,hd), n0: (B,H,hd), m0: (B,H)).
    Returns (h: (B,N,Ck,H,hd), final state).
    """
    B, N, Ck, H, hd = q.shape

    def step(carry, xs):
        C0, n0, m0 = carry
        qc, kc, vc, lf, li = xs            # (B,Ck,H,hd), ..., (B,Ck,H)
        cum_f = jnp.cumsum(lf, axis=1)                       # (B,Ck,H)
        # intra-chunk decay matrix D[t,s] = exp(cum_f_t - cum_f_s + li_s)
        lD = (cum_f[:, :, None] - cum_f[:, None, :]
              + li[:, None, :, :])                            # (B,Ck,Ck,H)
        tri = jnp.tril(jnp.ones((Ck, Ck), bool))
        lD = jnp.where(tri[None, :, :, None], lD, -jnp.inf)
        # inter-chunk contribution decays from m0
        l_inter = cum_f + m0[:, None, :]                      # (B,Ck,H)
        m_t = jnp.maximum(jnp.max(lD, axis=2), l_inter)       # (B,Ck,H)
        D = jnp.exp(lD - m_t[:, :, None])                     # (B,Ck,Ck,H)
        scores = jnp.einsum("bthd,bshd->btsh", qc, kc) * hd_scale
        w_ts = scores * D                                     # (B,Ck,Ck,H)
        h_num = jnp.einsum("btsh,bshd->bthd", w_ts, vc)
        h_den = jnp.einsum("btsh,bsh->bth", w_ts,
                           jnp.ones(kc.shape[:3], kc.dtype))
        # inter-chunk: q_t decayed against C0/n0
        fac = jnp.exp(l_inter - m_t)                          # (B,Ck,H)
        q_eff = qc * fac[..., None] * hd_scale
        h_num = h_num + jnp.einsum("bthd,bhde->bthe", q_eff, C0)
        h_den = h_den + jnp.einsum("bthd,bhd->bth", q_eff, n0)
        floor = jnp.exp(jnp.clip(-m_t, -60.0, 60.0))
        h = h_num / jnp.maximum(jnp.abs(h_den), floor)[..., None]
        # state update to end of chunk:
        # decay(s -> end) = exp(f_sum - cum_f_s), so the stabilizer is
        # m_next = max(f_sum + m0, max_s(f_sum - cum_f_s + li_s))
        f_sum = cum_f[:, -1]                                  # (B,H)
        m_next = jnp.maximum(f_sum + m0,
                             f_sum + jnp.max(li - cum_f, axis=1))
        k_dec = jnp.exp(f_sum[:, None] - cum_f + li - m_next[:, None])
        C1 = C0 * jnp.exp(f_sum + m0 - m_next)[..., None, None] \
            + jnp.einsum("bsh,bshd,bshe->bhde", k_dec, kc, vc)
        n1 = n0 * jnp.exp(f_sum + m0 - m_next)[..., None] \
            + jnp.einsum("bsh,bshd->bhd", k_dec, kc)
        return (C1, n1, m_next), h

    xs = (q.swapaxes(0, 1), k.swapaxes(0, 1), v.swapaxes(0, 1),
          log_f.swapaxes(0, 1), log_i.swapaxes(0, 1))
    state, hs = jax.lax.scan(step, state, xs)
    return hs.swapaxes(0, 1), state


def apply_mlstm(params, x, cfg, *, state=None):
    """x: (B,S,d) -> (y, new_state)."""
    policy = get_policy(cfg.policy)
    B, S, d = x.shape
    H, hd = cfg.n_heads, cfg.hd
    q = apply_linear(params["wq"], x, policy).reshape(B, S, H, hd)
    k = apply_linear(params["wk"], x, policy).reshape(B, S, H, hd)
    v = apply_linear(params["wv"], x, policy).reshape(B, S, H, hd)
    li = apply_linear(params["wi"], x, policy).astype(jnp.float32)  # (B,S,H)
    lf = jax.nn.log_sigmoid(
        apply_linear(params["wf"], x, policy).astype(jnp.float32))
    og = jax.nn.sigmoid(
        apply_linear(params["wo_gate"], x, policy).astype(jnp.float32))

    if state is None:
        state = (jnp.zeros((B, H, hd, hd), jnp.float32),
                 jnp.zeros((B, H, hd), jnp.float32),
                 jnp.zeros((B, H), jnp.float32))
    Ck = min(cfg.chunk, S)
    assert S % Ck == 0, (S, Ck)
    N = S // Ck
    shp = (B, N, Ck, H)
    hs, state = _mlstm_chunk_scan(
        q.reshape(shp + (hd,)).astype(jnp.float32),
        k.reshape(shp + (hd,)).astype(jnp.float32),
        v.reshape(shp + (hd,)).astype(jnp.float32),
        lf.reshape(shp), li.reshape(shp), state, hd ** -0.5)
    h = hs.reshape(B, S, H, hd) * og.reshape(B, S, H, hd)
    y = apply_linear(params["wo"], h.reshape(B, S, H * hd).astype(x.dtype),
                     policy)
    return y, state


def init_slstm(key, cfg):
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    return {"wz": init_linear(ks[0], d, d), "wi": init_linear(ks[1], d, d),
            "wf": init_linear(ks[2], d, d), "wo_gate": init_linear(ks[3], d, d),
            "r": jax.random.normal(ks[4], (4, d), jnp.float32) * d ** -0.5,
            "wo": init_linear(ks[5], d, d)}


def apply_slstm(params, x, cfg, *, state=None):
    """Sequential sLSTM with diagonal recurrent weights (per-channel r).
    x: (B,S,d) -> (y, state). state: (c,n,h,m) each (B,d)."""
    policy = get_policy(cfg.policy)
    B, S, d = x.shape
    zx = apply_linear(params["wz"], x, policy).astype(jnp.float32)
    ix = apply_linear(params["wi"], x, policy).astype(jnp.float32)
    fx = apply_linear(params["wf"], x, policy).astype(jnp.float32)
    ox = apply_linear(params["wo_gate"], x, policy).astype(jnp.float32)
    r = params["r"]
    if state is None:
        z0 = jnp.zeros((B, d), jnp.float32)
        state = (z0, z0, z0, z0 - 10.0)

    def step(carry, xs):
        c, n, h, m = carry
        zt, it, ft, ot = xs
        z = jnp.tanh(zt + r[0] * h)
        li = it + r[1] * h
        lf = jax.nn.log_sigmoid(ft + r[2] * h)
        m1 = jnp.maximum(lf + m, li)
        i_s = jnp.exp(li - m1)
        f_s = jnp.exp(lf + m - m1)
        c1 = f_s * c + i_s * z
        n1 = f_s * n + i_s
        h1 = jax.nn.sigmoid(ot + r[3] * h) * c1 / jnp.maximum(n1, 1e-6)
        return (c1, n1, h1, m1), h1

    xs = (zx.swapaxes(0, 1), ix.swapaxes(0, 1), fx.swapaxes(0, 1),
          ox.swapaxes(0, 1))
    state, hs = jax.lax.scan(step, state, xs)
    y = apply_linear(params["wo"], hs.swapaxes(0, 1).astype(x.dtype), policy)
    return y, state


# -----------------------------------------------------------------------------
# embeddings / unembedding
# -----------------------------------------------------------------------------

def init_embedding(key, vocab: int, d: int):
    return {"table": jax.random.normal(key, (vocab, d), jnp.float32) * 0.02}


def apply_embedding(params, ids, dtype):
    # residual stream is (batch, seq, d) with sequence-parallel layout
    return maybe_shard(params["table"].astype(dtype)[ids],
                       "data", "model", None)


def apply_unembed(params, x, *, table=None):
    """x: (B,S,d) -> logits (B,S,V), fp32 *accumulation* over compute-
    dtype operands (the DPA contract; casting the whole table to f32
    costs a hoisted V*d f32 buffer — 4.6 GiB on qwen2)."""
    w = table if table is not None else params["table"]
    entry = exec_plan.resolve("unembed", None, size=x.shape[-2] * w.shape[0])
    out = entry.run(x, w, get_policy("fp32"))
    return maybe_shard(out, "data", None, "model")
