"""Model stacks: pattern-scanned blocks covering all five families.

A family is a repeating block *pattern* (decoder: ("attn",); RecurrentGemma:
("rg","rg","attn_local"); xLSTM: 7x"mlstm"+1x"slstm"; enc-dec: two uniform
stacks).  Layers are grouped into `n_layers // len(pattern)` scan groups with
stacked params (HLO stays O(1) in depth); remainder layers run as an
unscanned tail.  Caches/states thread through the scan for prefill/decode.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import maybe_shard

from . import layers as L
from .config import ModelConfig

# -----------------------------------------------------------------------------
# blocks
# -----------------------------------------------------------------------------

ATTN_KINDS = ("attn", "attn_local", "enc", "dec")


def init_block(key, cfg: ModelConfig, kind: str):
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    p = {"norm1": L.init_norm(d, cfg.norm)}
    if kind in ATTN_KINDS:
        p["attn"] = L.init_attention(ks[0], cfg)
    elif kind == "rg":
        p["rg"] = L.init_rglru(ks[0], cfg)
    elif kind == "mlstm":
        p["mix"] = L.init_mlstm(ks[0], cfg)
    elif kind == "slstm":
        p["mix"] = L.init_slstm(ks[0], cfg)
    else:
        raise ValueError(kind)
    if kind == "dec":
        p["norm_cross"] = L.init_norm(d, cfg.norm)
        p["cross"] = L.init_attention(ks[1], cfg)
    if cfg.d_ff > 0:
        p["norm2"] = L.init_norm(d, cfg.norm)
        p["mlp"] = L.init_moe(ks[2], cfg) if cfg.is_moe else L.init_mlp(ks[2], cfg)
    return p


def init_block_cache(cfg: ModelConfig, kind: str, batch: int, s_ctx: int,
                     dtype):
    """Structural cache for one block (decode mode).

    Full-context attention caches honor the policy's KV bits: with
    fmt_kv set they are `repro.core.kvcache` pytrees (codes + per-row
    scales at format width) instead of raw compute-dtype tensors.
    Sliding-window caches stay raw — the shift-left update would have to
    roll codes and scales in lockstep for no bandwidth story (the window
    bounds the cache at W tokens already).
    """
    hd = cfg.hd
    if kind in ("attn", "dec"):
        from repro.core.policy import get_policy
        pol = get_policy(cfg.policy)
        if pol.kv_quantized:
            from repro.core.kvcache import init_kv_cache
            return init_kv_cache(batch, s_ctx, cfg.n_kv_heads, hd,
                                 fmt=pol.fmt_kv, packed=pol.kv_packed)
        shp = (batch, s_ctx, cfg.n_kv_heads, hd)
        return {"k": jnp.zeros(shp, dtype), "v": jnp.zeros(shp, dtype)}
    if kind == "attn_local":
        w = min(cfg.window or s_ctx, s_ctx)
        shp = (batch, w, cfg.n_kv_heads, hd)
        return {"k": jnp.zeros(shp, dtype), "v": jnp.zeros(shp, dtype)}
    if kind == "rg":
        dr = cfg.d_rnn or cfg.d_model
        return {"h": jnp.zeros((batch, dr), jnp.float32),
                "conv": jnp.zeros((batch, cfg.conv_width - 1, dr), dtype)}
    if kind == "mlstm":
        H = cfg.n_heads
        return (jnp.zeros((batch, H, hd, hd), jnp.float32),
                jnp.zeros((batch, H, hd), jnp.float32),
                jnp.zeros((batch, H), jnp.float32))
    if kind == "slstm":
        d = cfg.d_model
        z = jnp.zeros((batch, d), jnp.float32)
        return (z, z, z, z - 10.0)
    if kind == "enc":
        return ()
    raise ValueError(kind)


def apply_block(params, x, cfg: ModelConfig, kind: str, *, offset=0,
                cache=None, enc_out=None):
    """-> (x', new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = L.apply_norm(params["norm1"], x, eps=cfg.norm_eps)
    new_cache = cache
    if kind in ATTN_KINDS:
        window = cfg.window if kind == "attn_local" else None
        y, new_cache = L.apply_attention(
            params["attn"], h, cfg, offset=offset, cache=cache,
            window=window, causal=(kind != "enc"),
            use_rope=(cfg.rope_theta > 0),
            cache_mode="window" if kind == "attn_local" else "full")
    elif kind == "rg":
        y, new_cache = L.apply_rglru(params["rg"], h, cfg, state=cache)
    elif kind == "mlstm":
        y, new_cache = L.apply_mlstm(params["mix"], h, cfg, state=cache)
    elif kind == "slstm":
        y, new_cache = L.apply_slstm(params["mix"], h, cfg, state=cache)
    x = x + y.astype(x.dtype)
    if kind == "dec":
        from repro.core.linear import apply_linear
        from repro.core.policy import get_policy
        pol = get_policy(cfg.policy)
        h = L.apply_norm(params["norm_cross"], x, eps=cfg.norm_eps)
        B, Se = enc_out.shape[0], enc_out.shape[1]
        kc = apply_linear(params["cross"]["wk"], enc_out, pol).reshape(
            B, Se, cfg.n_kv_heads, cfg.hd)
        vc = apply_linear(params["cross"]["wv"], enc_out, pol).reshape(
            B, Se, cfg.n_kv_heads, cfg.hd)
        y, _ = L.apply_attention(params["cross"], h, cfg,
                                 cross_kv={"k": kc, "v": vc},
                                 causal=False, use_rope=False)
        x = x + y.astype(x.dtype)
    if cfg.d_ff > 0:
        h = L.apply_norm(params["norm2"], x, eps=cfg.norm_eps)
        if cfg.is_moe:
            y, aux = L.apply_moe(params["mlp"], h, cfg)
        else:
            y = L.apply_mlp(params["mlp"], h, cfg)
        x = x + y.astype(x.dtype)
    return x, new_cache, aux


# -----------------------------------------------------------------------------
# pattern stack (scan over groups)
# -----------------------------------------------------------------------------

def family_pattern(cfg: ModelConfig):
    if cfg.family in ("decoder", "vlm", "moe"):
        return ("attn",)
    if cfg.family == "rglru":
        return tuple(cfg.pattern) or ("rg", "rg", "attn_local")
    if cfg.family == "xlstm":
        n = cfg.slstm_every or 8
        return ("mlstm",) * (n - 1) + ("slstm",)
    raise ValueError(cfg.family)


def init_stack(key, cfg: ModelConfig, pattern, n_layers: int):
    P = len(pattern)
    n_groups, tail = divmod(n_layers, P)
    keys = jax.random.split(key, n_layers + 1)
    groups = {}
    for i, kind in enumerate(pattern):
        stacked = [init_block(keys[g * P + i], cfg, kind)
                   for g in range(n_groups)]
        groups[f"p{i}"] = jax.tree.map(lambda *xs: jnp.stack(xs), *stacked) \
            if n_groups > 1 else jax.tree.map(lambda x: x[None], stacked[0])
    tail_params = [init_block(keys[n_groups * P + j], cfg, pattern[j])
                   for j in range(tail)]
    return {"groups": groups, "tail": tail_params}


def _stack_caches(cfg, pattern, n_layers, batch, s_ctx, dtype):
    P = len(pattern)
    n_groups, tail = divmod(n_layers, P)
    groups = {}
    for i, kind in enumerate(pattern):
        one = init_block_cache(cfg, kind, batch, s_ctx, dtype)
        groups[f"p{i}"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (n_groups,) + x.shape), one)
    tail_caches = [init_block_cache(cfg, pattern[j], batch, s_ctx, dtype)
                   for j in range(tail)]
    return {"groups": groups, "tail": tail_caches}


def apply_stack(params, x, cfg: ModelConfig, pattern, *, offset=0,
                caches=None, enc_out=None, collect_cache=False,
                s_ctx: Optional[int] = None):
    """-> (x, new_caches, aux_total).

    caches=None & collect_cache=False : train (no state kept)
    caches=None & collect_cache=True  : prefill (states created, returned)
    caches given                      : decode (states updated)
    """
    P = len(pattern)

    def group_body(x, group_params, group_caches):
        # sequence-parallel residual stream: saved scan carries shard S on
        # "model", so remat-saved activations cost 1/TP per device
        x = maybe_shard(x, "data", "model", None)
        aux_t = jnp.zeros((), jnp.float32)
        new_caches = {}
        for i, kind in enumerate(pattern):
            c = None if group_caches is None else group_caches[f"p{i}"]
            if c is None and collect_cache:
                c = init_block_cache(cfg, kind, x.shape[0],
                                     s_ctx or x.shape[1], x.dtype)
            x, nc, aux = apply_block(group_params[f"p{i}"], x, cfg, kind,
                                     offset=offset, cache=c, enc_out=enc_out)
            new_caches[f"p{i}"] = nc
            aux_t = aux_t + aux
        return x, new_caches, aux_t

    if cfg.remat == "full":
        group_body = jax.checkpoint(group_body)
    elif cfg.remat == "dots":
        group_body = jax.checkpoint(
            group_body, policy=jax.checkpoint_policies.checkpoint_dots)

    keep_caches = collect_cache or caches is not None

    def scan_fn(carry, xs):
        x, aux_acc = carry
        gp = xs[0]
        gc = xs[1] if caches is not None else None
        x, nc, aux = group_body(x, gp, gc)
        return (x, aux_acc + aux), (nc if keep_caches else 0)

    k = cfg.remat_block
    n_groups = jax.tree.leaves(params["groups"])[0].shape[0] \
        if params["groups"] else 0
    if (k > 1 and not keep_caches and cfg.remat != "none"
            and n_groups % k == 0 and n_groups > k):
        # two-level remat: outer scan over super-groups saves x every k
        # groups; the inner scan re-runs under its own checkpoint —
        # sqrt-L activation memory at ~1/k extra recompute
        sup = jax.tree.map(
            lambda p: p.reshape((n_groups // k, k) + p.shape[1:]),
            params["groups"])

        def super_body(x, sp):
            (x, aux), _ = jax.lax.scan(
                scan_fn, (x, jnp.zeros((), jnp.float32)), (sp,))
            return x, aux

        super_body = jax.checkpoint(super_body)

        def outer_fn(carry, sp):
            x, aux_acc = carry
            x, aux = super_body(x, sp)
            return (x, aux_acc + aux), 0

        (x, aux_total), ys = jax.lax.scan(
            outer_fn, (x, jnp.zeros((), jnp.float32)), sup)
    else:
        xs = (params["groups"],) if caches is None \
            else (params["groups"], caches["groups"])
        (x, aux_total), ys = jax.lax.scan(
            scan_fn, (x, jnp.zeros((), jnp.float32)), xs)
    new_caches = {"groups": ys, "tail": []} if keep_caches else None

    for j, tp in enumerate(params["tail"]):
        kind = pattern[j]
        c = None if caches is None else caches["tail"][j]
        if c is None and collect_cache:
            c = init_block_cache(cfg, kind, x.shape[0], s_ctx or x.shape[1],
                                 x.dtype)
        x, nc, aux = apply_block(tp, x, cfg, kind, offset=offset, cache=c,
                                 enc_out=enc_out)
        aux_total = aux_total + aux
        if keep_caches:
            new_caches["tail"].append(nc)
    return x, new_caches, aux_total
