"""Model registry: builds (init, train_logits, prefill, decode_step) for a
ModelConfig across all five families.

Batch conventions
-----------------
train:   {"tokens": (B,S) i32} or {"embeddings": (B,S,d)} (+ encdec:
         {"frames": (B,Se,d), "tokens": (B,S)}), plus "labels" handled by
         the loss in repro.distributed.step.
prefill: same inputs; returns (logits_last, caches).
decode:  {"tokens": (B,1), "index": scalar i32, "caches": pytree}
         (+ encdec: {"enc_out": (B,Se,d)}); returns (logits, caches).
"""
from __future__ import annotations

from types import SimpleNamespace

import jax
import jax.numpy as jnp

from . import layers as L
from .config import ModelConfig
from .transformer import (apply_stack, family_pattern, init_stack,
                          _stack_caches)


def _dtype(cfg):
    return {"float32": jnp.float32, "bf16": jnp.bfloat16,
            "bfloat16": jnp.bfloat16, "fp16": jnp.float16}[cfg.dtype]


def _embed_in(params, batch, cfg):
    dt = _dtype(cfg)
    if "embeddings" in batch:
        return batch["embeddings"].astype(dt)
    return L.apply_embedding(params["embed"], batch["tokens"], dt)


def _unembed(params, x, cfg):
    table = params["embed"]["table"] if cfg.tie_embeddings \
        else params["unembed"]["table"]
    return L.apply_unembed(None, x, table=table)


def build_model(cfg: ModelConfig) -> SimpleNamespace:
    if cfg.family == "encdec":
        return _build_encdec(cfg)
    pattern = family_pattern(cfg)

    def init(key):
        ks = jax.random.split(key, 4)
        params = {"stack": init_stack(ks[0], cfg, pattern, cfg.n_layers),
                  "norm_f": L.init_norm(cfg.d_model, cfg.norm)}
        if cfg.frontend == "none" or cfg.family == "vlm":
            params["embed"] = L.init_embedding(ks[1], cfg.vocab_size,
                                               cfg.d_model)
        if not cfg.tie_embeddings:
            params["unembed"] = L.init_embedding(ks[2], cfg.vocab_size,
                                                 cfg.d_model)
        return params

    def backbone(params, x, *, offset=0, caches=None, collect=False,
                 s_ctx=None):
        x, nc, aux = apply_stack(params["stack"], x, cfg, pattern,
                                 offset=offset, caches=caches,
                                 collect_cache=collect, s_ctx=s_ctx)
        x = L.apply_norm(params["norm_f"], x, eps=cfg.norm_eps)
        return x, nc, aux

    def backbone_features(params, batch):
        """Final hidden states before unembedding (chunked-loss path)."""
        x = _embed_in(params, batch, cfg)
        x, _, aux = backbone(params, x)
        return x, aux

    def train_logits(params, batch):
        x, aux = backbone_features(params, batch)
        return _unembed(params, x, cfg), aux

    def prefill(params, batch):
        x = _embed_in(params, batch, cfg)
        x, caches, _ = backbone(params, x, collect=True, s_ctx=x.shape[1])
        return _unembed(params, x[:, -1:], cfg), caches

    def init_caches(batch_size: int, s_ctx: int):
        return _stack_caches(cfg, pattern, cfg.n_layers, batch_size, s_ctx,
                             _dtype(cfg))

    def decode_step(params, batch, caches):
        x = _embed_in(params, batch, cfg)
        x, caches, _ = backbone(params, x, offset=batch["index"],
                                caches=caches)
        return _unembed(params, x, cfg), caches

    return SimpleNamespace(cfg=cfg, init=init, train_logits=train_logits,
                           prefill=prefill, decode_step=decode_step,
                           init_caches=init_caches,
                           backbone_features=backbone_features)


# -----------------------------------------------------------------------------
# encoder-decoder (whisper-style)
# -----------------------------------------------------------------------------

def _build_encdec(cfg: ModelConfig):
    enc_pat, dec_pat = ("enc",), ("dec",)
    n_enc = cfg.n_enc_layers or cfg.n_layers

    def init(key):
        ks = jax.random.split(key, 6)
        params = {
            "enc_stack": init_stack(ks[0], cfg, enc_pat, n_enc),
            "dec_stack": init_stack(ks[1], cfg, dec_pat, cfg.n_layers),
            "enc_norm": L.init_norm(cfg.d_model, cfg.norm),
            "norm_f": L.init_norm(cfg.d_model, cfg.norm),
            "embed": L.init_embedding(ks[2], cfg.vocab_size, cfg.d_model),
            "pos_dec": jax.random.normal(ks[3], (cfg.max_seq, cfg.d_model),
                                         jnp.float32) * 0.01,
        }
        if not cfg.tie_embeddings:
            params["unembed"] = L.init_embedding(ks[4], cfg.vocab_size,
                                                 cfg.d_model)
        return params

    def encode(params, frames):
        x, _, _ = apply_stack(params["enc_stack"], frames.astype(_dtype(cfg)),
                              cfg, enc_pat)
        return L.apply_norm(params["enc_norm"], x, eps=cfg.norm_eps)

    def _dec_embed(params, tokens, index):
        dt = _dtype(cfg)
        x = L.apply_embedding(params["embed"], tokens, dt)
        pos = params["pos_dec"].astype(dt)
        S = tokens.shape[1]
        p = jax.lax.dynamic_slice_in_dim(pos, index, S, 0) if S == 1 \
            else pos[:S]
        return x + p[None]

    def backbone_features(params, batch):
        enc_out = encode(params, batch["frames"])
        x = _dec_embed(params, batch["tokens"], 0)
        x, _, aux = apply_stack(params["dec_stack"], x, cfg, dec_pat,
                                enc_out=enc_out)
        return L.apply_norm(params["norm_f"], x, eps=cfg.norm_eps), aux

    def train_logits(params, batch):
        x, aux = backbone_features(params, batch)
        return _unembed(params, x, cfg), aux

    def prefill(params, batch):
        enc_out = encode(params, batch["frames"])
        x = _dec_embed(params, batch["tokens"], 0)
        x, caches, _ = apply_stack(params["dec_stack"], x, cfg, dec_pat,
                                   enc_out=enc_out, collect_cache=True,
                                   s_ctx=x.shape[1])
        x = L.apply_norm(params["norm_f"], x, eps=cfg.norm_eps)
        return _unembed(params, x[:, -1:], cfg), caches

    def init_caches(batch_size: int, s_ctx: int):
        return _stack_caches(cfg, dec_pat, cfg.n_layers, batch_size, s_ctx,
                             _dtype(cfg))

    def decode_step(params, batch, caches):
        x = _dec_embed(params, batch["tokens"], batch["index"])
        x, caches, _ = apply_stack(params["dec_stack"], x, cfg, dec_pat,
                                   offset=batch["index"], caches=caches,
                                   enc_out=batch["enc_out"].astype(_dtype(cfg)))
        x = L.apply_norm(params["norm_f"], x, eps=cfg.norm_eps)
        return _unembed(params, x, cfg), caches

    return SimpleNamespace(cfg=cfg, init=init, train_logits=train_logits,
                           prefill=prefill, decode_step=decode_step,
                           init_caches=init_caches, encode=encode,
                           backbone_features=backbone_features)
