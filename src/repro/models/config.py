"""Architecture configuration shared by the model zoo and launch layer."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # decoder | encdec | rglru | xlstm | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    qk_norm: bool = False
    act: str = "silu"            # silu (SwiGLU) | gelu (plain MLP)
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # hybrid (RecurrentGemma): repeating block pattern
    pattern: Tuple[str, ...] = ()    # e.g. ("rg", "rg", "attn")
    window: int = 0                  # local-attention window
    d_rnn: int = 0                   # RG-LRU width (0 -> d_model)
    conv_width: int = 4
    # xLSTM
    slstm_every: int = 0             # one sLSTM per this many layers
    chunk: int = 64                  # mLSTM chunkwise-parallel chunk length
    # enc-dec (whisper): n_layers applies to BOTH stacks
    n_enc_layers: int = 0
    # modality frontend: "none" (token ids) | "stub" (precomputed embeddings)
    frontend: str = "none"
    max_seq: int = 1 << 20
    # execution
    dtype: str = "float32"           # compute dtype (bf16 on TPU)
    policy: str = "fp32"             # TransPrecisionPolicy preset name
    remat: str = "none"              # none | dots | full
    attn_chunk: int = 0              # q-block-chunked attention (0 = off)
    use_flash: bool = False          # Pallas attention kernel (prefill)
    logits_chunk: int = 0            # beyond-paper: chunked loss (0 = off)
    # --- perf-iteration knobs (EXPERIMENTS.md §Perf) ---
    mesh_plan: str = "tp"            # "tp" (TP+SP on model) | "fully_dp"
    params_dtype: str = "fp32"       # train-state param storage dtype
    serve_param_mode: str = "fsdp"   # "fsdp" | "tp_only" (serve replication)
    serve_quant: str = ""            # "" | "fp8_e4m3" weight-only storage
    flash_decode: bool = False       # shard_map partial-softmax decode
                                     # (raw caches only: with a
                                     # kv-quantized policy, decode takes
                                     # the DPA quantized-cache path and
                                     # this flag is ignored)
    remat_block: int = 0             # two-level remat: outer scan saves x
                                     # every `remat_block` groups (sqrt-L
                                     # activation memory)

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def _mixer_params(self, kind: str) -> int:
        d, hd, H, KV = self.d_model, self.hd, self.n_heads, self.n_kv_heads
        if kind in ("attn", "attn_local", "enc", "dec"):
            return d * hd * (H + 2 * KV) + H * hd * d
        if kind == "rg":
            dr = self.d_rnn or d
            return 3 * d * dr + dr * d + self.conv_width * dr
        if kind == "mlstm":
            return 5 * d * H * hd + 2 * d * H
        if kind == "slstm":
            return 5 * d * d + 4 * d
        raise ValueError(kind)

    def _pattern(self):
        if self.family == "rglru":
            return tuple(self.pattern) or ("rg", "rg", "attn_local")
        if self.family == "xlstm":
            n = self.slstm_every or 8
            return ("mlstm",) * (n - 1) + ("slstm",)
        return ("attn",)

    @property
    def n_params(self) -> int:
        """Parameter count (pattern-aware; embeddings included once)."""
        d, L = self.d_model, self.n_layers
        if self.act == "silu":
            mlp_dense = 3 * d * self.d_ff
        else:
            mlp_dense = 2 * d * self.d_ff
        if self.is_moe:
            mlp = self.n_experts * mlp_dense + d * self.n_experts
        else:
            mlp = mlp_dense
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.family == "encdec":
            attn = self._mixer_params("attn")
            per_layer = attn + mlp + 2 * d
            return ((self.n_enc_layers or L) * per_layer
                    + L * (per_layer + attn + d) + emb + self.max_seq * d)
        pat = self._pattern()
        total = emb
        for i in range(L):
            total += self._mixer_params(pat[i % len(pat)]) + mlp + 2 * d
        return total

    @property
    def n_active_params(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if not self.is_moe:
            return self.n_params
        d, L = self.d_model, self.n_layers
        mlp_dense = (3 if self.act == "silu" else 2) * d * self.d_ff
        inactive = L * (self.n_experts - self.top_k) * mlp_dense
        return self.n_params - inactive

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)
