from .config import ModelConfig
from .registry import build_model

__all__ = ["ModelConfig", "build_model"]
