"""JAX version compatibility for the Pallas kernels.

jax 0.4.x names the TPU compiler-params dataclass ``TPUCompilerParams``;
0.5+ renamed it to ``CompilerParams``.  Import from here so every kernel
module tracks the rename in one place.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams
