"""Pallas TPU kernels: grouped (per-expert) trans-precision DPA matmul.

The MoE expert contraction is a stack of independent DPA matmuls — one
(M,K)x(K,N) product per expert over the same Table-I datapath as
`dpa_matmul`.  These kernels add a leading *expert* grid dimension to
the dense kernels' (M-block, N-block, K-block) grid, so every expert's
operands move HBM->VMEM at format width (fp16 two bytes, fp8 one byte,
fp4 two E2M1 codes per byte when packed) and accumulate in fp32 VMEM
scratch across the K steps.  Expert weights are the dominant resident
bytes in MoE serving (dbrx/deepseek/granite); packing them 8x smaller is
the paper's bandwidth claim applied where it pays most.

Two entry points, mirroring the dense pair:

  dpa_grouped_matmul_prequant : both operand stacks pre-quantized (and
                                optionally nibble-packed along K);
                                per-expert row/column scales in the
                                epilogue.
  dpa_grouped_matmul_fused    : raw f32/bf16 activations quantized in
                                the kernel prologue — per-(row, K-block)
                                absmax scales folded into each partial
                                product, per-expert weight column scales
                                in the epilogue.

Grid is (expert, M//bm, N//bn, K//bk) with the K step innermost
(`arbitrary`), experts and output tiles parallel.  Validated on CPU via
interpret=True against the XLA fake-quant reference; compiled path
targets TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.formats import get_format
from repro.kernels._compat import CompilerParams as _CompilerParams
from repro.kernels.dpa_matmul import _quantize_block, _widen


def _gmm_params():
    return _CompilerParams(
        dimension_semantics=("parallel", "parallel", "parallel",
                             "arbitrary"))


# -----------------------------------------------------------------------------
# pre-quantized operand stacks (optionally packed)
# -----------------------------------------------------------------------------

def _grouped_prequant_kernel(x_ref, w_ref, sx_ref, sw_ref, o_ref, acc_ref, *,
                             n_k: int, fmt_x: str, fmt_w: str, pack_x: bool,
                             pack_w: bool):
    @pl.when(pl.program_id(3) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # block refs carry a leading length-1 expert dim; drop it for the MXU
    x = _widen(x_ref[0], fmt_x, packed=pack_x, axis=1)
    w = _widen(w_ref[0], fmt_w, packed=pack_w, axis=0)
    acc_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(3) == n_k - 1)
    def _store():
        # epilogue: this expert's row scales x column scales
        o_ref[0] = acc_ref[...] * sx_ref[0] * sw_ref[0]


@functools.partial(jax.jit, static_argnames=("fmt_x", "fmt_w", "bm", "bk",
                                             "bn", "pack_x", "pack_w",
                                             "interpret"))
def dpa_grouped_matmul_prequant(xq, wq, sx, sw, *, fmt_x: str, fmt_w: str,
                                bm: int = 128, bk: int = 128, bn: int = 128,
                                pack_x: bool = False, pack_w: bool = False,
                                interpret: bool = True):
    """(E,M,K) x (E,K,N) -> (E,M,N) f32 with per-expert fp32 accumulation.

    xq: quantized activation stack (native fp8/fp16/bf16 dtype, or uint8
        E2M1 codes when fmt_x == "fp4_e2m1"; shape (E, M, K//2) packed
        bytes when `pack_x`);          sx: (E, M, 1) row scales.
    wq: stacked expert weights ((E, K//2, N) when `pack_w`);
                                       sw: (E, 1, N) column scales.

    Packing halves the bytes the x/w BlockSpecs move HBM->VMEM per
    expert; nibbles unpack in VMEM, so the packed path is bit-identical
    to the unpacked one — the dense kernel's contract, per expert.
    """
    assert not (pack_x and fmt_x != "fp4_e2m1"), "pack_x needs fp4 codes"
    assert not (pack_w and fmt_w != "fp4_e2m1"), "pack_w needs fp4 codes"
    E, M = xq.shape[0], xq.shape[1]
    K = xq.shape[2] * (2 if pack_x else 1)
    K2 = wq.shape[1] * (2 if pack_w else 1)
    N = wq.shape[2]
    assert E == wq.shape[0], (xq.shape, wq.shape)
    assert K == K2, (xq.shape, wq.shape, pack_x, pack_w)
    assert M % bm == 0 and K % bk == 0 and N % bn == 0, \
        f"shapes ({M},{K},{N}) must be multiples of blocks ({bm},{bk},{bn})"
    assert bk % 2 == 0 or not (pack_x or pack_w), "packed bk must be even"
    sx = jnp.broadcast_to(sx.astype(jnp.float32), (E, M, 1))
    sw = jnp.broadcast_to(sw.astype(jnp.float32), (E, 1, N))
    n_k = K // bk
    bk_x = bk // 2 if pack_x else bk
    bk_w = bk // 2 if pack_w else bk

    kernel = functools.partial(_grouped_prequant_kernel, n_k=n_k,
                               fmt_x=fmt_x, fmt_w=fmt_w, pack_x=pack_x,
                               pack_w=pack_w)
    return pl.pallas_call(
        kernel,
        grid=(E, M // bm, N // bn, n_k),
        in_specs=[
            pl.BlockSpec((1, bm, bk_x), lambda e, i, j, k: (e, i, k)),
            pl.BlockSpec((1, bk_w, bn), lambda e, i, j, k: (e, k, j)),
            pl.BlockSpec((1, bm, 1), lambda e, i, j, k: (e, i, 0)),
            pl.BlockSpec((1, 1, bn), lambda e, i, j, k: (e, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda e, i, j, k: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, M, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_gmm_params(),
        interpret=interpret,
    )(xq, wq, sx, sw)


# -----------------------------------------------------------------------------
# fused quantize -> grouped matmul (activations quantized in the prologue)
# -----------------------------------------------------------------------------

def _grouped_fused_kernel(x_ref, w_ref, sw_ref, o_ref, acc_ref, *, n_k: int,
                          fmt_x: str, fmt_w: str, pack_w: bool,
                          target: float):
    @pl.when(pl.program_id(3) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # prologue: absmax -> scale -> saturating RNE cast in VMEM.  The scale
    # varies per (expert token row, K block), so it folds into this block's
    # partial product; only the K-invariant expert column scales wait for
    # the epilogue — identical numerics to the dense fused kernel.
    xq, sx = _quantize_block(x_ref[0].astype(jnp.float32), fmt_x, target)
    w = _widen(w_ref[0], fmt_w, packed=pack_w, axis=0)
    acc_ref[...] += jnp.dot(xq, w, preferred_element_type=jnp.float32) * sx

    @pl.when(pl.program_id(3) == n_k - 1)
    def _store():
        o_ref[0] = acc_ref[...] * sw_ref[0]


@functools.partial(jax.jit, static_argnames=("fmt_x", "fmt_w", "bm", "bk",
                                             "bn", "pack_w", "interpret"))
def dpa_grouped_matmul_fused(x, wq, sw, *, fmt_x: str, fmt_w: str,
                             bm: int = 128, bk: int = 128, bn: int = 128,
                             pack_w: bool = False, interpret: bool = True):
    """Fused quantize->grouped matmul: raw x (E,M,K) f32/bf16,
    pre-quantized (and optionally packed) expert weights -> (E,M,N) f32.

    Each expert's (bm, bk) activation block is absmax-scaled and cast in
    the kernel prologue — the activation stack never round-trips through
    HBM in quantized form, while the expert weights (the MoE-dominant
    resident bytes) stream at format width: 8x fewer bytes than f32 for
    packed fp4 nibbles, 4x/2x for fp8/fp16.
    """
    assert not (pack_w and fmt_w != "fp4_e2m1"), "pack_w needs fp4 codes"
    E, M, K = x.shape
    K2 = wq.shape[1] * (2 if pack_w else 1)
    N = wq.shape[2]
    assert E == wq.shape[0], (x.shape, wq.shape)
    assert K == K2, (x.shape, wq.shape, pack_w)
    assert M % bm == 0 and K % bk == 0 and N % bn == 0, \
        f"shapes ({M},{K},{N}) must be multiples of blocks ({bm},{bk},{bn})"
    assert bk % 2 == 0 or not pack_w, "packed bk must be even"
    sw = jnp.broadcast_to(sw.astype(jnp.float32), (E, 1, N))
    n_k = K // bk
    bk_w = bk // 2 if pack_w else bk

    kernel = functools.partial(
        _grouped_fused_kernel, n_k=n_k, fmt_x=fmt_x, fmt_w=fmt_w,
        pack_w=pack_w, target=get_format(fmt_x).quant_target)
    return pl.pallas_call(
        kernel,
        grid=(E, M // bm, N // bn, n_k),
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda e, i, j, k: (e, i, k)),
            pl.BlockSpec((1, bk_w, bn), lambda e, i, j, k: (e, k, j)),
            pl.BlockSpec((1, 1, bn), lambda e, i, j, k: (e, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda e, i, j, k: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, M, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_gmm_params(),
        interpret=interpret,
    )(x, wq, sw)
