"""Pallas TPU kernels: blocked online-softmax attention (forward).

Used by the long-context configs (prefill) where materializing S x S
logits is the memory-roofline killer.  Standard FlashAttention tiling
adapted to TPU VMEM: q tiles of (bq, D) stay resident; k/v stream in
(bk, D) tiles; the running (max, denom, acc) triple lives in VMEM
scratch.  GQA is handled in the index maps (q-head block -> kv-head
block via integer division), so grouped heads never duplicate KV in HBM
— the same "narrow wires, wide accumulator" economics as the DPA GEMM.

Two entry points:

  flash_attention     : the seed f32 datapath.
  dpa_flash_attention : both attention matmuls run the DPA contract —
      QK^T and PV accumulate in f32 over operands quantized to a Table-I
      mode (fp16/bf16 2-term, fp8 4-term, fp4 8-term), while the online
      softmax (running max / denominator / alpha rescales) stays entirely
      f32.  K/V either arrive raw (quantized per-row in the prologue) or
      as quantized KV-cache rows — codes + per-row f32 scales, fp4
      optionally nibble-packed along head_dim (`core.packing` layout, so
      the BlockSpec moves half the cache bytes) — and are *dequantized in
      the prologue* (widen(codes) * scale).  Semantic spec:
      `ref.dpa_flash_attention_ref`.

Supports causal and sliding-window (RecurrentGemma local attention)
masks.  Forward only: training configs use XLA attention + remat; the
kernel serves prefill.

  paged_decode_attention : the serving engine's decode step over the
      *paged* quantized KV cache (`core.kvcache` page pool + block
      table).  The block table rides scalar prefetch and the K/V
      BlockSpec index maps read *through* it — page j of request b
      streams straight from pool page ``table[b, j]`` into VMEM with
      prologue dequant, so the contiguous view is never re-materialized
      in HBM (`gather_paged_kv` stays as the jnp reference fallback).
      Bit-identical to that fallback across all Table-I KV formats,
      packed fp4 crossing page boundaries included; selected by
      `core.exec_plan` (route ``paged_decode/pallas_block_table``) like
      every other route.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.packing import unpack_fp4
from repro.core.quantize import decode_fp4, quant_rows_grid
from repro.kernels._compat import CompilerParams as _CompilerParams

_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  n_k: int, scale: float, causal: bool, window,
                  bq: int, bk: int, sq: int, sk: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # (bq, D)
    k = k_ref[0].astype(jnp.float32)                  # (bk, D)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)

    i = pl.program_id(1)
    qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) \
        + (sk - sq)                                   # align cache offsets
    kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_ref[...]                               # (bq, 1)
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_cur)
    alpha = jnp.exp(m_prev - m_cur)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v_ref[0].astype(jnp.float32), preferred_element_type=jnp.float32)
    m_ref[...] = m_cur

    @pl.when(j == n_k - 1)
    def _store():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "scale",
                                             "bq", "bk", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    scale=None, bq: int = 128, bk: int = 128,
                    interpret: bool = True):
    """(B,H,Sq,D),(B,Hkv,Sk,D),(B,Hkv,Sk,D) -> (B,H,Sq,D)."""
    B, H, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    g = H // Hkv
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    scale_v = float(scale if scale is not None else D ** -0.5)

    qr = q.reshape(B * H, Sq, D)
    kr = k.reshape(B * Hkv, Sk, D)
    vr = v.reshape(B * Hkv, Sk, D)
    kernel = functools.partial(
        _flash_kernel, n_k=Sk // bk, scale=scale_v, causal=causal,
        window=window, bq=bq, bk=bk, sq=Sq, sk=Sk)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, Sq // bq, Sk // bk),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j, g=g: (b // g, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j, g=g: (b // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, D), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, H, Sq, D)


# -----------------------------------------------------------------------------
# DPA-quantized attention: QK^T and PV accumulate f32 over narrow operands
# -----------------------------------------------------------------------------

def _widen_kv(codes, fmt_kv: str, packed: bool):
    """Cache codes -> f32 grid values (the prologue widening): native
    narrow dtypes cast up; fp4 E2M1 codes decode arithmetically, after a
    nibble unpack along head_dim when `packed`."""
    if fmt_kv == "fp4_e2m1":
        if packed:
            codes = unpack_fp4(codes)
        return decode_fp4(codes)
    return codes.astype(jnp.float32)


def _dpa_flash_kernel(*refs, n_k: int, scale: float, causal: bool, window,
                      bq: int, bk: int, sq: int, sk: int, fmt: str,
                      fmt_kv: str, kv_quant: bool, kv_packed: bool):
    if kv_quant:
        (q_ref, k_ref, v_ref, ks_ref, vs_ref, o_ref,
         m_ref, l_ref, acc_ref) = refs
    else:
        q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref = refs
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # prologue: quantize q onto fmt's grid (scale rides the epilogue of the
    # QK^T partial product); widen K/V to their dequantized values — from
    # cache codes * stored scales, or via in-block per-row quantization
    qg, qs = quant_rows_grid(q_ref[0], fmt)            # (bq, D), (bq, 1)
    if kv_quant:
        k_eff = _widen_kv(k_ref[0], fmt_kv, kv_packed) * ks_ref[0]
        v_eff = _widen_kv(v_ref[0], fmt_kv, kv_packed) * vs_ref[0]
    else:
        kg, ks = quant_rows_grid(k_ref[0], fmt_kv)
        vg, vs = quant_rows_grid(v_ref[0], fmt_kv)
        k_eff, v_eff = kg * ks, vg * vs

    # DPA matmul #1: narrow q x widened K, f32 accumulate, row scale after
    s = jnp.dot(qg, k_eff.T, preferred_element_type=jnp.float32) * qs * scale

    i = pl.program_id(1)
    qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) \
        + (sk - sq)
    kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, _NEG_INF)

    # online softmax: running max / denominator / rescales all f32
    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_cur)
    alpha = jnp.exp(m_prev - m_cur)
    # DPA matmul #2: probabilities quantized per (row, k-block) onto fmt's
    # grid; their scale folds into BOTH the f32 PV accumulation and the
    # f32 denominator, so numerator and normalizer see the same grid
    pg, ps = quant_rows_grid(p, fmt)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(pg, axis=1, keepdims=True) * ps
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        pg, v_eff, preferred_element_type=jnp.float32) * ps
    m_ref[...] = m_cur

    @pl.when(j == n_k - 1)
    def _store():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "fmt", "fmt_kv", "kv_quant", "kv_packed", "causal", "window", "scale",
    "bq", "bk", "interpret"))
def dpa_flash_attention(q, k, v, k_scale=None, v_scale=None, *, fmt: str,
                        fmt_kv: str | None = None, kv_quant: bool = False,
                        kv_packed: bool = False, causal: bool = True,
                        window=None, scale=None, bq: int = 128,
                        bk: int = 128, interpret: bool = True):
    """(B,H,Sq,D) x (B,Hkv,Sk,Dk) x (B,Hkv,Sk,Dk) -> (B,H,Sq,D).

    Raw path (kv_quant=False): k/v are float tensors, quantized per-row
    onto fmt_kv's grid in the kernel prologue.  Cache path (kv_quant=True):
    k/v are quantized KV-cache rows — native narrow dtype or uint8 E2M1
    codes (Dk = D // 2 packed bytes when kv_packed) — with per-row f32
    scales k_scale/v_scale (B,Hkv,Sk,1).  Both paths see bit-identical
    K/V values; the cache path just moves 2-8x fewer bytes HBM->VMEM.
    """
    B, H, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    g = H // Hkv
    fmt_kv = fmt_kv or fmt
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    scale_v = float(scale if scale is not None else D ** -0.5)
    dk = D // 2 if (kv_quant and kv_packed) else D

    qr = q.reshape(B * H, Sq, D)
    kr = k.reshape(B * Hkv, Sk, dk)
    vr = v.reshape(B * Hkv, Sk, dk)
    kernel = functools.partial(
        _dpa_flash_kernel, n_k=Sk // bk, scale=scale_v, causal=causal,
        window=window, bq=bq, bk=bk, sq=Sq, sk=Sk, fmt=fmt, fmt_kv=fmt_kv,
        kv_quant=kv_quant, kv_packed=kv_packed)
    in_specs = [
        pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        pl.BlockSpec((1, bk, dk), lambda b, i, j, g=g: (b // g, j, 0)),
        pl.BlockSpec((1, bk, dk), lambda b, i, j, g=g: (b // g, j, 0)),
    ]
    operands = [qr, kr, vr]
    if kv_quant:
        in_specs += [
            pl.BlockSpec((1, bk, 1), lambda b, i, j, g=g: (b // g, j, 0)),
            pl.BlockSpec((1, bk, 1), lambda b, i, j, g=g: (b // g, j, 0)),
        ]
        operands += [k_scale.reshape(B * Hkv, Sk, 1),
                     v_scale.reshape(B * Hkv, Sk, 1)]
    out = pl.pallas_call(
        kernel,
        grid=(B * H, Sq // bq, Sk // bk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, D), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(*operands)
    return out.reshape(B, H, Sq, D)


# -----------------------------------------------------------------------------
# paged decode: block-table reads through scalar-prefetched index maps
# -----------------------------------------------------------------------------

def _paged_decode_kernel(tab_ref, pos_ref, q_ref, kc_ref, ks_ref, vc_ref,
                         vs_ref, o_ref, k_s, v_s, *, n_pages: int, ps: int,
                         kv_heads: int, fmt: str, fmt_kv: str,
                         kv_packed: bool, scale: float, s_view: int):
    """Grid (B * KV, n_pages): page steps stream request b's timeline —
    pool page ``table[b, j]`` arrives via the BlockSpec index map — and
    widen codes * scales into VMEM scratch; the last step runs the whole
    DPA attention row.

    The final computation deliberately mirrors `models.decode_attn.
    dpa_attention`'s einsum structure (batch dims (head, s=1), per-batch
    (1, hd) x (hd, S) matvecs) instead of a flat (g, hd) @ (hd, S) dot:
    XLA tiles the two shapes differently, and the einsum form keeps the
    route bit-identical to the jnp gather fallback — the contract
    `tests/test_exec_plan.py` pins at tol 0.
    """
    i = pl.program_id(0)
    j = pl.program_id(1)
    b = i // kv_heads
    k_s[pl.ds(j * ps, ps), :] = _widen_kv(kc_ref[0, :, 0, :], fmt_kv,
                                          kv_packed) * ks_ref[0, :, 0, :]
    v_s[pl.ds(j * ps, ps), :] = _widen_kv(vc_ref[0, :, 0, :], fmt_kv,
                                          kv_packed) * vs_ref[0, :, 0, :]

    @pl.when(j == n_pages - 1)
    def _compute():
        g, hd = q_ref.shape[1], q_ref.shape[2]
        qg, qs = quant_rows_grid(q_ref[0][:, None, None, :], fmt)
        k_all = jnp.broadcast_to(k_s[...][None, :, None, :],
                                 (g, s_view, 1, hd))
        v_all = jnp.broadcast_to(v_s[...][None, :, None, :],
                                 (g, s_view, 1, hd))
        logits = jnp.einsum("bshd,bthd->bhst", qg, k_all,
                            preferred_element_type=jnp.float32)
        logits = logits * qs.transpose(0, 2, 1, 3) * scale
        kpos = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 3)
        logits = jnp.where(kpos <= pos_ref[b], logits, _NEG_INF)
        m = jnp.max(logits, axis=-1, keepdims=True)
        p = jnp.exp(logits - m)                       # f32 softmax core
        pg, psq = quant_rows_grid(p, fmt)
        den = jnp.sum(pg, axis=-1, keepdims=True) * psq
        num = jnp.einsum("bhst,bthd->bshd", pg, v_all,
                         preferred_element_type=jnp.float32)
        num = num * psq.transpose(0, 2, 1, 3)
        out = num / jnp.maximum(den, 1e-30).transpose(0, 2, 1, 3)
        o_ref[0] = out[:, 0, 0, :].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("fmt", "fmt_kv", "kv_packed",
                                             "scale", "interpret"))
def paged_decode_attention(q, k_codes, k_scale, v_codes, v_scale,
                           block_table, positions, *, fmt: str, fmt_kv: str,
                           kv_packed: bool = False, scale=None,
                           interpret: bool = True):
    """One decode step against the paged quantized KV cache.

    q: (B, 1, H, hd) (already rope'd at per-request positions);
    k/v_codes: (P, page, KV, wc) page pools (wc = hd, or hd // 2 packed
    fp4); k/v_scale: (P, page, KV, 1) f32 per-row scales; block_table:
    (B, max_pages) i32; positions: (B,) i32 current token index per
    request.  Same DPA contract as `dpa_flash_attention`'s cache mode —
    prologue dequant, f32 accumulation, f32 softmax glue — with the
    causal mask per request (row b attends key slots <= positions[b];
    scratch/stale tail pages are masked off).
    """
    B, Sq, H, hd = q.shape
    assert Sq == 1, "paged decode serves single-token steps"
    _, n_pages = block_table.shape
    _, ps, kv_heads, _ = k_codes.shape
    g = H // kv_heads
    s_view = n_pages * ps
    scale_v = float(scale if scale is not None else hd ** -0.5)
    qr = q[:, 0].reshape(B * kv_heads, g, hd)

    def page_idx(i, j, tab, pos, kv=kv_heads):
        return (tab[i // kv, j], 0, i % kv, 0)

    kernel = functools.partial(
        _paged_decode_kernel, n_pages=n_pages, ps=ps, kv_heads=kv_heads,
        fmt=fmt, fmt_kv=fmt_kv, kv_packed=kv_packed, scale=scale_v,
        s_view=s_view)
    wc = k_codes.shape[-1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B * kv_heads, n_pages),
        in_specs=[
            pl.BlockSpec((1, g, hd), lambda i, j, tab, pos: (i, 0, 0)),
            pl.BlockSpec((1, ps, 1, wc), page_idx),
            pl.BlockSpec((1, ps, 1, 1), page_idx),
            pl.BlockSpec((1, ps, 1, wc), page_idx),
            pl.BlockSpec((1, ps, 1, 1), page_idx),
        ],
        out_specs=pl.BlockSpec((1, g, hd), lambda i, j, tab, pos: (i, 0, 0)),
        scratch_shapes=[pltpu.VMEM((s_view, hd), jnp.float32),
                        pltpu.VMEM((s_view, hd), jnp.float32)],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * kv_heads, g, hd), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(jnp.asarray(block_table, jnp.int32), jnp.asarray(positions, jnp.int32),
      qr, k_codes, k_scale, v_codes, v_scale)
    return out.reshape(B, 1, H, hd)
