"""Pallas TPU kernel: blocked online-softmax attention (forward).

Used by the long-context configs (prefill) where materializing S x S
logits is the memory-roofline killer.  Standard FlashAttention tiling
adapted to TPU VMEM: q tiles of (bq, D) stay resident; k/v stream in
(bk, D) tiles; the running (max, denom, acc) triple lives in VMEM
scratch.  GQA is handled in the index maps (q-head block -> kv-head
block via integer division), so grouped heads never duplicate KV in HBM
— the same "narrow wires, wide accumulator" economics as the DPA GEMM.

Supports causal and sliding-window (RecurrentGemma local attention)
masks.  Forward only: training configs use XLA attention + remat; the
kernel serves prefill.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  n_k: int, scale: float, causal: bool, window,
                  bq: int, bk: int, sq: int, sk: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # (bq, D)
    k = k_ref[0].astype(jnp.float32)                  # (bk, D)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)

    i = pl.program_id(1)
    qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) \
        + (sk - sq)                                   # align cache offsets
    kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, _NEG_INF)

    m_prev = m_ref[...]                               # (bq, 1)
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_cur)
    alpha = jnp.exp(m_prev - m_cur)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v_ref[0].astype(jnp.float32), preferred_element_type=jnp.float32)
    m_ref[...] = m_cur

    @pl.when(j == n_k - 1)
    def _store():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "scale",
                                             "bq", "bk", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    scale=None, bq: int = 128, bk: int = 128,
                    interpret: bool = True):
    """(B,H,Sq,D),(B,Hkv,Sk,D),(B,Hkv,Sk,D) -> (B,H,Sq,D)."""
    B, H, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    g = H // Hkv
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    scale_v = float(scale if scale is not None else D ** -0.5)

    qr = q.reshape(B * H, Sq, D)
    kr = k.reshape(B * Hkv, Sk, D)
    vr = v.reshape(B * Hkv, Sk, D)
    kernel = functools.partial(
        _flash_kernel, n_k=Sk // bk, scale=scale_v, causal=causal,
        window=window, bq=bq, bk=bk, sq=Sq, sk=Sk)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, Sq // bq, Sk // bk),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j, g=g: (b // g, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j, g=g: (b // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, D), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, H, Sq, D)
