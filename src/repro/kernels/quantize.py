"""Pallas TPU kernel: fused row-wise quantization for DPA operands.

One VMEM pass computes per-row absmax, the scale, and the saturating cast
into the DPA operand format (fp8 native dtype, or uint8 E2M1 codes for
fp4).  Fusing the three stages keeps the activation tensor's HBM traffic
at 1R + (1/4..1/8)W — the software face of the paper's "preserve the
input interface bandwidth" argument.

Rows are tiled (bm, K): K stays resident so absmax is a single reduction
(activations in the model zoo have K <= 32k f32 = 128 KiB/row, well under
VMEM at bm rows per step).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.formats import get_format

_FMT_DTYPE = {"fp8_e4m3": jnp.float8_e4m3fn, "fp8_e5m2": jnp.float8_e5m2,
              "fp16": jnp.float16, "bf16": jnp.bfloat16}


def _encode_fp4(x):
    """f32 -> uint8 E2M1 codes, saturating RNE (arithmetic, no gather)."""
    s = (x < 0).astype(jnp.uint8)
    a = jnp.abs(x)
    # grid of representable magnitudes: 0, .5, 1, 1.5, 2, 3, 4, 6
    # RNE via midpoint thresholds (ties-to-even baked into <=/< choices)
    code = jnp.zeros(x.shape, jnp.uint8)
    mags = [0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0]
    for i in range(1, 8):
        mid = 0.5 * (mags[i - 1] + mags[i])
        even_low = (i - 1) % 2 == 0
        take = (a > mid) if even_low else (a >= mid)
        code = jnp.where(take, jnp.uint8(i), code)
    return code | (s << 3)


def _quantize_kernel(x_ref, q_ref, s_ref, *, fmt: str, target: float):
    x = x_ref[...].astype(jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    scale = jnp.maximum(amax, 1e-30) / target
    scale = jnp.maximum(scale, 2.0 ** -126)
    y = jnp.clip(x / scale, -target, target)
    if fmt == "fp4_e2m1":
        q_ref[...] = _encode_fp4(y)
    else:
        q_ref[...] = y.astype(_FMT_DTYPE[fmt])
    s_ref[...] = scale


@functools.partial(jax.jit, static_argnames=("fmt", "bm", "interpret"))
def quantize_rows(x, *, fmt: str, bm: int = 128, interpret: bool = True):
    """(M,K) f32/bf16 -> (q:(M,K) fmt dtype | uint8 codes, scale:(M,1) f32)."""
    M, K = x.shape
    assert M % bm == 0, f"M={M} must be a multiple of bm={bm}"
    f = get_format(fmt)
    out_dtype = jnp.uint8 if fmt == "fp4_e2m1" else _FMT_DTYPE[fmt]
    kernel = functools.partial(_quantize_kernel, fmt=fmt,
                               target=f.quant_target)
    return pl.pallas_call(
        kernel,
        grid=(M // bm,),
        in_specs=[pl.BlockSpec((bm, K), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((bm, K), lambda i: (i, 0)),
                   pl.BlockSpec((bm, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((M, K), out_dtype),
                   jax.ShapeDtypeStruct((M, 1), jnp.float32)],
        interpret=interpret,
    )(x)
