"""Pallas TPU kernel: fused row-wise quantization for DPA operands.

One VMEM pass computes per-row absmax, the scale, and the saturating cast
into the DPA operand format (fp8 native dtype, or uint8 E2M1 codes for
fp4).  Fusing the three stages keeps the activation tensor's HBM traffic
at 1R + (1/4..1/8)W — the software face of the paper's "preserve the
input interface bandwidth" argument.

Rows are tiled (bm, K): K stays resident so absmax is a single reduction
(activations in the model zoo have K <= 32k f32 = 128 KiB/row, well under
VMEM at bm rows per step).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.formats import get_format
from repro.core.packing import pack_fp4
from repro.core.quantize import absmax_block_scale, jnp_dtype
from repro.core.quantize import encode_fp4 as _encode_fp4


def _quantize_kernel(x_ref, q_ref, s_ref, *, fmt: str, target: float):
    x = x_ref[...].astype(jnp.float32)
    scale = absmax_block_scale(x, target)
    y = jnp.clip(x / scale, -target, target)
    if fmt == "fp4_e2m1":
        q_ref[...] = _encode_fp4(y)
    else:
        q_ref[...] = y.astype(jnp_dtype(fmt))
    s_ref[...] = scale


def _quantize_pack_kernel(x_ref, q_ref, s_ref, *, target: float):
    """Fused absmax -> E2M1 cast -> nibble pack: one VMEM pass, packed
    bytes out.  The write side of the paper's format-width interface: the
    quantized activation leaves VMEM at 0.5 B/code instead of 1 B."""
    x = x_ref[...].astype(jnp.float32)
    scale = absmax_block_scale(x, target)
    c = _encode_fp4(jnp.clip(x / scale, -target, target))
    q_ref[...] = pack_fp4(c)
    s_ref[...] = scale


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def quantize_pack_rows(x, *, bm: int = 128, interpret: bool = True):
    """(M,K) f32/bf16 -> (packed fp4 codes (M, K//2) uint8, scale (M,1))."""
    M, K = x.shape
    assert M % bm == 0, f"M={M} must be a multiple of bm={bm}"
    assert K % 2 == 0, f"fp4 packing needs even K, got {K}"
    f = get_format("fp4_e2m1")
    kernel = functools.partial(_quantize_pack_kernel, target=f.quant_target)
    return pl.pallas_call(
        kernel,
        grid=(M // bm,),
        in_specs=[pl.BlockSpec((bm, K), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((bm, K // 2), lambda i: (i, 0)),
                   pl.BlockSpec((bm, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((M, K // 2), jnp.uint8),
                   jax.ShapeDtypeStruct((M, 1), jnp.float32)],
        interpret=interpret,
    )(x)


@functools.partial(jax.jit, static_argnames=("fmt", "bm", "interpret"))
def quantize_rows(x, *, fmt: str, bm: int = 128, interpret: bool = True):
    """(M,K) f32/bf16 -> (q:(M,K) fmt dtype | uint8 codes, scale:(M,1) f32)."""
    M, K = x.shape
    assert M % bm == 0, f"M={M} must be a multiple of bm={bm}"
    f = get_format(fmt)
    out_dtype = jnp.uint8 if fmt == "fp4_e2m1" else jnp_dtype(fmt)
    kernel = functools.partial(_quantize_kernel, fmt=fmt,
                               target=f.quant_target)
    return pl.pallas_call(
        kernel,
        grid=(M // bm,),
        in_specs=[pl.BlockSpec((bm, K), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((bm, K), lambda i: (i, 0)),
                   pl.BlockSpec((bm, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((M, K), out_dtype),
                   jax.ShapeDtypeStruct((M, 1), jnp.float32)],
        interpret=interpret,
    )(x)
