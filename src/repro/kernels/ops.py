"""Jit'd public wrappers around the Pallas kernels.

These are what the framework calls: they quantize per the policy, pad to
block multiples, dispatch the kernel, and undo padding.  On CPU they run
in interpret mode (`REPRO_PALLAS_INTERPRET=0` to force compiled mode on
real TPUs).
"""
from __future__ import annotations

import os

import jax.numpy as jnp

from repro.core.policy import TransPrecisionPolicy, get_policy
from repro.core.quantize import compute_scale, cast_to
from repro.kernels import dpa_matmul as _dm
from repro.kernels import flash_attention as _fa
from repro.kernels import quantize as _q

INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


def _pad_to(x, mult, axis):
    r = x.shape[axis] % mult
    if r == 0:
        return x, 0
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, mult - r)
    return jnp.pad(x, pad), mult - r


def _quant_operand(x, fmt: str, axis_scale):
    """-> (codes/native, scale) with scale reduced over `axis_scale`."""
    if fmt == "fp4_e2m1":
        from repro.kernels.quantize import _encode_fp4
        from repro.core.formats import get_format
        f = get_format(fmt)
        scale = compute_scale(x, f, axis=axis_scale)
        q = _encode_fp4(jnp.clip(x.astype(jnp.float32) / scale,
                                 -f.max_finite, f.max_finite))
        return q, scale
    scale = compute_scale(x, fmt, axis=axis_scale)
    return cast_to(x.astype(jnp.float32) / scale, fmt), scale


def dpa_matmul(x, w, policy: TransPrecisionPolicy, *, bm=128, bk=128, bn=128):
    """Policy-driven trans-precision matmul: x (..., K) @ w (K, N)."""
    policy = get_policy(policy)
    lead = x.shape[:-1]
    K = x.shape[-1]
    N = w.shape[-1]
    x2 = x.reshape(-1, K)
    xq, sx = _quant_operand(x2, policy.fmt_acts, axis_scale=-1)
    wq, sw = _quant_operand(w, policy.fmt_weights, axis_scale=0)
    bm_ = min(bm, max(8, x2.shape[0]))
    xq, pm = _pad_to(xq, bm_, 0)
    sxp, _ = _pad_to(sx, bm_, 0)
    xq, pk = _pad_to(xq, bk, 1)
    wq, _ = _pad_to(wq, bk, 0)
    wq, pn = _pad_to(wq, bn, 1)
    swp, _ = _pad_to(sw, bn, 1)
    out = _dm.dpa_matmul_prequant(
        xq, wq, sxp, swp, fmt_x=policy.fmt_acts, fmt_w=policy.fmt_weights,
        bm=bm_, bk=bk, bn=bn, interpret=INTERPRET)
    if pm:
        out = out[: x2.shape[0]]
    if pn:
        out = out[:, :N]
    return out.reshape(*lead, N).astype(x.dtype)


def quantize_rows(x, fmt: str, *, bm=128):
    """Fused absmax+cast row quantization (2D input)."""
    x2, pm = _pad_to(x, bm, 0)
    q, s = _q.quantize_rows(x2, fmt=fmt, bm=bm, interpret=INTERPRET)
    if pm:
        q, s = q[: x.shape[0]], s[: x.shape[0]]
    return q, s


def flash_attention(q, k, v, *, causal=True, window=None, scale=None,
                    bq=128, bk=128):
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               scale=scale, bq=bq, bk=bk,
                               interpret=INTERPRET)
