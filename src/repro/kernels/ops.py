"""Jit'd public wrappers around the Pallas kernels.

These are what the execution-plan layer dispatches to: they quantize per
the policy, pad to block multiples, dispatch the kernel, and undo
padding.  On CPU they run in interpret mode (`REPRO_PALLAS_INTERPRET=0`
to force compiled mode on real TPUs).

Route selection does NOT live here: `repro.kernels.registry` registers
each pipeline below as a `core.exec_plan` route with an explicit
lowering predicate, and the policy-driven entry points (`dpa_matmul`,
`quantize_rows`) resolve through the plan so they stay semantically
identical to the call sites that use the plan directly.
"""
from __future__ import annotations

import os

import jax.numpy as jnp

from repro.core import exec_plan
from repro.core.packing import pack_fp4_axis
from repro.core.policy import TransPrecisionPolicy, get_policy
from repro.core.quantize import compute_scale, cast_to
from repro.kernels import dpa_grouped_matmul as _gm
from repro.kernels import dpa_matmul as _dm
from repro.kernels import flash_attention as _fa
from repro.kernels import quantize as _q

INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


def _pad_to(x, mult, axis):
    r = x.shape[axis] % mult
    if r == 0:
        return x, 0
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, mult - r)
    return jnp.pad(x, pad), mult - r


def _quant_operand(x, fmt: str, axis_scale):
    """-> (codes/native, scale) with scale reduced over `axis_scale`."""
    if fmt == "fp4_e2m1":
        from repro.core.formats import get_format
        from repro.core.quantize import encode_fp4
        f = get_format(fmt)
        scale = compute_scale(x, f, axis=axis_scale)
        q = encode_fp4(jnp.clip(x.astype(jnp.float32) / scale,
                                -f.max_finite, f.max_finite))
        return q, scale
    scale = compute_scale(x, fmt, axis=axis_scale)
    return cast_to(x.astype(jnp.float32) / scale, fmt), scale


def _prep_weights(w, policy, bk, bn):
    """Quantize + pad + (optionally) pack the weight side."""
    pack_w = policy.packed and policy.fmt_weights == "fp4_e2m1"
    wq, sw = _quant_operand(w, policy.fmt_weights, axis_scale=0)
    wq, _ = _pad_to(wq, bk, 0)
    wq, pn = _pad_to(wq, bn, 1)
    swp, _ = _pad_to(sw, bn, 1)
    if pack_w:
        wq = pack_fp4_axis(wq, 0)
    return wq, swp, pn, pack_w


def dpa_matmul_fused_pipeline(x, w, policy: TransPrecisionPolicy, *,
                              bm=128, bk=128, bn=128):
    """Fused-quant pipeline: x ships at its native width (f32/bf16) and
    quantizes in the kernel prologue with per-(row, K-block) scales;
    weights are pre-quantized (packed fp4 when the policy says)."""
    policy = get_policy(policy)
    lead, K, N = x.shape[:-1], x.shape[-1], w.shape[-1]
    x2 = x.reshape(-1, K)
    bm_ = min(bm, max(8, x2.shape[0]))
    wq, swp, pn, pack_w = _prep_weights(w, policy, bk, bn)
    x2p, pm = _pad_to(x2, bm_, 0)
    x2p, _ = _pad_to(x2p, bk, 1)
    out = _dm.dpa_matmul_fused(
        x2p, wq, swp, fmt_x=policy.fmt_acts, fmt_w=policy.fmt_weights,
        bm=bm_, bk=bk, bn=bn, pack_w=pack_w, interpret=INTERPRET)
    if pm:
        out = out[: x2.shape[0]]
    if pn:
        out = out[:, :N]
    return out.reshape(*lead, N).astype(x.dtype)


def dpa_matmul_prequant_pipeline(x, w, policy: TransPrecisionPolicy, *,
                                 bm=128, bk=128, bn=128):
    """Prequant pipeline: XLA quantize pass on both sides, prequant
    kernel; fp4 operand sides additionally packed 2 codes/byte before
    dispatch when the policy says — the BlockSpec moves half the bytes,
    bit-identical results."""
    policy = get_policy(policy)
    lead, K, N = x.shape[:-1], x.shape[-1], w.shape[-1]
    x2 = x.reshape(-1, K)
    bm_ = min(bm, max(8, x2.shape[0]))
    pack_x = policy.packed and policy.fmt_acts == "fp4_e2m1"
    wq, swp, pn, pack_w = _prep_weights(w, policy, bk, bn)
    xq, sx = _quant_operand(x2, policy.fmt_acts, axis_scale=-1)
    xq, pm = _pad_to(xq, bm_, 0)
    sxp, _ = _pad_to(sx, bm_, 0)
    xq, _ = _pad_to(xq, bk, 1)
    if pack_x:
        xq = pack_fp4_axis(xq, 1)
    out = _dm.dpa_matmul_prequant(
        xq, wq, sxp, swp, fmt_x=policy.fmt_acts,
        fmt_w=policy.fmt_weights, bm=bm_, bk=bk, bn=bn,
        pack_x=pack_x, pack_w=pack_w, interpret=INTERPRET)
    if pm:
        out = out[: x2.shape[0]]
    if pn:
        out = out[:, :N]
    return out.reshape(*lead, N).astype(x.dtype)


def _grouped_views(eq: str, x, w):
    """Normalize a known grouped einsum to stacked per-expert matmuls.

    -> (x3 (E,M,K), w3 (E,K,N), unview: (E,M,N) -> eq's output shape).
    The supported eqs are `core.linear.GROUPED_EQS`; the registry
    predicates keep the Pallas grouped routes off anything else."""
    if eq == "gti,gio->gto":
        return x, w, lambda o: o
    if eq == "becd,edf->becf":
        b, e, c, d = x.shape
        x3 = x.transpose(1, 0, 2, 3).reshape(e, b * c, d)
        return x3, w, lambda o: o.reshape(e, b, c,
                                          -1).transpose(1, 0, 2, 3)
    raise ValueError(f"unsupported grouped einsum {eq!r}")


def _prep_grouped_weights(w3, policy, bk, bn):
    """Quantize + pad + (optionally) pack the stacked expert weights."""
    pack_w = policy.packed and policy.fmt_weights == "fp4_e2m1"
    wq, sw = _quant_operand(w3, policy.fmt_weights, axis_scale=1)
    wq, _ = _pad_to(wq, bk, 1)
    wq, pn = _pad_to(wq, bn, 2)
    swp, _ = _pad_to(sw, bn, 2)
    if pack_w:
        wq = pack_fp4_axis(wq, 1)
    return wq, swp, pn, pack_w


def dpa_grouped_fused_pipeline(x, w, policy: TransPrecisionPolicy, *,
                               eq: str, bm=128, bk=128, bn=128):
    """Grouped fused-quant pipeline: per-expert activations ship at
    native width (f32/bf16) and quantize in the kernel prologue with
    per-(row, K-block) scales; expert weights are pre-quantized (packed
    fp4 nibbles when the policy says — 8x fewer resident weight bytes)."""
    policy = get_policy(policy)
    x3, w3, unview = _grouped_views(eq, x, w)
    M, N = x3.shape[1], w3.shape[-1]
    bm_ = min(bm, max(8, M))
    wq, swp, pn, pack_w = _prep_grouped_weights(w3, policy, bk, bn)
    x3p, pm = _pad_to(x3, bm_, 1)
    x3p, _ = _pad_to(x3p, bk, 2)
    out = _gm.dpa_grouped_matmul_fused(
        x3p, wq, swp, fmt_x=policy.fmt_acts, fmt_w=policy.fmt_weights,
        bm=bm_, bk=bk, bn=bn, pack_w=pack_w, interpret=INTERPRET)
    if pm:
        out = out[:, :M]
    if pn:
        out = out[:, :, :N]
    return unview(out.astype(x.dtype))


def dpa_grouped_prequant_pipeline(x, w, policy: TransPrecisionPolicy, *,
                                  eq: str, bm=128, bk=128, bn=128):
    """Grouped prequant pipeline: XLA quantize pass on both operand
    stacks, prequant grouped kernel; fp4 sides nibble-packed along K
    before dispatch when the policy says — per-expert BlockSpecs move
    half the bytes, bit-identical results."""
    policy = get_policy(policy)
    x3, w3, unview = _grouped_views(eq, x, w)
    M, N = x3.shape[1], w3.shape[-1]
    bm_ = min(bm, max(8, M))
    pack_x = policy.packed and policy.fmt_acts == "fp4_e2m1"
    wq, swp, pn, pack_w = _prep_grouped_weights(w3, policy, bk, bn)
    xq, sx = _quant_operand(x3, policy.fmt_acts, axis_scale=-1)
    xq, pm = _pad_to(xq, bm_, 1)
    sxp, _ = _pad_to(sx, bm_, 1)
    xq, _ = _pad_to(xq, bk, 2)
    if pack_x:
        xq = pack_fp4_axis(xq, 2)
    out = _gm.dpa_grouped_matmul_prequant(
        xq, wq, sxp, swp, fmt_x=policy.fmt_acts,
        fmt_w=policy.fmt_weights, bm=bm_, bk=bk, bn=bn,
        pack_x=pack_x, pack_w=pack_w, interpret=INTERPRET)
    if pm:
        out = out[:, :M]
    if pn:
        out = out[:, :, :N]
    return unview(out.astype(x.dtype))


def dpa_matmul(x, w, policy: TransPrecisionPolicy, *, bm=128, bk=128,
               bn=128):
    """Policy-driven trans-precision matmul: x (..., K) @ w (K, N).

    Resolves the kernel pipeline through `core.exec_plan` (routes
    ``matmul/pallas_fused`` and ``matmul/pallas_prequant``), so calling
    this directly is identical to routing via `core.linear.dpa_dot`."""
    policy = get_policy(policy)
    entry = exec_plan.resolve("matmul", policy, w_dtype=str(w.dtype),
                              kernel_only=True)
    return entry.run(x, w, policy, bm=bm, bk=bk, bn=bn)


def quantize_rows(x, fmt: str, *, bm=128, pack: bool = False):
    """Fused absmax+cast row quantization (2D input).  With `pack` (fp4
    only) the kernel also nibble-packs: (M, K//2) uint8 out — the
    quantize->pack half of the quantize->pack->DPA pipeline.  Resolved
    through `core.exec_plan` op ``quantize_pack``."""
    entry = exec_plan.resolve("quantize_pack", None, fmt=fmt, pack=pack)
    return entry.run(x, fmt=fmt, pack=pack, bm=bm)


def quantize_rows_pallas(x, *, fmt: str, pack: bool, bm=128):
    """The Pallas row-quantizer pipelines (`quantize_pack` routes)."""
    x2, pm = _pad_to(x, bm, 0)
    if pack:
        assert fmt == "fp4_e2m1", "pack=True is the fp4 pipeline"
        q, s = _q.quantize_pack_rows(x2, bm=bm, interpret=INTERPRET)
    else:
        q, s = _q.quantize_rows(x2, fmt=fmt, bm=bm, interpret=INTERPRET)
    if pm:
        q, s = q[: x.shape[0]], s[: x.shape[0]]
    return q, s


def flash_attention(q, k, v, *, causal=True, window=None, scale=None,
                    bq=128, bk=128):
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               scale=scale, bq=bq, bk=bk,
                               interpret=INTERPRET)


def dpa_flash_attention(q, k, v, *, fmt, fmt_kv=None, causal=True,
                        window=None, scale=None, bq=128, bk=128):
    """DPA-quantized flash attention over raw (B,H,S,D) operands: q and
    the softmax probabilities quantize onto fmt's grid in the kernel,
    K/V onto fmt_kv's (default fmt); accumulation and the online softmax
    stay f32.  See `flash_attention.dpa_flash_attention` for the
    quantized-KV-cache entry point (codes + scales in, fewer bytes moved).
    """
    return _fa.dpa_flash_attention(q, k, v, fmt=fmt, fmt_kv=fmt_kv,
                                   causal=causal, window=window,
                                   scale=scale, bq=bq, bk=bk,
                                   interpret=INTERPRET)


def paged_decode_attention(q, cache, positions, *, fmt, fmt_kv,
                           kv_packed=False, scale=None):
    """Block-table paged decode (route ``paged_decode/pallas_block_table``):
    unpacks the paged-cache pytree and dispatches the Pallas kernel —
    pages stream HBM->VMEM through the block table, no gathered view."""
    return _fa.paged_decode_attention(
        q, cache["k_codes"], cache["k_scale"], cache["v_codes"],
        cache["v_scale"], cache["block_table"], positions, fmt=fmt,
        fmt_kv=fmt_kv, kv_packed=kv_packed, scale=scale,
        interpret=INTERPRET)
