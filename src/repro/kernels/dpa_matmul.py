"""Pallas TPU kernel: trans-precision DPA matmul.

TPU adaptation of the TransDot datapath (DESIGN.md §2): the MXU is a
128x128 fp32-accumulating systolic dot-product engine — i.e. a very wide
DPA unit.  The paper's N-term DPA (narrow operands in, one wide
accumulation out) maps onto:

  HBM -> VMEM   : operands move at format width (fp8 = 1 byte, fp4 = one
                  uint8 code here / packed nibbles in storage) — the
                  "fixed-width FPU interface" of the paper becomes HBM
                  bandwidth actually saved.
  VMEM decode   : per-block dequant-free *widening* of operand codes into
                  MXU-ingestible values (the multi-mode multiplier's
                  operand partitioning).
  MXU + scratch : fp32 accumulation across the K grid dimension (the
                  paper's wide adder + the extra DPA pipeline stage: the
                  accumulator lives across K iterations).
  epilogue      : per-channel scales applied at the final K step (the
                  exponent datapath's contribution, hoisted to software
                  scales as in all block-scaled AI formats).

Block shapes default to MXU-aligned (128 multiples).  Validated on CPU
via interpret=True against `ref.py`; compiled path targets TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _widen(x, fmt_name: str):
    """Operand codes/values -> f32 products domain (the multiplier input)."""
    if fmt_name == "fp4_e2m1":
        # arithmetic E2M1 decode of uint8 codes (TPU-friendly, no gather):
        # value = (-1)^s * (e==0 ? m/2 : (1+m/2) * 2^(e-1))
        c = x.astype(jnp.int32)
        s = (c >> 3) & 1
        e = (c >> 1) & 3
        m = (c & 1).astype(jnp.float32)
        mag = jnp.where(e == 0, 0.5 * m,
                        (1.0 + 0.5 * m) * jnp.exp2((e - 1).astype(jnp.float32)))
        return jnp.where(s == 1, -mag, mag)
    return x.astype(jnp.float32)


def _dpa_matmul_kernel(x_ref, w_ref, sx_ref, sw_ref, o_ref, acc_ref, *,
                       n_k: int, fmt_x: str, fmt_w: str):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = _widen(x_ref[...], fmt_x)
    w = _widen(w_ref[...], fmt_w)
    acc_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _store():
        # epilogue: software exponent path — row scale x column scale
        o_ref[...] = acc_ref[...] * sx_ref[...] * sw_ref[...]


@functools.partial(jax.jit, static_argnames=("fmt_x", "fmt_w", "bm", "bk",
                                             "bn", "interpret"))
def dpa_matmul_prequant(xq, wq, sx, sw, *, fmt_x: str, fmt_w: str,
                        bm: int = 128, bk: int = 128, bn: int = 128,
                        interpret: bool = True):
    """(M,K) x (K,N) -> (M,N) f32 with fp32 accumulation.

    xq: quantized operand (native fp8/fp16/bf16 dtype, or uint8 E2M1 codes
        when fmt_x == "fp4_e2m1");  sx: (M,1) or (1,1) row scales.
    wq: same on the (K,N) side;     sw: (1,N) or (1,1) column scales.
    """
    M, K = xq.shape
    K2, N = wq.shape
    assert K == K2, (xq.shape, wq.shape)
    assert M % bm == 0 and K % bk == 0 and N % bn == 0, \
        f"shapes ({M},{K},{N}) must be multiples of blocks ({bm},{bk},{bn})"
    sx = jnp.broadcast_to(sx.astype(jnp.float32), (M, 1))
    sw = jnp.broadcast_to(sw.astype(jnp.float32), (1, N))
    n_k = K // bk

    kernel = functools.partial(_dpa_matmul_kernel, n_k=n_k,
                               fmt_x=fmt_x, fmt_w=fmt_w)
    return pl.pallas_call(
        kernel,
        grid=(M // bm, N // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xq, wq, sx, sw)
