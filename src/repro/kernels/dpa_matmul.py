"""Pallas TPU kernels: trans-precision DPA matmul (packed + fused).

TPU adaptation of the TransDot datapath (DESIGN.md §2): the MXU is a
128x128 fp32-accumulating systolic dot-product engine — i.e. a very wide
DPA unit.  The paper's N-term DPA (narrow operands in, one wide
accumulation out) maps onto:

  HBM -> VMEM   : operands move at *format width* — fp16 two bytes, fp8
                  one byte, fp4 two E2M1 codes per byte (`pack_x`/`pack_w`
                  halve the uint8 bytes the BlockSpec moves).  The paper's
                  "fixed-width FPU interface" becomes HBM bandwidth
                  actually saved: 2x/4x/8x fewer operand bytes than f32.
  VMEM decode   : in-kernel nibble unpack + dequant-free *widening* of
                  operand codes into MXU-ingestible values (the multi-mode
                  multiplier's operand partitioning).
  MXU + scratch : fp32 accumulation across the K grid dimension (the
                  paper's wide adder + the extra DPA pipeline stage: the
                  accumulator lives across K iterations).
  epilogue      : per-channel scales applied at the final K step (the
                  exponent datapath's contribution, hoisted to software
                  scales as in all block-scaled AI formats).

Two entry points:

  dpa_matmul_prequant : both operands already quantized (and optionally
                        packed); row/column scales applied in the epilogue.
  dpa_matmul_fused    : raw f32/bf16 activations quantized *inside* the
                        kernel prologue — per-(row, K-block) absmax scales
                        folded into the accumulation, weight column scales
                        in the epilogue.  No separate XLA quantize pass, no
                        quantized-activation round-trip through HBM.

Block shapes default to MXU-aligned (128 multiples).  Validated on CPU
via interpret=True against `ref.py`; compiled path targets TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.formats import get_format
from repro.core.packing import unpack_fp4_axis
from repro.core.quantize import (absmax_block_scale, decode_fp4, encode_fp4,
                                 jnp_dtype)
from repro.kernels._compat import CompilerParams as _CompilerParams


def _mm_params():
    return _CompilerParams(
        dimension_semantics=("parallel", "parallel", "arbitrary"))


def _widen(x, fmt_name: str, *, packed: bool = False, axis: int = 0):
    """Operand codes/values -> f32 products domain (the multiplier input).

    For fp4 the input is uint8 E2M1 codes — one per byte, or two per byte
    when `packed` (unpacked along `axis`, the K dimension of the block,
    with `core.packing`'s low-nibble-even layout — the helpers are pure
    jnp so they run inside the kernel)."""
    if fmt_name == "fp4_e2m1":
        if packed:
            x = unpack_fp4_axis(x, axis)
        return decode_fp4(x)
    return x.astype(jnp.float32)


# -----------------------------------------------------------------------------
# pre-quantized operands (optionally packed)
# -----------------------------------------------------------------------------

def _dpa_matmul_kernel(x_ref, w_ref, sx_ref, sw_ref, o_ref, acc_ref, *,
                       n_k: int, fmt_x: str, fmt_w: str, pack_x: bool,
                       pack_w: bool):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = _widen(x_ref[...], fmt_x, packed=pack_x, axis=1)
    w = _widen(w_ref[...], fmt_w, packed=pack_w, axis=0)
    acc_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _store():
        # epilogue: software exponent path — row scale x column scale
        o_ref[...] = acc_ref[...] * sx_ref[...] * sw_ref[...]


@functools.partial(jax.jit, static_argnames=("fmt_x", "fmt_w", "bm", "bk",
                                             "bn", "pack_x", "pack_w",
                                             "interpret"))
def dpa_matmul_prequant(xq, wq, sx, sw, *, fmt_x: str, fmt_w: str,
                        bm: int = 128, bk: int = 128, bn: int = 128,
                        pack_x: bool = False, pack_w: bool = False,
                        interpret: bool = True):
    """(M,K) x (K,N) -> (M,N) f32 with fp32 accumulation.

    xq: quantized operand (native fp8/fp16/bf16 dtype, or uint8 E2M1 codes
        when fmt_x == "fp4_e2m1"; shape (M, K//2) packed bytes when
        `pack_x`);                 sx: (M,1) or (1,1) row scales.
    wq: same on the (K,N) side ((K//2, N) when `pack_w`);
                                   sw: (1,N) or (1,1) column scales.

    Packing halves the bytes the x/w BlockSpecs move HBM->VMEM; the kernel
    unpacks nibbles in VMEM before widening, so the packed path is
    bit-identical to the unpacked one.
    """
    assert not (pack_x and fmt_x != "fp4_e2m1"), "pack_x needs fp4 codes"
    assert not (pack_w and fmt_w != "fp4_e2m1"), "pack_w needs fp4 codes"
    M = xq.shape[0]
    K = xq.shape[1] * (2 if pack_x else 1)
    K2 = wq.shape[0] * (2 if pack_w else 1)
    N = wq.shape[1]
    assert K == K2, (xq.shape, wq.shape, pack_x, pack_w)
    assert M % bm == 0 and K % bk == 0 and N % bn == 0, \
        f"shapes ({M},{K},{N}) must be multiples of blocks ({bm},{bk},{bn})"
    assert bk % 2 == 0 or not (pack_x or pack_w), "packed bk must be even"
    sx = jnp.broadcast_to(sx.astype(jnp.float32), (M, 1))
    sw = jnp.broadcast_to(sw.astype(jnp.float32), (1, N))
    n_k = K // bk
    bk_x = bk // 2 if pack_x else bk
    bk_w = bk // 2 if pack_w else bk

    kernel = functools.partial(_dpa_matmul_kernel, n_k=n_k, fmt_x=fmt_x,
                               fmt_w=fmt_w, pack_x=pack_x, pack_w=pack_w)
    return pl.pallas_call(
        kernel,
        grid=(M // bm, N // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk_x), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk_w, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_mm_params(),
        interpret=interpret,
    )(xq, wq, sx, sw)


# -----------------------------------------------------------------------------
# fused quantize -> matmul (activations quantized in the kernel prologue)
# -----------------------------------------------------------------------------

def _quantize_block(xb, fmt: str, target: float):
    """(bm, bk) f32 -> (values-on-the-format-grid f32, (bm,1) f32 scale).

    Per-(row, K-block) absmax scaling — the same recipe as
    `core.quantize.quantize_blockwise` with block == bk, computed in VMEM."""
    scale = absmax_block_scale(xb, target)
    y = jnp.clip(xb / scale, -target, target)
    if fmt == "fp4_e2m1":
        q = decode_fp4(encode_fp4(y))
    else:
        q = y.astype(jnp_dtype(fmt)).astype(jnp.float32)
    return q, scale


def _dpa_fused_kernel(x_ref, w_ref, sw_ref, o_ref, acc_ref, *, n_k: int,
                      fmt_x: str, fmt_w: str, pack_w: bool, target: float):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # prologue: absmax -> scale -> saturating RNE cast, all in VMEM.  The
    # scale varies per K block, so it is folded into this block's partial
    # product here; only the K-invariant weight scales wait for the epilogue.
    xq, sx = _quantize_block(x_ref[...].astype(jnp.float32), fmt_x, target)
    w = _widen(w_ref[...], fmt_w, packed=pack_w, axis=0)
    acc_ref[...] += jnp.dot(xq, w, preferred_element_type=jnp.float32) * sx

    @pl.when(pl.program_id(2) == n_k - 1)
    def _store():
        o_ref[...] = acc_ref[...] * sw_ref[...]


@functools.partial(jax.jit, static_argnames=("fmt_x", "fmt_w", "bm", "bk",
                                             "bn", "pack_w", "interpret"))
def dpa_matmul_fused(x, wq, sw, *, fmt_x: str, fmt_w: str, bm: int = 128,
                     bk: int = 128, bn: int = 128, pack_w: bool = False,
                     interpret: bool = True):
    """Fused quantize->matmul: raw x (M,K) f32/bf16, pre-quantized (and
    optionally packed) weights -> (M,N) f32.

    The activation tensor never round-trips through HBM in quantized form:
    each (bm, bk) block is absmax-scaled and cast in the kernel prologue,
    its per-(row, K-block) scale folded into the partial-product
    accumulation, and the (1, bn) weight column scales applied in the
    epilogue.  Numerics follow `quantize_blockwise(x, fmt, axis=-1,
    block=bk)` — *finer*-grained than the per-row unfused path.
    """
    assert not (pack_w and fmt_w != "fp4_e2m1"), "pack_w needs fp4 codes"
    M, K = x.shape
    K2 = wq.shape[0] * (2 if pack_w else 1)
    N = wq.shape[1]
    assert K == K2, (x.shape, wq.shape, pack_w)
    assert M % bm == 0 and K % bk == 0 and N % bn == 0, \
        f"shapes ({M},{K},{N}) must be multiples of blocks ({bm},{bk},{bn})"
    assert bk % 2 == 0 or not pack_w, "packed bk must be even"
    sw = jnp.broadcast_to(sw.astype(jnp.float32), (1, N))
    n_k = K // bk
    bk_w = bk // 2 if pack_w else bk

    kernel = functools.partial(
        _dpa_fused_kernel, n_k=n_k, fmt_x=fmt_x, fmt_w=fmt_w, pack_w=pack_w,
        target=get_format(fmt_x).quant_target)
    return pl.pallas_call(
        kernel,
        grid=(M // bm, N // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk_w, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_mm_params(),
        interpret=interpret,
    )(x, wq, sw)
