"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic specification its kernel is tested against
(tests/test_kernels.py sweeps shapes x dtypes and assert_allcloses).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.formats import get_format
from repro.core.quantize import (absmax_block_scale, cast_to, compute_scale,
                                 decode_fp4, encode_fp4, jnp_dtype,
                                 quant_rows_grid)


def widen_ref(x, fmt_name: str):
    """Reference operand widening (matches dpa_matmul._widen)."""
    if fmt_name == "fp4_e2m1":
        return decode_fp4(x)
    return x.astype(jnp.float32)


def dpa_matmul_ref(xq, wq, sx, sw, *, fmt_x: str, fmt_w: str):
    """fp32-accumulated matmul over widened operands, scaled epilogue."""
    x = widen_ref(xq, fmt_x)
    w = widen_ref(wq, fmt_w)
    out = jnp.dot(x, w, preferred_element_type=jnp.float32)
    return out * sx.astype(jnp.float32) * sw.astype(jnp.float32)


def dpa_matmul_fused_ref(x, wq, sw, *, fmt_x: str, fmt_w: str, bk: int):
    """Semantic spec of `dpa_matmul_fused`: per-(row, K-block) absmax
    quantization of raw x, blockwise scale folded into each partial
    product, weight column scales in the epilogue.  wq is *unpacked*."""
    f = get_format(fmt_x)
    target = f.quant_target
    M, K = x.shape
    xf = x.astype(jnp.float32)
    out = jnp.zeros((M, wq.shape[1]), jnp.float32)
    w = widen_ref(wq, fmt_w)
    for k0 in range(0, K, bk):
        xb = xf[:, k0:k0 + bk]
        scale = absmax_block_scale(xb, target)
        y = jnp.clip(xb / scale, -target, target)
        if fmt_x == "fp4_e2m1":
            q = decode_fp4(encode_fp4(y))
        else:
            q = y.astype(jnp_dtype(fmt_x)).astype(jnp.float32)
        out = out + jnp.dot(q, w[k0:k0 + bk],
                            preferred_element_type=jnp.float32) * scale
    return out * sw.astype(jnp.float32)


def quantize_rows_ref(x, *, fmt: str):
    """Row-wise absmax quantization (matches kernels.quantize)."""
    f = get_format(fmt)
    xf = x.astype(jnp.float32)
    scale = compute_scale(xf, f, axis=1)
    y = xf / scale
    if fmt == "fp4_e2m1":
        from repro.kernels.quantize import _encode_fp4
        q = _encode_fp4(jnp.clip(y, -f.max_finite, f.max_finite))
    else:
        q = cast_to(y, f)
    return q, scale


def flash_attention_ref(q, k, v, *, causal: bool = True, scale=None,
                        window: int | None = None):
    """Reference attention: (B,H,Sq,D),(B,Hkv,Sk,D),(B,Hkv,Sk,D)->(B,H,Sq,D).

    GQA: q heads grouped over kv heads.  Optional causal mask and local
    window (RecurrentGemma-style sliding attention).
    """
    B, H, Sq, D = q.shape
    Hkv = k.shape[1]
    g = H // Hkv
    scale = scale if scale is not None else D ** -0.5
    qf = q.astype(jnp.float32).reshape(B, Hkv, g, Sq, D)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kf) * scale
    Sk = kf.shape[2]
    qpos = jnp.arange(Sq)[:, None] + (Sk - Sq)
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask, logits, -1e30)
    probs = _softmax(logits)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", probs, vf)
    return out.reshape(B, H, Sq, D).astype(q.dtype)


def _softmax(x):
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def dpa_flash_attention_ref(q, k, v, *, fmt: str, fmt_kv: str | None = None,
                            causal: bool = True, window: int | None = None,
                            scale=None, bk: int = 128):
    """Semantic spec of `flash_attention.dpa_flash_attention`.

    Both attention matmuls accumulate in f32 over quantized operands
    (the Table-I DPA modes); the online-softmax running max/sum stay f32:

      q  : per-row absmax onto fmt's grid; the row scale multiplies the
           QK^T partial product (software exponent path).
      k,v: per-row absmax onto fmt_kv's grid (defaults to fmt), consumed
           *dequantized* — widen(codes) * scale — exactly the prologue of
           the quantized-KV cache path, so raw and cached K/V are
           bit-identical.
      p  : each (row, bk key-block) of exp(s - m_running) is absmax-
           quantized onto fmt's grid; its scale folds into the f32 PV
           accumulation AND the f32 denominator (probabilities and their
           normalizer see the same grid, so quantization error partially
           cancels in the ratio).

    The loop mirrors the kernel's K-grid iteration (running max, alpha
    rescale) so kernel-vs-ref parity is tight, not just statistical.
    """
    B, H, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    g = H // Hkv
    sc = float(scale if scale is not None else D ** -0.5)
    kf = fmt_kv or fmt

    qg, qs = quant_rows_grid(q, fmt)                    # (B,H,Sq,D),(..,1)
    kg, ks = quant_rows_grid(k, kf)
    vg, vs = quant_rows_grid(v, kf)
    k_eff = jnp.repeat(kg * ks, g, axis=1)              # dequant-in-prologue
    v_eff = jnp.repeat(vg * vs, g, axis=1)

    qpos = jnp.arange(Sq)[:, None] + (Sk - Sq)
    m = jnp.full((B, H, Sq, 1), -1e30, jnp.float32)
    l = jnp.zeros((B, H, Sq, 1), jnp.float32)
    acc = jnp.zeros((B, H, Sq, D), jnp.float32)
    for j0 in range(0, Sk, bk):
        kb = k_eff[:, :, j0:j0 + bk]
        s = jnp.einsum("bhqd,bhkd->bhqk", qg, kb,
                       preferred_element_type=jnp.float32) * qs * sc
        kpos = j0 + jnp.arange(kb.shape[2])[None, :]
        mask = jnp.ones(qpos.shape[:1] + kpos.shape[1:], bool)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, -1e30)
        m_cur = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_cur)
        alpha = jnp.exp(m - m_cur)
        pg, ps = quant_rows_grid(p, fmt)
        l = l * alpha + jnp.sum(pg, axis=-1, keepdims=True) * ps
        acc = acc * alpha + jnp.einsum(
            "bhqk,bhkd->bhqd", pg, v_eff[:, :, j0:j0 + bk],
            preferred_element_type=jnp.float32) * ps
        m = m_cur
    out = acc / jnp.maximum(l, 1e-30)
    return out.astype(q.dtype)
