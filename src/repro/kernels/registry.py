"""The DPA routing table: every `core.exec_plan` route, in one place.

This module is imported lazily by `core.exec_plan` on first resolution
and registers one `PlanEntry` per (op, route): the Pallas kernel
pipelines from `kernels.ops` and the XLA/jnp reference fallbacks each
kernel is pinned against.  All policy-mode interpretation that used to
be scattered across `core.linear`, `models.layers`,
`models.decode_attn`, and `launch.engine` lives in the predicates here —
the FPnew-style operation-group hierarchy, as a table.

Route conventions:

  - predicates return *named* boolean bits (`describe()` shows them), so
    a failed resolution states exactly which gate excluded each route;
  - every op's lowest-priority route is a reference fallback whose
    predicate checks only semantic viability (it can always serve what
    the op means);
  - `reference`/`tol` pin each route against its fallback —
    `tests/test_exec_plan.py` enumerates the table and enforces the pin;
  - `tests` names the tier-1 tests exercising the route;
    `tools/plan_table.py` fails CI when a route names none.

Uniform run signatures per op:

  matmul          run(x, w, policy, **block_kw) -> (..., N)
  grouped_matmul  run(x, w, policy, *, eq) -> einsum output, x.dtype
  flash_attn      run(q, k, v, *, policy, causal, window, offset, valid,
                      scale, kv_on_grid) -> (B, Sq, H, hd)
  decode_attn     run(q, cache, offset, *, policy, scale) -> (B,1,H,hd)
  paged_decode    run(q, cache, positions, *, policy, scale) -> (B,1,H,hd)
  verify_attn     run(q, cache, positions, *, policy, scale) -> (B,Sq,H,hd)
  quantize_pack   run(x, *, fmt, pack, bm) -> (codes, scales)
"""
from __future__ import annotations

import os

import jax.numpy as jnp

from repro.core import exec_plan
from repro.core.linear import GROUPED_EQS, NATIVE_NARROW
from repro.core.packing import operand_nbytes, pack_fp4_axis
from repro.core.quantize import cast_to, compute_scale, fake_quant
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.models import decode_attn as D


def _acc_t(policy):
    return jnp.float32 if policy.accum == "fp32" else jnp.float16


def _kv_fmt(policy):
    """fmt_kv the attention routes consume (None = quantize onto the
    attention grid, the raw-KV contract)."""
    return policy.fmt_kv if policy.kv_quantized else None


# -----------------------------------------------------------------------------
# matmul: x @ w under the DPA contract (core.linear.dpa_dot)
# -----------------------------------------------------------------------------

def _mm_native(x, w, policy, **_):
    # pre-quantized weights (serving): keep them NATIVE in the dot —
    # fp8 x fp8 -> fp32 is the MXU DPA path itself, and it leaves no
    # whole-stack weight convert for XLA to hoist out of the layer scan
    # (measured 13.7 GiB on dbrx decode; EXPERIMENTS.md §Perf).
    sx = compute_scale(x, policy.fmt_acts, axis=-1)
    xq = cast_to(x.astype(jnp.float32) / sx, policy.fmt_acts)
    out = jnp.dot(xq, w, preferred_element_type=jnp.float32)
    return out * sx


def _mm_fake_quant(x, w, policy, **_):
    wq = fake_quant(
        w, policy.fmt_weights,
        axis=0 if policy.w_granularity == "per_channel" else None,
        block=policy.block_size if policy.w_granularity == "per_block"
        else None)
    xq = fake_quant(
        x, policy.fmt_acts,
        axis=-1 if policy.a_granularity == "per_channel" else None,
        block=policy.block_size if policy.a_granularity == "per_block"
        else None)
    return jnp.dot(xq, wq, preferred_element_type=_acc_t(policy))


def _mm_f32(x, w, policy, **_):
    return jnp.dot(x, w, preferred_element_type=_acc_t(policy))


def _mm_operand_bytes(policy, ctx):
    m, k, n = ctx.get("m"), ctx.get("k"), ctx.get("n")
    if not (m and k and n):
        return None
    return (operand_nbytes(m * k, policy.fmt_acts, packed=policy.packed)
            + operand_nbytes(k * n, policy.fmt_weights, packed=policy.packed))


exec_plan.register(
    "matmul", "xla_native_narrow", backend="xla", run=_mm_native,
    priority=40, reference="xla_fake_quant", tol=0.35,
    predicate=lambda policy, ctx: {
        "native_narrow_weights": ctx.get("w_dtype") in NATIVE_NARROW,
        "full_policy_path": not ctx.get("kernel_only", False)},
    tests=("tests/test_perf_features.py::test_native_fp8_weight_dot",),
    note="serving: weights stay in their narrow dtype end to end")

exec_plan.register(
    "matmul", "pallas_fused", backend="pallas",
    run=kops.dpa_matmul_fused_pipeline,
    priority=30, reference="xla_fake_quant", tol=0.35,
    predicate=lambda policy, ctx: {
        "kernel_path": policy.use_kernel or ctx.get("kernel_only", False),
        "fused_quant": policy.fused_quant,
        "float_weights": ctx.get("w_dtype") not in NATIVE_NARROW,
        "dpa_enabled": policy.enabled},
    bytes_moved=_mm_operand_bytes,
    tests=("tests/test_kernels.py::test_fused_quantize_matmul_vs_ref",
           "tests/test_kernels.py::test_packed_fused_policy_wrapper"),
    note="in-kernel activation quantize, per-(row, K-block) scales",
    knobs=("bm", "bk", "bn"))

exec_plan.register(
    "matmul", "pallas_prequant", backend="pallas",
    run=kops.dpa_matmul_prequant_pipeline,
    priority=25, reference="xla_fake_quant", tol=0.35,
    predicate=lambda policy, ctx: {
        "kernel_path": policy.use_kernel or ctx.get("kernel_only", False),
        "prequant": not policy.fused_quant,
        "float_weights": ctx.get("w_dtype") not in NATIVE_NARROW,
        "dpa_enabled": policy.enabled},
    bytes_moved=_mm_operand_bytes,
    tests=("tests/test_kernels.py::test_dpa_matmul_vs_ref",
           "tests/test_kernels.py::test_dpa_matmul_policy_wrapper_padding"),
    note="XLA quantize pass, packed fp4 operand bytes when policy.packed",
    knobs=("bm", "bk", "bn"))

exec_plan.register(
    "matmul", "xla_fake_quant", backend="xla", run=_mm_fake_quant,
    priority=10,
    predicate=lambda policy, ctx: {
        "dpa_enabled": policy.enabled,
        "full_policy_path": not ctx.get("kernel_only", False)},
    tests=("tests/test_dpa_property.py", "tests/test_layers.py"),
    note="training path: STE quant-dequant operands, wide accumulation")

exec_plan.register(
    "matmul", "xla_f32", backend="xla", run=_mm_f32, priority=0,
    predicate=lambda policy, ctx: {
        "full_policy_path": not ctx.get("kernel_only", False)},
    tests=("tests/test_layers.py", "tests/test_archs.py"),
    note="DPA disabled: the seed f32 datapath")


# -----------------------------------------------------------------------------
# grouped_matmul: per-expert einsums (grouped linear / MoE)
# -----------------------------------------------------------------------------

def _gmm_native(x, w, policy, *, eq):
    sx = compute_scale(x, policy.fmt_acts, axis=-1)
    xq = cast_to(x.astype(jnp.float32) / sx, policy.fmt_acts)
    y = jnp.einsum(eq, xq, w, preferred_element_type=jnp.float32) * sx
    return y.astype(x.dtype)


def _gmm_fake_quant(x, w, policy, *, eq):
    # quantize the *master* weights (no pre-cast through x.dtype — that
    # would double-round them) with the same granularity treatment as the
    # dense `_mm_fake_quant`; the stacked expert layout (E, d_in, d_out)
    # puts the contraction axis at 1 where dense has it at 0.
    wq = fake_quant(
        w, policy.fmt_weights,
        axis=1 if policy.w_granularity == "per_channel" else None,
        block=policy.block_size if policy.w_granularity == "per_block"
        else None)
    xq = fake_quant(
        x, policy.fmt_acts,
        axis=-1 if policy.a_granularity == "per_channel" else None,
        block=policy.block_size if policy.a_granularity == "per_block"
        else None)
    return jnp.einsum(eq, xq, wq,
                      preferred_element_type=_acc_t(policy)).astype(x.dtype)


def _gmm_f32(x, w, policy, *, eq):
    return jnp.einsum(eq, x, w.astype(x.dtype),
                      preferred_element_type=_acc_t(policy)).astype(x.dtype)


def _gmm_operand_bytes(policy, ctx):
    """Format-width operand bytes for the stacked per-expert matmuls —
    the dense `_mm_operand_bytes` with the expert count folded in.
    `dpa_grouped_dot` derives e/m/k/n from the einsum + shapes."""
    e, m, k, n = ctx.get("e"), ctx.get("m"), ctx.get("k"), ctx.get("n")
    if not (e and m and k and n):
        return None
    return (operand_nbytes(e * m * k, policy.fmt_acts, packed=policy.packed)
            + operand_nbytes(e * k * n, policy.fmt_weights,
                             packed=policy.packed))


def _gmm_wide_bytes(policy, ctx):
    """Both operand stacks traverse at full f32 width (fake-quant and the
    disabled path quantize — if at all — inside XLA, post-load)."""
    e, m, k, n = ctx.get("e"), ctx.get("m"), ctx.get("k"), ctx.get("n")
    if not (e and m and k and n):
        return None
    return 4 * (e * m * k + e * k * n)


def _gmm_native_bytes(policy, ctx):
    """Native-narrow expert weights move at format width (never packed:
    packing needs the kernel path's nibble decode); activations quantize
    to fmt_acts before the einsum."""
    e, m, k, n = ctx.get("e"), ctx.get("m"), ctx.get("k"), ctx.get("n")
    if not (e and m and k and n):
        return None
    return (operand_nbytes(e * m * k, policy.fmt_acts, packed=False)
            + operand_nbytes(e * k * n, policy.fmt_weights, packed=False))


exec_plan.register(
    "grouped_matmul", "xla_native_narrow", backend="xla", run=_gmm_native,
    priority=40, reference="xla_fake_quant", tol=0.35,
    predicate=lambda policy, ctx: {
        "native_narrow_weights": ctx.get("w_dtype") in NATIVE_NARROW},
    bytes_moved=_gmm_native_bytes,
    tests=("tests/test_exec_plan.py::test_route_pinned_to_reference",),
    note="pre-quantized expert weights stay native in the einsum")

exec_plan.register(
    "grouped_matmul", "pallas_grouped_fused", backend="pallas",
    run=kops.dpa_grouped_fused_pipeline,
    priority=30, reference="xla_fake_quant", tol=0.35,
    predicate=lambda policy, ctx: {
        "kernel_path": policy.use_kernel or ctx.get("kernel_only", False),
        "fused_quant": policy.fused_quant,
        "float_weights": ctx.get("w_dtype") not in NATIVE_NARROW,
        "known_grouped_eq": ctx.get("eq") in GROUPED_EQS,
        "dpa_enabled": policy.enabled},
    bytes_moved=_gmm_operand_bytes,
    tests=("tests/test_grouped_dpa.py::test_grouped_pipeline_vs_fake_quant",
           "tests/test_grouped_dpa.py::test_grouped_kernel_capacity_"
           "dropped_rows"),
    note="per-expert in-kernel activation quantize; packed fp4 expert "
         "weights move 8x fewer resident bytes",
    knobs=("bm", "bk", "bn"))

exec_plan.register(
    "grouped_matmul", "pallas_grouped_prequant", backend="pallas",
    run=kops.dpa_grouped_prequant_pipeline,
    priority=25, reference="xla_fake_quant", tol=0.35,
    predicate=lambda policy, ctx: {
        "kernel_path": policy.use_kernel or ctx.get("kernel_only", False),
        "prequant": not policy.fused_quant,
        "float_weights": ctx.get("w_dtype") not in NATIVE_NARROW,
        "known_grouped_eq": ctx.get("eq") in GROUPED_EQS,
        "dpa_enabled": policy.enabled},
    bytes_moved=_gmm_operand_bytes,
    tests=("tests/test_grouped_dpa.py::test_grouped_pipeline_vs_fake_quant",
           "tests/test_grouped_dpa.py::test_grouped_prequant_matches_"
           "dense_per_expert"),
    note="XLA quantize pass over both stacks; packed fp4 operand bytes "
         "when policy.packed",
    knobs=("bm", "bk", "bn"))

exec_plan.register(
    "grouped_matmul", "xla_fake_quant", backend="xla", run=_gmm_fake_quant,
    priority=10,
    predicate=lambda policy, ctx: {"dpa_enabled": policy.enabled},
    bytes_moved=_gmm_wide_bytes,
    tests=("tests/test_layers.py::test_moe_capacity_drop_and_combine_weights",
           "tests/test_grouped_dpa.py::test_gmm_fake_quant_matches_dense_"
           "reference"),
    note="per-expert STE quant-dequant, wide accumulation")

exec_plan.register(
    "grouped_matmul", "xla_f32", backend="xla", run=_gmm_f32, priority=0,
    bytes_moved=_gmm_wide_bytes,
    tests=("tests/test_layers.py::test_moe_uniform_router_is_lossless_at_high_capacity",),
    note="DPA disabled: plain grouped einsum")


# -----------------------------------------------------------------------------
# flash_attn: full-sequence attention (models.layers._sdpa)
# -----------------------------------------------------------------------------

def _fit_block(b, s):
    """Largest block <= b that divides the sequence length — tuned
    block shapes must never break the flash kernels' divisibility
    contract (Sq % bq == 0), whatever the sweep proposes."""
    b = max(1, min(b, s))
    while s % b:
        b -= 1
    return b


def _fa_pallas_dpa(q, k, v, *, policy, causal, window, offset, valid,
                   scale, kv_on_grid, bq=128, bk=128):
    out = kops.dpa_flash_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), fmt=policy.fmt_attn, fmt_kv=_kv_fmt(policy),
        causal=causal, window=window,
        bq=_fit_block(bq, q.shape[1]), bk=_fit_block(bk, k.shape[1]))
    return out.transpose(0, 2, 1, 3)


def _fa_pallas_f32(q, k, v, *, policy, causal, window, offset, valid,
                   scale, kv_on_grid, bq=128, bk=128):
    out = kops.flash_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=causal, window=window,
        bq=_fit_block(bq, q.shape[1]), bk=_fit_block(bk, k.shape[1]))
    return out.transpose(0, 2, 1, 3)


def _fa_xla_dpa(q, k, v, *, policy, causal, window, offset, valid,
                scale, kv_on_grid):
    mask = D.build_sdpa_mask(q.shape[1], k.shape[1], offset, causal,
                             window, valid)
    return D.dpa_attention(q, k, v, mask[None, None], fmt=policy.fmt_attn,
                           fmt_kv=_kv_fmt(policy), scale=scale,
                           kv_on_grid=kv_on_grid)


def _fa_xla_ref(q, k, v, *, policy, causal, window, offset, valid,
                scale, kv_on_grid):
    mask = D.build_sdpa_mask(q.shape[1], k.shape[1], offset, causal,
                             window, valid)
    return D.sdpa_reference(q, k, v, mask[None, None], scale=scale)


def _fa_common_bits(policy, ctx):
    return {"flash_enabled": ctx.get("use_flash", False),
            "is_prefill": ctx.get("sq", 1) > 1,
            "no_valid_mask": not ctx.get("has_valid", False)}


exec_plan.register(
    "flash_attn", "pallas_dpa_flash", backend="pallas", run=_fa_pallas_dpa,
    priority=30, reference="xla_dpa_attn", tol=0.075,
    predicate=lambda policy, ctx: dict(
        _fa_common_bits(policy, ctx),
        dpa_attn=policy.attn_enabled,
        raw_kv=not ctx.get("kv_on_grid", False)),
    tests=("tests/test_attention_dpa.py::test_dpa_flash_attention_vs_spec",
           "tests/test_exec_plan.py::test_route_pinned_to_reference"),
    note="online-softmax tiling; tol vs the global-softmax jnp fallback "
         "is the blocked-p-quantization budget test_attention_dpa pins",
    knobs=("bq", "bk"))

exec_plan.register(
    "flash_attn", "pallas_f32_flash", backend="pallas", run=_fa_pallas_f32,
    priority=20, reference="xla_ref_attn", tol=2e-6,
    predicate=lambda policy, ctx: dict(
        _fa_common_bits(policy, ctx), f32_attn=not policy.attn_enabled),
    tests=("tests/test_kernels.py::test_flash_attention_vs_ref",),
    note="the seed f32 flash kernel", knobs=("bq", "bk"))

exec_plan.register(
    "flash_attn", "xla_dpa_attn", backend="xla", run=_fa_xla_dpa,
    priority=10,
    predicate=lambda policy, ctx: {"dpa_attn": policy.attn_enabled},
    tests=("tests/test_attention_dpa.py::test_jnp_fallback_matches_single_block_spec",),
    note="any-shape jnp DPA attention (global softmax max)")

exec_plan.register(
    "flash_attn", "xla_ref_attn", backend="xla", run=_fa_xla_ref, priority=0,
    tests=("tests/test_layers.py", "tests/test_archs.py"),
    note="reference einsum + f32 softmax (the seed datapath)")


# -----------------------------------------------------------------------------
# decode_attn: single-token decode over the contiguous quantized cache
# -----------------------------------------------------------------------------

def _da_xla(q, cache, offset, *, policy, scale):
    return D.dpa_decode_attn(q, cache, offset, fmt=policy.fmt_attn,
                             fmt_kv=policy.fmt_kv,
                             kv_packed=policy.kv_packed, scale=scale)


def _kv_rows_bytes(policy, n_rows, hd):
    """codes + f32 scales for K AND V over n_rows cache rows."""
    return 2 * (operand_nbytes(n_rows * hd, policy.fmt_kv,
                               packed=policy.kv_packed) + 4 * n_rows)


exec_plan.register(
    "decode_attn", "xla_dpa_decode", backend="xla", run=_da_xla, priority=0,
    predicate=lambda policy, ctx: {"kv_quantized": policy.kv_quantized},
    bytes_moved=lambda policy, ctx: _kv_rows_bytes(
        policy, ctx.get("batch", 1) * ctx.get("s_ctx", 0)
        * ctx.get("kv_heads", 1), ctx.get("hd", 0)),
    tests=("tests/test_attention_dpa.py::"
           "test_model_prefill_matches_stepped_decode",),
    note="prologue-dequant decode off the contiguous codes+scales cache")


# -----------------------------------------------------------------------------
# paged_decode: single-token decode over the paged cache (block table)
# -----------------------------------------------------------------------------

def _pd_pallas(q, cache, positions, *, policy, scale):
    return kops.paged_decode_attention(q, cache, positions,
                                       fmt=policy.fmt_attn,
                                       fmt_kv=policy.fmt_kv,
                                       kv_packed=policy.kv_packed,
                                       scale=scale)


def _pd_gather(q, cache, positions, *, policy, scale):
    return D.dpa_paged_decode_attn(q, cache, positions, fmt=policy.fmt_attn,
                                   fmt_kv=policy.fmt_kv,
                                   kv_packed=policy.kv_packed, scale=scale)


def _pd_view_rows(ctx):
    """Cache rows one batched decode step streams: every slot's full
    block-table window (B x max_pages x page rows, per KV head)."""
    return (ctx.get("batch", 1) * ctx.get("max_pages", 0)
            * ctx.get("page_size", 0) * ctx.get("kv_heads", 1))


exec_plan.register(
    "paged_decode", "pallas_block_table", backend="pallas", run=_pd_pallas,
    priority=10, reference="jnp_gather", tol=0.0,
    predicate=lambda policy, ctx: {
        "kv_quantized": policy.kv_quantized,
        "not_disabled": os.environ.get("REPRO_PAGED_KERNEL", "1") != "0"},
    bytes_moved=lambda policy, ctx: _kv_rows_bytes(
        policy, _pd_view_rows(ctx), ctx.get("hd", 0)),
    tests=("tests/test_exec_plan.py::test_paged_decode_kernel_bit_identical",
           "tests/test_engine.py::test_engine_matches_static_batch_"
           "per_request"),
    note="BlockSpec index maps read pages through the scalar-prefetched "
         "block table; codes+scales stream HBM->VMEM exactly once")

exec_plan.register(
    "paged_decode", "jnp_gather", backend="xla", run=_pd_gather, priority=0,
    predicate=lambda policy, ctx: {"kv_quantized": policy.kv_quantized},
    bytes_moved=lambda policy, ctx: 3 * _kv_rows_bytes(
        policy, _pd_view_rows(ctx), ctx.get("hd", 0)),
    tests=("tests/test_paged_kv.py::test_paged_decode_attn_matches_"
           "contiguous",),
    note="gather_paged_kv re-materializes the contiguous view in HBM "
         "(write + re-read: ~3x the page-pool traffic)")


# -----------------------------------------------------------------------------
# verify_attn: S_q causal query tokens scored against the paged cache
# (the speculative-decoding verify pass; see serving.spec_decode)
# -----------------------------------------------------------------------------

def _va_gather(q, cache, positions, *, policy, scale):
    return D.dpa_paged_verify_attn(q, cache, positions, fmt=policy.fmt_attn,
                                   fmt_kv=policy.fmt_kv,
                                   kv_packed=policy.kv_packed, scale=scale)


exec_plan.register(
    "verify_attn", "jnp_gather", backend="xla", run=_va_gather, priority=0,
    predicate=lambda policy, ctx: {"kv_quantized": policy.kv_quantized},
    # gather re-materializes the view (read pages + write + re-read, the
    # jnp_gather 3x), then the batch-fold repeats it per query row
    # (write + attention read: 2 more view passes per sq) — the price of
    # the bit-exact decode-shaped reductions, amortized over k+1 scored
    # tokens
    bytes_moved=lambda policy, ctx: (3 + 2 * ctx.get("sq", 1))
    * _kv_rows_bytes(policy, _pd_view_rows(ctx), ctx.get("hd", 0)),
    tests=("tests/test_spec_decode.py::test_verify_attn_matches_stepped_"
           "paged_decode",
           "tests/test_spec_decode.py::test_spec_engine_greedy_matches_"
           "plain_engine"),
    note="speculative verify: per-request causal mask over the gathered "
         "block-table view (chunked-prefill masking, paged pool); row i "
         "is bit-identical to a decode step at positions[b] + i")


# -----------------------------------------------------------------------------
# sharded paged attention: the pool lives 1/n per device ("model" axis,
# within-page rows — the cache_spec rule), the wire carries format-width
# codes + per-row scales, and the reassembled pool runs the exact
# single-device op.  Bit-identical to single-device serving by
# construction: no cross-device float reduction touches the softmax.
# -----------------------------------------------------------------------------

def _pd_sharded(q, cache, positions, *, policy, scale):
    from functools import partial

    from repro.distributed import tp as TP
    fn = partial(D.dpa_paged_decode_attn, fmt=policy.fmt_attn,
                 fmt_kv=policy.fmt_kv, kv_packed=policy.kv_packed,
                 scale=scale)
    return TP.sharded_paged_attn(fn, q, cache, positions)


def _va_sharded(q, cache, positions, *, policy, scale):
    from functools import partial

    from repro.distributed import tp as TP
    fn = partial(D.dpa_paged_verify_attn, fmt=policy.fmt_attn,
                 fmt_kv=policy.fmt_kv, kv_packed=policy.kv_packed,
                 scale=scale)
    return TP.sharded_paged_attn(fn, q, cache, positions)


def _pool_rows(ctx):
    """Rows in the whole page pool (what the all-gather moves)."""
    return (ctx.get("n_pages", 0) * ctx.get("page_size", 0)
            * ctx.get("kv_heads", 1))


def _tp_wire_bytes(policy, ctx):
    """Bytes-on-wire per device for the pool all-gather: each device
    receives the other (n-1)/n of the pool as codes + per-row scales —
    the same 2x/4x/8x under an f32 wire the cache bytes enjoy."""
    n = ctx.get("n_devices", 1)
    if n <= 1:
        return 0
    return int((n - 1) / n
               * _kv_rows_bytes(policy, _pool_rows(ctx), ctx.get("hd", 0)))


exec_plan.register(
    "paged_decode", "paged_decode_sharded", backend="xla", run=_pd_sharded,
    priority=20, reference="jnp_gather", tol=0.0,
    predicate=lambda policy, ctx: {
        "kv_quantized": policy.kv_quantized,
        "multi_device": ctx.get("n_devices", 1) > 1},
    # gather-route compute bytes + the wire term the plan now prices
    bytes_moved=lambda policy, ctx: 3 * _kv_rows_bytes(
        policy, _pd_view_rows(ctx), ctx.get("hd", 0))
    + _tp_wire_bytes(policy, ctx),
    tests=("tests/test_tp_engine.py::test_tp_engine_bit_identical_"
           "across_formats",
           "tests/test_tp_engine.py::test_tp_prefix_and_spec_decode_"
           "bit_identical"),
    note="shard_map over the \"model\" axis: all-gather pool shards at "
         "format width (pure relayout), then the exact jnp_gather body — "
         "bit-identical to single-device decode")

exec_plan.register(
    "verify_attn", "verify_attn_sharded", backend="xla", run=_va_sharded,
    priority=10, reference="jnp_gather", tol=0.0,
    predicate=lambda policy, ctx: {
        "kv_quantized": policy.kv_quantized,
        "multi_device": ctx.get("n_devices", 1) > 1},
    bytes_moved=lambda policy, ctx: (3 + 2 * ctx.get("sq", 1))
    * _kv_rows_bytes(policy, _pd_view_rows(ctx), ctx.get("hd", 0))
    + _tp_wire_bytes(policy, ctx),
    tests=("tests/test_tp_engine.py::test_tp_prefix_and_spec_decode_"
           "bit_identical",),
    note="sharded speculative verify: same pool all-gather wire, same "
         "bit-exact batch-fold body as the jnp_gather reference")


# -----------------------------------------------------------------------------
# quantize_pack: fused row quantization (+fp4 nibble pack)
# -----------------------------------------------------------------------------

def _qp_pallas(x, *, fmt, pack, bm):
    return kops.quantize_rows_pallas(x, fmt=fmt, pack=pack, bm=bm)


def _qp_xla(x, *, fmt, pack, **_):
    # swallows bm: the reference quantizer has no tiling to tune
    q, s = kref.quantize_rows_ref(x, fmt=fmt)
    if pack:
        q = pack_fp4_axis(q, 1)
    return q, s


exec_plan.register(
    "quantize_pack", "pallas_quantize_pack", backend="pallas",
    run=_qp_pallas, priority=20, reference="xla_quantize", tol=1e-6,
    predicate=lambda policy, ctx: {"fp4": ctx.get("fmt") == "fp4_e2m1",
                                   "pack": ctx.get("pack", False)},
    tests=("tests/test_kernels.py::test_quantize_pack_rows_matches_unpacked",),
    note="absmax -> E2M1 cast -> nibble pack, one kernel",
    knobs=("bm",))

exec_plan.register(
    "quantize_pack", "pallas_quantize_rows", backend="pallas",
    run=_qp_pallas, priority=10, reference="xla_quantize", tol=1e-6,
    predicate=lambda policy, ctx: {"unpacked": not ctx.get("pack", False)},
    tests=("tests/test_kernels.py::test_quantize_rows_vs_ref",),
    note="fused absmax + cast row quantizer", knobs=("bm",))

exec_plan.register(
    "quantize_pack", "xla_quantize", backend="xla", run=_qp_xla, priority=0,
    predicate=lambda policy, ctx: {
        "pack_needs_fp4": (not ctx.get("pack", False))
        or ctx.get("fmt") == "fp4_e2m1"},
    tests=("tests/test_kernels.py::test_quantize_rows_vs_ref",),
    note="jnp reference quantizer (+XLA nibble pack)")


# -----------------------------------------------------------------------------
# allreduce: gradient/partial reduction across a mesh axis (shard_map
# body).  run(grad, err, *, axis_name, fmt_name) -> (mean, new_err)
# -----------------------------------------------------------------------------

def _ar_wire(grad, err, *, axis_name, fmt_name):
    from repro.distributed.collectives import ef_compress_allreduce
    return ef_compress_allreduce(grad, err, axis_name, fmt_name)


def _ar_psum(grad, err, *, axis_name, fmt_name):
    import jax
    return (jax.lax.pmean(grad.astype(jnp.float32), axis_name),
            jnp.zeros_like(err, dtype=jnp.float32))


def _wire_fmt_bytes(ctx, default_bits=32):
    from repro.core.formats import get_format
    fmt = ctx.get("wire_fmt")
    bits = get_format(fmt).bits if fmt else default_bits
    return ctx.get("size", 0) * bits // 8


exec_plan.register(
    "allreduce", "wire_compressed", backend="xla", run=_ar_wire,
    priority=10, reference="xla_psum_f32", tol=0.1,
    predicate=lambda policy, ctx: {
        "multi_device": ctx.get("n_devices", 1) > 1,
        "wire_fmt": ctx.get("wire_fmt") is not None},
    bytes_moved=lambda policy, ctx: _wire_fmt_bytes(ctx) + 4,
    tests=("tests/test_distributed.py::"
           "test_compressed_allreduce_error_feedback",
           "tests/test_tp_engine.py::test_wire_collectives_parity",),
    note="error-feedback all-gather at wire-format width, f32 "
         "accumulation (the DPA contract on the slow axis); tol is the "
         "fp8 wire's quantization noise, killed over steps by the "
         "residual feedback")

exec_plan.register(
    "allreduce", "xla_psum_f32", backend="xla", run=_ar_psum, priority=0,
    predicate=lambda policy, ctx: {},
    bytes_moved=lambda policy, ctx: 4 * ctx.get("size", 0),
    tests=("tests/test_distributed.py::"
           "test_compressed_allreduce_error_feedback",),
    note="plain f32 psum-mean (4 bytes/element on the wire); also the "
         "identity on a size-1 axis")


# -----------------------------------------------------------------------------
# unembed: logits over the (tied) vocab table.  run(x, table, policy)
# -> (B, S, V) f32-accumulated
# -----------------------------------------------------------------------------

def _ue_xla(x, table, policy):
    return jnp.einsum("bsd,vd->bsv", x, table.astype(x.dtype),
                      preferred_element_type=jnp.float32)


exec_plan.register(
    "unembed", "xla_tied_table", backend="xla", run=_ue_xla, priority=0,
    predicate=lambda policy, ctx: {},
    bytes_moved=lambda policy, ctx: 4 * ctx.get("size", 0),
    tests=("tests/test_layers.py", "tests/test_archs.py"),
    note="fp32-accumulation logits over the transposed embedding table; "
         "the narrow-format story deliberately stops before the unembed "
         "(quality), so the only route is the wide reference")
