"""TransDot golden model: trans-precision dot-product accumulation (DPA).

This is the bit-accurate functional model of the TransDot datapath
(paper §II): N low-precision products (N=1 scalar/SIMD FMA, N=2 FP16,
N=4 FP8-E4M3, N=8 FP4-E2M1) are computed *exactly*, aligned into a wide
windowed accumulator anchored at the maximum operand exponent (the
reconfigurable barrel shifter + the multi-mode multiplier's reduction
tree), summed together with a higher-precision addend C, normalized,
and rounded once (RNE) into the accumulate format (FP32 or FP16,
Table I).

Datapath correspondence
-----------------------
  exact sub-multiplier products     -> integer mantissa products
  reconfigurable alignment shifter  -> per-term variable shift into the
                                       window, out-shifted bits -> sticky
  wide no-precision-loss adder      -> multi-limb integer accumulator of
                                       width 3*p_acc + 4 + ceil(log2(N+1))
                                       (the paper's 3p+4 FMA adder widened
                                       by the DPA term count)
  LZC + normalization shifter       -> exact bit-length scan + extraction
  rounding stage (per-lane)         -> single RNE encode

The model is vectorized jnp integer arithmetic (jit/vmap-friendly).
It requires 64-bit integers; importing this module enables jax x64.
All other repro modules use explicit dtypes so this is safe.
"""
from __future__ import annotations

import math
from functools import partial

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402

from .formats import (FP32, FloatFormat, decode, encode_from_parts,  # noqa: E402
                      get_format, inf_code, nan_code)

# Number of 32-bit limbs in the wide accumulator (little-endian digits held
# in int64 so per-limb sums never overflow).
_LIMBS = 6
_MASK32 = (1 << 32) - 1


def default_window_bits(fmt_acc: FloatFormat, n_terms: int) -> int:
    """The paper's no-precision-loss adder width generalized to N terms."""
    return 3 * fmt_acc.precision + 4 + max(1, math.ceil(math.log2(n_terms + 1)))


# -----------------------------------------------------------------------------
# wide-integer helpers (radix 2^32 digits in int64)
# -----------------------------------------------------------------------------

def _place(limbs, mag, shift, sign):
    """limbs += (-1)^sign * mag * 2^shift   (shift >= 0, mag < 2^48)."""
    k = shift >> 5          # limb index
    rr = shift & 31         # intra-limb offset
    m_lo = mag & _MASK32
    m_hi = mag >> 32
    t0 = m_lo << rr                       # < 2^63
    t1 = m_hi << rr                       # < 2^47
    d = [t0 & _MASK32,
         (t0 >> 32) + (t1 & _MASK32),     # < 2^33
         t1 >> 32]
    s = jnp.where(sign == 1, -1, 1).astype(limbs.dtype)
    pos = jnp.arange(_LIMBS, dtype=k.dtype)
    for j, dj in enumerate(d):
        sel = (pos == (k + j)[..., None]).astype(limbs.dtype)
        limbs = limbs + sel * (s * dj)[..., None]
    return limbs


def _carry_normalize(limbs):
    """Signed carry propagation -> digits in [0, 2^32), negative flag."""
    out = []
    carry = jnp.zeros(limbs.shape[:-1], limbs.dtype)
    for j in range(_LIMBS):
        v = limbs[..., j] + carry
        carry = v >> 32          # arithmetic shift = floor division
        out.append(v - (carry << 32))
    # after the top limb, `carry` is 0 (non-negative total) or -1 (negative)
    neg = carry < 0
    limbs = jnp.stack(out, axis=-1)
    # two's-complement negate where negative: invert digits, +1 with carry
    inv = (~limbs) & _MASK32
    carry2 = jnp.ones(limbs.shape[:-1], limbs.dtype)
    neg_digits = []
    for j in range(_LIMBS):
        v = inv[..., j] + carry2
        carry2 = v >> 32
        neg_digits.append(v & _MASK32)
    neg_limbs = jnp.stack(neg_digits, axis=-1)
    return jnp.where(neg[..., None], neg_limbs, limbs), neg


def _bitlen32(x):
    """Bit length of values in [0, 2^32)."""
    n = jnp.zeros_like(x)
    for k in (16, 8, 4, 2, 1):
        m = x >> k
        take = m != 0
        n = n + k * take.astype(x.dtype)
        x = jnp.where(take, m, x)
    return n + (x != 0).astype(x.dtype)


def _msb(limbs):
    """Index+1 of the highest set bit; -1 if the value is zero."""
    pos = jnp.arange(_LIMBS, dtype=limbs.dtype)
    cand = jnp.where(limbs != 0, 32 * pos + _bitlen32(limbs), -1)
    return jnp.max(cand, axis=-1)


def _get_limb(limbs, idx):
    idx_c = jnp.clip(idx, 0, _LIMBS - 1)
    v = jnp.take_along_axis(limbs, idx_c[..., None], axis=-1)[..., 0]
    return jnp.where((idx < 0) | (idx >= _LIMBS), 0, v)


def _extract_top(limbs, msb, nbits):
    """T = floor(value / 2^(msb-nbits)), sticky = dropped bits != 0."""
    r = msb - nbits
    # r > 0 path: gather the straddling limbs
    k = jnp.maximum(r, 0) >> 5
    rr = jnp.maximum(r, 0) & 31
    l0 = _get_limb(limbs, k)
    l1 = _get_limb(limbs, k + 1)
    mask26 = (1 << (nbits + 1)) - 1
    t_pos = ((l0 >> rr) | ((l1 & mask26) << (32 - rr))) & ((1 << nbits) - 1)
    # sticky: limbs fully below k, plus low rr bits of limb k
    pos = jnp.arange(_LIMBS, dtype=limbs.dtype)
    below = jnp.any((limbs != 0) & (pos < k[..., None]), axis=-1)
    sticky_pos = below | ((l0 & ((1 << rr) - 1)) != 0)
    # r <= 0 path: value < 2^nbits, lives in limb 0 (nbits <= 27)
    t_neg = (limbs[..., 0] << jnp.minimum(-r, 32)) & ((1 << nbits) - 1)
    t = jnp.where(r > 0, t_pos, jnp.where(r == 0, t_pos, t_neg))
    sticky = jnp.where(r > 0, sticky_pos, False)
    return t, sticky


# -----------------------------------------------------------------------------
# the DPA datapath
# -----------------------------------------------------------------------------

def dpa_codes(a_codes, b_codes, c_codes, fmt_ab, fmt_acc=FP32,
              window_bits=None):
    """N-term trans-precision dot-product accumulation on integer codes.

    a_codes, b_codes: integer codes of shape (..., N) in ``fmt_ab``.
    c_codes:          integer codes of shape (...,) in ``fmt_acc``.
    Returns integer codes of shape (...,) in ``fmt_acc``:
        round_RNE( sum_i a_i * b_i + c )   computed as one windowed sum.
    """
    fmt_ab = get_format(fmt_ab)
    fmt_acc = get_format(fmt_acc)
    a_codes = jnp.asarray(a_codes)
    n_terms = a_codes.shape[-1]
    W = window_bits or default_window_bits(fmt_acc, n_terms)
    if W + 52 > 32 * _LIMBS:
        raise ValueError(f"window_bits={W} too wide for {_LIMBS} limbs")

    i64 = jnp.int64
    sa, ma, ea, za, ia, na = decode(a_codes, fmt_ab)
    sb, mb, eb, zb, ib, nb = decode(b_codes, fmt_ab)
    sc, mc, ec, zc, ic, nc = decode(c_codes, fmt_acc)

    # --- exact products ------------------------------------------------------
    sp = sa ^ sb
    mp = ma.astype(i64) * mb.astype(i64)            # <= 2^48 (fp32 scalar mode)
    qp = (ea + eb - 2 * fmt_ab.man_bits).astype(i64)
    mcw = mc.astype(i64)
    qc = (ec - fmt_acc.man_bits).astype(i64)

    # --- anchor & window -----------------------------------------------------
    def blen(m):  # bit length of int64 magnitudes < 2^48
        hi = _bitlen32(m >> 32)
        lo = _bitlen32(m & _MASK32)
        return jnp.where(hi > 0, hi + 32, lo)

    NEG = jnp.asarray(-(1 << 40), i64)
    tops = jnp.concatenate(
        [jnp.where(mp != 0, qp + blen(mp), NEG),
         jnp.where(mcw != 0, qc + blen(mcw), NEG)[..., None]], axis=-1)
    anchor = jnp.max(tops, axis=-1)
    lam = anchor - W                                 # weight of window bit 0

    # --- align + accumulate (shifter + wide adder) ---------------------------
    # Window layout: bits [2, W+2) hold in-window data (weight 2^(lam+b-2));
    # bit 0 receives a SIGNED +-1 residue unit whenever a term loses bits
    # below the window — the end-around-borrow behaviour of a hardware
    # aligner, so a negative sub-window addend correctly breaks RNE ties
    # downward instead of acting as an unsigned sticky.
    limbs = jnp.zeros(a_codes.shape[:-1] + (_LIMBS,), i64)
    any_resid = jnp.zeros(a_codes.shape[:-1], bool)

    def add_term(limbs, any_resid, m, q, s):
        sh = q - lam + 2
        rs = jnp.clip(-sh, 0, 63)
        lost = (m & ((jnp.asarray(1, i64) << rs) - 1)) != 0
        m = m >> rs
        sh = jnp.clip(sh, 0, 32 * _LIMBS - 49)
        limbs = _place(limbs, m, sh, s)
        limbs = _place(limbs, lost.astype(i64), jnp.zeros_like(sh), s)
        return limbs, any_resid | lost

    for i in range(n_terms):
        limbs, any_resid = add_term(limbs, any_resid,
                                    mp[..., i], qp[..., i], sp[..., i])
    limbs, any_resid = add_term(limbs, any_resid, mcw, qc, sc)
    sticky_in = jnp.zeros(a_codes.shape[:-1], bool)

    # --- normalize + round ---------------------------------------------------
    limbs, neg = _carry_normalize(limbs)
    msb = _msb(limbs)
    is_zero = msb < 0
    nbits = fmt_acc.man_bits + 3                     # 1.man | G | R
    msb_c = jnp.maximum(msb, 1)
    t, sticky_lo = _extract_top(limbs, msb_c, nbits)
    sticky = sticky_in | sticky_lo
    e_lead = (lam - 2) + msb_c - 1                   # window floor at lam-2
    sign_out = neg.astype(t.dtype)
    code = encode_from_parts(sign_out, t, e_lead.astype(t.dtype), sticky,
                             fmt_acc)

    # value exactly zero inside the window: sign = AND of all input signs
    # (IEEE-754 sum-of-zeros rule applied across the flattened sum); when
    # mixed-sign sub-window residues cancelled, the true value is an
    # unknowably-signed tiny -> +0 (documented 1-window-ulp contract).
    all_neg = jnp.all(sp == 1, axis=-1) & (sc == 1)
    zero_code = (all_neg & ~any_resid).astype(t.dtype) << (fmt_acc.bits - 1)
    code = jnp.where(is_zero, zero_code, code)

    # --- special values ------------------------------------------------------
    prod_nan = na | nb | (ia & zb) | (ib & za)
    prod_inf = (ia | ib) & ~prod_nan
    pos_inf = jnp.any(prod_inf & (sp == 0), axis=-1) | (ic & (sc == 0))
    neg_inf = jnp.any(prod_inf & (sp == 1), axis=-1) | (ic & (sc == 1))
    any_nan = jnp.any(prod_nan, axis=-1) | nc | (pos_inf & neg_inf)
    any_inf = (pos_inf | neg_inf) & ~any_nan

    if fmt_acc.has_inf:
        code = jnp.where(any_inf,
                         inf_code(fmt_acc, neg_inf.astype(t.dtype)), code)
        code = jnp.where(any_nan, nan_code(fmt_acc), code)
    else:
        code = jnp.where(any_nan | any_inf, nan_code(fmt_acc), code)
    return code.astype(jnp.uint32)


@partial(jax.jit, static_argnames=("fmt_ab", "fmt_acc", "window_bits"))
def dpa_codes_jit(a_codes, b_codes, c_codes, fmt_ab="fp16", fmt_acc="fp32",
                  window_bits=None):
    return dpa_codes(a_codes, b_codes, c_codes, fmt_ab, fmt_acc, window_bits)


# -----------------------------------------------------------------------------
# convenience float front-ends (test / benchmark plumbing)
# -----------------------------------------------------------------------------

def dpa(a, b, c, fmt_ab, fmt_acc=FP32, window_bits=None):
    """DPA on float inputs: quantizes a/b into fmt_ab codes (RNE via
    ml_dtypes), c into fmt_acc, runs the datapath, returns float output."""
    import numpy as np

    from .formats import codes_to_np, float_to_codes
    fmt_ab = get_format(fmt_ab)
    fmt_acc = get_format(fmt_acc)
    ac = float_to_codes(np.asarray(a), fmt_ab)
    bc = float_to_codes(np.asarray(b), fmt_ab)
    cc = float_to_codes(np.asarray(c), fmt_acc)
    out = dpa_codes(ac, bc, cc, fmt_ab, fmt_acc, window_bits)
    return codes_to_np(np.asarray(out), fmt_acc).astype(np.float64)
