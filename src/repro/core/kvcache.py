"""Quantized KV-cache layer: format-width storage for decode attention.

Serving cost on long contexts is dominated by streaming the KV cache every
decode step; the paper's format-width I/O contract applies directly — a
cache held at operand width moves 2x/4x (fp16/fp8) or ~8x (packed fp4,
two E2M1 codes per byte via `core.packing`) fewer bytes than the seed f32
cache.  This module owns the storage layout; the *compute* contract (DPA
f32 accumulation for QK^T/PV over the dequantized-in-prologue operands)
lives in `kernels.flash_attention` / `models.decode_attn`.

Contiguous layout — one entry per (batch, position, kv-head) row of
head_dim values:

  k_codes / v_codes : (B, S, KV, hd)  native narrow dtype (fp16/bf16/fp8),
                      or uint8 E2M1 codes for fp4 — (B, S, KV, hd // 2)
                      packed bytes when `packed` (low nibble = even index).
  k_scale / v_scale : (B, S, KV, 1) f32 per-row absmax scales — the
                      software exponent path; dequant = widen(codes) * scale.

Paged layout — the serving-engine variant.  A static (B, S_max) cache is
the software analogue of FPnew-style lane replication: memory sized for
the longest request, replicated per batch slot.  The paged cache removes
it the same way TransDot removes idle mantissa lanes — storage is a pool
of fixed-size pages shared by every live request, and a per-request block
table maps its token timeline onto pages, so cache memory scales with
*live tokens*, not B x S_max:

  k_codes / v_codes : (P, page, KV, wc) page pool (same code dtype/width
                      rules as the contiguous layout)
  k_scale / v_scale : (P, page, KV, 1) f32 per-row scales
  block table       : (B, max_pages) i32, row b listing the pages that
                      hold request b's tokens in timeline order; token t
                      lives at (table[b, t // page], t % page).

Page 0 is a scratch page (see `PageAllocator`): idle batch slots point
their whole table row at it so a fixed-shape decode step can harmlessly
write there, and no live request ever references it.

Both layouts share one quantization recipe — exactly
`core.quantize.quant_rows_grid` over the head_dim axis — so a cache
round-trip is bit-identical to the fake-quant the attention reference
applies to raw K/V, and a paged cache holds bit-identical codes/scales to
the contiguous cache it replaces (paging is pure relayout).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .formats import get_format
from .packing import operand_nbytes, pack_fp4, unpack_fp4
from .quantize import decode_fp4, encode_fp4, jnp_dtype, quant_rows_grid

QUANT_KEYS = ("k_codes", "k_scale", "v_codes", "v_scale")


def is_quantized(cache) -> bool:
    """True for the quantized layout ({k,v}_codes/{k,v}_scale pytree)."""
    return isinstance(cache, dict) and "k_codes" in cache


def _codes_dtype(fmt):
    fmt = get_format(fmt)
    return jnp.uint8 if fmt.name == "fp4_e2m1" else jnp_dtype(fmt)


def _codes_width(hd: int, fmt, packed: bool) -> int:
    fmt = get_format(fmt)
    if fmt.name == "fp4_e2m1" and packed:
        if hd % 2:
            raise ValueError(f"packed fp4 KV needs an even head_dim, got {hd}")
        return hd // 2
    return hd


def quantize_kv(x, *, fmt, packed: bool = False):
    """(..., hd) raw K or V -> (codes, scale) in the cache layout.

    Per-row absmax over the trailing head_dim axis; codes are the format's
    storage representation (native dtype, or E2M1 nibbles — packed two per
    byte along hd when `packed`).  Built ON `quant_rows_grid` — not a
    re-implementation — so the cache recipe cannot drift from the one the
    attention kernels/oracles use: re-encoding exact grid values is a
    bit-exact round trip."""
    fmt = get_format(fmt)
    grid, scale = quant_rows_grid(x, fmt)
    if fmt.name == "fp4_e2m1":
        codes = encode_fp4(grid)
        if packed:
            codes = pack_fp4(codes)
    else:
        codes = grid.astype(jnp_dtype(fmt))
    return codes, scale


def dequantize_kv(codes, scale, *, fmt, packed: bool = False):
    """Cache rows -> f32 values: widen(codes) * scale (dequant-in-prologue
    semantics; identical to `quant_rows_grid(x)[0] * scale` of the raw
    tensor, so the cached path reproduces the fake-quant path bit-for-bit)."""
    fmt = get_format(fmt)
    if fmt.name == "fp4_e2m1":
        c = unpack_fp4(codes) if packed else codes
        grid = decode_fp4(c)
    else:
        grid = codes.astype(jnp.float32)
    return grid * scale


def init_kv_cache(batch: int, s_ctx: int, n_kv: int, hd: int, *, fmt,
                  packed: bool = False):
    """Zeroed quantized cache pytree for a full-context decode cache."""
    wc = _codes_width(hd, fmt, packed)
    codes = jnp.zeros((batch, s_ctx, n_kv, wc), _codes_dtype(fmt))
    scale = jnp.zeros((batch, s_ctx, n_kv, 1), jnp.float32)
    return {"k_codes": codes, "k_scale": scale,
            "v_codes": codes, "v_scale": scale}


def update_kv_cache(cache, k_new, v_new, offset, *, fmt,
                    packed: bool = False):
    """Quantize k/v (B, S_new, KV, hd) and write them at `offset` along the
    sequence axis.  Returns the new cache pytree."""
    kc, ks = quantize_kv(k_new, fmt=fmt, packed=packed)
    vc, vs = quantize_kv(v_new, fmt=fmt, packed=packed)
    z = jnp.zeros((), jnp.int32)
    off = jnp.asarray(offset, jnp.int32)
    at = (z, off, z, z)
    return {
        "k_codes": jax.lax.dynamic_update_slice(cache["k_codes"], kc, at),
        "k_scale": jax.lax.dynamic_update_slice(cache["k_scale"], ks, at),
        "v_codes": jax.lax.dynamic_update_slice(cache["v_codes"], vc, at),
        "v_scale": jax.lax.dynamic_update_slice(cache["v_scale"], vs, at),
    }


def dequantize_cache(cache, *, fmt, packed: bool = False):
    """-> (k, v) f32 (B, S, KV, hd) — the prologue widening, as one op."""
    k = dequantize_kv(cache["k_codes"], cache["k_scale"], fmt=fmt,
                      packed=packed)
    v = dequantize_kv(cache["v_codes"], cache["v_scale"], fmt=fmt,
                      packed=packed)
    return k, v


def kv_cache_nbytes(batch: int, s_ctx: int, n_kv: int, hd: int, *, fmt,
                    packed: bool = False) -> dict:
    """Bytes one layer's K+V cache moves through the interface per full
    sweep (codes + f32 scales), vs the seed f32 cache, and the reduction.

    This is the decode-attention bandwidth story: every generated token
    streams the whole cache, so the reduction here is the per-token HBM
    saving (≈8x for packed fp4 at hd=128, ≈7x at hd=64 — the scale row
    amortizes over head_dim)."""
    n_rows = batch * s_ctx * n_kv
    code_b = operand_nbytes(n_rows * hd, fmt, packed=packed)
    total = 2 * (code_b + 4 * n_rows)          # K and V, codes + scales
    f32 = 2 * 4 * n_rows * hd
    return {"total": total, "f32_total": f32,
            "reduction_vs_f32": f32 / total}


# -----------------------------------------------------------------------------
# paged layout: page pool + block table (the continuous-batching cache)
# -----------------------------------------------------------------------------

SCRATCH_PAGE = 0


def is_paged(cache) -> bool:
    """True for the paged layout (page pool + "block_table" pytree)."""
    return isinstance(cache, dict) and "block_table" in cache


def init_paged_kv_cache(n_pages: int, page_size: int, n_kv: int, hd: int,
                        *, fmt, packed: bool = False):
    """Zeroed page pool: {k,v}_codes (P, page, KV, wc) + f32 scales.

    The pool carries no block table — tables are per-request routing state
    owned by the scheduler (`launch.engine`); `make_block_table` builds the
    (B, max_pages) leaf the decode step consumes alongside the pool."""
    wc = _codes_width(hd, fmt, packed)
    codes = jnp.zeros((n_pages, page_size, n_kv, wc), _codes_dtype(fmt))
    scale = jnp.zeros((n_pages, page_size, n_kv, 1), jnp.float32)
    return {"k_codes": codes, "k_scale": scale,
            "v_codes": codes, "v_scale": scale}


def make_block_table(n_slots: int, max_pages: int):
    """All-scratch (B, max_pages) i32 table — every slot starts idle."""
    return jnp.full((n_slots, max_pages), SCRATCH_PAGE, jnp.int32)


def paged_write_tokens(cache, k_new, v_new, positions, *, fmt,
                       packed: bool = False):
    """Quantize a run of S_new tokens per batch slot into its pages.

    k_new/v_new: (B, S_new, KV, hd); positions: (B,) i32 absolute index
    of each request's *first* new token (token i of row b lands at
    timeline position ``positions[b] + i``, i.e. at
    (table[b, p // page], p % page)).  S_new == 1 is the decode step;
    S_new > 1 is the speculative draft/verify window, whose query rows
    quantize independently per row (absmax over head_dim), so a
    multi-token write is bit-identical to S_new single-token writes.
    Idle slots carry an all-scratch table row, so their writes hit the
    scratch page and never touch live data.  Returns the cache pytree
    with updated pools (block_table passes through unchanged)."""
    ps = cache["k_codes"].shape[1]
    table = cache["block_table"]
    s_new = k_new.shape[1]
    pos = jnp.asarray(positions, jnp.int32)[:, None] \
        + jnp.arange(s_new, dtype=jnp.int32)[None]          # (B, S_new)
    page = jnp.take_along_axis(table, pos // ps, axis=1)    # (B, S_new)
    slot = pos % ps
    kc, ks = quantize_kv(k_new, fmt=fmt, packed=packed)
    vc, vs = quantize_kv(v_new, fmt=fmt, packed=packed)
    out = dict(cache)
    for key, new in (("k_codes", kc), ("k_scale", ks),
                     ("v_codes", vc), ("v_scale", vs)):
        out[key] = cache[key].at[page, slot].set(new)
    return out


def paged_write_token(cache, k_new, v_new, positions, *, fmt,
                      packed: bool = False):
    """Quantize one token per batch slot into its page (the decode step;
    see `paged_write_tokens` for the multi-token contract)."""
    return paged_write_tokens(cache, k_new, v_new, positions, fmt=fmt,
                              packed=packed)


def gather_paged_kv(cache):
    """Page pool + block table -> contiguous-layout view.

    Returns a {k,v}_codes/{k,v}_scale pytree shaped (B, max_pages * page,
    KV, ...) — request b's timeline re-materialized in order, exactly the
    contiguous layout `dequantize_cache` (and thus the whole DPA decode
    path) consumes.  This is the jnp gather fallback of the block-table
    read; rows past a request's live length come from whatever pages its
    table names (scratch for idle tail entries) and must be masked by
    position, as `models.decode_attn.dpa_paged_decode_attn` does.  Pure
    relayout: gathered codes/scales are bit-identical to the pool's."""
    table = cache["block_table"]
    B, n_pg = table.shape
    out = {}
    for key in QUANT_KEYS:
        pool = cache[key]                       # (P, page, KV, w)
        ps = pool.shape[1]
        g = pool[table]                         # (B, n_pg, page, KV, w)
        out[key] = g.reshape((B, n_pg * ps) + pool.shape[2:])
    return out


def write_prefill_rows(cache, rows, page_ids, length: int, *,
                       start: int = 0):
    """Scatter a prefill's rows [`start`, `length`) into pages.

    rows: contiguous-layout pytree with leaves (S, KV, ...) (one request,
    batch dim already stripped); page_ids: host list of the request's
    pages in timeline order; length: host int, number of live rows;
    start: host int, first row to write (rows before it — a shared or
    copy-on-write prefix the engine matched from the prefix cache — are
    already in their pages and MUST NOT be rewritten: pages below the
    start row may be read-only shared pages).  Copies whole pages plus
    the partial head/tail pages — pure relayout, so the pages hold
    codes/scales bit-identical to the staging cache's.  Returns the
    cache with updated pools."""
    ps = cache["k_codes"].shape[1]
    n_need = -(-length // ps) if length else 0
    if n_need > len(page_ids):
        raise ValueError(f"{length} rows need {n_need} pages, "
                         f"got {len(page_ids)}")
    if not 0 <= start <= length:
        raise ValueError(f"start ({start}) outside [0, {length}]")
    out = dict(cache)
    for key in QUANT_KEYS:
        pool, src = out[key], rows[key]
        for j in range(n_need):
            if (j + 1) * ps <= start:
                continue                    # page fully covered by prefix
            pid = int(page_ids[j])
            lo = max(start - j * ps, 0)
            n = min(ps, length - j * ps)
            pool = pool.at[pid, lo:n].set(src[j * ps + lo:j * ps + n])
        out[key] = pool
    return out


def paged_from_contiguous(ref, lengths, *, page_size: int,
                          n_pages: int = None):
    """Relayout a contiguous quantized cache into a fresh paged one.

    ref: contiguous pytree with leaves (B, S, KV, ...); lengths: host
    ints, request b's live rows (its first `lengths[b]` positions of
    `ref` scatter into freshly allocated pages).  Returns the paged
    cache pytree with the block table installed.  Pure relayout — pages
    hold codes/scales bit-identical to `ref` — which makes this the
    standard paged-vs-contiguous fixture for tests and benchmarks."""
    import numpy as np
    B = ref["k_codes"].shape[0]
    n_need = [max(1, -(-int(n) // page_size)) for n in lengths]
    if n_pages is None:
        n_pages = sum(n_need) + 2
    alloc = PageAllocator(n_pages)
    # empty workloads are legal (an engine draining to idle): the table
    # is a valid all-scratch (B, 1) — never max() of an empty sequence
    table = np.full((B, max(n_need, default=1)), SCRATCH_PAGE, np.int32)
    cache = {key: jnp.zeros((n_pages, page_size) + ref[key].shape[2:],
                            ref[key].dtype) for key in QUANT_KEYS}
    for b, n in enumerate(lengths):
        ids = alloc.alloc(n_need[b])
        table[b, :len(ids)] = ids
        rows = {key: ref[key][b] for key in QUANT_KEYS}
        cache = write_prefill_rows(cache, rows, ids, int(n))
    cache["block_table"] = jnp.asarray(table)
    return cache


def paged_kv_cache_nbytes(live_tokens: int, pages_in_use: int,
                          page_size: int, n_kv: int, hd: int, *, fmt,
                          packed: bool = False) -> dict:
    """Byte accounting for a paged cache vs the static (B, S_max) layouts.

    `live` counts exactly the rows live requests occupy (the engine
    report's honest number); `paged` counts whole pages in use (live
    rounded up by page granularity — the allocator's footprint).  Compare
    against `kv_cache_nbytes(B, S_max, ...)` for the static-batch
    baselines the engine replaces."""
    def row_bytes(n_rows):
        return 2 * (operand_nbytes(n_rows * hd, fmt, packed=packed)
                    + 4 * n_rows)               # K and V, codes + scales
    return {"live": row_bytes(live_tokens * n_kv),
            "paged": row_bytes(pages_in_use * page_size * n_kv)}


class PageAllocator:
    """Free-list page allocator for the paged KV cache.

    Page 0 is reserved as the scratch page idle decode slots write to, so
    `capacity` pages yield `capacity - 1` allocatable ones.  Freed pages
    return to the free list and are reused LIFO (hot pages stay cache-
    warm).  Tracks in-use count and the peak for utilization reporting.

    Reservations (the speculative-decoding commit/rollback protocol):
    a request may `reserve(n)` pages without popping them — reserved
    pages stay on the free list but are excluded from `can_alloc`, so no
    other request can claim them (the engine's no-OOM-mid-decode
    invariant survives lazy committing).  `alloc(n, reserved=True)`
    *commits* pages out of the caller's reservation as its timeline
    grows; `free(pages, to_reserved=True)` rolls committed pages back
    into the reservation (the KV-rollback path: pages holding only
    rejected draft tokens return without becoming grabbable by anyone
    else); `unreserve(n)` releases the unused remainder at finish.
    Invariant: ``reserved <= n_free`` always — every reserved page is
    physically on the free list until committed.

    Reference counts (the prefix-sharing protocol): `alloc` hands a page
    out with refcount 1; `incref` adds holders (a prefix-cache entry, a
    request matching a cached prefix).  `free` is a *decref* — the page
    only returns to the free list when its last holder releases it, so a
    shared page can never be freed or re-handed-out while any request's
    block table still points at it.  Shared pages (refcount > 1) are
    read-only by convention: a diverging request must copy-on-write into
    a private page (the engine's `_cow_copy`).  Rollback
    (`to_reserved=True`) refuses shared pages outright — only a page the
    caller exclusively owns can fold back into its reservation."""

    def __init__(self, capacity: int):
        if capacity < 2:
            raise ValueError("need >= 2 pages (page 0 is scratch)")
        self.capacity = capacity
        self._free = list(range(capacity - 1, 0, -1))   # pop() -> page 1 first
        self._used = set()
        self._refs = {}                                 # page -> holder count
        self.reserved = 0
        self.peak_in_use = 0

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return len(self._used)

    @property
    def n_available(self) -> int:
        """Free pages not spoken for by a reservation."""
        return self.n_free - self.reserved

    def can_alloc(self, n: int) -> bool:
        return n <= self.n_available

    def reserve(self, n: int) -> None:
        """Earmark `n` free pages without popping them off the free list."""
        if n > self.n_available:
            raise MemoryError(f"reserve({n}): only {self.n_available} "
                              "pages available")
        self.reserved += n

    def unreserve(self, n: int) -> None:
        """Release `n` reserved-but-uncommitted pages back to the pool."""
        if n > self.reserved:
            raise ValueError(f"unreserve({n}) exceeds reserved "
                             f"({self.reserved})")
        self.reserved -= n

    def alloc(self, n: int, *, reserved: bool = False) -> list:
        """Pop `n` pages off the free list (raises if short — callers gate
        admission on `can_alloc`, so running out mid-flight is a bug).
        With `reserved`, the pages commit out of the caller's reservation
        (which must cover them)."""
        if reserved:
            if n > self.reserved:
                raise ValueError(f"alloc({n}, reserved=True) exceeds "
                                 f"reserved ({self.reserved})")
            self.reserved -= n
        elif not self.can_alloc(n):
            raise MemoryError(f"alloc({n}): only {self.n_available} pages "
                              "available")
        pages = [self._free.pop() for _ in range(n)]
        self._used.update(pages)
        for p in pages:
            self._refs[p] = 1
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        return pages

    def incref(self, pages) -> None:
        """Add one holder to each in-use page (prefix sharing: a cache
        entry or a prefix-hit request pointing its table at the page).
        Referencing a page nobody holds is a bug, not a no-op."""
        for p in pages:
            if p not in self._used:
                raise ValueError(f"incref of page {p} that is not in use")
            self._refs[p] += 1

    def refcount(self, page) -> int:
        """Current holder count (0 for free pages and the scratch page)."""
        return self._refs.get(page, 0)

    def is_shared(self, page) -> bool:
        """True when more than one holder references the page (read-only
        by the copy-on-write convention)."""
        return self.refcount(page) > 1

    def free(self, pages, *, to_reserved: bool = False) -> None:
        """Drop one holder per page (decref); a page returns to the free
        list only when its last holder releases it.  With `to_reserved`,
        the page folds back into the caller's reservation (rollback) —
        refused for shared pages, which the caller does not own alone."""
        for p in pages:
            if p == SCRATCH_PAGE:
                raise ValueError("page 0 is the reserved scratch page")
            if p not in self._used:
                raise ValueError(f"double free of page {p}")
            if to_reserved and self._refs[p] > 1:
                raise ValueError(
                    f"page {p} is shared ({self._refs[p]} holders); a "
                    "rollback may only reclaim exclusively-owned pages")
            self._refs[p] -= 1
            if self._refs[p] == 0:
                del self._refs[p]
                self._used.remove(p)
                self._free.append(p)
        if to_reserved:
            self.reserved += len(pages)

    def utilization(self) -> float:
        """Fraction of allocatable pages currently in use."""
        return self.in_use / (self.capacity - 1)
