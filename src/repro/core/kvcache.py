"""Quantized KV-cache layer: format-width storage for decode attention.

Serving cost on long contexts is dominated by streaming the KV cache every
decode step; the paper's format-width I/O contract applies directly — a
cache held at operand width moves 2x/4x (fp16/fp8) or ~8x (packed fp4,
two E2M1 codes per byte via `core.packing`) fewer bytes than the seed f32
cache.  This module owns the storage layout; the *compute* contract (DPA
f32 accumulation for QK^T/PV over the dequantized-in-prologue operands)
lives in `kernels.flash_attention` / `models.decode_attn`.

Layout — one entry per (batch, position, kv-head) row of head_dim values:

  k_codes / v_codes : (B, S, KV, hd)  native narrow dtype (fp16/bf16/fp8),
                      or uint8 E2M1 codes for fp4 — (B, S, KV, hd // 2)
                      packed bytes when `packed` (low nibble = even index).
  k_scale / v_scale : (B, S, KV, 1) f32 per-row absmax scales — the
                      software exponent path; dequant = widen(codes) * scale.

The quantization recipe is exactly `core.quantize.quant_rows_grid` over the
head_dim axis, so a cache round-trip is bit-identical to the fake-quant the
attention reference applies to raw K/V — prefill (raw operands) and decode
(cached operands) see the same numbers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .formats import get_format
from .packing import operand_nbytes, pack_fp4, unpack_fp4
from .quantize import decode_fp4, encode_fp4, jnp_dtype, quant_rows_grid

QUANT_KEYS = ("k_codes", "k_scale", "v_codes", "v_scale")


def is_quantized(cache) -> bool:
    """True for the quantized layout ({k,v}_codes/{k,v}_scale pytree)."""
    return isinstance(cache, dict) and "k_codes" in cache


def _codes_dtype(fmt):
    fmt = get_format(fmt)
    return jnp.uint8 if fmt.name == "fp4_e2m1" else jnp_dtype(fmt)


def _codes_width(hd: int, fmt, packed: bool) -> int:
    fmt = get_format(fmt)
    if fmt.name == "fp4_e2m1" and packed:
        if hd % 2:
            raise ValueError(f"packed fp4 KV needs an even head_dim, got {hd}")
        return hd // 2
    return hd


def quantize_kv(x, *, fmt, packed: bool = False):
    """(..., hd) raw K or V -> (codes, scale) in the cache layout.

    Per-row absmax over the trailing head_dim axis; codes are the format's
    storage representation (native dtype, or E2M1 nibbles — packed two per
    byte along hd when `packed`).  Built ON `quant_rows_grid` — not a
    re-implementation — so the cache recipe cannot drift from the one the
    attention kernels/oracles use: re-encoding exact grid values is a
    bit-exact round trip."""
    fmt = get_format(fmt)
    grid, scale = quant_rows_grid(x, fmt)
    if fmt.name == "fp4_e2m1":
        codes = encode_fp4(grid)
        if packed:
            codes = pack_fp4(codes)
    else:
        codes = grid.astype(jnp_dtype(fmt))
    return codes, scale


def dequantize_kv(codes, scale, *, fmt, packed: bool = False):
    """Cache rows -> f32 values: widen(codes) * scale (dequant-in-prologue
    semantics; identical to `quant_rows_grid(x)[0] * scale` of the raw
    tensor, so the cached path reproduces the fake-quant path bit-for-bit)."""
    fmt = get_format(fmt)
    if fmt.name == "fp4_e2m1":
        c = unpack_fp4(codes) if packed else codes
        grid = decode_fp4(c)
    else:
        grid = codes.astype(jnp.float32)
    return grid * scale


def init_kv_cache(batch: int, s_ctx: int, n_kv: int, hd: int, *, fmt,
                  packed: bool = False):
    """Zeroed quantized cache pytree for a full-context decode cache."""
    wc = _codes_width(hd, fmt, packed)
    codes = jnp.zeros((batch, s_ctx, n_kv, wc), _codes_dtype(fmt))
    scale = jnp.zeros((batch, s_ctx, n_kv, 1), jnp.float32)
    return {"k_codes": codes, "k_scale": scale,
            "v_codes": codes, "v_scale": scale}


def update_kv_cache(cache, k_new, v_new, offset, *, fmt,
                    packed: bool = False):
    """Quantize k/v (B, S_new, KV, hd) and write them at `offset` along the
    sequence axis.  Returns the new cache pytree."""
    kc, ks = quantize_kv(k_new, fmt=fmt, packed=packed)
    vc, vs = quantize_kv(v_new, fmt=fmt, packed=packed)
    z = jnp.zeros((), jnp.int32)
    off = jnp.asarray(offset, jnp.int32)
    at = (z, off, z, z)
    return {
        "k_codes": jax.lax.dynamic_update_slice(cache["k_codes"], kc, at),
        "k_scale": jax.lax.dynamic_update_slice(cache["k_scale"], ks, at),
        "v_codes": jax.lax.dynamic_update_slice(cache["v_codes"], vc, at),
        "v_scale": jax.lax.dynamic_update_slice(cache["v_scale"], vs, at),
    }


def dequantize_cache(cache, *, fmt, packed: bool = False):
    """-> (k, v) f32 (B, S, KV, hd) — the prologue widening, as one op."""
    k = dequantize_kv(cache["k_codes"], cache["k_scale"], fmt=fmt,
                      packed=packed)
    v = dequantize_kv(cache["v_codes"], cache["v_scale"], fmt=fmt,
                      packed=packed)
    return k, v


def kv_cache_nbytes(batch: int, s_ctx: int, n_kv: int, hd: int, *, fmt,
                    packed: bool = False) -> dict:
    """Bytes one layer's K+V cache moves through the interface per full
    sweep (codes + f32 scales), vs the seed f32 cache, and the reduction.

    This is the decode-attention bandwidth story: every generated token
    streams the whole cache, so the reduction here is the per-token HBM
    saving (≈8x for packed fp4 at hd=128, ≈7x at hd=64 — the scale row
    amortizes over head_dim)."""
    n_rows = batch * s_ctx * n_kv
    code_b = operand_nbytes(n_rows * hd, fmt, packed=packed)
    total = 2 * (code_b + 4 * n_rows)          # K and V, codes + scales
    f32 = 2 * 4 * n_rows * hd
    return {"total": total, "f32_total": f32,
            "reduction_vs_f32": f32 / total}
