"""Core: the paper's contribution — trans-precision DPA — as composable JAX.

Import layering note: `repro.core.dpa` (the bit-accurate golden model)
enables jax x64 on import; the deployment modules (quantize / policy /
linear) do not import it, so model/dry-run code never flips global jax
config.  Import `repro.core.dpa` explicitly where the golden model is
needed (tests, numerics benchmarks).
"""
from .formats import (BF16, FP4_E2M1, FP8_E4M3, FP8_E5M2, FP16, FP32,
                      FloatFormat, get_format)
from .linear import (apply_grouped_linear, apply_linear, dpa_dot,
                     init_grouped_linear, init_linear)
from .policy import DPA_TERMS, POLICIES, TransPrecisionPolicy, get_policy
from .quantize import (cast_to, compute_scale, decode_fp4, dequantize,
                       encode_fp4, fake_quant, has_native_dtype, jnp_dtype,
                       quant_dequant, quantize, quantize_blockwise)

__all__ = [
    "FP32", "FP16", "BF16", "FP8_E4M3", "FP8_E5M2", "FP4_E2M1",
    "FloatFormat", "get_format",
    "TransPrecisionPolicy", "POLICIES", "DPA_TERMS", "get_policy",
    "quantize", "quantize_blockwise", "dequantize", "quant_dequant",
    "fake_quant", "cast_to", "compute_scale", "jnp_dtype",
    "encode_fp4", "decode_fp4", "has_native_dtype",
    "init_linear", "apply_linear", "dpa_dot",
    "init_grouped_linear", "apply_grouped_linear",
]
