"""Exact (big-integer) oracle for trans-precision DPA.

Computes  round_RNE( sum_i a_i*b_i + c )  with *no* intermediate rounding,
using Python integers (values are scaled to a common power-of-two grid, so
the exact sum is an integer).  This is the reference the golden model
(`repro.core.dpa`) is property-tested against: the windowed hardware
datapath must match the exact result bit-for-bit unless cancellation digs
below its accumulation window (tests check the error bound in that regime).

Pure Python / numpy-object code — test plumbing, not a performance path.
"""
from __future__ import annotations

import numpy as np

from .formats import FloatFormat, get_format


def _decode_int(code: int, fmt: FloatFormat):
    """code -> (sign, mant, exp) with value = (-1)^s * mant * 2^(exp-man_bits),
    or the strings 'nan'/'inf' for specials."""
    sign = (code >> (fmt.exp_bits + fmt.man_bits)) & 1
    e_raw = (code >> fmt.man_bits) & fmt.exp_mask
    frac = code & fmt.man_mask
    if fmt.special == "ieee" and e_raw == fmt.exp_mask:
        return (sign, None, "nan" if frac else "inf")
    if fmt.special == "fn" and e_raw == fmt.exp_mask and frac == fmt.man_mask:
        return (sign, None, "nan")
    if e_raw == 0:
        return (sign, frac, fmt.emin)
    return (sign, frac | (1 << fmt.man_bits), e_raw - fmt.bias)


def _round_to_format(num: int, scale_exp: int, fmt: FloatFormat):
    """Exact value = num * 2^scale_exp  ->  RNE code in fmt."""
    if num == 0:
        return 0
    sign = 1 if num < 0 else 0
    num = abs(num)
    m = fmt.man_bits
    e = (num.bit_length() - 1) + scale_exp    # exponent of the leading bit
    # quantize to q * 2^ulp_exp with integer q via RNE
    ulp_exp = max(e, fmt.emin) - m
    shift = ulp_exp - scale_exp
    if shift <= 0:
        q = num << (-shift)
    else:
        q = num >> shift
        rem = num & ((1 << shift) - 1)
        half = 1 << (shift - 1)
        if rem > half or (rem == half and (q & 1)):
            q += 1
    if q == 0:
        return sign << (fmt.bits - 1)
    if q.bit_length() > m + 1:                # rounding carry: q == 2^(m+1)
        q >>= 1
        ulp_exp += 1
    if q.bit_length() == m + 1:               # normal
        e_lead = ulp_exp + m
        if e_lead > fmt.emax:
            if fmt.has_inf:
                return (sign << (fmt.bits - 1)) | (fmt.exp_mask << m)
            sat = fmt.man_mask - 1 if fmt.special == "fn" else fmt.man_mask
            return (sign << (fmt.bits - 1)) | (fmt.exp_mask << m) | sat
        return ((sign << (fmt.bits - 1)) | ((e_lead + fmt.bias) << m)
                | (q - (1 << m)))
    # subnormal (ulp_exp == emin - m by construction)
    return (sign << (fmt.bits - 1)) | q


def dpa_exact_code(a_codes, b_codes, c_code, fmt_ab, fmt_acc) -> int:
    """Exact DPA for ONE lane: lists of int codes -> int code in fmt_acc."""
    fmt_ab = get_format(fmt_ab)
    fmt_acc = get_format(fmt_acc)
    terms = []          # (sign, mant:int, exp:int) exact products
    pos_inf = neg_inf = has_nan = False
    for ac, bc in zip(a_codes, b_codes):
        sa, ma, ea = _decode_int(int(ac), fmt_ab)
        sb, mb, eb = _decode_int(int(bc), fmt_ab)
        s = sa ^ sb
        if ea == "nan" or eb == "nan":
            has_nan = True
            continue
        if ea == "inf" or eb == "inf":
            other_zero = (mb == 0 if ea == "inf" and eb not in ("inf",) else
                          (ma == 0 if eb == "inf" and ea not in ("inf",) else False))
            if other_zero:
                has_nan = True
            elif s:
                neg_inf = True
            else:
                pos_inf = True
            continue
        terms.append((s, ma * mb, ea + eb - 2 * fmt_ab.man_bits))
    sc, mc, ec = _decode_int(int(c_code), fmt_acc)
    if ec == "nan":
        has_nan = True
    elif ec == "inf":
        if sc:
            neg_inf = True
        else:
            pos_inf = True
    else:
        terms.append((sc, mc, ec - fmt_acc.man_bits))
    if has_nan or (pos_inf and neg_inf):
        from .formats import nan_code
        return nan_code(fmt_acc)
    if pos_inf or neg_inf:
        from .formats import inf_code
        return int(inf_code(fmt_acc, 1 if neg_inf else 0))
    if not terms or all(m == 0 for _, m, _ in terms):
        all_neg = all(s == 1 for s, _, _ in terms) if terms else False
        return (1 << (fmt_acc.bits - 1)) if all_neg else 0
    qmin = min(q for _, m, q in terms if m != 0)
    total = 0
    for s, m, q in terms:
        if m != 0:
            total += (-m if s else m) << (q - qmin)
    if total == 0:
        return 0        # exact cancellation -> +0 (RNE)
    return _round_to_format(total, qmin, fmt_acc)


def dpa_exact(a_codes, b_codes, c_codes, fmt_ab, fmt_acc) -> np.ndarray:
    """Vector front-end: a/b (..., N), c (...,) integer code arrays."""
    a = np.asarray(a_codes)
    b = np.asarray(b_codes)
    c = np.asarray(c_codes)
    flat_a = a.reshape(-1, a.shape[-1])
    flat_b = b.reshape(-1, b.shape[-1])
    flat_c = c.reshape(-1)
    out = np.array([dpa_exact_code(fa, fb, fc, fmt_ab, fmt_acc)
                    for fa, fb, fc in zip(flat_a, flat_b, flat_c)],
                   dtype=np.uint32)
    return out.reshape(c.shape)
