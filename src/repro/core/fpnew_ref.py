"""FPnew-style baseline: sequential trans-precision FMA.

FPnew (the paper's baseline) has no DPA datapath: accumulating an
N-element low-precision dot product into FP32 issues N dependent FMAs,
each individually rounded (paper Fig. 1, "w/o DPA").  This module models
that execution contract bit-exactly by chaining the golden FMA
(`dpa_codes` with N=1 — the windowed datapath is correctly-rounded for a
single product).

It is both (a) the numerics baseline the paper motivates against (one
rounding per term vs one rounding total), and (b) the throughput baseline
(N cycles vs 1 cycle — modeled in `repro.hwmodel.throughput`).
"""
from __future__ import annotations

import numpy as np

from .dpa import dpa_codes
from .formats import FP32, get_format


def fma_codes(a_codes, b_codes, c_codes, fmt_ab, fmt_acc=FP32):
    """Single correctly-rounded trans-precision FMA on codes (shape (...,))."""
    import jax.numpy as jnp
    a = jnp.asarray(a_codes)[..., None]
    b = jnp.asarray(b_codes)[..., None]
    return dpa_codes(a, b, c_codes, fmt_ab, fmt_acc)


def sequential_fma_codes(a_codes, b_codes, c_codes, fmt_ab, fmt_acc=FP32):
    """FPnew execution of an N-term dot product: N chained rounded FMAs.

    a_codes/b_codes: (..., N) codes in fmt_ab; c_codes: (...,) in fmt_acc.
    """
    fmt_ab = get_format(fmt_ab)
    fmt_acc = get_format(fmt_acc)
    n = a_codes.shape[-1]
    acc = c_codes
    for i in range(n):
        acc = dpa_codes(a_codes[..., i:i + 1], b_codes[..., i:i + 1], acc,
                        fmt_ab, fmt_acc)
    return acc


def sequential_fma(a, b, c, fmt_ab, fmt_acc=FP32):
    """Float front-end mirroring `repro.core.dpa.dpa`."""
    from .formats import codes_to_np, float_to_codes
    fmt_ab = get_format(fmt_ab)
    fmt_acc = get_format(fmt_acc)
    ac = float_to_codes(np.asarray(a), fmt_ab)
    bc = float_to_codes(np.asarray(b), fmt_ab)
    cc = float_to_codes(np.asarray(c), fmt_acc)
    out = sequential_fma_codes(ac, bc, cc, fmt_ab, fmt_acc)
    return codes_to_np(np.asarray(out), fmt_acc).astype(np.float64)
