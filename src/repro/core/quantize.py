"""Quantization policies feeding the DPA datapath.

The hardware multiplies raw low-precision operands; software decides how
tensors are scaled into those formats.  We implement the standard
deployment recipe: absmax scaling at per-tensor / per-channel / per-block
granularity, saturating RNE cast into the target format (native XLA
convert for fp16/bf16/fp8/fp4 via ml_dtypes), and straight-through
estimation for training.

All casts preserve the DPA contract: the *product/accumulate* dtype is
always the policy's accumulate format (fp32 by default) — low precision
only ever touches the multiplier inputs, exactly as in the paper.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import ml_dtypes

from .formats import get_format


def _probe_dtype(dt):
    """Return dt if this JAX build can actually compute with it, else None
    (jax 0.4.x predates native float4 support; ml_dtypes has the dtype but
    jnp refuses it as an array dtype)."""
    if dt is None:
        return None
    try:
        jnp.zeros((1,), dt)
        return dt
    except (TypeError, ValueError):
        return None


_FP4_NATIVE = _probe_dtype(getattr(jnp, "float4_e2m1fn", None)) \
    or _probe_dtype(getattr(ml_dtypes, "float4_e2m1fn", None))

# FloatFormat -> native jnp storage dtype (None: emulated via uint8 codes)
_JNP_DTYPE = {
    "fp32": jnp.float32,
    "fp16": jnp.float16,
    "bf16": jnp.bfloat16,
    "fp8_e4m3": jnp.float8_e4m3fn,
    "fp8_e5m2": jnp.float8_e5m2,
    "fp4_e2m1": _FP4_NATIVE,
}


def has_native_dtype(fmt) -> bool:
    return _JNP_DTYPE[get_format(fmt).name] is not None


def jnp_dtype(fmt) -> jnp.dtype:
    """Storage dtype for fmt.  Emulated sub-byte formats (fp4 on JAX builds
    without float4) store one E2M1 code per uint8 byte — the same container
    ml_dtypes uses — so shape/byte accounting stays identical."""
    dt = _JNP_DTYPE[get_format(fmt).name]
    return jnp.dtype(dt) if dt is not None else jnp.dtype(jnp.uint8)


# -----------------------------------------------------------------------------
# FP4-E2M1 arithmetic encode/decode (TPU-friendly: no gathers, pure jnp,
# usable inside Pallas kernels).  Shared by the quantizers, the matmul
# kernels, and the emulated cast path below.
# -----------------------------------------------------------------------------

def encode_fp4(x):
    """f32 values (pre-clipped to [-6, 6]) -> uint8 E2M1 codes, RNE.

    The representable magnitudes are 0, .5, 1, 1.5, 2, 3, 4, 6; rounding is
    via midpoint thresholds with ties-to-even baked into the <=/< choices."""
    s = (x < 0).astype(jnp.uint8)
    a = jnp.abs(x)
    code = jnp.zeros(x.shape, jnp.uint8)
    mags = [0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0]
    for i in range(1, 8):
        mid = 0.5 * (mags[i - 1] + mags[i])
        even_low = (i - 1) % 2 == 0
        take = (a > mid) if even_low else (a >= mid)
        code = jnp.where(take, jnp.uint8(i), code)
    return code | (s << 3)


def decode_fp4(codes):
    """uint8 E2M1 codes -> exact f32 values.

    value = (-1)^s * (e==0 ? m/2 : (1+m/2) * 2^(e-1)) — arithmetic decode,
    no lookup table."""
    c = codes.astype(jnp.int32)
    s = (c >> 3) & 1
    e = (c >> 1) & 3
    m = (c & 1).astype(jnp.float32)
    mag = jnp.where(e == 0, 0.5 * m,
                    (1.0 + 0.5 * m) * jnp.exp2((e - 1).astype(jnp.float32)))
    return jnp.where(s == 1, -mag, mag)


def absmax_block_scale(xb, target: float, *, axis=1):
    """The kernels' VMEM scale recipe: absmax/target with the eps and
    f32-normal floors — `compute_scale` restated for a resident block with
    a static Python-float target (Pallas-safe, shared by the quantize and
    fused-matmul kernels and their references so their bit contract cannot
    drift)."""
    amax = jnp.max(jnp.abs(xb), axis=axis, keepdims=True)
    return jnp.maximum(jnp.maximum(amax, 1e-30) / target, 2.0 ** -126)


def quant_rows_grid(x, fmt, *, axis=-1):
    """Absmax-quantize along `axis` onto fmt's value grid.

    -> (values-on-the-grid f32, f32 scale with `axis` kept) such that
    grid * scale is the dequantized tensor.  This is the operand recipe the
    DPA attention path shares between the Pallas kernels, the jnp fallback,
    the quantized KV cache, and the `kernels.ref` oracles — one definition
    so their bit contract cannot drift.  fmt "fp32" is the identity
    (grid = x, scale = 1): the disabled-path contract of the attention ops.
    """
    fmt = get_format(fmt)
    xf = x.astype(jnp.float32)
    if fmt.name == "fp32":
        return xf, jnp.ones(jnp.max(xf, axis=axis, keepdims=True).shape,
                            jnp.float32)
    target = fmt.quant_target
    scale = absmax_block_scale(xf, target, axis=axis)
    y = jnp.clip(xf / scale, -target, target)
    if fmt.name == "fp4_e2m1":
        grid = decode_fp4(encode_fp4(y))
    else:
        grid = y.astype(jnp_dtype(fmt)).astype(jnp.float32)
    return grid, scale


def compute_scale(x, fmt, *, axis=None, keepdims=True, eps=1e-30):
    """absmax / max_finite scale so that x/scale fits fmt's range.

    Clamped to the fp32 normal range so wide-range target formats (bf16,
    whose max_finite ~ 3.4e38) cannot underflow the scale to zero."""
    fmt = get_format(fmt)
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=keepdims)
    scale = jnp.maximum(amax, eps).astype(jnp.float32) / fmt.quant_target
    return jnp.maximum(scale, jnp.float32(2.0) ** -126)


def cast_to(x, fmt):
    """Saturating RNE cast into fmt's native dtype (no scaling).

    When the format has no native dtype in this JAX build (fp4 on 0.4.x)
    the cast is emulated: values are RNE-rounded onto the E2M1 grid and
    returned as f32 — bit-identical values, wide container.  Use
    `encode_fp4` directly when the uint8 code representation is wanted."""
    fmt = get_format(fmt)
    xf = x.astype(jnp.float32)
    xf = jnp.clip(xf, -fmt.max_finite, fmt.max_finite)
    dt = _JNP_DTYPE[fmt.name]
    if dt is None:
        return decode_fp4(encode_fp4(xf))
    return xf.astype(dt)


def quantize(x, fmt, *, axis=None):
    """-> (q: fmt dtype, scale: f32 broadcastable). axis=None: per-tensor;
    int/tuple: reduce over that axis (per-channel over the others)."""
    fmt = get_format(fmt)
    scale = compute_scale(x, fmt, axis=axis)
    q = cast_to(x.astype(jnp.float32) / scale, fmt)
    return q, scale


def quantize_blockwise(x, fmt, *, axis, block):
    """Per-block scales along `axis` (block must divide the dim).  Returns
    (q, scale) with scale shaped like x but with `axis` reduced per block
    and kept broadcastable after `dequantize_blockwise`."""
    fmt = get_format(fmt)
    axis = axis % x.ndim
    d = x.shape[axis]
    if d % block:
        raise ValueError(f"block {block} does not divide dim {d}")
    shp = x.shape[:axis] + (d // block, block) + x.shape[axis + 1:]
    xb = x.reshape(shp)
    scale = compute_scale(xb, fmt, axis=axis + 1)
    q = cast_to(xb.astype(jnp.float32) / scale, fmt)
    return q.reshape(x.shape), scale  # scale: (..., d//block, 1, ...)


def dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def dequantize_blockwise(q, scale, *, axis, block):
    axis = axis % q.ndim
    d = q.shape[axis]
    shp = q.shape[:axis] + (d // block, block) + q.shape[axis + 1:]
    return (q.reshape(shp).astype(jnp.float32) * scale).reshape(q.shape)


def quant_dequant(x, fmt, *, axis=None, block=None):
    fmt = get_format(fmt)
    if fmt.name == "fp32":
        return x
    if block is not None and axis is not None:
        q, s = quantize_blockwise(x, fmt, axis=axis, block=block)
        return dequantize_blockwise(q, s, axis=axis, block=block).astype(x.dtype)
    q, s = quantize(x, fmt, axis=axis)
    return dequantize(q, s).astype(x.dtype)


def fake_quant(x, fmt, *, axis=None, block=None):
    """Straight-through-estimated quantization: forward = quant-dequant,
    backward = identity.  This is how DPA formats enter the training graph
    (weights/activations are *represented* low precision; gradients flow in
    the accumulate format — the paper's stability contract)."""
    fmt = get_format(fmt)
    if fmt.name == "fp32":
        return x
    qdq = quant_dequant(x, fmt, axis=axis, block=block)
    return x + jax.lax.stop_gradient(qdq - x)
