"""Quantization policies feeding the DPA datapath.

The hardware multiplies raw low-precision operands; software decides how
tensors are scaled into those formats.  We implement the standard
deployment recipe: absmax scaling at per-tensor / per-channel / per-block
granularity, saturating RNE cast into the target format (native XLA
convert for fp16/bf16/fp8/fp4 via ml_dtypes), and straight-through
estimation for training.

All casts preserve the DPA contract: the *product/accumulate* dtype is
always the policy's accumulate format (fp32 by default) — low precision
only ever touches the multiplier inputs, exactly as in the paper.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .formats import FloatFormat, get_format

# FloatFormat -> native jnp storage dtype
_JNP_DTYPE = {
    "fp32": jnp.float32,
    "fp16": jnp.float16,
    "bf16": jnp.bfloat16,
    "fp8_e4m3": jnp.float8_e4m3fn,
    "fp8_e5m2": jnp.float8_e5m2,
    "fp4_e2m1": jnp.float4_e2m1fn,
}


def jnp_dtype(fmt) -> jnp.dtype:
    return _JNP_DTYPE[get_format(fmt).name]


def compute_scale(x, fmt, *, axis=None, keepdims=True, eps=1e-30):
    """absmax / max_finite scale so that x/scale fits fmt's range.

    Clamped to the fp32 normal range so wide-range target formats (bf16,
    whose max_finite ~ 3.4e38) cannot underflow the scale to zero."""
    fmt = get_format(fmt)
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=keepdims)
    scale = jnp.maximum(amax, eps).astype(jnp.float32) / fmt.quant_target
    return jnp.maximum(scale, jnp.float32(2.0) ** -126)


def cast_to(x, fmt):
    """Saturating RNE cast into fmt's native dtype (no scaling)."""
    fmt = get_format(fmt)
    xf = x.astype(jnp.float32)
    xf = jnp.clip(xf, -fmt.max_finite, fmt.max_finite)
    return xf.astype(jnp_dtype(fmt))


def quantize(x, fmt, *, axis=None):
    """-> (q: fmt dtype, scale: f32 broadcastable). axis=None: per-tensor;
    int/tuple: reduce over that axis (per-channel over the others)."""
    fmt = get_format(fmt)
    scale = compute_scale(x, fmt, axis=axis)
    q = cast_to(x.astype(jnp.float32) / scale, fmt)
    return q, scale


def quantize_blockwise(x, fmt, *, axis, block):
    """Per-block scales along `axis` (block must divide the dim).  Returns
    (q, scale) with scale shaped like x but with `axis` reduced per block
    and kept broadcastable after `dequantize_blockwise`."""
    fmt = get_format(fmt)
    axis = axis % x.ndim
    d = x.shape[axis]
    if d % block:
        raise ValueError(f"block {block} does not divide dim {d}")
    shp = x.shape[:axis] + (d // block, block) + x.shape[axis + 1:]
    xb = x.reshape(shp)
    scale = compute_scale(xb, fmt, axis=axis + 1)
    q = cast_to(xb.astype(jnp.float32) / scale, fmt)
    return q.reshape(x.shape), scale  # scale: (..., d//block, 1, ...)


def dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def dequantize_blockwise(q, scale, *, axis, block):
    axis = axis % q.ndim
    d = q.shape[axis]
    shp = q.shape[:axis] + (d // block, block) + q.shape[axis + 1:]
    return (q.reshape(shp).astype(jnp.float32) * scale).reshape(q.shape)


def quant_dequant(x, fmt, *, axis=None, block=None):
    fmt = get_format(fmt)
    if fmt.name == "fp32":
        return x
    if block is not None and axis is not None:
        q, s = quantize_blockwise(x, fmt, axis=axis, block=block)
        return dequantize_blockwise(q, s, axis=axis, block=block).astype(x.dtype)
    q, s = quantize(x, fmt, axis=axis)
    return dequantize(q, s).astype(x.dtype)


def fake_quant(x, fmt, *, axis=None, block=None):
    """Straight-through-estimated quantization: forward = quant-dequant,
    backward = identity.  This is how DPA formats enter the training graph
    (weights/activations are *represented* low precision; gradients flow in
    the accumulate format — the paper's stability contract)."""
    fmt = get_format(fmt)
    if fmt.name == "fp32":
        return x
    qdq = quant_dequant(x, fmt, axis=axis, block=block)
    return x + jax.lax.stop_gradient(qdq - x)
