"""Execution-plan layer — one dispatch seam for every DPA-shaped op.

TransDot's hardware routes every Table-I mode through a *single
reconfigurable datapath* selected by the mode register; FPnew gets the
same effect from an operation-group hierarchy behind one dispatch
interface.  This module is the software analogue of that seam: a
declarative routing table keyed on

    (op, policy mode bits, shape/alignment predicates, backend)

whose entries are registered by the kernel modules themselves
(`repro.kernels.registry`), each with an explicit lowering predicate and
a reference fallback.  `resolve(op, policy, **ctx)` replaces every
scattered ``if use_kernel and Sq > 1 and ...`` branch that used to live
in `core.linear`, `models.layers`, `models.decode_attn`, and
`launch.engine`: call sites ask the table which route serves their
(policy, shapes) and run it — adding a kernel is one `register()` call,
not a cross-cutting edit.

Measured tuning: when `REPRO_TUNED_DB` names a measurement database
(built by `tools/tune.py`; see `repro.runtime.tuner`), `resolve`
consults it *after* computing the static priority-order choice — the
untuned prior.  A tuned selection may only move the resolution within
the prior's reference family (routes pinned against the same fallback),
so any tuned table preserves the table's numerics contract; unmeasured
(op, policy, shape-class) keys, ineligible tuned routes, and corrupt DB
entries all fall back to the prior.  `REPRO_TUNED=0` is the kill
switch.  `describe()` states whether a resolution was ``tuned`` or
``prior``.

Ops routed here:

  matmul          x @ w under the DPA contract (`core.linear.dpa_dot`)
  grouped_matmul  per-expert einsum matmuls (grouped linear / MoE)
  flash_attn      full-sequence attention (`models.layers._sdpa`)
  decode_attn     single-token decode over the contiguous quantized cache
  paged_decode    single-token decode over the paged cache (block table)
  verify_attn     S_q causal query tokens over the paged cache (the
                  speculative-decoding verify pass)
  quantize_pack   fused row quantization (+fp4 nibble pack)

Every resolved plan is introspectable: `describe(op, policy, **ctx)`
returns the op, the selected route, each candidate's predicate results,
and a bytes-moved estimate, so serve/engine reports and `hlo_analysis`
can state which kernel actually ran (`tools/plan_table.py` prints the
whole table).  Resolution is deterministic: candidates are ordered by
(priority desc, name), the first fully-eligible entry wins, and every op
carries a reference fallback whose predicate only checks semantic
viability — `resolve` never silently picks between equals.
"""
from __future__ import annotations

import dataclasses
import importlib
import os
from typing import Callable, Optional

from .policy import get_policy


class PlanError(ValueError):
    """No registered route can serve (op, policy, shapes)."""


@dataclasses.dataclass(frozen=True)
class PlanEntry:
    """One row of the routing table.

    predicate(policy, ctx) returns a dict of named boolean predicate
    results; the route is eligible iff all are True.  `run` is the route
    implementation (signature is per-op, uniform across the op's
    routes).  `reference` names the op's fallback route this entry is
    pinned against, at `tol` max-abs error (0.0 = bit-identical;
    `tests/test_exec_plan.py` enforces the pin for every route).
    `tests` names the tier-1 tests exercising the route —
    `tools/plan_table.py` fails CI when a registered route names none.
    `knobs` names the tunable keyword arguments the route's `run`
    exposes (kernel block shapes); `repro.runtime.tuner` sweeps them and
    `tools/plan_table.py --check` fails CI when a run signature exposes
    a knob the tuner's config space does not know.
    """
    op: str
    name: str
    backend: str                       # "pallas" | "xla"
    run: Callable
    predicate: Callable                # (policy, ctx) -> {bit: bool}
    priority: int = 0
    reference: Optional[str] = None    # route name of the fallback
    tol: float = 0.0                   # pinned max-abs error vs reference
    bytes_moved: Optional[Callable] = None   # (policy, ctx) -> int
    tests: tuple = ()
    note: str = ""
    knobs: tuple = ()                  # tunable kwarg names of `run`
    # -- tuned-resolution provenance (set only on entries minted by the
    #    tuner; registered table rows always carry the defaults) --
    tuned: bool = False
    tuned_class: str = ""              # shape-class the measurement keyed on
    tuned_knobs: tuple = ()            # sorted ((knob, value), ...) applied

    def eligible(self, policy, ctx) -> bool:
        return all(self.predicate(policy, ctx).values())

    def describe(self, policy, ctx) -> dict:
        bm = self.bytes_moved(policy, ctx) if self.bytes_moved else None
        d = {"op": self.op, "route": self.name, "backend": self.backend,
             "predicates": self.predicate(policy, ctx),
             "bytes_moved": bm, "reference": self.reference,
             "tol": self.tol,
             "selection": "tuned" if self.tuned else "prior"}
        if self.tuned:
            d["shape_class"] = self.tuned_class
            d["tuned_knobs"] = dict(self.tuned_knobs)
        return d


_TABLE: dict[str, list[PlanEntry]] = {}
_BACKENDS_LOADED = False


def register(op: str, name: str, *, backend: str, run: Callable,
             predicate: Callable = None, priority: int = 0,
             reference: Optional[str] = None, tol: float = 0.0,
             bytes_moved: Optional[Callable] = None, tests: tuple = (),
             note: str = "", knobs: tuple = ()) -> PlanEntry:
    """Add one route to the table (kernel modules call this at import).

    Duplicate (op, name) registrations are an error — the table is the
    single source of truth and must stay deterministic."""
    rows = _TABLE.setdefault(op, [])
    if any(e.name == name for e in rows):
        raise ValueError(f"route {op}/{name} registered twice")
    entry = PlanEntry(op=op, name=name, backend=backend, run=run,
                      predicate=predicate or (lambda policy, ctx: {}),
                      priority=priority, reference=reference, tol=tol,
                      bytes_moved=bytes_moved, tests=tuple(tests),
                      note=note, knobs=tuple(knobs))
    rows.append(entry)
    rows.sort(key=lambda e: (-e.priority, e.name))
    return entry


def _ensure_backends() -> None:
    """Import the kernel registry exactly once, on first resolution.

    This one lazy import is the whole layer's deferred dependency — it
    replaces the per-function `from repro.kernels import ops as kops`
    imports the call sites used to carry to dodge import cycles."""
    global _BACKENDS_LOADED
    if not _BACKENDS_LOADED:
        # flag flips only after a *successful* import: a failed registry
        # import (broken dependency) must surface again on the next
        # resolve, not decay into "unknown op" against an empty table.
        # No recursion risk — nothing resolves during registration.
        importlib.import_module("repro.kernels.registry")
        _BACKENDS_LOADED = True


def candidates(op: str) -> list:
    """All registered routes for `op`, in resolution order."""
    _ensure_backends()
    if op not in _TABLE:
        raise PlanError(f"unknown op {op!r}; registered: {sorted(_TABLE)}")
    return list(_TABLE[op])


def ops() -> list:
    """All op names with registered routes."""
    _ensure_backends()
    return sorted(_TABLE)


def route(op: str, name: str) -> PlanEntry:
    """Fetch one route by name (tests/benchmarks pin specific routes)."""
    for e in candidates(op):
        if e.name == name:
            return e
    raise PlanError(f"no route {op}/{name}")


def resolve(op: str, policy=None, **ctx) -> PlanEntry:
    """-> the highest-priority eligible route for (op, policy, ctx).

    `ctx` carries the static shape/alignment facts the predicates gate
    on (all python ints/bools/strs, so resolution is trace-time-stable
    under jit).  When `REPRO_TUNED_DB` is set the measurement database
    may override the static choice within its reference family (see the
    module docstring); without it resolution is exactly the priority
    scan.  Raises `PlanError` — with every candidate's predicate
    results — when nothing can serve the request."""
    policy = get_policy(policy if policy is not None else "fp32")
    for entry in candidates(op):
        if entry.eligible(policy, ctx):
            tuned = _tuned_choice(op, policy, ctx, entry)
            return tuned if tuned is not None else entry
    tried = {e.name: e.predicate(policy, ctx) for e in _TABLE[op]}
    raise PlanError(f"no {op} route serves policy={policy} ctx={ctx}; "
                    f"predicates: {tried}")


def _tuned_choice(op: str, policy, ctx: dict, static: PlanEntry):
    """Consult the measurement DB for (op, policy, ctx); None -> prior.

    Every failure mode — no DB, kill switch, unmeasured key, unknown or
    ineligible tuned route, corrupt DB — degrades to the static prior;
    tuning must never make a resolvable request unresolvable."""
    if os.environ.get("REPRO_TUNED", "1") == "0":
        return None
    db_path = os.environ.get("REPRO_TUNED_DB", "")
    if not db_path:
        return None
    from repro.runtime import tuner
    try:
        return tuner.tuned_entry(db_path, op, policy, ctx, static)
    except Exception as exc:  # noqa: BLE001 — corrupt DB must not break resolve
        tuner.warn_once(f"tuned lookup failed for {op}: {exc!r}; "
                        "falling back to priority order")
        return None


def describe(op: str, policy=None, **ctx) -> dict:
    """Introspect a resolution: selected route + every candidate's
    predicate results + the selected route's bytes-moved estimate."""
    policy = get_policy(policy if policy is not None else "fp32")
    entry = resolve(op, policy, **ctx)
    return dict(entry.describe(policy, ctx),
                candidates={e.name: e.predicate(policy, ctx)
                            for e in candidates(op)})


def reference_entry(entry: PlanEntry) -> Optional[PlanEntry]:
    """The fallback route `entry` is pinned against (None for the
    reference itself)."""
    if entry.reference is None:
        return None
    return route(entry.op, entry.reference)
