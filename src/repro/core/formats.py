"""Floating-point format descriptors and bit-level decode/encode.

These are the formats of TransDot Table I (plus BF16 / FP8-E5M2 which the
quantization policy layer also offers):

    FP32  E8M23   IEEE-754 binary32
    FP16  E5M10   IEEE-754 binary16
    BF16  E8M7    bfloat16
    FP8   E4M3    OCP FP8 E4M3 ("fn": no infinities, NaN = S.1111.111)
    FP8   E5M2    OCP FP8 E5M2 (IEEE-like specials)
    FP4   E2M1    OCP FP4 E2M1 (no infinities, no NaN)

Decode produces a uniform unpacked representation used by the DPA golden
model (`repro.core.dpa`):

    value = (-1)^sign * mant * 2^(exp - man_bits)

where ``mant`` carries the implicit bit for normals (``mant ∈ [2^m, 2^{m+1})``)
and the raw fraction for subnormals (``exp`` pinned at ``1 - bias``).  All
arithmetic is plain jnp integer ops so the decoder runs under jit/vmap and
inside Pallas interpret mode.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import ml_dtypes
import numpy as np


@dataclasses.dataclass(frozen=True)
class FloatFormat:
    name: str
    exp_bits: int
    man_bits: int
    has_inf: bool = True
    # "ieee": exp==all-ones encodes inf (mant==0) / NaN (mant!=0)
    # "fn":   no inf; only exp==all-ones & mant==all-ones is NaN (OCP E4M3)
    # "none": every code is finite (OCP E2M1)
    special: str = "ieee"
    ml_dtype: Optional[np.dtype] = None

    # ---- derived quantities -------------------------------------------------
    @property
    def bits(self) -> int:
        return 1 + self.exp_bits + self.man_bits

    @property
    def bias(self) -> int:
        return (1 << (self.exp_bits - 1)) - 1

    @property
    def precision(self) -> int:
        """p = man_bits + 1 (the paper's ``p``)."""
        return self.man_bits + 1

    @property
    def emin(self) -> int:
        return 1 - self.bias

    @property
    def emax(self) -> int:
        if self.special == "ieee":
            return (1 << self.exp_bits) - 2 - self.bias
        # fn / none formats use the top exponent for finite values
        return (1 << self.exp_bits) - 1 - self.bias

    @property
    def max_finite(self) -> float:
        if self.special == "ieee":
            frac = 2.0 - 2.0 ** (-self.man_bits)
        elif self.special == "fn":
            # all-ones exponent, mantissa all-ones reserved for NaN
            frac = 2.0 - 2.0 ** (-self.man_bits) * 2.0
        else:  # none
            frac = 2.0 - 2.0 ** (-self.man_bits)
        return frac * 2.0 ** self.emax

    @property
    def min_subnormal(self) -> float:
        return 2.0 ** (self.emin - self.man_bits)

    @property
    def quant_target(self) -> float:
        """absmax target for quantization scaling.  Capped at 2^14 so that
        wide-range formats (bf16/fp16) don't scale operands into a range
        where fp32-accumulated dot products overflow — narrow formats use
        their full range (fp8 448, fp4 6), matching deployment practice."""
        return min(self.max_finite, 2.0 ** 14)

    # masks
    @property
    def exp_mask(self) -> int:
        return (1 << self.exp_bits) - 1

    @property
    def man_mask(self) -> int:
        return (1 << self.man_bits) - 1

    def code_dtype(self):
        return jnp.uint32 if self.bits > 16 else (jnp.uint16 if self.bits > 8 else jnp.uint8)


FP32 = FloatFormat("fp32", 8, 23, ml_dtype=np.dtype(np.float32))
FP16 = FloatFormat("fp16", 5, 10, ml_dtype=np.dtype(np.float16))
BF16 = FloatFormat("bf16", 8, 7, ml_dtype=np.dtype(ml_dtypes.bfloat16))
FP8_E4M3 = FloatFormat("fp8_e4m3", 4, 3, has_inf=False, special="fn",
                       ml_dtype=np.dtype(ml_dtypes.float8_e4m3fn))
FP8_E5M2 = FloatFormat("fp8_e5m2", 5, 2, ml_dtype=np.dtype(ml_dtypes.float8_e5m2))
FP4_E2M1 = FloatFormat("fp4_e2m1", 2, 1, has_inf=False, special="none",
                       ml_dtype=np.dtype(ml_dtypes.float4_e2m1fn))

FORMATS = {f.name: f for f in (FP32, FP16, BF16, FP8_E4M3, FP8_E5M2, FP4_E2M1)}
# Aliases used by configs / CLI flags.
FORMATS.update({"fp8": FP8_E4M3, "fp4": FP4_E2M1})


def get_format(name) -> FloatFormat:
    if isinstance(name, FloatFormat):
        return name
    return FORMATS[name]


# -----------------------------------------------------------------------------
# Decode: code -> (sign, mant, exp, is_zero, is_inf, is_nan)
# -----------------------------------------------------------------------------

def decode(codes, fmt: FloatFormat):
    """Unpack integer codes into sign/significand/exponent fields.

    Returns int32 arrays (int64-safe under x64): ``sign`` in {0,1}, ``mant``
    the integer significand including the implicit bit for normals, ``exp``
    the unbiased exponent such that value = (-1)^s * mant * 2^(exp-man_bits),
    and boolean special flags.
    """
    c = jnp.asarray(codes).astype(jnp.int32)
    sign = (c >> (fmt.exp_bits + fmt.man_bits)) & 1
    e_raw = (c >> fmt.man_bits) & fmt.exp_mask
    frac = c & fmt.man_mask

    is_sub = e_raw == 0
    mant = jnp.where(is_sub, frac, frac | (1 << fmt.man_bits))
    exp = jnp.where(is_sub, fmt.emin, e_raw - fmt.bias)

    is_zero = (e_raw == 0) & (frac == 0)
    mant = jnp.where(is_zero, 0, mant)

    if fmt.special == "ieee":
        top = e_raw == fmt.exp_mask
        is_inf = top & (frac == 0)
        is_nan = top & (frac != 0)
        mant = jnp.where(top, 0, mant)
    elif fmt.special == "fn":
        is_nan = (e_raw == fmt.exp_mask) & (frac == fmt.man_mask)
        is_inf = jnp.zeros_like(is_nan)
        mant = jnp.where(is_nan, 0, mant)
    else:  # none
        is_inf = jnp.zeros(c.shape, bool)
        is_nan = jnp.zeros(c.shape, bool)
    return sign, mant, exp, is_zero, is_inf, is_nan


# -----------------------------------------------------------------------------
# Encode: (sign, mant, exp) -> code, with RNE rounding + subnormal/overflow
# -----------------------------------------------------------------------------

def encode_from_parts(sign, mant, exp, sticky, fmt: FloatFormat):
    """Round-to-nearest-even encode of value = (-1)^s * mant * 2^(exp-man_bits).

    ``mant`` must already be normalized so that the implicit bit sits at
    position ``man_bits + 2``: i.e. mant has exactly man_bits+3 significant
    bits (mantissa | guard | round) for a normal result, with any lower bits
    ORed into the boolean ``sticky``.  This is the post-normalization shape
    the DPA datapath hands to its rounding stage.  Handles subnormal
    underflow, overflow (-> inf or max-finite for non-inf formats), and zero.
    """
    m = fmt.man_bits
    # Current layout: [ 1 . m man bits | G | R ], value = mant * 2^(exp - m - 2)
    # Subnormal: shift right until exp == emin.
    shift = jnp.maximum(0, fmt.emin - exp)
    shift_c = jnp.minimum(shift, m + 4)
    lost = mant & ((1 << shift_c) - 1)
    sticky = sticky | (lost != 0)
    mant = mant >> shift_c
    exp = exp + shift

    # RNE on [man | G | R+sticky]
    guard = (mant >> 1) & 1
    rnd = mant & 1
    keep = mant >> 2
    round_up = guard & (rnd | sticky.astype(mant.dtype) | (keep & 1))
    keep = keep + round_up
    # rounding overflow: mantissa carried out
    carried = keep >> (m + 1) != 0
    keep = jnp.where(carried, keep >> 1, keep)
    exp = jnp.where(carried, exp + 1, exp)

    is_zero = keep == 0
    # Biased exponent: normals get e_raw = exp + bias; subnormal results have
    # no implicit bit at position m -> e_raw 0.
    is_sub = keep < (1 << m)
    e_raw = jnp.where(is_sub | is_zero, 0, exp + fmt.bias)
    frac = keep & fmt.man_mask

    overflow = exp > fmt.emax
    code = (sign << (fmt.exp_bits + fmt.man_bits)) | (e_raw << m) | frac

    if fmt.has_inf:
        inf_code = (sign << (fmt.exp_bits + fmt.man_bits)) | (fmt.exp_mask << m)
        code = jnp.where(overflow, inf_code, code)
    else:
        # saturating encode for inf-less formats
        if fmt.special == "fn":
            max_code = (fmt.exp_mask << m) | (fmt.man_mask - 1)
        else:
            max_code = (fmt.exp_mask << m) | fmt.man_mask
        code = jnp.where(overflow,
                         (sign << (fmt.exp_bits + fmt.man_bits)) | max_code, code)
    code = jnp.where(is_zero, sign << (fmt.exp_bits + fmt.man_bits), code)
    return code


def nan_code(fmt: FloatFormat):
    m = fmt.man_bits
    if fmt.special == "ieee":
        return (fmt.exp_mask << m) | (1 << (m - 1) if m else 0) | (1 if m == 0 else 0)
    if fmt.special == "fn":
        return (fmt.exp_mask << m) | fmt.man_mask
    raise ValueError(f"{fmt.name} has no NaN encoding")


def inf_code(fmt: FloatFormat, sign):
    if not fmt.has_inf:
        raise ValueError(f"{fmt.name} has no inf encoding")
    return (sign << (fmt.exp_bits + fmt.man_bits)) | (fmt.exp_mask << fmt.man_bits)


# -----------------------------------------------------------------------------
# numpy <-> code helpers (test plumbing)
# -----------------------------------------------------------------------------

def np_to_codes(x, fmt: FloatFormat) -> np.ndarray:
    """Bit-cast a numpy array in fmt.ml_dtype to integer codes."""
    x = np.asarray(x, fmt.ml_dtype)
    u = x.view({1: np.uint8, 2: np.uint16, 4: np.uint32}[x.dtype.itemsize])
    return u.astype(np.uint32)


def codes_to_np(codes, fmt: FloatFormat) -> np.ndarray:
    codes = np.asarray(codes)
    if fmt.bits > 16:
        return codes.astype(np.uint32).view(np.float32)
    if fmt.bits > 8:
        return codes.astype(np.uint16).view(fmt.ml_dtype)
    # fp8 / fp4 families: ml_dtypes store one value per byte (fp4 uses the
    # low nibble of a byte container)
    return codes.astype(np.uint8).view(fmt.ml_dtype)


def float_to_codes(x, fmt: FloatFormat) -> np.ndarray:
    """Cast float64/float32 numpy data into fmt (RNE, numpy/ml_dtypes) codes."""
    return np_to_codes(np.asarray(x).astype(fmt.ml_dtype), fmt)
