"""Bit packing for sub-byte formats.

TransDot's I/O contract packs operands at format width: FP8 one code per
byte, FP4 two codes per byte (the FP4 DP2 stage consumes 8 operand pairs
= 4 packed bytes per side).  These helpers implement that packing for
storage/transport (checkpoint shards, compressed collectives, kernel
operand layout); they are pure jnp and usable inside Pallas interpret.
"""
from __future__ import annotations

import jax.numpy as jnp

from .formats import FP4_E2M1, FloatFormat, get_format


def pack_fp4(codes):
    """uint8 codes in [0,16) with even last dim -> packed uint8 (low nibble
    = even index, high nibble = odd index)."""
    c = jnp.asarray(codes).astype(jnp.uint8)
    if c.shape[-1] % 2:
        raise ValueError("fp4 packing needs an even trailing dimension")
    lo = c[..., 0::2] & 0xF
    hi = c[..., 1::2] & 0xF
    return lo | (hi << 4)


def unpack_fp4(packed):
    p = jnp.asarray(packed).astype(jnp.uint8)
    lo = p & 0xF
    hi = p >> 4
    out = jnp.stack([lo, hi], axis=-1)
    return out.reshape(p.shape[:-1] + (p.shape[-1] * 2,))


def pack_fp4_axis(codes, axis: int):
    """Pack two E2M1 codes per byte along an arbitrary axis.

    The kernel operand layout: activations pack along their K axis (-1),
    weights along theirs (0), so the matmul BlockSpec moves half the bytes
    and `kernels.dpa_matmul` unpacks nibbles in VMEM."""
    c = jnp.asarray(codes)
    axis = axis % c.ndim
    if axis == c.ndim - 1:
        return pack_fp4(c)
    return jnp.moveaxis(pack_fp4(jnp.moveaxis(c, axis, -1)), -1, axis)


def unpack_fp4_axis(packed, axis: int):
    p = jnp.asarray(packed)
    axis = axis % p.ndim
    if axis == p.ndim - 1:
        return unpack_fp4(p)
    return jnp.moveaxis(unpack_fp4(jnp.moveaxis(p, axis, -1)), -1, axis)


def packed_nbytes(n_elems: int, fmt: FloatFormat) -> int:
    fmt = get_format(fmt)
    if fmt is FP4_E2M1 or fmt.bits == 4:
        return (n_elems + 1) // 2
    return n_elems * ((fmt.bits + 7) // 8)


def operand_nbytes(n_elems: int, fmt: FloatFormat, *, packed: bool = True) -> int:
    """Bytes one operand tensor moves through the fixed-width interface.

    `packed=True` is the TransDot I/O contract (format-width wires: fp4 at
    half a byte per code); `packed=False` is the byte-per-code layout an
    unpacked fp4 operand burns (ml_dtypes container width).  This is the
    quantity the paper's Table I bandwidth story — and our bytes-moved
    benchmark — is about: fp16/fp8/packed-fp4 move 2x/4x/8x fewer operand
    bytes than fp32."""
    fmt = get_format(fmt)
    if fmt.bits == 4 and not packed:
        return n_elems
    return packed_nbytes(n_elems, fmt)


def matmul_operand_bytes(M: int, K: int, N: int, policy) -> dict:
    """Operand-interface bytes for an (M,K)x(K,N) DPA matmul under `policy`
    (quantized operands + their f32 scales), with the f32 baseline and the
    reduction ratio.  Scale vectors use the kernel layout: (M,1) row scales
    and (1,N) column scales.

    fused_quant policies are accounted honestly: their activations traverse
    HBM *raw* (quantization happens in VMEM, scales never leave the chip),
    so the x side is full-width input bytes and only the weight side earns
    a format-width reduction."""
    from .policy import get_policy
    policy = get_policy(policy)
    if policy.fused_quant:
        x_bytes = 4 * M * K
    else:
        x_bytes = operand_nbytes(M * K, policy.fmt_acts,
                                 packed=policy.packed) + 4 * M
    w_bytes = operand_nbytes(K * N, policy.fmt_weights,
                             packed=policy.packed) + 4 * N
    f32 = 4 * (M * K + K * N)
    total = x_bytes + w_bytes
    return {"x_bytes": x_bytes, "w_bytes": w_bytes, "total": total,
            "f32_total": f32, "reduction_vs_f32": f32 / total}


def pack_codes(codes, fmt: FloatFormat):
    fmt = get_format(fmt)
    if fmt.bits == 4:
        return pack_fp4(codes)
    if fmt.bits == 8:
        return jnp.asarray(codes).astype(jnp.uint8)
    if fmt.bits == 16:
        return jnp.asarray(codes).astype(jnp.uint16)
    return jnp.asarray(codes).astype(jnp.uint32)


def unpack_codes(packed, fmt: FloatFormat):
    fmt = get_format(fmt)
    if fmt.bits == 4:
        return unpack_fp4(packed)
    return jnp.asarray(packed)
