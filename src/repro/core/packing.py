"""Bit packing for sub-byte formats.

TransDot's I/O contract packs operands at format width: FP8 one code per
byte, FP4 two codes per byte (the FP4 DP2 stage consumes 8 operand pairs
= 4 packed bytes per side).  These helpers implement that packing for
storage/transport (checkpoint shards, compressed collectives, kernel
operand layout); they are pure jnp and usable inside Pallas interpret.
"""
from __future__ import annotations

import jax.numpy as jnp

from .formats import FP4_E2M1, FloatFormat, get_format


def pack_fp4(codes):
    """uint8 codes in [0,16) with even last dim -> packed uint8 (low nibble
    = even index, high nibble = odd index)."""
    c = jnp.asarray(codes).astype(jnp.uint8)
    if c.shape[-1] % 2:
        raise ValueError("fp4 packing needs an even trailing dimension")
    lo = c[..., 0::2] & 0xF
    hi = c[..., 1::2] & 0xF
    return lo | (hi << 4)


def unpack_fp4(packed):
    p = jnp.asarray(packed).astype(jnp.uint8)
    lo = p & 0xF
    hi = p >> 4
    out = jnp.stack([lo, hi], axis=-1)
    return out.reshape(p.shape[:-1] + (p.shape[-1] * 2,))


def packed_nbytes(n_elems: int, fmt: FloatFormat) -> int:
    fmt = get_format(fmt)
    if fmt is FP4_E2M1 or fmt.bits == 4:
        return (n_elems + 1) // 2
    return n_elems * ((fmt.bits + 7) // 8)


def pack_codes(codes, fmt: FloatFormat):
    fmt = get_format(fmt)
    if fmt.bits == 4:
        return pack_fp4(codes)
    if fmt.bits == 8:
        return jnp.asarray(codes).astype(jnp.uint8)
    if fmt.bits == 16:
        return jnp.asarray(codes).astype(jnp.uint16)
    return jnp.asarray(codes).astype(jnp.uint32)


def unpack_codes(packed, fmt: FloatFormat):
    fmt = get_format(fmt)
    if fmt.bits == 4:
        return unpack_fp4(packed)
    return jnp.asarray(packed)
