"""DPALinear — every matmul in the framework goes through here.

Forward contract (the paper's Table I):  y = sum_k q(x)_k * q(w)_k + c
with products in the operand format and accumulation in fp32 (or fp16).
Which execution route serves a given call — plain f32 dot, STE
fake-quant (training), native-narrow-weight dot (serving), or one of the
Pallas kernel pipelines (packed / fused-quant) — is decided by the
execution-plan layer: `dpa_dot` asks `core.exec_plan.resolve("matmul",
policy, ...)` and runs the winning route.  The routes themselves and
their lowering predicates live in `repro.kernels.registry`; this module
keeps only the parameter plumbing and dtype guards.

Parameters are plain pytrees ({"w": ..., "b": ...}); the module system in
`repro.models` composes these functions.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from . import exec_plan
from .policy import TransPrecisionPolicy, get_policy


def init_linear(key, d_in: int, d_out: int, *, bias: bool = False,
                dtype=jnp.float32, scale: Optional[float] = None):
    wkey, _ = jax.random.split(key)
    s = scale if scale is not None else d_in ** -0.5
    params = {"w": (jax.random.normal(wkey, (d_in, d_out), jnp.float32) * s
                    ).astype(dtype)}
    if bias:
        params["b"] = jnp.zeros((d_out,), dtype)
    return params


# jnp dtypes whose arrays are accepted *as-is* as pre-quantized weights.
# (float4 only exists on newer JAX builds; on 0.4.x fp4 weights are uint8
# codes and ride the kernel path instead.)
NATIVE_NARROW = ("float8_e4m3fn", "float8_e5m2", "float4_e2m1fn")
_NATIVE_NARROW = NATIVE_NARROW


def dpa_dot(x, w, policy: TransPrecisionPolicy):
    """The DPA execution contract for x @ w (contraction on last/first)."""
    policy = get_policy(policy)
    entry = exec_plan.resolve("matmul", policy, w_dtype=str(w.dtype),
                              m=int(jnp.size(x) // x.shape[-1]),
                              k=x.shape[-1], n=w.shape[-1])
    return entry.run(x, w, policy)


# grouped einsums the Pallas grouped-DPA pipelines understand as a stack
# of per-expert (M,K)x(K,N) products.  Anything else falls back to the
# XLA grouped routes (the registry predicates gate on this tuple).
GROUPED_EQS = ("gti,gio->gto", "becd,edf->becf")


def grouped_dims(eq: str, x_shape, w_shape):
    """(experts, per-expert M, K, N) for a known grouped einsum, else
    None.  "becd,edf->becf" folds the batch dim into per-expert rows
    (M = B*C), matching the pipeline's normalized (E,M,K) view."""
    if eq == "gti,gio->gto":
        return x_shape[0], x_shape[1], x_shape[2], w_shape[2]
    if eq == "becd,edf->becf":
        b, e, c, d = x_shape
        return e, b * c, d, w_shape[2]
    return None


def dpa_grouped_dot(x, w, policy: TransPrecisionPolicy, *, eq: str):
    """The grouped (per-expert) DPA contract: einsum `eq` over x and the
    stacked expert weights w, routed through the plan layer."""
    policy = get_policy(policy)
    dims = grouped_dims(eq, x.shape, w.shape)
    ctx = {} if dims is None else dict(zip(("e", "m", "k", "n"), map(int,
                                                                     dims)))
    entry = exec_plan.resolve("grouped_matmul", policy,
                              w_dtype=str(w.dtype), eq=eq, **ctx)
    return entry.run(x, w, policy, eq=eq)


def apply_linear(params, x, policy: TransPrecisionPolicy = None):
    policy = get_policy(policy or "fp32")
    w = params["w"]
    if w.dtype == jnp.uint8:
        # fp4 E2M1 *code* weights (the storage dtype on JAX builds without
        # native float4).  Casting codes 0..15 to floats would silently
        # produce garbage — code-weight serving needs the kernel path with
        # explicit scales, which plain params don't carry.
        raise TypeError(
            "apply_linear got uint8 code weights; store fp4 weights as "
            "floats (fake-quant / kernel policies quantize them) or drive "
            "repro.kernels.ops.dpa_matmul with explicit scales")
    if str(w.dtype) not in _NATIVE_NARROW:
        w = w.astype(x.dtype)
    y = dpa_dot(x, w, policy)
    if "b" in params:
        y = y + params["b"].astype(y.dtype)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# grouped (expert) linear for MoE: contraction per expert
# ---------------------------------------------------------------------------

def init_grouped_linear(key, n_groups: int, d_in: int, d_out: int, *,
                        dtype=jnp.float32):
    s = d_in ** -0.5
    w = jax.random.normal(key, (n_groups, d_in, d_out), jnp.float32) * s
    return {"w": w.astype(dtype)}


def apply_grouped_linear(params, x, policy: TransPrecisionPolicy = None):
    """x: (n_groups, tokens, d_in) -> (n_groups, tokens, d_out)."""
    policy = get_policy(policy or "fp32")
    return dpa_grouped_dot(x, params["w"], policy, eq="gti,gio->gto")
