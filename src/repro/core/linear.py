"""DPALinear — every matmul in the framework goes through here.

Forward contract (the paper's Table I):  y = sum_k q(x)_k * q(w)_k + c
with products in the operand format and accumulation in fp32 (or fp16).
Three execution paths, selected by the policy:

  fp32        : plain dot (DPA disabled / baseline).
  fake-quant  : STE quant-dequant of both operands + fp32-accumulated dot.
                This is the *training* path — numerics match the hardware
                contract (operands carry format precision, accumulation is
                wide) while gradients flow.
  kernel      : Pallas `dpa_matmul` (serving / TPU path; interpret-mode on
                CPU).  The policy's `packed` / `fused_quant` bits select
                the packed-fp4 operand layout and the fused in-kernel
                quantize prologue (see `repro.kernels.ops.dpa_matmul`).

Parameters are plain pytrees ({"w": ..., "b": ...}); the module system in
`repro.models` composes these functions.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .policy import TransPrecisionPolicy, get_policy
from .quantize import fake_quant


def init_linear(key, d_in: int, d_out: int, *, bias: bool = False,
                dtype=jnp.float32, scale: Optional[float] = None):
    wkey, _ = jax.random.split(key)
    s = scale if scale is not None else d_in ** -0.5
    params = {"w": (jax.random.normal(wkey, (d_in, d_out), jnp.float32) * s
                    ).astype(dtype)}
    if bias:
        params["b"] = jnp.zeros((d_out,), dtype)
    return params


# jnp dtypes whose arrays are accepted *as-is* as pre-quantized weights.
# (float4 only exists on newer JAX builds; on 0.4.x fp4 weights are uint8
# codes and ride the kernel path instead.)
NATIVE_NARROW = ("float8_e4m3fn", "float8_e5m2", "float4_e2m1fn")
_NATIVE_NARROW = NATIVE_NARROW


def dpa_dot(x, w, policy: TransPrecisionPolicy):
    """The DPA execution contract for x @ w (contraction on last/first)."""
    policy = get_policy(policy)
    acc_t = jnp.float32 if policy.accum == "fp32" else jnp.float16
    if str(w.dtype) in _NATIVE_NARROW:
        # pre-quantized weights (serving): keep them NATIVE in the dot —
        # fp8 x fp8 -> fp32 is the MXU DPA path itself, and it leaves no
        # whole-stack weight convert for XLA to hoist out of the layer
        # scan (measured 13.7 GiB on dbrx decode; EXPERIMENTS.md §Perf).
        from .quantize import cast_to, compute_scale
        sx = compute_scale(x, policy.fmt_acts, axis=-1)
        xq = cast_to(x.astype(jnp.float32) / sx, policy.fmt_acts)
        out = jnp.dot(xq, w, preferred_element_type=jnp.float32)
        return out * sx
    if not policy.enabled:
        return jnp.dot(x, w, preferred_element_type=acc_t)
    if policy.use_kernel:
        from repro.kernels import ops as kops
        return kops.dpa_matmul(x, w, policy)
    # fake-quant path: operands at format precision, wide accumulation
    wq = fake_quant(
        w, policy.fmt_weights,
        axis=0 if policy.w_granularity == "per_channel" else None,
        block=policy.block_size if policy.w_granularity == "per_block" else None)
    xq = fake_quant(
        x, policy.fmt_acts,
        axis=-1 if policy.a_granularity == "per_channel" else None,
        block=policy.block_size if policy.a_granularity == "per_block" else None)
    return jnp.dot(xq, wq, preferred_element_type=acc_t)


def apply_linear(params, x, policy: TransPrecisionPolicy = None):
    policy = get_policy(policy or "fp32")
    w = params["w"]
    if w.dtype == jnp.uint8:
        # fp4 E2M1 *code* weights (the storage dtype on JAX builds without
        # native float4).  Casting codes 0..15 to floats would silently
        # produce garbage — code-weight serving needs the kernel path with
        # explicit scales, which plain params don't carry.
        raise TypeError(
            "apply_linear got uint8 code weights; store fp4 weights as "
            "floats (fake-quant / kernel policies quantize them) or drive "
            "repro.kernels.ops.dpa_matmul with explicit scales")
    if str(w.dtype) not in _NATIVE_NARROW:
        w = w.astype(x.dtype)
    y = dpa_dot(x, w, policy)
    if "b" in params:
        y = y + params["b"].astype(y.dtype)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# grouped (expert) linear for MoE: contraction per expert
# ---------------------------------------------------------------------------

def init_grouped_linear(key, n_groups: int, d_in: int, d_out: int, *,
                        dtype=jnp.float32):
    s = d_in ** -0.5
    w = jax.random.normal(key, (n_groups, d_in, d_out), jnp.float32) * s
    return {"w": w.astype(dtype)}


def apply_grouped_linear(params, x, policy: TransPrecisionPolicy = None):
    """x: (n_groups, tokens, d_in) -> (n_groups, tokens, d_out)."""
    policy = get_policy(policy or "fp32")
    w = params["w"]
    acc_t = jnp.float32 if policy.accum == "fp32" else jnp.float16
    if str(w.dtype) in _NATIVE_NARROW:
        from .quantize import cast_to, compute_scale
        sx = compute_scale(x, policy.fmt_acts, axis=-1)
        xq = cast_to(x.astype(jnp.float32) / sx, policy.fmt_acts)
        y = jnp.einsum("gti,gio->gto", xq, w,
                       preferred_element_type=jnp.float32) * sx
        return y.astype(x.dtype)
    w = w.astype(x.dtype)
    if policy.enabled:
        w = fake_quant(w, policy.fmt_weights,
                       axis=1 if policy.w_granularity == "per_channel" else None)
        x = fake_quant(x, policy.fmt_acts)
    y = jnp.einsum("gti,gio->gto", x, w,
                   preferred_element_type=acc_t)
    return y.astype(x.dtype)
