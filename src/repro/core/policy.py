"""Trans-precision execution policy — the software mode register.

The hardware selects an execution mode (Table I) through configuration
signals; the framework selects it through a `TransPrecisionPolicy` carried
by every DPA-shaped op.  A policy names the operand format for weights and
activations, the accumulate format, and the scale granularity.  `dpa_terms`
is the paper's N (how many products the FPU folds per issue) — it drives
the throughput model and the kernel K-packing.
"""
from __future__ import annotations

import dataclasses

from .formats import get_format

# Table I: format -> DPA terms folded into one FP32 accumulation
DPA_TERMS = {"fp32": 1, "bf16": 2, "fp16": 2, "fp8_e4m3": 4, "fp8_e5m2": 4,
             "fp4_e2m1": 8}


@dataclasses.dataclass(frozen=True)
class TransPrecisionPolicy:
    """Per-op trans-precision configuration.

    fmt_weights / fmt_acts: operand formats fed to the multiplier array.
    accum: the accumulate format (Table I column "Accumulate Format").
    granularities: "per_tensor" | "per_channel" | "per_block".
    use_kernel: route through the Pallas dpa_matmul kernel when shapes
    allow (TPU target; interpret-mode on CPU).
    packed: move fp4 operand sides as packed bytes (2 E2M1 codes/byte)
    through the kernel BlockSpec — the paper's format-width I/O contract,
    halving fp4 operand bytes HBM->VMEM.  Bit-identical to unpacked.
    fused_quant: quantize activations *inside* the matmul kernel prologue
    (per-(row, K-block) absmax scales folded into the accumulation) instead
    of a separate XLA pass — no quantized-activation HBM round-trip.
    fmt_attn: operand format for the attention matmuls (QK^T and PV both
    accumulate in f32 over fmt_attn operands; the online-softmax running
    max/sum stay f32).  "fp32" leaves attention on the seed datapath.
    fmt_kv: storage format of the KV cache ("fp32" = raw compute-dtype
    cache).  K/V are dequantized in the kernel prologue, so a narrow cache
    trades per-row scales for 2x/4x/~8x fewer cache bytes per decode step.
    kv_packed: pack fp4 KV codes two per byte along head_dim
    (`core.packing` nibble layout — bit-identical to unpacked).
    """
    fmt_weights: str = "fp32"
    fmt_acts: str = "fp32"
    accum: str = "fp32"
    w_granularity: str = "per_channel"
    a_granularity: str = "per_tensor"
    block_size: int = 128
    use_kernel: bool = False
    packed: bool = False
    fused_quant: bool = False
    fmt_attn: str = "fp32"
    fmt_kv: str = "fp32"
    kv_packed: bool = False

    def __post_init__(self):
        get_format(self.fmt_weights), get_format(self.fmt_acts)
        get_format(self.fmt_attn), get_format(self.fmt_kv)
        if get_format(self.accum).name not in ("fp32", "fp16"):
            raise ValueError("TransDot accumulates into FP32 or FP16")
        if self.fused_quant and not self.use_kernel:
            raise ValueError("fused_quant is a kernel-path feature; set "
                             "use_kernel=True")
        if self.packed and not self.use_kernel:
            raise ValueError("packed operand movement is a kernel-path "
                             "feature; set use_kernel=True")
        if self.packed and not (get_format(self.fmt_weights).bits == 4
                                or get_format(self.fmt_acts).bits == 4):
            raise ValueError("packed storage needs a 4-bit operand format")
        if self.kv_packed and get_format(self.fmt_kv).bits != 4:
            raise ValueError("kv_packed needs a 4-bit fmt_kv")

    @property
    def enabled(self) -> bool:
        return not (self.fmt_weights == "fp32" and self.fmt_acts == "fp32")

    @property
    def attn_enabled(self) -> bool:
        """True when attention runs the DPA path (quantized operands
        and/or a quantized KV cache)."""
        return not (self.fmt_attn == "fp32" and self.fmt_kv == "fp32")

    @property
    def kv_quantized(self) -> bool:
        return self.fmt_kv != "fp32"

    @property
    def dpa_terms(self) -> int:
        """N = products per accumulation issue (min across operand sides)."""
        return min(DPA_TERMS[get_format(self.fmt_weights).name],
                   DPA_TERMS[get_format(self.fmt_acts).name])

    def replace(self, **kw) -> "TransPrecisionPolicy":
        return dataclasses.replace(self, **kw)


# Presets: the paper's four headline modes + bf16 (TPU-native comparison)
POLICIES = {
    "fp32": TransPrecisionPolicy(),
    "bf16_dpa": TransPrecisionPolicy("bf16", "bf16"),
    "fp16_dpa": TransPrecisionPolicy("fp16", "fp16"),
    "fp8_dpa": TransPrecisionPolicy("fp8_e4m3", "fp8_e4m3"),
    "fp4_dpa": TransPrecisionPolicy("fp4_e2m1", "fp8_e4m3"),
    # weight-only variants (serving: weights ride the narrow wires)
    "w8a16": TransPrecisionPolicy("fp8_e4m3", "fp16"),
    "w4a8": TransPrecisionPolicy("fp4_e2m1", "fp8_e4m3"),
    # kernel-path serving modes: packed fp4 operand bytes and/or in-kernel
    # activation quantization (the fused quantize->pack->DPA pipeline)
    "fp8_dpa_fused": TransPrecisionPolicy("fp8_e4m3", "fp8_e4m3",
                                          use_kernel=True, fused_quant=True),
    "fp4_dpa_packed": TransPrecisionPolicy("fp4_e2m1", "fp4_e2m1",
                                           use_kernel=True, packed=True),
    "fp4_dpa_fused": TransPrecisionPolicy("fp4_e2m1", "fp4_e2m1",
                                          use_kernel=True, packed=True,
                                          fused_quant=True),
    "w4a8_packed": TransPrecisionPolicy("fp4_e2m1", "fp8_e4m3",
                                        use_kernel=True, packed=True,
                                        fused_quant=True),
    # DPA-quantized attention: QK^T / PV accumulate f32 over narrow
    # operands; fmt_kv holds the cache at format width (decode bandwidth)
    "attn_fp16_dpa": TransPrecisionPolicy(fmt_attn="fp16", fmt_kv="fp16"),
    "attn_fp8_dpa": TransPrecisionPolicy(fmt_attn="fp8_e4m3",
                                         fmt_kv="fp8_e4m3"),
    "attn_fp4_packed": TransPrecisionPolicy(fmt_attn="fp4_e2m1",
                                            fmt_kv="fp4_e2m1",
                                            kv_packed=True),
    # trans-precision serving sweet spot: fp8 attention arithmetic over a
    # packed-fp4 cache (the w4a8 idea applied to attention operands)
    "kv4_attn8_packed": TransPrecisionPolicy(fmt_attn="fp8_e4m3",
                                             fmt_kv="fp4_e2m1",
                                             kv_packed=True),
    # cache-only compression: attention arithmetic stays f32
    "kv8_attn_f32": TransPrecisionPolicy(fmt_kv="fp8_e4m3"),
    "kv16_attn_f32": TransPrecisionPolicy(fmt_kv="fp16"),
    # self-speculative draft mode: every matmul side (linears AND both
    # attention matmuls) runs fp4-grid operands — the paper's 8-term DPA
    # route end to end — over the same packed-fp4 cache the fp4-KV
    # serving presets keep, so the draft and verify policies share one
    # page pool (serving.spec_decode pairs this with kv4_attn8_packed)
    "w4a4_kv4_attn4": TransPrecisionPolicy("fp4_e2m1", "fp4_e2m1",
                                           fmt_attn="fp4_e2m1",
                                           fmt_kv="fp4_e2m1",
                                           kv_packed=True),
    # fp16-class draft rung over the packed-fp4 cache: fp16 operands on
    # the linears and both attention matmuls (2-term DPA, the most
    # precise Table-I mode above fp32) while KV storage stays fp4 packed
    # — the top of the adaptive draft ladder for fp4-cache serving
    # presets (`repro.runtime.controller.DEFAULT_LADDERS`)
    "w16a16_kv4_attn16": TransPrecisionPolicy("fp16", "fp16",
                                              fmt_attn="fp16",
                                              fmt_kv="fp4_e2m1",
                                              kv_packed=True),
    # full serving path: packed-fp4 weights + fused fp8 activations on the
    # linears, fp8 DPA attention, packed-fp4 KV cache
    "w4a8_kv4_attn8": TransPrecisionPolicy("fp4_e2m1", "fp8_e4m3",
                                           use_kernel=True, packed=True,
                                           fused_quant=True,
                                           fmt_attn="fp8_e4m3",
                                           fmt_kv="fp4_e2m1",
                                           kv_packed=True),
    # all-fp8 serving: fused fp8 kernel linears, fp8 DPA attention, fp8
    # cache — the 4x-vs-f32 operand-byte point on the Table-I ladder (the
    # packed-fp4 preset above is the 8x point)
    "w8a8_kv8_attn8": TransPrecisionPolicy("fp8_e4m3", "fp8_e4m3",
                                           use_kernel=True,
                                           fused_quant=True,
                                           fmt_attn="fp8_e4m3",
                                           fmt_kv="fp8_e4m3"),
}


def get_policy(name) -> TransPrecisionPolicy:
    if isinstance(name, TransPrecisionPolicy):
        return name
    return POLICIES[name]
