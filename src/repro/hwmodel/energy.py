"""Energy model — Table II (post-PnR, 12 nm, 1 GHz, 0.8 V, TT corner).

The measured pJ/FLOP anchors are the model; derived quantities (per-op
energy, efficiency ratios, energy of a GEMM under a policy) are computed
from them.  This is the deployment-facing face of the paper's energy
claim: FP8 DPA costs 0.84 pJ/FLOP vs 3.75 for FP32 scalar — 4.5x — and
FP4 DPA reaches 9.1x.
"""
from __future__ import annotations

from .throughput import MODE_BY_NAME, Mode, gflops

# Table II, column "Energy (pJ/FLOP)"
ENERGY_PJ_PER_FLOP = {
    "fp32_fma_scalar": 3.75,
    "fp16_fma_scalar": 2.76,
    "fp16_fma_simd": 1.85,
    "fp16_dpa_fp32": 1.80,
    "fp8_fma_scalar": 2.21,
    "fp8_fma_simd": 0.84,
    "fp8_dpa_fp32": 0.84,
    "fp4_dpa_fp32": 0.41,
}

# policy format -> Table II DPA mode used for deployment-energy estimates
_POLICY_MODE = {"fp32": "fp32_fma_scalar", "fp16": "fp16_dpa_fp32",
                "bf16": "fp16_dpa_fp32", "fp8_e4m3": "fp8_dpa_fp32",
                "fp8_e5m2": "fp8_dpa_fp32", "fp4_e2m1": "fp4_dpa_fp32"}


def energy_per_flop(mode_name: str) -> float:
    return ENERGY_PJ_PER_FLOP[mode_name]


def energy_per_op(mode_name: str) -> float:
    """pJ per issued FPU op (an op retires 2*ways FLOPs)."""
    mode: Mode = MODE_BY_NAME[mode_name]
    return ENERGY_PJ_PER_FLOP[mode_name] * gflops(mode) / 1.0  # 1 GHz -> per ns


def efficiency_vs_fp32(mode_name: str) -> float:
    return ENERGY_PJ_PER_FLOP["fp32_fma_scalar"] / ENERGY_PJ_PER_FLOP[mode_name]


def gemm_energy_mj(m: int, k: int, n: int, fmt_name: str) -> float:
    """Energy (mJ) of an (m,k)x(k,n) GEMM executed in the given DPA mode."""
    flops = 2.0 * m * k * n
    return flops * ENERGY_PJ_PER_FLOP[_POLICY_MODE[fmt_name]] * 1e-9
