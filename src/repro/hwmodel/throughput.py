"""Throughput / area-efficiency model — Table I, Table II, Fig. 1, Fig. 7a.

The execution contract per mode:

  FPnew (baseline): scalar FMA and packed-SIMD FMA at format width, but
  any *trans-precision* accumulation (low-precision product into FP32)
  issues ONE FMA per cycle — the fixed-width output interface can retire
  only a single high-precision result (paper Fig. 1).

  TransDot: adds N-term DPA (Table I), retiring N MACs per cycle into a
  single FP32/FP16 result through the same interface.

Area efficiency (Fig. 7a) = throughput ratio / area ratio.
"""
from __future__ import annotations

import dataclasses

from .area import (TRANSDOT_AREA_RATIO_MEAN, TRANSDOT_AREA_RATIO_RANGE,
                   transdot_area_ratio)

CLOCK_GHZ = 1.0          # paper's synthesis point
LATENCY_CYCLES = 4       # Table II "Lat"
DPA_EXTRA_STAGE = 1      # §III-B / abstract: +1 pipeline stage in DPA mode


@dataclasses.dataclass(frozen=True)
class Mode:
    name: str
    fmt: str
    kind: str            # "scalar" | "simd" | "dpa"
    ways: int            # lanes (simd) or terms (dpa)
    acc_fmt: str


# Table I (+ Table II rows)
MODES = [
    Mode("fp32_fma_scalar", "fp32", "scalar", 1, "fp32"),
    Mode("fp16_fma_scalar", "fp16", "scalar", 1, "fp16"),
    Mode("fp16_fma_simd", "fp16", "simd", 2, "fp16"),
    Mode("fp16_dpa_fp32", "fp16", "dpa", 2, "fp32"),
    Mode("fp8_fma_scalar", "fp8_e4m3", "scalar", 1, "fp8_e4m3"),
    Mode("fp8_fma_simd", "fp8_e4m3", "simd", 4, "fp8_e4m3"),
    Mode("fp8_dpa_fp32", "fp8_e4m3", "dpa", 4, "fp32"),
    Mode("fp4_dpa_fp32", "fp4_e2m1", "dpa", 8, "fp32"),
]
MODE_BY_NAME = {m.name: m for m in MODES}


def macs_per_cycle(mode: Mode, unit: str = "transdot") -> int:
    """MAC throughput of one FPU issue port."""
    if unit == "transdot":
        return mode.ways
    # FPnew: no DPA; trans-precision accumulate serializes to 1/cycle
    if mode.kind == "dpa":
        return 1
    return mode.ways


def gflops(mode: Mode, unit: str = "transdot") -> float:
    """Table II 'Perf' column: 2 FLOP per MAC at 1 GHz."""
    return 2.0 * macs_per_cycle(mode, unit) * CLOCK_GHZ


def latency_cycles(mode: Mode) -> int:
    return LATENCY_CYCLES  # Table II: 4 for every mode (DPA stage retimed)


def area_efficiency(mode: Mode, *, area_ratio: float = None) -> float:
    """Throughput/area of TransDot relative to FPnew for this mode."""
    r = area_ratio if area_ratio is not None else TRANSDOT_AREA_RATIO_MEAN
    return (macs_per_cycle(mode, "transdot")
            / macs_per_cycle(mode, "fpnew")) / r


def area_efficiency_range(mode: Mode):
    lo, hi = TRANSDOT_AREA_RATIO_RANGE
    return (area_efficiency(mode, area_ratio=hi),
            area_efficiency(mode, area_ratio=lo))


def area_efficiency_at_delay(mode: Mode, delay_ns: float) -> float:
    return area_efficiency(mode, area_ratio=transdot_area_ratio(delay_ns))


# -----------------------------------------------------------------------------
# TPU roofline coupling: the DPA contract changes the *compute* peak the
# same way the paper's Fig. 1 scales FPU throughput.  TPU v5e MXU native
# issue is bf16 (197 TF/s) = the 2-term row; fp8 doubles, fp4 quadruples
# (the paper's 2x/4x/8x are vs FP32 scalar; TPU native width is already
# the 2x point).
# -----------------------------------------------------------------------------

PEAK_SCALE_VS_BF16 = {"fp32": 0.5, "bf16": 1.0, "fp16": 1.0,
                      "fp8_e4m3": 2.0, "fp8_e5m2": 2.0, "fp4_e2m1": 4.0}


def peak_flops_scale(fmt_name: str) -> float:
    return PEAK_SCALE_VS_BF16[fmt_name]
