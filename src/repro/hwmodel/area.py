"""Analytical area models — the paper's §II-B formulas + §III anchors.

Everything here is either (a) a closed-form count the paper derives
(mux counting for the reconfigurable barrel shifter), or (b) a model
calibrated to the paper's synthesis anchor points (FPnew slice breakdown
of Fig. 3, TransDot ratios of Fig. 7a, layout shares of Fig. 7b).
Benchmarks regenerate the tables; tests assert the paper's headline
percentages fall out of the formulas.
"""
from __future__ import annotations

import math

# -----------------------------------------------------------------------------
# §II-B1: reconfigurable barrel shifter mux counts
# -----------------------------------------------------------------------------

def barrel_shifter_muxes(n: int) -> int:
    """Conventional n-bit barrel shifter: log2(n) stages x n 2:1 muxes."""
    return n * int(math.log2(n))


def reconfig_extra_muxes(n: int) -> float:
    """Paper's count of additional muxes for full/half/quarter modes:
    5n/8 + 3*log2(n) - 5."""
    return 5 * n / 8 + 3 * math.log2(n) - 5


def reconfig_overhead(n: int) -> float:
    """Relative area overhead of the reconfigurable shifter (paper: 10.7%
    at n=128, 13.8% at n=64)."""
    return reconfig_extra_muxes(n) / barrel_shifter_muxes(n)


def multilane_muxes(n: int) -> int:
    """FPnew-style lane replication: one full + one half + two quarter
    shifters (the four lanes TransDot's quarter mode replaces)."""
    return (barrel_shifter_muxes(n)
            + barrel_shifter_muxes(n // 2)
            + 2 * barrel_shifter_muxes(n // 4))


def multilane_overhead(n: int) -> float:
    """Paper: ~78.5% for n=128, 75% for n=64."""
    base = barrel_shifter_muxes(n)
    return (multilane_muxes(n) - base) / base


# -----------------------------------------------------------------------------
# Fig. 3: FPnew multi-format FMA slice area breakdown (relative shares).
# Numeric anchors reconstructed from the figure + §II-B text ("shifters
# 15-20%", "multiplier about 30%").
# -----------------------------------------------------------------------------

FPNEW_BREAKDOWN = {
    "mantissa_multiplier": 0.30,
    "alignment_shifter": 0.11,
    "normalization_shifter": 0.08,
    "wide_adder": 0.12,
    "exponent_datapath": 0.08,
    "rounding_special": 0.10,
    "simd_lanes_overhead": 0.13,
    "other": 0.08,
}

# Fig. 7b: TransDot layout shares (given explicitly in the caption).
TRANSDOT_LAYOUT = {
    "multi_mode_multiplier": 0.345,
    "normalization": 0.155,
    "exponent": 0.118,
    "alignment_shifter_adder": 0.181,
    "fp4_dp2": 0.039,
    "others": 0.162,
}

# -----------------------------------------------------------------------------
# §II-B2: multi-mode multiplier structure counts
# -----------------------------------------------------------------------------

def array_multiplier_cells(p: int) -> int:
    """p x p array multiplier: p^2 partial-product cells (AND + CSA)."""
    return p * p


def multimode_multiplier_extra(p: int = 24, segments: int = 4) -> dict:
    """TransDot's additions on top of the partitioned array multiplier
    (Fig. 5): six DPA alignment shifters, six negate units, mode gates.
    Returns structure counts in units of 1-bit cells (model granularity:
    a 2p-bit shifter ~ 2p*log2(2p) mux-cells; negate ~ 2p cells)."""
    sub = p // segments  # 6-bit sub-operands
    pp12 = 8             # 12-bit partial products generated once
    pp24 = 2             # 24-bit partial products
    shifter_cells = 6 * (2 * p) * int(math.log2(2 * p))
    negate_cells = 6 * (2 * p)
    gate_cells = pp12 * 2 * sub + pp24 * 2 * p
    return {"sub_width": sub, "pp12": pp12, "pp24": pp24,
            "dpa_shifter_cells": shifter_cells,
            "dpa_negate_cells": negate_cells,
            "mode_gate_cells": gate_cells}


# -----------------------------------------------------------------------------
# §III-C / Fig. 7a: FPU-level area ratios (synthesis anchors)
# -----------------------------------------------------------------------------

# TransDot/FPnew cell-area ratio across the swept delay targets.
# Mean +37.3%, range +31.8% .. +56.8% (tightest timing replicates logic).
TRANSDOT_AREA_RATIO_MEAN = 1.373
TRANSDOT_AREA_RATIO_RANGE = (1.318, 1.568)
# Merged-SIMD-lanes TransDot (datapath reuse only, no DPA): -9.44%.
MERGED_SIMD_AREA_RATIO = 1.0 - 0.0944


def transdot_area_ratio(delay_ns: float, *, d_knee: float = 1.0,
                        d_tight: float = 0.7) -> float:
    """Area ratio vs delay target: converges to the relaxed-timing ratio
    above the knee and climbs toward the tight-timing ratio below it
    (synthesis replicates/decouples shared datapath segments under
    pressure — §III-A's observed behaviour, applied at FPU level)."""
    lo, hi = TRANSDOT_AREA_RATIO_RANGE
    if delay_ns >= d_knee:
        return lo
    if delay_ns <= d_tight:
        return hi
    t = (d_knee - delay_ns) / (d_knee - d_tight)
    return lo + (hi - lo) * t
