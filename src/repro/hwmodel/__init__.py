"""Analytical hardware model reproducing the paper's evaluation
(Tables I/II, Figs. 3/6/7).  See DESIGN.md §2 — the paper's claims are
synthesis numbers; this package encodes its formulas and anchors so the
benchmarks regenerate every table and the tests assert the headline
results (2x/4x/8x DPA throughput, +37.3% area, 1.46x/2.92x area
efficiency, 10.7%/13.8% shifter overhead, 78.5%/75% multi-lane cost)."""
from . import area, energy, throughput, timing  # noqa: F401
