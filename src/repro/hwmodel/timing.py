"""Area-delay trade-off curves — Fig. 6a (shifters) and Fig. 6b (multipliers).

Synthesis area(delay) is modeled as a paper-anchored curve per design:
flat at its relaxed-timing asymptote, rising as the delay target
approaches the design's minimum achievable delay (the synthesizer trades
area for speed).  All anchor constants come from §III-A/§III-B.
"""
from __future__ import annotations

from .area import barrel_shifter_muxes


def _curve_factor(delay, d_min, *, steep=2.0):
    """Relative synthesis area factor >= 1: 1.0 at relaxed delay, rising
    as the target approaches the design's minimum achievable delay.
    Unachievable targets (delay < d_min) sit on the max-effort wall."""
    d_eff = max(delay, d_min * 1.02)
    k = (d_min * 1.02 * 1.6) / d_eff
    return 1.0 + max(0.0, k - 1.0) ** steep


def _synth_curve(delay, a_relaxed, d_min, *, steep=2.0):
    return a_relaxed * _curve_factor(delay, d_min, steep=steep)


# ---------------------------------------------------------------------------
# Fig. 6a: 100-bit shifters.  Anchors: reconfigurable converges to baseline
# above 400 ps; multi-lane stays 35.8%..67.2% larger; tightening below
# 400 ps drives the reconfigurable design toward the multi-lane area.
# ---------------------------------------------------------------------------

SHIFTER_WIDTH = 100
_S_BASE = barrel_shifter_muxes(128)        # synthesized-cell proxy units
_S_DMIN_PS = 180.0


def shifter_area(delay_ps: float, design: str) -> float:
    base = _synth_curve(delay_ps, _S_BASE, _S_DMIN_PS)
    if design == "single":
        return base
    if design == "multilane":
        lo, hi = 0.358, 0.672
        t = min(1.0, max(0.0, (500.0 - delay_ps) / (500.0 - _S_DMIN_PS)))
        return base * (1.0 + lo + (hi - lo) * t)
    if design == "reconfig":
        # converges to baseline >=400ps; approaches multi-lane when tight
        if delay_ps >= 400.0:
            return base
        t = (400.0 - delay_ps) / (400.0 - _S_DMIN_PS)
        target = shifter_area(delay_ps, "multilane")
        return base + (target - base) * min(1.0, t) ** 2
    raise ValueError(design)


# ---------------------------------------------------------------------------
# Fig. 6b: multipliers.  Anchors (§III-B): combinational TransDot min
# delay 1.38 ns vs separated 1.50 ns; -15.4% area at 1.6 ns.  Pipelined:
# 0.86 vs 0.88 ns; -15.8% area at 1.0 ns.
# ---------------------------------------------------------------------------

_M_BASE = 1000.0


def multiplier_area(delay_ns: float, design: str, *, pipelined: bool) -> float:
    if design == "transdot":
        d_min, a_rel = (0.86, _M_BASE * 1.06) if pipelined else (1.38, _M_BASE)
        return _synth_curve(delay_ns, a_rel, d_min)
    if design == "separated":
        if pipelined:
            d_min, d_anchor, saving = 0.88, 1.0, 0.158
        else:
            d_min, d_anchor, saving = 1.50, 1.6, 0.154
        # calibrate the relaxed asymptote so the paper's saving holds
        # exactly at its anchor delay
        target = multiplier_area(d_anchor, "transdot",
                                 pipelined=pipelined) / (1 - saving)
        a_rel = target / _curve_factor(d_anchor, d_min)
        return _synth_curve(delay_ns, a_rel, d_min)
    raise ValueError(design)


def multiplier_min_delay(design: str, *, pipelined: bool) -> float:
    return {("transdot", False): 1.38, ("separated", False): 1.50,
            ("transdot", True): 0.86, ("separated", True): 0.88}[
        (design, pipelined)]
