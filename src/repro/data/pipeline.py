"""Deterministic, seekable, sharded data pipeline.

Fault-tolerance contract: batch(step) is a pure function of (seed, step,
shard), so restart-from-checkpoint resumes the exact token stream with no
iterator state to persist.  Two sources:

  SyntheticLM  — structured pseudo-text (Zipf unigrams + Markov bigram
                 mixing) so small models show a real decreasing loss.
  MemmapTokens — packed uint16/uint32 token files (production path),
                 sliced per (step, shard) without loading the file.

Both emit {"tokens": (B,S), "labels": (B,S)} with next-token labels, or
stub-modality batches ({"embeddings"/"frames"}) for VLM/audio configs.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    batch: int              # global batch
    seq: int
    seed: int = 0
    kind: str = "synthetic"          # synthetic | memmap
    path: Optional[str] = None       # memmap token file
    n_shards: int = 1
    shard: int = 0
    frontend: str = "none"           # none | stub (emit embeddings)
    d_model: int = 0                 # for stub frontends
    frames: int = 0                  # encdec: encoder length


class SyntheticLM:
    """Zipf-distributed tokens with a deterministic bigram structure: the
    model can learn P(next | cur) so training loss decreases visibly."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        v = cfg.vocab_size
        rng = np.random.default_rng(cfg.seed)
        self._perm = jnp.asarray(rng.permutation(v), jnp.int32)
        ranks = np.arange(1, v + 1, dtype=np.float64)
        p = 1.0 / ranks
        self._logits = jnp.asarray(np.log(p / p.sum()), jnp.float32)

    def batch(self, step: int):
        cfg = self.cfg
        b_local = cfg.batch // cfg.n_shards
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step), cfg.shard)
        k1, k2 = jax.random.split(key)
        base = jax.random.categorical(
            k1, self._logits, shape=(b_local, cfg.seq + 1))
        # bigram mixing: with p=0.5 the next token is perm[cur] (learnable)
        follow = self._perm[base[:, :-1]]
        coin = jax.random.bernoulli(k2, 0.5, follow.shape)
        seq = jnp.concatenate(
            [base[:, :1], jnp.where(coin, follow, base[:, 1:])], axis=1)
        out = {"tokens": seq[:, :-1], "labels": seq[:, 1:]}
        return _add_frontend(out, cfg, key)


class MemmapTokens:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        dtype = np.uint32 if cfg.vocab_size > 65535 else np.uint16
        self._data = np.memmap(cfg.path, dtype=dtype, mode="r")
        self._n = len(self._data)

    def batch(self, step: int):
        cfg = self.cfg
        b_local = cfg.batch // cfg.n_shards
        span = cfg.seq + 1
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 97 + cfg.shard)
        starts = rng.integers(0, self._n - span, size=b_local)
        rows = np.stack([self._data[s:s + span] for s in starts]).astype(
            np.int32)
        out = {"tokens": jnp.asarray(rows[:, :-1]),
               "labels": jnp.asarray(rows[:, 1:])}
        return _add_frontend(out, cfg, jax.random.PRNGKey(step))


def _add_frontend(batch, cfg: DataConfig, key):
    if cfg.frontend == "stub" and cfg.frames:      # enc-dec: audio frames
        b = batch["tokens"].shape[0]
        batch["frames"] = jax.random.normal(
            key, (b, cfg.frames, cfg.d_model), jnp.float32)
    elif cfg.frontend == "stub":                   # vlm: fused embeddings
        b, s = batch["tokens"].shape
        batch["embeddings"] = jax.random.normal(
            key, (b, s, cfg.d_model), jnp.float32)
        del batch["tokens"]
    return batch


def make_pipeline(cfg: DataConfig):
    return MemmapTokens(cfg) if cfg.kind == "memmap" else SyntheticLM(cfg)
