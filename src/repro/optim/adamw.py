"""AdamW with mixed-precision master weights, schedules, global-norm clip.

Pure-pytree implementation (no optax dependency): states are {m, v, count}
mirroring the param tree, so the distributed layer shards optimizer state
exactly like parameters (ZeRO-1 via FSDP specs).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"         # cosine | linear | constant
    min_lr_ratio: float = 0.1


def lr_at(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32) if hasattr(step, "astype") \
        else jnp.float32(step)
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    if cfg.schedule == "cosine":
        decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) \
            * 0.5 * (1 + jnp.cos(jnp.pi * t))
    elif cfg.schedule == "linear":
        decay = 1.0 - (1 - cfg.min_lr_ratio) * t
    else:
        decay = jnp.float32(1.0)
    return cfg.lr * warm * decay


def init(params):
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32),
                         params)
    return {"m": zeros,
            "v": jax.tree.map(jnp.zeros_like, zeros),
            "count": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def update(cfg: AdamWConfig, grads, opt_state, params,
           decay_mask: Optional[Callable] = None):
    """-> (new_params, new_opt_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    count = opt_state["count"] + 1
    cf = count.astype(jnp.float32)
    lr = lr_at(cfg, opt_state["count"])
    bc1 = 1.0 - cfg.b1 ** cf
    bc2 = 1.0 - cfg.b2 ** cf

    def upd(p, g, m, v, path_decay):
        m1 = cfg.b1 * m + (1 - cfg.b1) * g
        v1 = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        step = (m1 / bc1) / (jnp.sqrt(v1 / bc2) + cfg.eps)
        pf = p.astype(jnp.float32)
        step = step + cfg.weight_decay * path_decay * pf
        return (pf - lr * step).astype(p.dtype), m1, v1

    # weight decay only on matrices (>=2D), standard practice
    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    outs = [upd(p, g, m, v, 1.0 if p.ndim >= 2 else 0.0)
            for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in outs])
    new_m = treedef.unflatten([o[1] for o in outs])
    new_v = treedef.unflatten([o[2] for o in outs])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "count": count}, metrics
