"""Continuous-batching serving engine over the paged quantized KV cache.

The static serving path (`launch.serve.generate`) holds a (B, S_max)
cache: memory sized for the longest request, replicated per batch slot —
the software analogue of the FPnew lane replication TransDot removes in
hardware.  This engine removes it the same way: cache storage is a pool
of fixed-size pages (`core.kvcache` paged layout) shared by every live
request through per-request block tables, so cache memory scales with
live tokens, and one jit'd decode step serves a batch of requests at
*different* positions (per-request rope/mask via vector offsets).

Request lifecycle — admit -> prefill -> decode -> finish/evict:

  admit   : a waiting request is admitted when a decode slot is free and
            the `PageAllocator` can reserve ceil((prompt + max_new) /
            page) pages (full reservation, so a request never OOMs
            mid-decode; pages are reused off the free list).
  prefill : the prompt runs in fixed-size chunks against a contiguous
            (1, S_max) *staging* cache — the PR-2 quantized-cache path,
            unchanged — then the staged rows scatter into the request's
            pages (`write_prefill_rows`, pure relayout, bit-identical
            codes/scales).  The final chunk's logits yield the first
            generated token.
  decode  : all running requests step together through one fixed-shape
            jit'd call; each slot writes its token into its own page
            (`paged_write_token`) and attends through its block-table row
            via the `core.exec_plan` ``paged_decode`` route — the Pallas
            block-table kernel by default, with the `dpa_paged_decode_
            attn` jnp gather fallback pinned bit-identical.  Idle slots
            point at the scratch page and are ignored.
  finish  : on max_new (or eos) the request's pages return to the free
            list and its table row resets to scratch — eviction is page
            reuse, not memory churn.

The scheduler is token-budgeted: every step spends up to
`EngineConfig.token_budget` tokens — one per running decode request
first (decode latency is the serving SLO), the remainder on prefill
chunks of the oldest admitted request — so long prompts cannot starve
in-flight generations (chunked-prefill interleaving, the
Sarathi/DPUV4E-style scheduler-over-shared-engine structure).

Numerics contract: every path reuses the PR-2 quantized-cache machinery
(same `quant_rows_grid` recipe, same dequant-in-prologue attention), and
paging is pure relayout, so per-request greedy outputs are bit-identical
to the static-batch `serve.generate` path (pinned by
`tests/test_engine.py`).

Entry points: `Engine` (programmatic), `synthetic_workload` (open-loop
Poisson traffic), `python -m repro.launch.serve --engine` (CLI demo).
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import exec_plan
from repro.core import kvcache as KV
from repro.core.policy import get_policy
from repro.distributed.step import make_serve_step

WAITING, PREFILL, DECODE, FINISHED = "waiting", "prefill", "decode", "done"


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Engine geometry + scheduler knobs.

    S_max per request = max_pages_per_req * page_size (the block-table
    width bounds a request's timeline, not the pool's memory)."""
    page_size: int = 16
    n_pages: int = 64            # pool capacity (page 0 is scratch)
    max_batch: int = 4           # concurrent decode slots
    max_pages_per_req: int = 8   # block-table width
    token_budget: int = 16       # tokens per scheduler step
    prefill_chunk: int = 8       # prompt tokens per prefill call
    eos_id: int = -1             # stop token (-1: run to max_new)

    @property
    def s_max(self) -> int:
        return self.max_pages_per_req * self.page_size


@dataclasses.dataclass
class Request:
    """One serving request plus its lifecycle/accounting state."""
    rid: int
    prompt: np.ndarray           # (S0,) int32 token ids
    max_new: int
    arrival: float = 0.0         # seconds after engine start (open loop)
    # -- runtime state (engine-owned) --
    state: str = WAITING
    out_tokens: list = dataclasses.field(default_factory=list)
    pages: list = dataclasses.field(default_factory=list)
    slot: int = -1
    pos: int = 0                 # tokens written to the cache so far
    prefill_done: int = 0
    t_admit: float = 0.0
    t_first: float = 0.0         # first generated token (TTFT anchor)
    t_finish: float = 0.0

    @property
    def n_prompt(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def n_generated(self) -> int:
        return len(self.out_tokens)

    def tokens(self) -> np.ndarray:
        """prompt + generated, the static path's (S0 + max_new,) layout."""
        return np.concatenate([self.prompt,
                               np.asarray(self.out_tokens, np.int32)])


def synthetic_workload(n_requests: int, *, vocab: int, seed: int = 0,
                       rate: float = 0.0, prompt_range=(8, 32),
                       gen_range=(4, 16)) -> List[Request]:
    """Open-loop synthetic traffic: Poisson arrivals (exponential
    inter-arrival at `rate` req/s; rate 0 = all arrive at t=0), prompt
    and output lengths uniform over the given inclusive ranges."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, n_requests)) \
        if rate > 0 else np.zeros(n_requests)
    reqs = []
    for i in range(n_requests):
        s0 = int(rng.integers(prompt_range[0], prompt_range[1] + 1))
        gen = int(rng.integers(gen_range[0], gen_range[1] + 1))
        prompt = rng.integers(0, vocab, size=s0).astype(np.int32)
        reqs.append(Request(rid=i, prompt=prompt, max_new=gen,
                            arrival=float(arrivals[i])))
    return reqs


def _attn_group_kinds(cfg):
    """(pattern, n_groups, tail) with the engine's support check."""
    from repro.models.transformer import family_pattern
    pattern = family_pattern(cfg)
    if set(pattern) != {"attn"}:
        raise ValueError(
            f"engine serves uniform-attention decoder stacks; {cfg.name} "
            f"has pattern {pattern} (sliding-window/recurrent blocks keep "
            "per-slot state the paged cache does not model)")
    n_groups, tail = divmod(cfg.n_layers, len(pattern))
    return pattern, n_groups, tail


class Engine:
    """Continuous-batching engine bound to one model + params."""

    def __init__(self, model, params, ecfg: EngineConfig):
        cfg = model.cfg
        pol = get_policy(cfg.policy)
        # the plan layer owns kernel selection: resolving the decode route
        # up front validates the policy (a raw-f32-cache policy has no
        # paged_decode route) and makes the report say which kernel runs
        self._plan_ctx = dict(batch=ecfg.max_batch,
                              page_size=ecfg.page_size,
                              max_pages=ecfg.max_pages_per_req,
                              kv_heads=cfg.n_kv_heads, hd=cfg.hd)
        try:
            self.plan = exec_plan.describe("paged_decode", pol,
                                           **self._plan_ctx)
        except exec_plan.PlanError as e:
            raise ValueError(
                f"policy {cfg.policy!r} keeps a raw f32 cache; the paged "
                "engine stores format-width codes — pick a fmt_kv preset "
                "(e.g. kv8_attn_f32 for f32 arithmetic over an fp8 cache)"
            ) from e
        if ecfg.s_max % ecfg.prefill_chunk:
            # the last chunk's fixed-size window must stay inside the
            # staging cache (dynamic_update_slice clamps, which would
            # shift the write over real rows)
            raise ValueError(f"S_max ({ecfg.s_max}) must be a multiple of "
                             f"prefill_chunk ({ecfg.prefill_chunk})")
        _, self._n_groups, self._n_tail = _attn_group_kinds(cfg)
        self.model, self.params, self.ecfg = model, params, ecfg
        self.cfg, self.pol = cfg, pol
        self.alloc = KV.PageAllocator(ecfg.n_pages)
        self._table = np.full((ecfg.max_batch, ecfg.max_pages_per_req),
                              KV.SCRATCH_PAGE, np.int32)
        self.caches = self._init_paged_caches()
        # staging cache for chunked prefill: the contiguous PR-2 layout
        self._staging = model.init_caches(1, ecfg.s_max)
        self._prefill_fn = jax.jit(model.decode_step)
        self._decode_fn = jax.jit(make_serve_step(model),
                                  donate_argnums=(2,))
        self.slots: List[Optional[Request]] = [None] * ecfg.max_batch
        self.waiting: List[Request] = []
        self._tables_dirty = False
        self.finished: List[Request] = []
        self.peak_live_tokens = 0
        self.n_steps = 0

    # -- cache plumbing ----------------------------------------------------

    def _init_paged_caches(self):
        """Paged pools in the model's scanned-cache structure: every leaf
        gains a leading (n_groups,) dim; per-layer pools are independent
        but share the one block table (vLLM-style: a request's page ids
        index every layer's pool)."""
        e, cfg = self.ecfg, self.cfg
        one = dict(KV.init_paged_kv_cache(e.n_pages, e.page_size,
                                          cfg.n_kv_heads, cfg.hd,
                                          fmt=self.pol.fmt_kv,
                                          packed=self.pol.kv_packed),
                   block_table=jnp.asarray(self._table))
        g = jax.tree.map(
            lambda x: jnp.array(jnp.broadcast_to(
                x[None], (self._n_groups,) + x.shape)), one)
        tail = [jax.tree.map(jnp.array, one) for _ in range(self._n_tail)]
        return {"groups": {"p0": g}, "tail": tail}

    def _sync_tables(self):
        """Push the host block table into every layer's cache leaf."""
        t = jnp.asarray(self._table)
        g = self.caches["groups"]["p0"]
        g = dict(g, block_table=jnp.asarray(np.ascontiguousarray(
            np.broadcast_to(self._table[None],
                            (self._n_groups,) + self._table.shape))))
        tail = [dict(c, block_table=t) for c in self.caches["tail"]]
        self.caches = {"groups": {"p0": g}, "tail": tail}

    def _scatter_staging_to_pages(self, req: Request):
        """Copy the staged prompt rows into the request's pages (pure
        relayout; see `core.kvcache.write_prefill_rows`)."""
        n = req.n_prompt
        ids = req.pages

        def copy_group(pages, staged):
            rows = {k: staged[k][0] for k in KV.QUANT_KEYS}
            return KV.write_prefill_rows(pages, rows, ids, n)

        g = self.caches["groups"]["p0"]
        sg = self._staging["groups"]["p0"]
        g = jax.vmap(copy_group)({k: g[k] for k in KV.QUANT_KEYS},
                                 {k: sg[k] for k in KV.QUANT_KEYS})
        self.caches["groups"]["p0"] = dict(self.caches["groups"]["p0"], **g)
        for i, (pc, sc) in enumerate(zip(self.caches["tail"],
                                         self._staging["tail"])):
            rows = {k: sc[k][0] for k in KV.QUANT_KEYS}
            self.caches["tail"][i] = KV.write_prefill_rows(pc, rows, ids, n)

    # -- lifecycle ---------------------------------------------------------

    def submit(self, req: Request):
        e = self.ecfg
        total = req.n_prompt + req.max_new
        if total > e.s_max:
            raise ValueError(f"request {req.rid}: {total} tokens exceed "
                             f"S_max = {e.s_max} "
                             "(raise max_pages_per_req or page_size)")
        if -(-total // e.page_size) > self.alloc.capacity - 1:
            raise ValueError(f"request {req.rid} can never fit the pool")
        req.state = WAITING
        self.waiting.append(req)

    def _admit(self, now: float):
        for slot in range(self.ecfg.max_batch):
            if self.slots[slot] is not None or not self.waiting:
                continue
            req = self.waiting[0]
            n_pages = -(-(req.n_prompt + req.max_new) // self.ecfg.page_size)
            if not self.alloc.can_alloc(n_pages):
                break                      # FIFO: don't starve the head
            self.waiting.pop(0)
            req.pages = self.alloc.alloc(n_pages)
            req.slot, req.state, req.t_admit = slot, PREFILL, now
            self.slots[slot] = req
            # the table row stays scratch until prefill lands: a PREFILL
            # slot rides decode steps as idle and must not touch its pages

    def _finish(self, req: Request, now: float):
        self.alloc.free(req.pages)
        req.pages = []
        self._table[req.slot] = KV.SCRATCH_PAGE
        self.slots[req.slot] = None
        req.slot = -1
        req.state, req.t_finish = FINISHED, now
        self.finished.append(req)
        self._tables_dirty = True

    def _prefill_step(self, req: Request, now: float) -> int:
        """Run one prompt chunk; returns real tokens consumed."""
        e = self.ecfg
        c0 = req.prefill_done
        n = min(e.prefill_chunk, req.n_prompt - c0)
        chunk = np.zeros((1, e.prefill_chunk), np.int32)
        chunk[0, :n] = req.prompt[c0:c0 + n]
        logits, self._staging = self._prefill_fn(
            self.params, {"tokens": jnp.asarray(chunk),
                          "index": jnp.int32(c0)}, self._staging)
        req.prefill_done += n
        if req.prefill_done == req.n_prompt:
            self._scatter_staging_to_pages(req)
            self._table[req.slot, :len(req.pages)] = req.pages
            self._tables_dirty = True
            first = int(jnp.argmax(logits[0, n - 1]))
            req.out_tokens.append(first)
            req.pos = req.n_prompt
            req.state, req.t_first = DECODE, now
            self._maybe_finish(req, first, now)
        return n

    def _decode_batch(self, now: float) -> int:
        """One batched decode step over every DECODE-state slot."""
        e = self.ecfg
        live = [r for r in self.slots if r is not None and r.state == DECODE]
        if not live:
            return 0
        tokens = np.zeros((e.max_batch, 1), np.int32)
        positions = np.zeros((e.max_batch,), np.int32)
        for r in live:
            tokens[r.slot, 0] = r.out_tokens[-1]
            positions[r.slot] = r.pos
        nxt, self.caches = self._decode_fn(
            self.params, {"tokens": jnp.asarray(tokens),
                          "index": jnp.asarray(positions)}, self.caches)
        nxt = np.asarray(nxt)
        for r in live:
            tok = int(nxt[r.slot])
            r.pos += 1
            r.out_tokens.append(tok)
            self._maybe_finish(r, tok, now)
        return len(live)

    def _maybe_finish(self, req: Request, tok: int, now: float):
        if req.n_generated >= req.max_new or tok == self.ecfg.eos_id:
            self._finish(req, now)

    def step(self, now: float = 0.0):
        """One scheduler tick: admit, decode the running batch, spend the
        leftover token budget on prefill chunks."""
        self._admit(now)
        budget = self.ecfg.token_budget
        budget -= self._decode_batch(now)
        while budget > 0:
            pre = [r for r in self.slots
                   if r is not None and r.state == PREFILL]
            if not pre:
                break
            # a partially-prefilled request MUST keep the baton until its
            # prompt is fully staged: the staging cache is shared, so
            # switching mid-prefill would interleave two prompts' rows
            # (there is at most one partial request by induction).  Ties
            # on t_admit (same tick) then break by admission order (rid)
            budget -= self._prefill_step(
                min(pre, key=lambda r: (r.prefill_done == 0,
                                        r.t_admit, r.rid)), now)
        self._admit(now)        # freed slots/pages admit within the tick
        if self._tables_dirty:
            # one device sync per tick, after all finish/prefill events —
            # the next tick's decode reads tables through the cache pytree.
            # Deferring past _finish is safe: the freed slot's stale row
            # only matters to decode, which never runs before this sync
            self._sync_tables()
            self._tables_dirty = False
        self.peak_live_tokens = max(self.peak_live_tokens,
                                    self.live_tokens())
        self.n_steps += 1

    def live_tokens(self) -> int:
        return sum(r.pos for r in self.slots if r is not None)

    def reset_stats(self):
        """Clear accounting between workloads (keeps compiled steps and
        the page pool; only legal when nothing is in flight)."""
        if any(self.slots) or self.waiting:
            raise RuntimeError("reset_stats with requests in flight")
        self.finished = []
        self.peak_live_tokens = 0
        self.n_steps = 0
        self.alloc.peak_in_use = self.alloc.in_use

    def run(self, requests: List[Request]) -> dict:
        """Serve an open-loop workload to completion; returns `report()`.

        Requests arrive at wall-clock `arrival` offsets; the engine idles
        (sleeps) when nothing is live and the next arrival is in the
        future."""
        pending = sorted(requests, key=lambda r: r.arrival)
        t0 = time.monotonic()
        while pending or self.waiting or any(self.slots):
            now = time.monotonic() - t0
            while pending and pending[0].arrival <= now:
                self.submit(pending.pop(0))
            if not self.waiting and not any(self.slots):
                time.sleep(min(0.001, max(0.0,
                                          pending[0].arrival - now)))
                continue
            self.step(now)
        wall = time.monotonic() - t0
        return self.report(wall)

    # -- accounting --------------------------------------------------------

    def kv_bytes_report(self) -> dict:
        """Cache bytes from *actual per-request lengths* (live or peak
        tokens), vs the static (B, S_max) baselines — both the f32 seed
        cache and the format-width static cache the engine replaces."""
        e, cfg, pol = self.ecfg, self.cfg, self.pol
        n_attn = self._n_groups + self._n_tail
        live = KV.paged_kv_cache_nbytes(
            self.peak_live_tokens, self.alloc.peak_in_use, e.page_size,
            cfg.n_kv_heads, cfg.hd, fmt=pol.fmt_kv, packed=pol.kv_packed)
        static = KV.kv_cache_nbytes(e.max_batch, e.s_max, cfg.n_kv_heads,
                                    cfg.hd, fmt=pol.fmt_kv,
                                    packed=pol.kv_packed)
        return {
            "live_bytes": live["live"] * n_attn,
            "paged_bytes": live["paged"] * n_attn,
            "static_bytes": static["total"] * n_attn,
            "static_f32_bytes": static["f32_total"] * n_attn,
            "peak_live_tokens": self.peak_live_tokens,
            "page_util": self.alloc.peak_in_use / (self.alloc.capacity - 1),
            "pages_peak": self.alloc.peak_in_use,
            "pages_total": self.alloc.capacity - 1,
        }

    def report(self, wall: float) -> dict:
        # re-describe at report time: the decode step re-resolves its
        # route per trace (e.g. REPRO_PAGED_KERNEL flipped after
        # construction), and the report must state what actually ran
        self.plan = exec_plan.describe("paged_decode", self.pol,
                                       **self._plan_ctx)
        lat = np.array([r.t_finish - r.arrival for r in self.finished])
        ttft = np.array([r.t_first - r.arrival for r in self.finished])
        gen = sum(r.n_generated for r in self.finished)
        kv = self.kv_bytes_report()
        return {
            "n_requests": len(self.finished),
            "wall_s": wall,
            "steps": self.n_steps,
            "gen_tokens": gen,
            "tokens_per_s": gen / wall if wall > 0 else float("inf"),
            "p50_latency_s": float(np.percentile(lat, 50)) if len(lat) else 0.0,
            "p99_latency_s": float(np.percentile(lat, 99)) if len(lat) else 0.0,
            "p50_ttft_s": float(np.percentile(ttft, 50)) if len(ttft) else 0.0,
            "decode_route": self.plan["route"],
            "decode_backend": self.plan["backend"],
            "decode_bytes_per_step_layer": self.plan["bytes_moved"],
            **kv,
        }


def format_report(rep: dict, policy: str) -> str:
    """The serve.py report lines: throughput/latency + honest cache bytes
    (counted from actual per-request lengths, not B x S_max) + page-
    allocator utilization."""
    mb = 1e6
    return (
        f"engine: {rep['n_requests']} reqs, {rep['gen_tokens']} tokens in "
        f"{rep['wall_s']:.2f}s ({rep['tokens_per_s']:.1f} tok/s, "
        f"{rep['steps']} steps, policy={policy})\n"
        f"latency: p50 {rep['p50_latency_s'] * 1e3:.0f} ms, "
        f"p99 {rep['p99_latency_s'] * 1e3:.0f} ms, "
        f"ttft p50 {rep['p50_ttft_s'] * 1e3:.0f} ms\n"
        f"kv-cache: peak live {rep['live_bytes'] / mb:.2f} MB "
        f"({rep['peak_live_tokens']} tokens) in "
        f"{rep['paged_bytes'] / mb:.2f} MB of pages vs static "
        f"{rep['static_bytes'] / mb:.2f} MB (B x S_max, same format) / "
        f"f32 {rep['static_f32_bytes'] / mb:.2f} MB; "
        f"page util peak {rep['page_util']:.0%} "
        f"({rep['pages_peak']}/{rep['pages_total']} pages)\n"
        f"plan: decode via {rep['decode_route']} "
        f"[{rep['decode_backend']}], "
        f"{rep['decode_bytes_per_step_layer'] / 1e3:.1f} KB KV moved "
        "per step/layer")
