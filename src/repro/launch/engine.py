"""Continuous-batching serving engine over the paged quantized KV cache.

The static serving path (`launch.serve.generate`) holds a (B, S_max)
cache: memory sized for the longest request, replicated per batch slot —
the software analogue of the FPnew lane replication TransDot removes in
hardware.  This engine removes it the same way: cache storage is a pool
of fixed-size pages (`core.kvcache` paged layout) shared by every live
request through per-request block tables, so cache memory scales with
live tokens, and one jit'd decode step serves a batch of requests at
*different* positions (per-request rope/mask via vector offsets).

Request lifecycle — admit -> prefill -> decode -> finish/evict:

  admit   : a waiting request is admitted when a decode slot is free and
            the `PageAllocator` can reserve ceil((prompt + max_new) /
            page) pages (full reservation, so a request never OOMs
            mid-decode; pages are reused off the free list).  With the
            prefix cache on (`EngineConfig.prefix_cache`), admission
            first matches the prompt against the radix index
            (`repro.serving.prefix_cache`): fully-matched pages are
            shared read-only into the block table (allocator refcounts
            keep them alive), a partial-page match copies-on-write into
            a private page, only the uncovered remainder allocates fresh
            pages, and cold cached prefixes LRU-evict under pool
            pressure.
  prefill : the prompt runs in fixed-size chunks against a contiguous
            (1, S_max) *staging* cache — the PR-2 quantized-cache path,
            unchanged — then the staged rows scatter into the request's
            pages (`write_prefill_rows`, pure relayout, bit-identical
            codes/scales).  The final chunk's logits yield the first
            generated token.  A prefix-hit request first materializes
            the matched rows from its (shared) pages into staging (pure
            relayout again) and prefills only from the divergence point
            — the skipped chunks are the `prefill_tokens_saved` the
            report counts; outputs stay bit-identical to a cold serve
            because the shared pages hold exactly the codes/scales a
            cold prefill of the same tokens would have written.  After
            the scatter, the request's pure full-prompt pages register
            in the prefix index for later requests to hit.
  decode  : all running requests step together through one fixed-shape
            jit'd call; each slot writes its token into its own page
            (`paged_write_token`) and attends through its block-table row
            via the `core.exec_plan` ``paged_decode`` route — the Pallas
            block-table kernel by default, with the `dpa_paged_decode_
            attn` jnp gather fallback pinned bit-identical.  Idle slots
            point at the scratch page and are ignored.
  finish  : on max_new (or eos) the request drops its page references;
            private pages return to the free list, shared prefix pages
            stay resident for future hits (the prefix cache holds its
            own reference), and the table row resets to scratch —
            eviction is page reuse, not memory churn.

The scheduler is token-budgeted: every step spends up to
`EngineConfig.token_budget` tokens — one per running decode request
first (decode latency is the serving SLO), the remainder on prefill
chunks of the oldest admitted request — so long prompts cannot starve
in-flight generations (chunked-prefill interleaving, the
Sarathi/DPUV4E-style scheduler-over-shared-engine structure).

Sampling: tokens draw through `repro.serving.sampler` — fixed-shape
temperature/top-k/top-p with per-request threefry streams keyed on
(seed, request id, token index), so a request's tokens are independent
of batch composition.  The default `SamplerConfig()` is greedy and
bit-identical to the argmax path this engine shipped with.

Speculative decoding (`SpecConfig`): the same weights draft k tokens
per request under a cheap low-precision policy, then ONE batched pass
under the serving policy verifies all k via the ``verify_attn`` route
and accepts with rejection sampling (`repro.serving.spec_decode`) —
greedy outputs stay token-for-token identical to plain decode.  Spec
mode commits pages lazily out of an up-front `PageAllocator`
reservation (the no-OOM guarantee survives) and rolls back pages
holding only rejected-draft rows after every round.  The token budget
prices a round at its real work: k draft + k+1 verify tokens per live
request.

Adaptive drafting (`repro.runtime.controller.ControllerConfig`): the
runtime analogue of the paper's mode register.  The engine pre-builds
one draft view per ladder rung at construction — every rung shares the
params and the page pool (`validate_policy_pair` against the serving
policy), each rung's ``paged_decode`` route resolved through the
exec-plan (tuned-DB consult included) — and a pure per-request feedback
controller demotes drafts toward fp4 while the acceptance EMA stays
high and promotes toward fp8/fp16 when it sags (hysteresis + dwell, no
flapping).  Each scheduler tick batches live requests *by current rung*
and runs one speculative round per rung group; requests on other rungs
ride the fixed-shape batch as ghosts (their stray writes land at rows
>= pos — stale territory every round rewrites before reading — or on
the scratch page, never over committed history).  Page reservations
size against the ladder-wide max draft k, so a rung switch can never
violate the no-OOM invariant.  Rejection sampling makes the output
distribution invariant to which rung drafted; greedy adaptive output is
token-for-token the plain engine's (pinned by
`tests/test_adaptive_engine.py`, adversarial controllers included).

Numerics contract: every path reuses the PR-2 quantized-cache machinery
(same `quant_rows_grid` recipe, same dequant-in-prologue attention), and
paging is pure relayout, so per-request greedy outputs are bit-identical
to the static-batch `serve.generate` path (pinned by
`tests/test_engine.py`), speculative or not (`tests/test_spec_decode.py`).

Entry points: `Engine` (programmatic), `synthetic_workload` (open-loop
Poisson traffic), `python -m repro.launch.serve --engine` (CLI demo).
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import exec_plan
from repro.core import kvcache as KV
from repro.core.packing import operand_nbytes
from repro.core.policy import get_policy
from repro.distributed import tp as TP
from repro.runtime import controller as CTRL
from repro.runtime.controller import ControllerConfig
from repro.serving import sampler as SMP
from repro.serving import spec_decode as SPD
from repro.serving.prefix_cache import PrefixCache, PrefixMatch
from repro.serving.sampler import SamplerConfig
from repro.serving.spec_decode import SpecConfig

WAITING, PREFILL, DECODE, FINISHED = "waiting", "prefill", "decode", "done"


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Engine geometry + scheduler knobs.

    S_max per request = max_pages_per_req * page_size (the block-table
    width bounds a request's timeline, not the pool's memory)."""
    page_size: int = 16
    n_pages: int = 64            # pool capacity (page 0 is scratch)
    max_batch: int = 4           # concurrent decode slots
    max_pages_per_req: int = 8   # block-table width
    token_budget: int = 16       # tokens per scheduler step
    prefill_chunk: int = 8       # prompt tokens per prefill call
    eos_id: int = -1             # stop token (-1: run to max_new)
    prefix_cache: bool = False   # share prompt prefixes across requests
    # tensor-parallel width: shard the page pool across a (1, tp) "model"
    # mesh and serve through the `*_sharded` exec-plan routes (bit-
    # identical outputs; the wire carries format-width codes + scales).
    # Falls back to 1 — replicate, never crash — when tp exceeds the
    # visible devices or page_size % tp != 0 (the within-page row dim is
    # the sharded one); report() states the reason.
    tp: int = 1

    @property
    def s_max(self) -> int:
        return self.max_pages_per_req * self.page_size


@dataclasses.dataclass
class Request:
    """One serving request plus its lifecycle/accounting state."""
    rid: int
    prompt: np.ndarray           # (S0,) int32 token ids
    max_new: int
    arrival: float = 0.0         # seconds after engine start (open loop)
    # -- runtime state (engine-owned) --
    state: str = WAITING
    out_tokens: list = dataclasses.field(default_factory=list)
    pages: list = dataclasses.field(default_factory=list)
    reserved_left: int = 0       # reserved-but-uncommitted pages (spec mode)
    rung: int = 0                # current draft-ladder rung (adaptive mode)
    ctrl: object = None          # ControllerState (adaptive mode)
    slot: int = -1
    pos: int = 0                 # tokens written to the cache so far
    prefill_done: int = 0
    prefill_skip: int = 0        # prompt tokens covered by a prefix hit
    t_admit: float = 0.0
    t_first: float = 0.0         # first generated token (TTFT anchor)
    t_finish: float = 0.0

    @property
    def n_prompt(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def n_generated(self) -> int:
        return len(self.out_tokens)

    def tokens(self) -> np.ndarray:
        """prompt + generated, the static path's (S0 + max_new,) layout."""
        return np.concatenate([self.prompt,
                               np.asarray(self.out_tokens, np.int32)])


def synthetic_workload(n_requests: int, *, vocab: int, seed: int = 0,
                       rate: float = 0.0, prompt_range=(8, 32),
                       gen_range=(4, 16), shared_prefix: int = 0,
                       mixed: float = 0.0) -> List[Request]:
    """Open-loop synthetic traffic: Poisson arrivals (exponential
    inter-arrival at `rate` req/s; rate 0 = all arrive at t=0), prompt
    and output lengths uniform over the given inclusive ranges.

    `shared_prefix` > 0 prepends the same `shared_prefix` drawn tokens
    to every prompt — a system-prompt workload, the prefix cache's
    target shape (the default 0 leaves the RNG stream, and so existing
    workloads, untouched).

    `mixed` > 0 makes the traffic heterogeneous: each request is a
    long-prompt/long-gen class member with probability `mixed` — prompt
    length uniform over [2*hi, 4*hi] of `prompt_range`, gen likewise of
    `gen_range` — the shape the adaptive draft controller is for (long
    generations give the acceptance EMA time to move the rung).  Every
    long-class draw (the class coin, lengths, AND tokens) comes from a
    *forked* RNG stream keyed (seed, 1), so the default ``mixed=0``
    leaves the base stream — and every existing workload and
    seed-determinism pin — byte-identical."""
    rng = np.random.default_rng(seed)
    hetero = np.random.default_rng([seed, 1]) if mixed > 0 else None
    arrivals = np.cumsum(rng.exponential(1.0 / rate, n_requests)) \
        if rate > 0 else np.zeros(n_requests)
    prefix = (rng.integers(0, vocab, size=shared_prefix).astype(np.int32)
              if shared_prefix > 0 else None)
    reqs = []
    for i in range(n_requests):
        if hetero is not None and hetero.random() < mixed:
            s0 = int(hetero.integers(2 * prompt_range[1],
                                     4 * prompt_range[1] + 1))
            gen = int(hetero.integers(2 * gen_range[1],
                                      4 * gen_range[1] + 1))
            prompt = hetero.integers(0, vocab, size=s0).astype(np.int32)
        else:
            s0 = int(rng.integers(prompt_range[0], prompt_range[1] + 1))
            gen = int(rng.integers(gen_range[0], gen_range[1] + 1))
            prompt = rng.integers(0, vocab, size=s0).astype(np.int32)
        if prefix is not None:
            prompt = np.concatenate([prefix, prompt])
        reqs.append(Request(rid=i, prompt=prompt, max_new=gen,
                            arrival=float(arrivals[i])))
    return reqs


def _attn_group_kinds(cfg):
    """(pattern, n_groups, tail) with the engine's support check."""
    from repro.models.transformer import family_pattern
    pattern = family_pattern(cfg)
    if set(pattern) != {"attn"}:
        raise ValueError(
            f"engine serves uniform-attention decoder stacks; {cfg.name} "
            f"has pattern {pattern} (sliding-window/recurrent blocks keep "
            "per-slot state the paged cache does not model)")
    n_groups, tail = divmod(cfg.n_layers, len(pattern))
    return pattern, n_groups, tail


@dataclasses.dataclass
class _Rung:
    """One pre-built draft view on the adaptive ladder: the rung's
    policy/model share the serving params and page pool; only the
    compute routing (and jit'd step functions) differ per rung."""
    name: str
    k: int
    pol: object                  # validated TransPrecisionPolicy
    model: object                # serving model rebuilt under the rung
    plan: dict                   # paged_decode route description
    verify_plan: dict            # verify_attn route at sq = k + 1
    draft_fn: object             # jit'd draft step (donates caches)
    accept_fn: object            # jit'd rejection-sampling acceptance


class Engine:
    """Continuous-batching engine bound to one model + params.

    `sampler` selects the token-draw rule (default: greedy argmax);
    `spec` turns on self-speculative decoding (draft under
    `spec.draft_policy`, verify under the model's own policy);
    `adaptive` replaces the single static draft policy with a
    `ControllerConfig` precision ladder walked per request by the
    acceptance-feedback controller (`repro.runtime.controller`)."""

    def __init__(self, model, params, ecfg: EngineConfig, *,
                 sampler: Optional[SamplerConfig] = None,
                 spec: Optional[SpecConfig] = None,
                 adaptive: Optional[ControllerConfig] = None):
        if spec is not None and adaptive is not None:
            raise ValueError("pass spec= (one static draft policy) or "
                             "adaptive= (a controller-walked ladder), "
                             "not both")
        cfg = model.cfg
        pol = get_policy(cfg.policy)
        # tensor parallelism: a (1, tp) host mesh whose "model" axis
        # shards the page pool's within-page row dim (cache_spec's kv
        # rule).  The fallback is replication, never a crash — the
        # sharded routes' in_specs would reject a non-dividing dim.
        self.tp, self.tp_fallback, self._mesh = 1, "", None
        if ecfg.tp > 1:
            n_dev = len(jax.devices())
            if ecfg.tp > n_dev:
                self.tp_fallback = (f"tp={ecfg.tp} exceeds {n_dev} visible "
                                    "device(s); serving replicated")
            elif ecfg.page_size % ecfg.tp:
                self.tp_fallback = (f"page_size={ecfg.page_size} not "
                                    f"divisible by tp={ecfg.tp}; serving "
                                    "replicated")
            else:
                from repro.launch.mesh import make_host_mesh
                self._mesh = make_host_mesh(n_data=1, n_model=ecfg.tp)
                self.tp = ecfg.tp
        # the plan layer owns kernel selection: resolving the decode route
        # up front validates the policy (a raw-f32-cache policy has no
        # paged_decode route) and makes the report say which kernel runs
        self._plan_ctx = dict(batch=ecfg.max_batch,
                              page_size=ecfg.page_size,
                              max_pages=ecfg.max_pages_per_req,
                              kv_heads=cfg.n_kv_heads, hd=cfg.hd,
                              n_pages=ecfg.n_pages, n_devices=self.tp)
        try:
            self.plan = exec_plan.describe("paged_decode", pol,
                                           **self._plan_ctx)
        except exec_plan.PlanError as e:
            raise ValueError(
                f"policy {cfg.policy!r} keeps a raw f32 cache; the paged "
                "engine stores format-width codes — pick a fmt_kv preset "
                "(e.g. kv8_attn_f32 for f32 arithmetic over an fp8 cache)"
            ) from e
        # MoE configs serve through the grouped_matmul plan: resolving it
        # up front states which grouped kernel the expert contraction
        # runs (the decode-step dispatch shape: each batch row buffers
        # its single token into (B, E, C, d) with C = f(S=1))
        self.moe_plan, self._moe_ctx = None, None
        if cfg.is_moe:
            c = int(cfg.capacity_factor * cfg.top_k / cfg.n_experts) + 1
            self._moe_ctx = dict(w_dtype="float32", eq="becd,edf->becf",
                                 e=cfg.n_experts, m=ecfg.max_batch * c,
                                 k=cfg.d_model, n=cfg.d_ff)
            self.moe_plan = exec_plan.describe("grouped_matmul", pol,
                                               **self._moe_ctx)
        if ecfg.s_max % ecfg.prefill_chunk:
            # the last chunk's fixed-size window must stay inside the
            # staging cache (dynamic_update_slice clamps, which would
            # shift the write over real rows)
            raise ValueError(f"S_max ({ecfg.s_max}) must be a multiple of "
                             f"prefill_chunk ({ecfg.prefill_chunk})")
        _, self._n_groups, self._n_tail = _attn_group_kinds(cfg)
        self.model, self.params, self.ecfg = model, params, ecfg
        self.cfg, self.pol = cfg, pol
        self.sampler = sampler or SamplerConfig()
        self.spec = spec
        self.alloc = KV.PageAllocator(ecfg.n_pages)
        self._table = np.full((ecfg.max_batch, ecfg.max_pages_per_req),
                              KV.SCRATCH_PAGE, np.int32)
        self.caches = self._init_paged_caches()
        if self._mesh is not None:
            self.caches = self._shard_caches(self.caches)
        # staging cache for chunked prefill: the contiguous PR-2 layout.
        # NEVER sharded: prefill softmax must stay a single-device
        # reduction or chunked prefill loses bit-identity with tp=1
        self._staging = model.init_caches(1, ecfg.s_max)
        self._prefill_fn = jax.jit(model.decode_step)
        self._decode_fn = jax.jit(self._make_decode_step(),
                                  donate_argnums=(2,))
        if spec is not None:
            self.draft_pol = SPD.validate_policy_pair(spec.draft_policy,
                                                      pol)
            from repro.models import build_model
            self.draft_model = build_model(
                cfg.replace(policy=spec.draft_policy))
            self.draft_plan = exec_plan.describe("paged_decode",
                                                 self.draft_pol,
                                                 **self._plan_ctx)
            self.verify_plan = exec_plan.describe("verify_attn", pol,
                                                  sq=spec.k + 1,
                                                  **self._plan_ctx)
            self._draft_fn = jax.jit(
                SPD.make_draft_step(self.draft_model, self.sampler),
                donate_argnums=(2,))
            self._verify_fn = jax.jit(self.model.decode_step,
                                      donate_argnums=(2,))
            self._accept_fn = jax.jit(
                SPD.make_accept_fn(self.sampler, spec.k))
        self.adaptive = adaptive
        self.rungs: List[_Rung] = []
        if adaptive is not None:
            # one draft view per rung, all sharing params and page pool:
            # validate_policy_pair pins the shared-cache precondition,
            # and each rung's paged_decode route resolves through the
            # exec-plan (tuned-DB consult included) at construction, so
            # a bad ladder entry fails here, not mid-request
            from repro.models import build_model
            for name, rk in zip(adaptive.ladder, adaptive.rung_ks):
                rpol = SPD.validate_policy_pair(name, pol)
                rmodel = build_model(cfg.replace(policy=name))
                self.rungs.append(_Rung(
                    name=name, k=rk, pol=rpol, model=rmodel,
                    plan=exec_plan.describe("paged_decode", rpol,
                                            **self._plan_ctx),
                    verify_plan=exec_plan.describe("verify_attn", pol,
                                                   sq=rk + 1,
                                                   **self._plan_ctx),
                    draft_fn=jax.jit(SPD.make_draft_step(rmodel,
                                                         self.sampler),
                                     donate_argnums=(2,)),
                    accept_fn=jax.jit(SPD.make_accept_fn(self.sampler,
                                                         rk))))
            self._verify_fn = jax.jit(self.model.decode_step,
                                      donate_argnums=(2,))
            # overridable seam: tests install adversarial controllers
            # (e.g. switch-every-round) through this attribute
            self._ctrl_step = CTRL.step
        self.prefix = (PrefixCache(ecfg.page_size, self.alloc)
                       if ecfg.prefix_cache else None)
        self.slots: List[Optional[Request]] = [None] * ecfg.max_batch
        self.waiting: List[Request] = []
        self._tables_dirty = False
        self.finished: List[Request] = []
        self.peak_live_tokens = 0
        self.n_steps = 0
        self.spec_rounds = 0
        self.spec_request_rounds = 0
        self.drafted = 0
        self.drafts_accepted = 0
        self.spec_emitted = 0
        self.prefix_queries = 0
        self.prefix_hits = 0
        self.prefill_tokens_saved = 0
        self.cow_copies = 0
        self.rung_rounds = [0] * len(self.rungs)
        self.rung_drafted = [0] * len(self.rungs)
        self.rung_accepted = [0] * len(self.rungs)
        self.rung_emitted = [0] * len(self.rungs)
        self.rung_wall = [0.0] * len(self.rungs)
        self.ctrl_switches = 0
        self.ctrl_demotes = 0
        self.ctrl_promotes = 0

    def _make_decode_step(self):
        """The jit'd plain decode step: model step + per-request sampling
        (greedy configs reduce to the argmax this engine always ran)."""
        model, scfg = self.model, self.sampler

        def step(params, batch, caches, rids):
            logits, caches = model.decode_step(params, batch, caches)
            tok = SMP.sample_tokens(logits[:, -1], rids,
                                    batch["index"] + 1, scfg)
            return tok, caches

        return step

    @property
    def _spec_k(self) -> int:
        """Draft-window rows priced into reservations and the submit
        guard.  Adaptive mode prices the *ladder-wide max* k: a rung
        switch mid-request must never grow a request past what was
        reserved at admission (the no-OOM invariant survives any
        controller trajectory)."""
        if self.adaptive is not None:
            return self.adaptive.max_k
        return self.spec.k if self.spec is not None else 0

    # -- cache plumbing ----------------------------------------------------

    def _init_paged_caches(self):
        """Paged pools in the model's scanned-cache structure: every leaf
        gains a leading (n_groups,) dim; per-layer pools are independent
        but share the one block table (vLLM-style: a request's page ids
        index every layer's pool)."""
        e, cfg = self.ecfg, self.cfg
        one = dict(KV.init_paged_kv_cache(e.n_pages, e.page_size,
                                          cfg.n_kv_heads, cfg.hd,
                                          fmt=self.pol.fmt_kv,
                                          packed=self.pol.kv_packed),
                   block_table=jnp.asarray(self._table))
        g = jax.tree.map(
            lambda x: jnp.array(jnp.broadcast_to(
                x[None], (self._n_groups,) + x.shape)), one)
        tail = [jax.tree.map(jnp.array, one) for _ in range(self._n_tail)]
        return {"groups": {"p0": g}, "tail": tail}

    def _shard_caches(self, caches):
        """Lay the page pools out on the TP mesh: within-page rows on
        "model" (cache_spec's kv rule, 1/tp of the pool per device),
        block tables replicated."""
        from repro.distributed.sharding import cache_spec
        return jax.tree.map(jax.device_put, caches,
                            cache_spec(caches, self._mesh))

    def _tp_scope(self):
        """Context the jit'd steps run (and so trace) under: the active
        TP mesh the sharded exec-plan routes read back."""
        return (TP.activate(self._mesh) if self._mesh is not None
                else contextlib.nullcontext())

    def _unshard_staging(self):
        """Pull the staging cache back to one uncommitted device buffer.
        Gathering prefix rows out of the sharded pool leaves staging
        sharded; prefill must stay a single-device reduction (the tp=1
        bit-identity anchor), and an *uncommitted* buffer keeps the later
        pool scatter free to colocate with the committed pool."""
        self._staging = jax.tree.map(
            lambda x: jnp.asarray(np.asarray(x)), self._staging)

    def _sync_tables(self):
        """Push the host block table into every layer's cache leaf."""
        t = jnp.asarray(self._table)
        g = self.caches["groups"]["p0"]
        g = dict(g, block_table=jnp.asarray(np.ascontiguousarray(
            np.broadcast_to(self._table[None],
                            (self._n_groups,) + self._table.shape))))
        tail = [dict(c, block_table=t) for c in self.caches["tail"]]
        self.caches = {"groups": {"p0": g}, "tail": tail}

    def _scatter_staging_to_pages(self, req: Request):
        """Copy the staged prompt rows into the request's pages (pure
        relayout; see `core.kvcache.write_prefill_rows`).  A prefix-hit
        request scatters only from its divergence point on — rows before
        `prefill_skip` live in shared (or CoW-copied) pages that must
        not be written."""
        n, start = req.n_prompt, req.prefill_skip
        ids = req.pages

        def copy_group(pages, staged):
            rows = {k: staged[k][0] for k in KV.QUANT_KEYS}
            return KV.write_prefill_rows(pages, rows, ids, n, start=start)

        g = self.caches["groups"]["p0"]
        sg = self._staging["groups"]["p0"]
        g = jax.vmap(copy_group)({k: g[k] for k in KV.QUANT_KEYS},
                                 {k: sg[k] for k in KV.QUANT_KEYS})
        self.caches["groups"]["p0"] = dict(self.caches["groups"]["p0"], **g)
        for i, (pc, sc) in enumerate(zip(self.caches["tail"],
                                         self._staging["tail"])):
            rows = {k: sc[k][0] for k in KV.QUANT_KEYS}
            self.caches["tail"][i] = KV.write_prefill_rows(pc, rows, ids, n,
                                                           start=start)
        if self._mesh is not None:
            # eager scatter output sharding is compiler-chosen; pin the
            # pool back to its canonical mesh layout (pure relayout)
            self.caches = self._shard_caches(self.caches)

    def _cow_copy(self, src: int, dst: int, n_rows: int):
        """Copy the first `n_rows` rows of pool page `src` into the
        private page `dst`, every layer — pure relayout (codes and
        scales move bit-for-bit), so the diverging request's view of the
        partially-shared block is exactly what a cold prefill would have
        written there.  The shared source page is read, never written."""
        def copy_group(pool):
            return {k: pool[k].at[dst, :n_rows].set(pool[k][src, :n_rows])
                    for k in KV.QUANT_KEYS}

        g = self.caches["groups"]["p0"]
        g2 = jax.vmap(copy_group)({k: g[k] for k in KV.QUANT_KEYS})
        self.caches["groups"]["p0"] = dict(g, **g2)
        for i, pc in enumerate(self.caches["tail"]):
            self.caches["tail"][i] = dict(pc, **copy_group(pc))
        if self._mesh is not None:
            self.caches = self._shard_caches(self.caches)
        self.cow_copies += 1

    def _load_prefix_to_staging(self, req: Request):
        """Materialize the matched rows [0, prefill_skip) from the
        request's pages into the contiguous staging cache — the inverse
        relayout of `_scatter_staging_to_pages` — so the warm prefill's
        chunks attend over exactly the codes/scales a cold prefill of
        the same prompt would have staged (the bit-identity anchor)."""
        m, ps = req.prefill_skip, self.ecfg.page_size
        ids = np.asarray(req.pages[:-(-m // ps)], np.int32)

        def gather_group(pool, staged):
            out = {}
            for k in KV.QUANT_KEYS:
                rows = pool[k][ids].reshape((-1,) + pool[k].shape[2:])[:m]
                out[k] = staged[k].at[0, :m].set(rows)
            return out

        g = self.caches["groups"]["p0"]
        sg = self._staging["groups"]["p0"]
        new = jax.vmap(gather_group)({k: g[k] for k in KV.QUANT_KEYS},
                                     {k: sg[k] for k in KV.QUANT_KEYS})
        self._staging["groups"]["p0"] = dict(sg, **new)
        for i, (pc, sc) in enumerate(zip(self.caches["tail"],
                                         self._staging["tail"])):
            self._staging["tail"][i] = dict(sc, **gather_group(pc, sc))
        if self._mesh is not None:
            self._unshard_staging()

    # -- lifecycle ---------------------------------------------------------

    def _pages_needed(self, req: Request) -> int:
        """Pages a request may touch over its lifetime.  Spec mode adds
        the draft window: a round writes query rows up to pos + k, so
        the reservation prices prompt + max_new + k rows (admission
        accounts the speculation overhead up front — the no-OOM-
        mid-decode invariant is a reservation, never a hope)."""
        rows = req.n_prompt + req.max_new + self._spec_k
        return -(-rows // self.ecfg.page_size)

    def submit(self, req: Request):
        e = self.ecfg
        total = req.n_prompt + req.max_new + self._spec_k
        if total > e.s_max:
            raise ValueError(f"request {req.rid}: {total} tokens "
                             f"(incl. the {self._spec_k}-token draft "
                             f"window) exceed S_max = {e.s_max} "
                             "(raise max_pages_per_req or page_size)")
        if self._pages_needed(req) > self.alloc.capacity - 1:
            raise ValueError(f"request {req.rid} can never fit the pool")
        req.state = WAITING
        self.waiting.append(req)

    def _match_prefix(self, req: Request) -> Optional[PrefixMatch]:
        """Match-and-pin: look the prompt up in the prefix index, take a
        request reference on every matched page (the shared full pages
        AND the CoW source) *before* any eviction runs — a just-matched
        cache-only page sits at refcount 1 and must not be reclaimed
        between the match and this request's use of it — then LRU-evict
        cold cached prefixes to cover the allocation shortfall."""
        if self.prefix is None:
            return None
        e = self.ecfg
        # at least one prompt token must prefill (the final chunk's
        # logits yield the first generated token), and the warm start's
        # fixed chunk window must fit inside the staging cache
        limit = min(req.n_prompt - 1, e.s_max - e.prefill_chunk)
        m = self.prefix.match(req.prompt, limit)
        self.alloc.incref(m.pages)
        if m.cow is not None:
            self.alloc.incref([m.cow[0]])
        short = (self._pages_needed(req) - len(m.pages)
                 - self.alloc.n_available)
        if short > 0:
            self.prefix.evict(short)
        return m

    def _unpin_match(self, m: PrefixMatch):
        """Drop the references `_match_prefix` pinned (admission did not
        go through); the pages stay resident under the cache's own ref."""
        self.alloc.free(m.pages)
        if m.cow is not None:
            self.alloc.free([m.cow[0]])

    def _admit(self, now: float):
        for slot in range(self.ecfg.max_batch):
            if self.slots[slot] is not None or not self.waiting:
                continue
            req = self.waiting[0]
            n_pages = self._pages_needed(req)
            match = self._match_prefix(req)     # pins matched pages
            shared = list(match.pages) if match is not None else []
            fresh = n_pages - len(shared)
            if not self.alloc.can_alloc(fresh):
                if match is not None:
                    self._unpin_match(match)
                break                      # FIFO: don't starve the head
            self.waiting.pop(0)
            if self.spec is not None:
                # lazy commit: reserve the lifetime worst case, pop only
                # the prompt's pages now; rounds commit/roll back the rest
                n0 = -(-req.n_prompt // self.ecfg.page_size)
                self.alloc.reserve(fresh)
                req.pages = shared + self.alloc.alloc(n0 - len(shared),
                                                      reserved=True)
                req.reserved_left = fresh - (n0 - len(shared))
            else:
                req.pages = shared + self.alloc.alloc(fresh)
            if match is not None:
                # stats count admissions, not retries: a request that
                # waited several ticks for pages is still one query
                self.prefix_queries += 1
                req.prefill_skip = req.prefill_done = match.tokens
                self.prefix_hits += match.tokens > 0
                self.prefill_tokens_saved += match.tokens
                if match.cow is not None:
                    src, rows = match.cow
                    # copy now, while the source pin is held; afterwards
                    # the source's content no longer matters to us
                    self._cow_copy(src, req.pages[len(shared)], rows)
                    self.alloc.free([src])
            if self.adaptive is not None:
                req.rung = self.adaptive.start_rung
                req.ctrl = CTRL.init_state(self.adaptive)
            req.slot, req.state, req.t_admit = slot, PREFILL, now
            self.slots[slot] = req
            # the table row stays scratch until prefill lands: a PREFILL
            # slot rides decode steps as idle and must not touch its pages

    def _finish(self, req: Request, now: float):
        self.alloc.free(req.pages)
        req.pages = []
        if req.reserved_left:
            self.alloc.unreserve(req.reserved_left)
            req.reserved_left = 0
        self._table[req.slot] = KV.SCRATCH_PAGE
        self.slots[req.slot] = None
        req.slot = -1
        req.state, req.t_finish = FINISHED, now
        self.finished.append(req)
        self._tables_dirty = True

    def _commit_pages(self, req: Request, n_rows: int) -> bool:
        """Commit pages out of the request's reservation until its block
        table covers `n_rows` timeline rows.  Returns True when the host
        table changed (caller syncs before the next device step)."""
        need = -(-n_rows // self.ecfg.page_size) - len(req.pages)
        if need <= 0:
            return False
        if need > req.reserved_left:
            raise RuntimeError(
                f"request {req.rid}: {n_rows} rows need {need} more pages "
                f"but only {req.reserved_left} are reserved (reservation "
                "accounting bug)")
        for pid in self.alloc.alloc(need, reserved=True):
            self._table[req.slot, len(req.pages)] = pid
            req.pages.append(pid)
        req.reserved_left -= need
        return True

    def _rollback(self, req: Request, n_rows: int):
        """Free committed pages past the accepted timeline (`n_rows`
        valid rows) back into the request's reservation and point the
        truncated block-table tail at scratch.  Pages holding only
        rejected-draft rows return here; pages the accepted timeline
        still touches are kept (stale rows inside them are masked by
        position and overwritten by the next round's writes)."""
        keep = -(-n_rows // self.ecfg.page_size)
        drop = req.pages[keep:]
        if not drop:
            return
        self.alloc.free(drop, to_reserved=True)
        req.reserved_left += len(drop)
        req.pages = req.pages[:keep]
        self._table[req.slot, keep:] = KV.SCRATCH_PAGE
        self._tables_dirty = True

    def _prefill_step(self, req: Request, now: float) -> int:
        """Run one prompt chunk; returns real tokens consumed."""
        e = self.ecfg
        c0 = req.prefill_done
        if req.prefill_skip > 0 and c0 == req.prefill_skip:
            # first chunk of a prefix-hit request: pull the matched rows
            # out of its (shared/CoW) pages into staging, then prefill
            # only from the divergence point
            self._load_prefix_to_staging(req)
        n = min(e.prefill_chunk, req.n_prompt - c0)
        if c0 % e.prefill_chunk:
            # realign a warm start to the chunk grid with one short
            # chunk, so every later fixed-size window stays inside the
            # staging cache (S_max is a chunk multiple; chunk splits do
            # not change numerics — rows are quantized before attention)
            n = min(n, e.prefill_chunk - c0 % e.prefill_chunk)
        chunk = np.zeros((1, e.prefill_chunk), np.int32)
        chunk[0, :n] = req.prompt[c0:c0 + n]
        logits, self._staging = self._prefill_fn(
            self.params, {"tokens": jnp.asarray(chunk),
                          "index": jnp.int32(c0)}, self._staging)
        req.prefill_done += n
        if req.prefill_done == req.n_prompt:
            self._scatter_staging_to_pages(req)
            self._table[req.slot, :len(req.pages)] = req.pages
            self._tables_dirty = True
            if self.prefix is not None:
                # only now do the pages hold the prompt's rows; register
                # the pure full-prompt blocks for later requests to hit
                self.prefix.insert(req.prompt, req.pages)
            # the first generated token sits at timeline index n_prompt;
            # greedy configs reduce to the original argmax bit-for-bit
            first = int(SMP.sample_tokens(
                logits[:, n - 1], jnp.asarray([req.rid], jnp.int32),
                jnp.asarray([req.n_prompt], jnp.int32), self.sampler)[0])
            req.out_tokens.append(first)
            req.pos = req.n_prompt
            req.state, req.t_first = DECODE, now
            self._maybe_finish(req, first, now)
        return n

    def _live_batch(self):
        """(live requests, tokens (B,1), positions (B,), rids (B,)) for
        one fixed-shape step; idle slots ride along pointing at scratch."""
        e = self.ecfg
        live = [r for r in self.slots if r is not None and r.state == DECODE]
        tokens = np.zeros((e.max_batch, 1), np.int32)
        positions = np.zeros((e.max_batch,), np.int32)
        rids = np.zeros((e.max_batch,), np.int32)
        for r in live:
            tokens[r.slot, 0] = r.out_tokens[-1]
            positions[r.slot] = r.pos
            rids[r.slot] = r.rid
        return live, tokens, positions, rids

    def _decode_batch(self, now: float) -> int:
        """One batched decode step over every DECODE-state slot."""
        live, tokens, positions, rids = self._live_batch()
        if not live:
            return 0
        with self._tp_scope():
            nxt, self.caches = self._decode_fn(
                self.params, {"tokens": jnp.asarray(tokens),
                              "index": jnp.asarray(positions)}, self.caches,
                jnp.asarray(rids))
        nxt = np.asarray(nxt)
        for r in live:
            tok = int(nxt[r.slot])
            r.pos += 1
            r.out_tokens.append(tok)
            self._maybe_finish(r, tok, now)
        return len(live)

    def _spec_round(self, now: float, live: List[Request], k: int,
                    draft_fn, accept_fn, rung_i: Optional[int] = None) -> int:
        """One speculative round over the `live` participants: k draft
        steps under the draft policy, one k+1-token verify pass under
        the serving policy, rejection-sampled acceptance, then paged-KV
        rollback of pages holding only rejected rows.  Returns the
        token-budget cost: the round really runs 2k+1 model tokens per
        participant (k draft + k+1 verify).

        `live` may be a *subset* of the DECODE slots (adaptive mode
        batches by rung).  The fixed-shape batch still carries every
        DECODE slot at its real (last token, position) — non-
        participants are ghost riders: their stray K/V writes land at
        rows >= pos (stale territory their own next round rewrites
        before any read) or on the scratch page (rows past their
        committed tables), never over committed history; their sampled
        draws burn no RNG state (stateless threefry keyed on (seed,
        rid, index)); and only participants' outputs are read back."""
        e = self.ecfg
        _, tokens, positions, rids = self._live_batch()
        # commit pages for the participants' draft window (rows pos ..
        # pos+k) and push the grown tables before anything reads them
        dirty = [self._commit_pages(r, r.pos + k + 1) for r in live]
        if any(dirty) or self._tables_dirty:
            self._sync_tables()
            self._tables_dirty = False
        toks = jnp.asarray(tokens)
        pos = jnp.asarray(positions)
        rid_arr = jnp.asarray(rids)
        cur, drafts, draft_probs = toks, [], []
        with self._tp_scope():
            for i in range(k):
                d, q, self.caches = draft_fn(
                    self.params, {"tokens": cur, "index": pos + i},
                    self.caches, rid_arr)
                drafts.append(d)
                draft_probs.append(q)
                cur = d[:, None]
            drafts = jnp.stack(drafts, axis=1)               # (B, k)
            logits, self.caches = self._verify_fn(
                self.params,
                {"tokens": jnp.concatenate([toks, drafts], axis=1),
                 "index": pos}, self.caches)
        emitted, acc = accept_fn(
            drafts, None if self.sampler.greedy
            else jnp.stack(draft_probs, axis=1), logits, rid_arr, pos)
        emitted, acc = np.asarray(emitted), np.asarray(acc)
        self.spec_rounds += 1
        self.spec_request_rounds += len(live)
        if rung_i is not None:
            self.rung_rounds[rung_i] += 1
        for r in live:
            a = int(acc[r.slot])
            self.drafted += k
            self.drafts_accepted += a
            emit = [int(emitted[r.slot, j])
                    for j in range(min(a + 1, r.max_new - r.n_generated))]
            for j, tok in enumerate(emit):
                if tok == e.eos_id:
                    emit = emit[:j + 1]
                    break
            r.out_tokens.extend(emit)
            r.pos += len(emit)
            self.spec_emitted += len(emit)
            if rung_i is not None:
                self.rung_drafted[rung_i] += k
                self.rung_accepted[rung_i] += a
                self.rung_emitted[rung_i] += len(emit)
            if r.n_generated >= r.max_new or emit[-1] == e.eos_id:
                self._finish(r, now)
            else:
                self._rollback(r, r.pos)
                if rung_i is not None:
                    # pure feedback update — no wall clock, no RNG; the
                    # seam is overridable so tests can drive adversarial
                    # (e.g. switch-every-round) trajectories
                    r.ctrl, nxt = self._ctrl_step(self.adaptive, r.ctrl,
                                                  a, k)
                    if nxt != r.rung:
                        self.ctrl_switches += 1
                        if nxt < r.rung:
                            self.ctrl_demotes += 1
                        else:
                            self.ctrl_promotes += 1
                        r.rung = nxt
        return len(live) * (2 * k + 1)

    def _spec_decode_batch(self, now: float) -> int:
        """One static-draft speculative round over every DECODE slot."""
        live = [r for r in self.slots if r is not None and r.state == DECODE]
        if not live:
            return 0
        return self._spec_round(now, live, self.spec.k, self._draft_fn,
                                self._accept_fn)

    def _spec_decode_all(self, now: float) -> int:
        """Adaptive tick: batch live requests by current rung, run one
        speculative round per non-empty rung group (groups snapshot up
        front — a request that switches rungs during its own round is
        not served twice in one tick)."""
        live = [r for r in self.slots if r is not None and r.state == DECODE]
        if not live:
            return 0
        groups = [[r for r in live if r.rung == i]
                  for i in range(len(self.rungs))]
        cost = 0
        for i, group in enumerate(groups):
            if not group:
                continue
            rg = self.rungs[i]
            t0 = time.monotonic()
            cost += self._spec_round(now, group, rg.k, rg.draft_fn,
                                     rg.accept_fn, rung_i=i)
            self.rung_wall[i] += time.monotonic() - t0
        return cost

    def _maybe_finish(self, req: Request, tok: int, now: float):
        if req.n_generated >= req.max_new or tok == self.ecfg.eos_id:
            self._finish(req, now)

    def step(self, now: float = 0.0):
        """One scheduler tick: admit, decode the running batch, spend the
        leftover token budget on prefill chunks."""
        self._admit(now)
        budget = self.ecfg.token_budget
        if self.adaptive is not None:
            budget -= self._spec_decode_all(now)
        elif self.spec is not None:
            budget -= self._spec_decode_batch(now)
        else:
            budget -= self._decode_batch(now)
        while budget > 0:
            pre = [r for r in self.slots
                   if r is not None and r.state == PREFILL]
            if not pre:
                break
            # a partially-prefilled request MUST keep the baton until its
            # prompt is fully staged: the staging cache is shared, so
            # switching mid-prefill would interleave two prompts' rows
            # (there is at most one partial request by induction; a
            # prefix-hit request starts at prefill_done == prefill_skip,
            # so "untouched" is done == skip, not done == 0).  Ties on
            # t_admit (same tick) then break by admission order (rid)
            budget -= self._prefill_step(
                min(pre, key=lambda r: (r.prefill_done == r.prefill_skip,
                                        r.t_admit, r.rid)), now)
        self._admit(now)        # freed slots/pages admit within the tick
        if self._tables_dirty:
            # one device sync per tick, after all finish/prefill events —
            # the next tick's decode reads tables through the cache pytree.
            # Deferring past _finish is safe: the freed slot's stale row
            # only matters to decode, which never runs before this sync
            self._sync_tables()
            self._tables_dirty = False
        self.peak_live_tokens = max(self.peak_live_tokens,
                                    self.live_tokens())
        self.n_steps += 1

    def live_tokens(self) -> int:
        return sum(r.pos for r in self.slots if r is not None)

    def reset_stats(self):
        """Clear accounting between workloads (keeps compiled steps, the
        page pool, AND any resident cached prefixes — a warm cache is
        the point; only legal when nothing is in flight)."""
        if any(self.slots) or self.waiting:
            raise RuntimeError("reset_stats with requests in flight")
        self.finished = []
        self.peak_live_tokens = 0
        self.n_steps = 0
        self.spec_rounds = 0
        self.spec_request_rounds = 0
        self.drafted = 0
        self.drafts_accepted = 0
        self.spec_emitted = 0
        self.prefix_queries = 0
        self.prefix_hits = 0
        self.prefill_tokens_saved = 0
        self.cow_copies = 0
        self.rung_rounds = [0] * len(self.rungs)
        self.rung_drafted = [0] * len(self.rungs)
        self.rung_accepted = [0] * len(self.rungs)
        self.rung_emitted = [0] * len(self.rungs)
        self.rung_wall = [0.0] * len(self.rungs)
        self.ctrl_switches = 0
        self.ctrl_demotes = 0
        self.ctrl_promotes = 0
        self.alloc.peak_in_use = self.alloc.in_use

    def run(self, requests: List[Request]) -> dict:
        """Serve an open-loop workload to completion; returns `report()`.

        Requests arrive at wall-clock `arrival` offsets; the engine idles
        (sleeps) when nothing is live and the next arrival is in the
        future."""
        pending = sorted(requests, key=lambda r: r.arrival)
        t0 = time.monotonic()
        while pending or self.waiting or any(self.slots):
            now = time.monotonic() - t0
            while pending and pending[0].arrival <= now:
                self.submit(pending.pop(0))
            if not self.waiting and not any(self.slots):
                time.sleep(min(0.001, max(0.0,
                                          pending[0].arrival - now)))
                continue
            self.step(now)
        wall = time.monotonic() - t0
        return self.report(wall)

    # -- accounting --------------------------------------------------------

    def kv_bytes_report(self) -> dict:
        """Cache bytes from *actual per-request lengths* (live or peak
        tokens), vs the static (B, S_max) baselines — both the f32 seed
        cache and the format-width static cache the engine replaces."""
        e, cfg, pol = self.ecfg, self.cfg, self.pol
        n_attn = self._n_groups + self._n_tail
        live = KV.paged_kv_cache_nbytes(
            self.peak_live_tokens, self.alloc.peak_in_use, e.page_size,
            cfg.n_kv_heads, cfg.hd, fmt=pol.fmt_kv, packed=pol.kv_packed)
        static = KV.kv_cache_nbytes(e.max_batch, e.s_max, cfg.n_kv_heads,
                                    cfg.hd, fmt=pol.fmt_kv,
                                    packed=pol.kv_packed)
        return {
            "live_bytes": live["live"] * n_attn,
            "paged_bytes": live["paged"] * n_attn,
            "static_bytes": static["total"] * n_attn,
            "static_f32_bytes": static["f32_total"] * n_attn,
            "peak_live_tokens": self.peak_live_tokens,
            "page_util": self.alloc.peak_in_use / (self.alloc.capacity - 1),
            "pages_peak": self.alloc.peak_in_use,
            "pages_total": self.alloc.capacity - 1,
        }

    def report(self, wall: float) -> dict:
        # re-describe at report time: the decode step re-resolves its
        # route per trace (e.g. REPRO_PAGED_KERNEL flipped after
        # construction), and the report must state what actually ran
        self.plan = exec_plan.describe("paged_decode", self.pol,
                                       **self._plan_ctx)
        lat = np.array([r.t_finish - r.arrival for r in self.finished])
        ttft = np.array([r.t_first - r.arrival for r in self.finished])
        gen = sum(r.n_generated for r in self.finished)
        kv = self.kv_bytes_report()
        rep = {
            "n_requests": len(self.finished),
            "wall_s": wall,
            "steps": self.n_steps,
            "gen_tokens": gen,
            # 0.0 (not inf) on a zero-length wall: the report must stay
            # strict JSON (json.dumps(..., allow_nan=False) round-trips)
            "tokens_per_s": gen / wall if wall > 0 else 0.0,
            "p50_latency_s": float(np.percentile(lat, 50)) if len(lat) else 0.0,
            "p99_latency_s": float(np.percentile(lat, 99)) if len(lat) else 0.0,
            "p50_ttft_s": float(np.percentile(ttft, 50)) if len(ttft) else 0.0,
            "decode_route": self.plan["route"],
            "decode_backend": self.plan["backend"],
            "decode_selection": self.plan["selection"],
            "decode_bytes_per_step_layer": self.plan["bytes_moved"],
            "temperature": self.sampler.temperature,
            **kv,
        }
        rep["tp"] = self.tp
        if self.ecfg.tp > 1:
            rep["tp_requested"] = self.ecfg.tp
            if self.tp_fallback:
                rep["tp_fallback_reason"] = self.tp_fallback
        if self.tp > 1:
            # wire + residency accounting from the *actual device
            # arrays*, not the bytes model: one decode step all-gathers
            # each layer's pool shards, so each device receives
            # (tp-1)/tp of the codes+scales pool per layer
            g = self.caches["groups"]["p0"]
            pool_layer = sum(int(g[k].nbytes)
                             for k in KV.QUANT_KEYS) // self._n_groups
            f32_layer = 2 * 4 * (self.ecfg.n_pages * self.ecfg.page_size
                                 * self.cfg.n_kv_heads * self.cfg.hd)
            frac = (self.tp - 1) / self.tp
            rep.update({
                "tp_wire_bytes_per_step_layer": int(frac * pool_layer),
                "tp_wire_reduction_vs_f32": f32_layer / pool_layer,
                "pool_bytes_per_device": kv["paged_bytes"] // self.tp,
            })
        if self.spec is not None:
            # re-describe like the decode plan above: the report states
            # which kernel drafted and which verified
            self.draft_plan = exec_plan.describe(
                "paged_decode", self.draft_pol, **self._plan_ctx)
            self.verify_plan = exec_plan.describe(
                "verify_attn", self.pol, sq=self.spec.k + 1,
                **self._plan_ctx)
            rep.update({
                "spec_draft_policy": self.spec.draft_policy,
                "spec_k": self.spec.k,
                "spec_rounds": self.spec_rounds,
                "acceptance_rate": (self.drafts_accepted / self.drafted
                                    if self.drafted else 0.0),
                # tokens one request advances per round it participates
                # in — the speculative speedup knob, in [1, k+1]
                "eff_tokens_per_round": (self.spec_emitted
                                         / self.spec_request_rounds
                                         if self.spec_request_rounds
                                         else 0.0),
                "draft_route": self.draft_plan["route"],
                "draft_backend": self.draft_plan["backend"],
                "verify_route": self.verify_plan["route"],
                "verify_backend": self.verify_plan["backend"],
            })
        if self.adaptive is not None:
            # per-rung breakdown; the global acceptance_rate stays the
            # drafted-token-weighted aggregate over rungs (== the old
            # scalar when the ladder has one rung)
            tw = sum(self.rung_wall)
            rungs = []
            for i, rg in enumerate(self.rungs):
                # re-describe per rung, like the decode plan above
                rg.plan = exec_plan.describe("paged_decode", rg.pol,
                                             **self._plan_ctx)
                rungs.append({
                    "policy": rg.name,
                    "k": rg.k,
                    "rounds": self.rung_rounds[i],
                    "drafted": self.rung_drafted[i],
                    "accepted": self.rung_accepted[i],
                    "acceptance_rate": (self.rung_accepted[i]
                                        / self.rung_drafted[i]
                                        if self.rung_drafted[i] else 0.0),
                    "emitted": self.rung_emitted[i],
                    "wall_share": (self.rung_wall[i] / tw
                                   if tw > 0 else 0.0),
                    "draft_route": rg.plan["route"],
                    "draft_backend": rg.plan["backend"],
                })
            rep.update({
                "adaptive_ladder": [rg.name for rg in self.rungs],
                "adaptive_switches": self.ctrl_switches,
                "adaptive_demotes": self.ctrl_demotes,
                "adaptive_promotes": self.ctrl_promotes,
                "adaptive_rungs": rungs,
                "spec_rounds": self.spec_rounds,
                "acceptance_rate": (self.drafts_accepted / self.drafted
                                    if self.drafted else 0.0),
                "eff_tokens_per_round": (self.spec_emitted
                                         / self.spec_request_rounds
                                         if self.spec_request_rounds
                                         else 0.0),
            })
        if self.prefix is not None:
            e, cfg, pol = self.ecfg, self.cfg, self.pol
            n_attn = self._n_groups + self._n_tail
            resident = KV.paged_kv_cache_nbytes(
                0, self.prefix.n_pages, e.page_size, cfg.n_kv_heads,
                cfg.hd, fmt=pol.fmt_kv, packed=pol.kv_packed)
            rep.update({
                "prefix_queries": self.prefix_queries,
                "prefix_hits": self.prefix_hits,
                "prefix_hit_rate": (self.prefix_hits / self.prefix_queries
                                    if self.prefix_queries else 0.0),
                "prefill_tokens_saved": self.prefill_tokens_saved,
                "prefix_cow_copies": self.cow_copies,
                "resident_prefix_pages": self.prefix.n_pages,
                # what keeping the cached prefixes warm actually costs at
                # format width (quantized pages make residency cheap)
                "resident_prefix_bytes": resident["paged"] * n_attn,
            })
        if self.cfg.is_moe:
            # re-describe like the decode plan: which grouped kernel the
            # expert contraction actually ran
            self.moe_plan = exec_plan.describe("grouped_matmul", self.pol,
                                               **self._moe_ctx)
            cfg = self.cfg
            n_mats = 3 if cfg.act == "silu" else 2
            n_w = (cfg.n_layers * n_mats * cfg.n_experts
                   * cfg.d_model * cfg.d_ff)
            w_bytes = operand_nbytes(n_w, self.pol.fmt_weights,
                                     packed=self.pol.packed)
            rep.update({
                "moe_experts": cfg.n_experts,
                "moe_top_k": cfg.top_k,
                "moe_grouped_route": self.moe_plan["route"],
                "moe_grouped_backend": self.moe_plan["backend"],
                "moe_grouped_selection": self.moe_plan["selection"],
                "moe_grouped_bytes_per_step_layer":
                    self.moe_plan["bytes_moved"],
                # expert weights through the grouped route's operand
                # interface, all layers x (gate/up/down) mats — vs the
                # f32 residency the seed's experts burned
                "expert_w_bytes": w_bytes,
                "expert_w_bytes_f32": 4 * n_w,
                "expert_w_reduction_vs_f32": 4 * n_w / w_bytes,
            })
        return rep


def format_report(rep: dict, policy: str) -> str:
    """The serve.py report lines: throughput/latency + honest cache bytes
    (counted from actual per-request lengths, not B x S_max) + page-
    allocator utilization."""
    mb = 1e6
    return (
        f"engine: {rep['n_requests']} reqs, {rep['gen_tokens']} tokens in "
        f"{rep['wall_s']:.2f}s ({rep['tokens_per_s']:.1f} tok/s, "
        f"{rep['steps']} steps, policy={policy})\n"
        f"latency: p50 {rep['p50_latency_s'] * 1e3:.0f} ms, "
        f"p99 {rep['p99_latency_s'] * 1e3:.0f} ms, "
        f"ttft p50 {rep['p50_ttft_s'] * 1e3:.0f} ms\n"
        f"kv-cache: peak live {rep['live_bytes'] / mb:.2f} MB "
        f"({rep['peak_live_tokens']} tokens) in "
        f"{rep['paged_bytes'] / mb:.2f} MB of pages vs static "
        f"{rep['static_bytes'] / mb:.2f} MB (B x S_max, same format) / "
        f"f32 {rep['static_f32_bytes'] / mb:.2f} MB; "
        f"page util peak {rep['page_util']:.0%} "
        f"({rep['pages_peak']}/{rep['pages_total']} pages)\n"
        f"plan: decode via {rep['decode_route']} "
        f"[{rep['decode_backend']}, {rep['decode_selection']}], "
        f"{rep['decode_bytes_per_step_layer'] / 1e3:.1f} KB KV moved "
        "per step/layer"
        + (f"\nspec: draft k={rep['spec_k']} under "
           f"{rep['spec_draft_policy']} via {rep['draft_route']} "
           f"[{rep['draft_backend']}], verify via {rep['verify_route']} "
           f"[{rep['verify_backend']}]; acceptance "
           f"{rep['acceptance_rate']:.0%}, "
           f"{rep['eff_tokens_per_round']:.2f} tokens/round over "
           f"{rep['spec_rounds']} rounds"
           if "spec_k" in rep else "")
        + ((f"\nadaptive: {len(rep['adaptive_rungs'])}-rung ladder, "
            f"{rep['adaptive_switches']} switches "
            f"({rep['adaptive_demotes']} demote, "
            f"{rep['adaptive_promotes']} promote); acceptance "
            f"{rep['acceptance_rate']:.0%}, "
            f"{rep['eff_tokens_per_round']:.2f} tokens/round over "
            f"{rep['spec_rounds']} rounds\n"
            + "\n".join(
                f"  rung {i}: {r['policy']} (k={r['k']}) acceptance "
                f"{r['acceptance_rate']:.0%}, {r['rounds']} rounds, "
                f"{r['drafted']} drafted, {r['emitted']} emitted, "
                f"{r['wall_share']:.0%} of spec wall via "
                f"{r['draft_route']} [{r['draft_backend']}]"
                for i, r in enumerate(rep["adaptive_rungs"])))
           if "adaptive_rungs" in rep else "")
        + (f"\nprefix: {rep['prefix_hits']}/{rep['prefix_queries']} hits "
           f"({rep['prefix_hit_rate']:.0%}), "
           f"{rep['prefill_tokens_saved']} prefill tokens saved, "
           f"{rep['prefix_cow_copies']} CoW copies; "
           f"{rep['resident_prefix_pages']} resident pages "
           f"({rep['resident_prefix_bytes'] / mb:.2f} MB at format width)"
           if "prefix_hit_rate" in rep else "")
        + (f"\ntp: {rep['tp']} devices on \"model\", pool "
           f"{rep['pool_bytes_per_device'] / mb:.2f} MB/device; wire "
           f"{rep['tp_wire_bytes_per_step_layer'] / 1e3:.1f} KB "
           f"codes+scales per step/layer "
           f"({rep['tp_wire_reduction_vs_f32']:.1f}x under an f32 wire)"
           if rep.get("tp", 1) > 1 else "")
        + (f"\ntp: requested {rep['tp_requested']}, serving replicated — "
           f"{rep['tp_fallback_reason']}"
           if "tp_fallback_reason" in rep else "")
        + (f"\nmoe: {rep['moe_experts']} experts top-{rep['moe_top_k']}, "
           f"grouped via {rep['moe_grouped_route']} "
           f"[{rep['moe_grouped_backend']}, "
           f"{rep['moe_grouped_selection']}]; expert weights "
           f"{rep['expert_w_bytes'] / mb:.2f} MB at format width vs f32 "
           f"{rep['expert_w_bytes_f32'] / mb:.2f} MB "
           f"({rep['expert_w_reduction_vs_f32']:.1f}x), "
           f"{rep['moe_grouped_bytes_per_step_layer'] / 1e3:.1f} KB "
           "expert operands per step/layer"
           if "moe_experts" in rep else ""))
