"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module does not touch jax device state.  The production target is a TPU
v5e pod of 16x16 = 256 chips; multi-pod doubles it with a leading "pod"
axis (DP across pods, whose ICI/DCN links are the scarce resource —
see distributed.collectives for the compressed cross-pod reduction).
"""
from __future__ import annotations

import jax


def _mesh(shape, axes):
    # jax.sharding.AxisType landed after 0.4.x; Auto is the default there,
    # so only pass axis_types when the installed JAX knows about it.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_host_mesh(n_data: int = None, n_model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    n_data = n_data or (n // n_model)
    return _mesh((n_data, n_model), ("data", "model"))
