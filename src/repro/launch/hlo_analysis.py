"""Post-compile HLO analysis: collective traffic + roofline terms.

`collective_bytes` parses the optimized HLO text and accounts each
communication op with a ring-model byte estimate per device:

    all-gather        out_bytes * (n-1)/n          (~out_bytes)
    all-reduce        out_bytes * 2(n-1)/n         (~2x)
    reduce-scatter    out_bytes * (n-1)            (~input bytes)
    all-to-all        out_bytes * (n-1)/n
    collective-permute out_bytes

where n is the participant-group size parsed from replica_groups (both
explicit {{...}} and iota [a,b]<=[...] forms).  The raw per-op records
are kept so EXPERIMENTS.md can show the schedule, not just the sum.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "f8e4m3": 1, "f8e8m0fnu": 1, "f4e2m1fn": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\]))\S*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    b = n * _DTYPE_BYTES[dtype]
    return b if _DTYPE_BYTES[dtype] >= 1 else n // 2


def _result_bytes(shape_str: str) -> int:
    """Largest component of the (possibly tuple) result shape."""
    best = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        best = max(best, _shape_bytes(dtype, dims))
    return best


def _group_size(line: str):
    m = _GROUPS_EXPL_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    return None


_COMP_HDR_RE = re.compile(r"^%?([\w.\-]+)\s*\(.*\)\s*->", re.M)
_WHILE_RE = re.compile(
    r"while\(.*?\),\s*condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CALL_RE = re.compile(r"(?:to_apply|calls)=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _split_computations(hlo_text: str):
    """-> {comp_name: body_text} by splitting on computation headers."""
    comps = {}
    spans = [(m.start(), m.group(1)) for m in _COMP_HDR_RE.finditer(hlo_text)]
    # the entry computation header uses "ENTRY %name"
    for m in re.finditer(r"^ENTRY\s+%?([\w.\-]+)", hlo_text, re.M):
        spans.append((m.start(), m.group(1)))
    spans.sort()
    for i, (start, name) in enumerate(spans):
        end = spans[i + 1][0] if i + 1 < len(spans) else len(hlo_text)
        comps[name] = hlo_text[start:end]
    return comps


def _wire_bytes(kind: str, out_b: float, n: int) -> float:
    frac = (n - 1) / n
    if kind == "all-gather":
        return out_b * frac
    if kind == "all-reduce":
        return out_b * 2 * frac
    if kind == "reduce-scatter":
        return out_b * (n - 1)
    if kind == "all-to-all":
        return out_b * frac
    return out_b          # collective-permute


def collective_bytes(hlo_text: str):
    """-> (per-device wire bytes by op kind, op records).

    `while` bodies are multiplied by their trip count (scan-over-layers
    programs put most collectives inside loops; XLA's own cost analysis
    counts them once).  Trip counts are read from the largest integer
    constant in each loop's condition computation — exact for lax.scan
    lowerings (induction 0..N-1 against constant N).
    """
    comps = _split_computations(hlo_text)
    if not comps:
        comps = {"__all__": hlo_text}

    # per-computation local collective tallies
    local = {}
    for name, body in comps.items():
        totals = defaultdict(float)
        records = []
        for line in body.splitlines():
            m = _COLL_RE.search(line)
            if not m:
                continue
            shape_str, kind = m.group(1), m.group(2)
            out_b = _result_bytes(shape_str)
            n = _group_size(line) or 2
            wire = _wire_bytes(kind, out_b, n)
            totals[kind] += wire
            records.append(dict(kind=kind, out_bytes=out_b, group=n,
                                wire_bytes=wire))
        local[name] = (totals, records)

    # call graph with while-trip multipliers
    children = {name: [] for name in comps}
    for name, body in comps.items():
        for m in _WHILE_RE.finditer(body):
            cond, wbody = m.group(1), m.group(2)
            consts = [int(c) for c in _CONST_RE.findall(comps.get(cond, ""))]
            trips = max(consts) if consts else 1
            children[name].append((wbody, max(trips, 1)))
        for m in _CALL_RE.finditer(body):
            callee = m.group(1)
            if callee in comps:
                children[name].append((callee, 1))

    def roll_up(name, seen):
        if name in seen or name not in local:   # cycle / unknown guard
            return defaultdict(float), []
        seen = seen | {name}
        totals = defaultdict(float, local[name][0])
        records = list(local[name][1])
        for callee, mult in children.get(name, []):
            ct, cr = roll_up(callee, seen)
            for k, v in ct.items():
                totals[k] += v * mult
            for r in cr:
                records.append(dict(r, wire_bytes=r["wire_bytes"] * mult,
                                    in_loop=mult))
        return totals, records

    entry = None
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo_text, re.M)
    if m and m.group(1) in comps:
        entry = m.group(1)
    if entry is None:
        # fall back: sum every computation once
        agg = defaultdict(float)
        recs = []
        for t, r in local.values():
            for k, v in t.items():
                agg[k] += v
            recs.extend(r)
        return dict(agg), recs
    totals, records = roll_up(entry, frozenset())
    return dict(totals), records


# -----------------------------------------------------------------------------
# roofline terms (TPU v5e constants from the assignment)
# -----------------------------------------------------------------------------

PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # B/s per chip
ICI_BW = 50e9                   # B/s per link


def roofline_terms(flops, hbm_bytes, coll_bytes, n_chips,
                   peak_scale: float = 1.0):
    """All inputs are whole-program totals per device-program; flops/bytes
    from cost_analysis are per-device in SPMD modules."""
    compute_s = flops / (PEAK_FLOPS_BF16 * peak_scale)
    memory_s = hbm_bytes / HBM_BW
    coll_s = coll_bytes / ICI_BW
    dominant = max((compute_s, "compute"), (memory_s, "memory"),
                   (coll_s, "collective"))[1]
    total = max(compute_s, memory_s, coll_s)
    return dict(compute_s=compute_s, memory_s=memory_s,
                collective_s=coll_s, dominant=dominant, bound_s=total,
                n_chips=n_chips)


def model_flops(cfg, shape, kind: str) -> float:
    """MODEL_FLOPS: 6*N*D train (fwd+bwd), 2*N*D inference, N = active."""
    n = cfg.n_active_params
    if kind == "train":
        tokens = shape["batch"] * shape["seq"]
        return 6.0 * n * tokens
    if kind == "prefill":
        tokens = shape["batch"] * shape["seq"]
        return 2.0 * n * tokens
    return 2.0 * n * shape["batch"]     # decode: one token per sequence


# -----------------------------------------------------------------------------
# execution-plan annotation: which kernel actually ran
# -----------------------------------------------------------------------------

def plan_routes(policy, shapes=None):
    """-> {op: exec_plan.describe(...)} for the DPA ops a serving step
    exercises under `policy`.

    HLO text names fused XLA computations, not the repo's kernels; this
    resolves the same execution-plan routes the model code resolves, so
    an HLO/roofline report can state which kernel served each op
    (`describe()` carries route, backend, predicate results, and the
    bytes-moved estimate).  `shapes` optionally overrides the per-op ctx
    (e.g. {"paged_decode": {"page_size": 16, "max_pages": 8, ...}})."""
    from repro.core import exec_plan
    from repro.core.policy import get_policy
    pol = get_policy(policy)
    ctx = {
        "matmul": {"w_dtype": "float32"},
        "flash_attn": {"sq": 128, "skv": 128, "use_flash": True},
        "decode_attn": {"s_ctx": 128},
        "paged_decode": {"page_size": 16, "max_pages": 8},
        "verify_attn": {"page_size": 16, "max_pages": 8, "sq": 4},
    }
    for op, over in (shapes or {}).items():
        ctx.setdefault(op, {}).update(over)
    out = {}
    for op, c in ctx.items():
        try:
            out[op] = exec_plan.describe(op, pol, **c)
        except exec_plan.PlanError:
            out[op] = None           # policy has no viable route (e.g.
                                     # raw-f32 cache has no paged decode)
    return out
