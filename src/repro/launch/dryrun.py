import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=" +
                           os.environ.get("REPRO_DRYRUN_DEVICES", "512")
                           ).strip()
# ^ MUST run before any jax import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the production mesh (16x16 single-pod /
2x16x16 multi-pod placeholder devices), constructs ShapeDtypeStruct
inputs (launch.specs — no allocation), applies the sharding rules
(distributed.sharding), and runs jit(...).lower(...).compile().  The
compiled artifact yields:

  memory_analysis()  — proves the program fits per-device HBM
  cost_analysis()    — HLO FLOPs / bytes for the roofline terms
  as_text()          — the collective schedule (launch.hlo_analysis)

Records land in experiments/dryrun/<arch>__<shape>__<mesh>.json and are
aggregated by launch.roofline into EXPERIMENTS.md tables.

Usage:
  python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import SHAPES, cell_applicable, list_archs
from repro.distributed import sharding as shd
from repro.distributed.step import (make_prefill_step, make_serve_step,
                                    make_train_step)
from repro.launch import hlo_analysis as hlo
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import cell_specs  # noqa: E402
from repro.models import build_model
from repro.optim.adamw import AdamWConfig


def _mem_analysis(compiled):
    try:
        ma = compiled.memory_analysis()
        if ma is None:
            return {}
        keys = ("generated_code_size_in_bytes", "argument_size_in_bytes",
                "output_size_in_bytes", "temp_size_in_bytes",
                "alias_size_in_bytes", "host_generated_code_size_in_bytes",
                "host_argument_size_in_bytes", "host_temp_size_in_bytes")
        return {k: getattr(ma, k) for k in keys if hasattr(ma, k)}
    except Exception as e:                                # pragma: no cover
        return {"error": repr(e)}


def _cost_analysis(compiled):
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        return {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float))}
    except Exception as e:                                # pragma: no cover
        return {"error": repr(e)}


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               cfg_override=None):
    """-> (lowered, compiled, record_dict)."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg, kind, specs = cell_specs(arch, shape_name, cfg_override)
    model = build_model(cfg)
    shd.set_mesh_plan(cfg.mesh_plan)
    t0 = time.monotonic()
    with mesh:
        if kind == "train":
            step = make_train_step(model, AdamWConfig())
            state_sh = {
                "params": shd.make_param_shardings(specs["state"]["params"],
                                                   mesh),
                "opt": {"m": shd.make_param_shardings(
                            specs["state"]["opt"]["m"], mesh),
                        "v": shd.make_param_shardings(
                            specs["state"]["opt"]["v"], mesh),
                        "count": jax.sharding.NamedSharding(
                            mesh, jax.sharding.PartitionSpec())},
            }
            batch_sh = shd.batch_spec(specs["batch"], mesh)
            jitted = jax.jit(step, in_shardings=(state_sh, batch_sh),
                             donate_argnums=(0,))
            lowered = jitted.lower(specs["state"], specs["batch"])
        elif kind == "prefill":
            step = make_prefill_step(model)
            p_sh = shd.make_param_shardings(specs["params"], mesh,
                                            mode=cfg.serve_param_mode)
            b_sh = shd.batch_spec(specs["batch"], mesh)
            jitted = jax.jit(step, in_shardings=(p_sh, b_sh))
            lowered = jitted.lower(specs["params"], specs["batch"])
        else:
            step = make_serve_step(model)
            p_sh = shd.make_param_shardings(specs["params"], mesh,
                                            mode=cfg.serve_param_mode)
            b_sh = shd.batch_spec(specs["batch"], mesh)
            c_sh = shd.cache_spec(specs["caches"], mesh)
            jitted = jax.jit(step, in_shardings=(p_sh, b_sh, c_sh),
                             donate_argnums=(2,))
            lowered = jitted.lower(specs["params"], specs["batch"],
                                   specs["caches"])
        t_lower = time.monotonic() - t0
        compiled = lowered.compile()
        t_compile = time.monotonic() - t0 - t_lower

    text = compiled.as_text()
    coll, coll_records = hlo.collective_bytes(text)
    cost = _cost_analysis(compiled)
    record = dict(
        arch=arch, shape=shape_name, kind=kind,
        override=cfg_override or {},
        mesh="2x16x16" if multi_pod else "16x16",
        n_chips=512 if multi_pod else 256,
        lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        memory=_mem_analysis(compiled),
        cost=cost,
        collective_bytes=coll,
        collective_total=float(sum(coll.values())),
        n_collectives=len(coll_records),
        policy=cfg.policy, dtype=cfg.dtype, remat=cfg.remat,
        n_params=cfg.n_params, n_active_params=cfg.n_active_params,
        model_flops=hlo.model_flops(cfg, SHAPES[shape_name], kind),
    )
    return lowered, compiled, record


def run_cell(arch, shape_name, *, multi_pod=False, out_dir="experiments/dryrun",
             cfg_override=None, keep_hlo=False):
    _, compiled, record = lower_cell(arch, shape_name, multi_pod=multi_pod,
                                     cfg_override=cfg_override)
    os.makedirs(out_dir, exist_ok=True)
    tag = f"{arch}__{shape_name}__{record['mesh']}"
    if cfg_override:
        tag += "__" + "_".join(f"{k}-{v}" for k, v in
                               sorted(cfg_override.items()))
    with open(os.path.join(out_dir, tag + ".json"), "w") as f:
        json.dump(record, f, indent=1)
    if keep_hlo:
        with open(os.path.join(out_dir, tag + ".hlo.txt"), "w") as f:
            f.write(compiled.as_text())
    print(f"[dryrun OK] {tag}  compile={record['compile_s']}s "
          f"flops={record['cost'].get('flops', 0):.3e} "
          f"coll={record['collective_total']:.3e}B "
          f"temp={record['memory'].get('temp_size_in_bytes', 0)/2**30:.2f}GiB")
    print("  memory_analysis:", record["memory"])
    print("  cost_analysis:", {k: v for k, v in record["cost"].items()
                               if k in ("flops", "bytes accessed",
                                        "transcendentals")})
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--keep-hlo", action="store_true")
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in list_archs():
            for s in SHAPES:
                if cell_applicable(a, s):
                    cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    failures = []
    for a, s in cells:
        try:
            run_cell(a, s, multi_pod=args.multi_pod, out_dir=args.out,
                     keep_hlo=args.keep_hlo)
        except Exception:
            failures.append((a, s))
            print(f"[dryrun FAIL] {a} {s}")
            traceback.print_exc()
    if failures:
        raise SystemExit(f"dry-run failures: {failures}")
    print(f"all {len(cells)} cells green")


if __name__ == "__main__":
    main()
