"""ShapeDtypeStruct input specs for every (arch x shape) dry-run cell.

No device memory is ever allocated here: model/optimizer state shapes
come from jax.eval_shape over the real init functions, batches are
constructed ShapeDtypeStructs.  This is the weak-type-correct, shardable
stand-in pattern the dry-run lowers against.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config
from repro.models import build_model
from repro.models.config import ModelConfig
from repro.optim import adamw

N_AUDIO_CTX = 1500   # whisper stub frontend output length


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _cast_float(tree, dtype):
    def c(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return jax.ShapeDtypeStruct(x.shape, dtype)
        return x
    return jax.tree.map(c, tree)


def param_shapes(cfg: ModelConfig, *, serve: bool = False):
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    if serve:   # serving keeps weights in the compute dtype
        shapes = _cast_float(shapes, jnp.bfloat16 if cfg.dtype == "bf16"
                             else jnp.float32)
        if cfg.serve_quant:   # weight-only storage format (matmul weights)
            from repro.core.quantize import jnp_dtype
            qdt = jnp_dtype(cfg.serve_quant)

            def q(path, x):
                names = [str(getattr(p, "key", getattr(p, "idx", "")))
                         for p in path]
                if x.ndim >= 2 and names[-1] == "w":
                    return jax.ShapeDtypeStruct(x.shape, qdt)
                return x
            shapes = jax.tree_util.tree_map_with_path(q, shapes)
    elif cfg.params_dtype == "bf16":
        shapes = _cast_float(shapes, jnp.bfloat16)
    return shapes


def train_state_shapes(cfg: ModelConfig):
    params = param_shapes(cfg)
    opt = jax.eval_shape(adamw.init, params)
    return {"params": params, "opt": opt}


def batch_specs(cfg: ModelConfig, shape_name: str):
    """-> (kind, batch pytree of ShapeDtypeStructs [, cache pytree])."""
    sh = SHAPES[shape_name]
    B, S = sh["batch"], sh["seq"]
    kind = sh["kind"]
    dt = jnp.bfloat16 if cfg.dtype == "bf16" else jnp.float32
    model = build_model(cfg)

    def token_inputs():
        if cfg.family == "encdec":
            return {"frames": _sds((B, N_AUDIO_CTX, cfg.d_model), dt),
                    "tokens": _sds((B, S), jnp.int32)}
        if cfg.frontend == "stub":          # vlm: fused patch embeddings
            return {"embeddings": _sds((B, S, cfg.d_model), dt)}
        return {"tokens": _sds((B, S), jnp.int32)}

    if kind == "train":
        batch = token_inputs()
        batch["labels"] = _sds((B, S), jnp.int32)
        return kind, batch, None
    if kind == "prefill":
        return kind, token_inputs(), None
    # decode: one new token against an S-long context
    batch = {"tokens": _sds((B, 1), jnp.int32),
             "index": _sds((), jnp.int32)}
    if cfg.family == "encdec":
        batch["enc_out"] = _sds((B, N_AUDIO_CTX, cfg.d_model), dt)
    caches = jax.eval_shape(lambda: model.init_caches(B, S))
    return kind, batch, caches


def cell_specs(arch: str, shape_name: str, cfg_override=None):
    """Everything dryrun needs for one cell."""
    cfg = get_config(arch)
    if cfg_override:
        cfg = cfg.replace(**cfg_override)
    kind, batch, caches = batch_specs(cfg, shape_name)
    if kind == "train":
        state = train_state_shapes(cfg)
        return cfg, kind, dict(state=state, batch=batch)
    params = param_shapes(cfg, serve=True)
    if kind == "prefill":
        return cfg, kind, dict(params=params, batch=batch)
    return cfg, kind, dict(params=params, batch=batch, caches=caches)
