"""Roofline aggregation: dry-run records -> per-cell three-term table.

  compute term    = analytic HLO FLOPs / (chips x peak)   [bf16 peak and
                    the DPA-adjusted peak per the policy format]
  memory term     = analytic HBM bytes / (chips x HBM bw)
  collective term = loop-corrected HLO wire bytes / (chips... per-chip
                    link bw; wire bytes are already per-device)

plus MODEL_FLOPS/HLO_FLOPs and the dominant bottleneck with a one-line
suggestion.  Reads experiments/dryrun/*.json (written by launch.dryrun);
emits a markdown table + per-cell suggestions for EXPERIMENTS.md.

Usage: python -m repro.launch.roofline [--dir experiments/dryrun]
       [--mesh 16x16] [--md experiments/roofline.md]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.configs import SHAPES, get_config
from repro.hwmodel.throughput import peak_flops_scale
from repro.launch import analytic as A
from repro.launch import hlo_analysis as H

SUGGEST = {
    "compute": "raise DPA term count (fp8->fp4 operands) or cut remat "
               "recompute (selective checkpointing)",
    "memory": "quantize the streamed side (weights for decode, cache to "
              "fp8) — the paper's narrow-wire contract on HBM",
    "collective": "re-balance mesh axes for this model size (batch onto "
                  "'model' for small TP gains), sequence-parallel "
                  "collectives, or fp8 compressed reductions",
}


def load_records(d: str, mesh: str):
    recs = []
    for f in sorted(glob.glob(os.path.join(d, f"*__{mesh}.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def cell_roofline(rec: dict):
    cfg = get_config(rec["arch"])
    if rec.get("policy") and rec["policy"] != cfg.policy:
        cfg = cfg.replace(policy=rec["policy"])
    sh = SHAPES[rec["shape"]]
    n = rec["n_chips"]
    kind = rec["kind"]
    flops = A.cell_flops_per_device(cfg, sh["seq"], sh["batch"], kind, n)
    hbm = A.cell_hbm_bytes_per_device(cfg, sh["seq"], sh["batch"], kind, n)
    coll = rec["collective_total"]
    from repro.core.policy import get_policy
    pol = get_policy(cfg.policy)
    scale = peak_flops_scale(pol.fmt_acts) if pol.enabled else 0.5
    base = H.roofline_terms(flops, hbm, coll, n, peak_scale=1.0)
    dpa = H.roofline_terms(flops, hbm, coll, n, peak_scale=scale)
    model_fl = rec["model_flops"] / n
    util = model_fl / flops
    # roofline fraction: useful model compute time / achievable bound
    frac = (model_fl / (H.PEAK_FLOPS_BF16 * scale)) / dpa["bound_s"]
    return dict(
        arch=rec["arch"], shape=rec["shape"], kind=kind, mesh=rec["mesh"],
        compute_s=base["compute_s"], compute_dpa_s=dpa["compute_s"],
        memory_s=base["memory_s"], collective_s=base["collective_s"],
        dominant=dpa["dominant"], bound_s=dpa["bound_s"],
        model_hlo_ratio=util, roofline_frac=frac,
        temp_gib=rec["memory"].get("temp_size_in_bytes", 0) / 2 ** 30,
        compile_s=rec["compile_s"],
        suggest=SUGGEST[dpa["dominant"]],
    )


def markdown_table(rows):
    hdr = ("| arch | shape | dominant | compute(bf16) s | compute(DPA) s | "
           "memory s | collective s | MODEL/HLO | roofline frac | "
           "temp GiB |\n|---|---|---|---|---|---|---|---|---|---|")
    out = [hdr]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | **{r['dominant']}** | "
            f"{r['compute_s']:.3e} | {r['compute_dpa_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"{r['model_hlo_ratio']:.2f} | {r['roofline_frac']:.3f} | "
            f"{r['temp_gib']:.1f} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--md", default=None)
    args = ap.parse_args()
    rows = [cell_roofline(r) for r in load_records(args.dir, args.mesh)]
    rows.sort(key=lambda r: (r["shape"], r["arch"]))
    table = markdown_table(rows)
    print(table)
    print()
    for r in rows:
        print(f"- {r['arch']} x {r['shape']}: {r['dominant']}-bound -> "
              f"{r['suggest']}")
    if args.md:
        with open(args.md, "w") as f:
            f.write(table + "\n")
    # summary picks for the hillclimb
    worst = min(rows, key=lambda r: r["roofline_frac"])
    coll = max(rows, key=lambda r: r["collective_s"] / max(r["bound_s"],
                                                           1e-12))
    print(f"\nworst roofline fraction: {worst['arch']} x {worst['shape']} "
          f"({worst['roofline_frac']:.3f})")
    print(f"most collective-bound: {coll['arch']} x {coll['shape']}")


if __name__ == "__main__":
    main()
