"""Analytic per-cell cost census: FLOPs and HBM bytes per device.

XLA's `compiled.cost_analysis()` counts `while` bodies ONCE, so any
scan-over-layers program under-reports by ~n_layers x (verified in
EXPERIMENTS.md §Dry-run).  Since we control every matmul in the model
zoo, the exact FLOP census is derivable from the config; that is what
the roofline uses as HLO_FLOPs (it includes remat recompute, attention
quadratics, MoE capacity overhead — everything the 6ND MODEL_FLOPS
misses, so the MODEL/HLO ratio stays meaningful).

Byte model (per device):
  train   = opt traffic (params+m+v read&write, f32) + weight fwd/bwd
            reads + activation stores/loads per layer
  prefill = weight reads + activation traffic
  decode  = weight reads + FULL KV-cache read (the decode roofline) +
            cache write + small activations
"""
from __future__ import annotations

from repro.models.config import ModelConfig


def _attn_macs_per_token(cfg: ModelConfig, s_ctx: float, *, decode=False):
    hd = cfg.hd
    proj = cfg.d_model * hd * (cfg.n_heads + 2 * cfg.n_kv_heads) \
        + cfg.n_heads * hd * cfg.d_model
    kv_len = s_ctx if decode else s_ctx / 2.0        # causal average
    scores = 2.0 * cfg.n_heads * hd * kv_len
    return proj, scores


def _mlp_macs_per_token(cfg: ModelConfig):
    if cfg.d_ff == 0:
        return 0.0
    mult = 3 if cfg.act == "silu" else 2
    if cfg.is_moe:
        return cfg.top_k * cfg.capacity_factor * mult * cfg.d_model * cfg.d_ff \
            + cfg.d_model * cfg.n_experts        # router
    return mult * cfg.d_model * cfg.d_ff


def _block_macs_per_token(cfg: ModelConfig, kind: str, s_ctx, *, decode):
    d, hd, H = cfg.d_model, cfg.hd, cfg.n_heads
    dr = cfg.d_rnn or d
    if kind in ("attn", "enc", "dec"):
        proj, scores = _attn_macs_per_token(cfg, s_ctx, decode=decode)
        m = proj + scores
        if kind == "dec":   # cross-attention (kv over audio ctx = 1500)
            proj2, scores2 = _attn_macs_per_token(cfg, 1500, decode=True)
            m += proj2 + scores2
    elif kind == "attn_local":
        win = min(cfg.window or s_ctx, s_ctx)
        proj, scores = _attn_macs_per_token(cfg, win, decode=True)
        m = proj + scores
    elif kind == "rg":
        m = 3 * d * dr + dr * d + cfg.conv_width * dr
    elif kind == "mlstm":
        m = 4 * d * H * hd + 2 * d * H \
            + (cfg.chunk * H * hd * 2) + 3 * H * hd * hd
    elif kind == "slstm":
        m = 5 * d * d
    else:
        raise ValueError(kind)
    return m + _mlp_macs_per_token(cfg)


def _pattern_counts(cfg: ModelConfig):
    from repro.models.transformer import family_pattern
    if cfg.family == "encdec":
        return {"enc": cfg.n_enc_layers or cfg.n_layers, "dec": cfg.n_layers}
    pat = family_pattern(cfg)
    counts = {}
    for i in range(cfg.n_layers):
        k = pat[i % len(pat)]
        counts[k] = counts.get(k, 0) + 1
    return counts


def forward_macs(cfg: ModelConfig, seq: int, batch: int, kind: str) -> float:
    """Total forward MACs for the whole (global) batch."""
    decode = kind == "decode"
    tokens = batch * (1 if decode else seq)
    counts = _pattern_counts(cfg)
    total = 0.0
    for block_kind, n in counts.items():
        if block_kind == "enc":
            enc_tokens = batch * 1500
            total += n * enc_tokens * _block_macs_per_token(
                cfg, "enc", 1500, decode=False)
        else:
            total += n * tokens * _block_macs_per_token(
                cfg, block_kind, seq, decode=decode)
    total += tokens * cfg.d_model * cfg.vocab_size      # unembed
    return total


def cell_flops_per_device(cfg: ModelConfig, seq: int, batch: int, kind: str,
                          n_chips: int) -> float:
    fwd = forward_macs(cfg, seq, batch, kind)
    if kind == "train":
        remat = {"none": 0.0, "dots": 0.5, "full": 1.0}[cfg.remat]
        macs = fwd * (3.0 + remat)
    else:
        macs = fwd
    return 2.0 * macs / n_chips


def _dtype_bytes(cfg: ModelConfig) -> int:
    return 2 if cfg.dtype in ("bf16", "fp16") else 4


def cell_hbm_bytes_per_device(cfg: ModelConfig, seq: int, batch: int,
                              kind: str, n_chips: int) -> float:
    """Per-device HBM traffic for one step."""
    act_b = _dtype_bytes(cfg)
    n_params = cfg.n_params
    counts = _pattern_counts(cfg)
    n_layers_total = sum(counts.values())
    param_b = 2 if cfg.params_dtype == "bf16" else 4
    if kind == "train":
        # opt update r/w: m,v f32 + param read/write at storage dtype;
        # fwd/bwd weight reads stream at storage dtype
        opt = n_params / n_chips * (4 * 4 + 2 * param_b)
        wread = n_params / n_chips * param_b * 3
        tokens_local = batch * seq / n_chips
        acts = tokens_local * cfg.d_model * act_b * 8 * n_layers_total
        return opt + wread + acts
    serve_b = 1 if cfg.serve_quant else act_b
    # serving: tp_only replicates params across DP — per-device weight
    # reads cover the model-shard, fsdp covers 1/n_chips then gathers
    model_shard = 16 if cfg.serve_param_mode == "tp_only" else n_chips
    if kind == "prefill":
        wread = n_params * serve_b / model_shard
        tokens_local = batch * seq / n_chips
        acts = tokens_local * cfg.d_model * act_b * 8 * n_layers_total
        return wread + acts
    # decode
    wread = n_params * serve_b / model_shard
    cache = 0.0
    hd = cfg.hd
    for k, n in counts.items():
        if k in ("attn", "dec"):
            cache += n * batch * seq * cfg.n_kv_heads * hd * 2 * act_b
        elif k == "attn_local":
            win = min(cfg.window or seq, seq)
            cache += n * batch * win * cfg.n_kv_heads * hd * 2 * act_b
        elif k == "rg":
            dr = cfg.d_rnn or cfg.d_model
            cache += n * batch * dr * 4 * 2
        elif k == "mlstm":
            cache += n * batch * cfg.n_heads * hd * hd * 4 * 2
        elif k == "slstm":
            cache += n * batch * cfg.d_model * 4 * 4
    return wread + cache / n_chips + batch * cfg.d_model * act_b
