"""Batched serving driver: prefill + greedy decode with KV caches.

Demonstrates the inference side of the DPA contract: weights quantized to
the policy format ride the narrow wires (HBM), activations quantize
per-row, accumulation stays FP32.  Attention policies (attn_fp8_dpa,
kv4_attn8_packed, ...) extend the contract to the serving hot path: both
attention matmuls accumulate f32 over narrow operands and the KV cache is
stored at format width, so every decode step streams 2-8x fewer cache
bytes.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --reduced \
      --batch 4 --prompt-len 32 --gen 16 --policy kv4_attn8_packed

Two modes:

  static (default) : one rigid (B, S_max) batch stepped in lockstep —
      every request pays for the longest sequence.  Its report prices the
      cache at B x S_max, because that is what this mode really holds.
  --engine : the continuous-batching engine (`repro.launch.engine`) over
      the *paged* quantized KV cache — mixed-length requests under
      open-loop Poisson traffic, cache memory proportional to live
      tokens, and a report that counts KV bytes from actual per-request
      lengths plus page-allocator utilization (see `docs/serving.md`).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --reduced \
      --engine --requests 16 --rate 50 --policy kv4_attn8_packed
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduce_config
from repro.core.policy import get_policy
from repro.distributed.step import make_serve_step
from repro.launch.mesh import make_host_mesh
from repro.models import build_model


def report_plan(cfg, s_ctx: int) -> str:
    """One line naming the exec-plan routes this policy's serving step
    resolves to (the introspectable answer to "which kernel ran?").
    Static mode only runs the contiguous-cache ops — paged_decode is the
    engine's route and is deliberately left out here."""
    from repro.launch.hlo_analysis import plan_routes
    routes = plan_routes(cfg.policy, shapes={
        "flash_attn": {"sq": s_ctx, "skv": s_ctx,
                       "use_flash": cfg.use_flash},
        "decode_attn": {"s_ctx": s_ctx, "kv_heads": cfg.n_kv_heads,
                        "hd": cfg.hd}})
    static_ops = ("matmul", "flash_attn", "decode_attn")
    parts = [f"{op}->{routes[op]['route']}" for op in sorted(static_ops)
             if routes.get(op) is not None]
    return "plan: " + " ".join(parts)


def report_kv_cache(cfg, batch: int, s_ctx: int) -> str:
    """One-line KV-cache footprint for the selected policy."""
    pol = get_policy(cfg.policy)
    if not pol.kv_quantized:
        return "kv-cache: raw %s (policy %s)" % (cfg.dtype, cfg.policy)
    from repro.core.kvcache import kv_cache_nbytes
    nb = kv_cache_nbytes(batch, s_ctx, cfg.n_kv_heads, cfg.hd,
                         fmt=pol.fmt_kv, packed=pol.kv_packed)
    n_attn = sum(1 for i in range(cfg.n_layers)
                 if _pattern_kind(cfg, i) in ("attn", "dec"))
    return (f"kv-cache: {pol.fmt_kv}{' packed' if pol.kv_packed else ''} "
            f"{nb['total'] * n_attn / 1e6:.2f} MB vs f32 "
            f"{nb['f32_total'] * n_attn / 1e6:.2f} MB "
            f"({nb['reduction_vs_f32']:.2f}x fewer bytes/decode-step, "
            f"{n_attn} attn layers)")


def _pattern_kind(cfg, layer: int) -> str:
    from repro.models.transformer import family_pattern
    pat = family_pattern(cfg)
    return pat[layer % len(pat)]


def generate(model, params, prompt, n_gen: int, s_ctx: int):
    """prompt: (B, S0) -> tokens (B, S0+n_gen).  Greedy."""
    cfg = model.cfg
    B, S0 = prompt.shape
    caches = model.init_caches(B, s_ctx)
    serve_step = jax.jit(make_serve_step(model), donate_argnums=(2,))

    # prefill by stepping the decode path over the prompt (production uses
    # model.prefill; stepping exercises the exact serving cache path)
    tok = prompt[:, :1]
    toks = [tok]
    for t in range(S0 + n_gen - 1):
        nxt, caches = serve_step(
            params, {"tokens": tok, "index": jnp.int32(t)}, caches)
        tok = prompt[:, t + 1:t + 2] if t + 1 < S0 else nxt[:, None]
        toks.append(tok)
    return jnp.concatenate(toks, axis=1)


def run_engine(cfg, model, args):
    """--engine mode: continuous batching over the paged quantized cache,
    driven by an open-loop synthetic workload.  --spec-draft turns on
    self-speculative decoding (draft under the named low-precision
    policy, verify under --policy); --adaptive-draft replaces the static
    draft policy with the acceptance-feedback precision ladder; --mixed
    makes the traffic heterogeneous; --temperature/--top-k/--top-p
    select sampling (default greedy)."""
    from repro.launch.engine import (Engine, EngineConfig, SamplerConfig,
                                     SpecConfig, format_report,
                                     synthetic_workload)
    from repro.runtime.controller import ControllerConfig, default_ladder
    if args.tuned_db:
        # export first so every exec_plan.resolve() below (engine
        # construction included) consults the measured table
        os.environ["REPRO_TUNED_DB"] = args.tuned_db
        from repro.runtime import tuner
        best = tuner.best_engine_knobs(args.tuned_db)
        if best:
            ps = int(best.get("page_size", args.page_size))
            if ps != args.page_size:
                # rescale the per-request page budget so S_max (tokens a
                # request may hold) is preserved under the tuned page size
                s_max = args.page_size * args.max_pages_per_req
                args.max_pages_per_req = max(1, s_max // ps)
                args.page_size = ps
            if not args.spec_draft and int(best.get("spec_k", 0)) > 0:
                args.spec_draft = tuner.ENGINE_DRAFT_POLICY
                args.spec_k = int(best["spec_k"])
            print(f"tuned engine knobs from {args.tuned_db}: {best}")
    ecfg = EngineConfig(page_size=args.page_size, n_pages=args.pages,
                        max_batch=args.max_batch or args.batch,
                        max_pages_per_req=args.max_pages_per_req,
                        token_budget=args.token_budget,
                        prefill_chunk=args.prefill_chunk,
                        prefix_cache=args.prefix_cache,
                        tp=args.tp)
    adaptive = None
    if args.adaptive_draft:
        if args.spec_draft:
            raise SystemExit("--adaptive-draft replaces --spec-draft "
                             "(the ladder covers the static draft "
                             "policy); pass one or the other")
        adaptive = ControllerConfig(default_ladder(cfg.policy),
                                    k=args.spec_k)
    spec = SpecConfig(args.spec_draft, args.spec_k) if args.spec_draft \
        else None
    spec_k = adaptive.max_k if adaptive else (args.spec_k if spec else 0)
    # mixed traffic stretches the longest request to 4x the --gen /
    # --prompt-len ceilings (see synthetic_workload); guard for that
    p_max = 4 * args.prompt_len if args.mixed > 0 else args.prompt_len
    g_max = 4 * args.gen if args.mixed > 0 else args.gen
    if args.shared_prefix + p_max + g_max + spec_k > ecfg.s_max:
        raise SystemExit(
            f"--shared-prefix {args.shared_prefix} + prompt {p_max} + "
            f"gen {g_max}{' (4x for --mixed)' if args.mixed > 0 else ''} "
            f"(+ the {spec_k}-token draft window) exceeds the engine's "
            f"S_max = {ecfg.s_max} tokens/request; raise "
            "--max-pages-per-req or --page-size")
    sampler = SamplerConfig(temperature=args.temperature, top_k=args.top_k,
                            top_p=args.top_p, seed=args.seed)
    params = model.init(jax.random.PRNGKey(0))
    engine = Engine(model, params, ecfg, sampler=sampler, spec=spec,
                    adaptive=adaptive)
    reqs = synthetic_workload(
        args.requests, vocab=cfg.vocab_size, seed=args.seed,
        rate=args.rate, prompt_range=(max(1, args.prompt_len // 2),
                                      args.prompt_len),
        gen_range=(max(1, args.gen // 2), args.gen),
        shared_prefix=args.shared_prefix, mixed=args.mixed)
    rep = engine.run(reqs)
    print(format_report(rep, cfg.policy))
    if engine.finished:
        sample = engine.finished[0]
        print(f"sample (req {sample.rid}): {sample.tokens()[:24].tolist()}")
    if args.json:
        import json
        with open(args.json, "w") as f:
            json.dump(rep, f, indent=2, allow_nan=False)
        print(f"report written to {args.json}")
    return rep


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--policy", default=None)
    ap.add_argument("--n-model", type=int, default=1)
    eg = ap.add_argument_group("engine", "continuous-batching mode")
    eg.add_argument("--engine", action="store_true",
                    help="serve with the paged-cache engine")
    eg.add_argument("--page-size", type=int, default=16)
    eg.add_argument("--pages", type=int, default=128,
                    help="page-pool capacity (page 0 is scratch)")
    eg.add_argument("--max-batch", type=int, default=0,
                    help="decode slots (default: --batch)")
    eg.add_argument("--max-pages-per-req", type=int, default=8)
    eg.add_argument("--token-budget", type=int, default=32,
                    help="tokens per scheduler step")
    eg.add_argument("--prefill-chunk", type=int, default=16)
    eg.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel width: shard the KV page pool "
                         "across a (1, tp) \"model\" mesh and serve "
                         "through the sharded exec-plan routes (bit-"
                         "identical to --tp 1).  On CPU, expose devices "
                         "with XLA_FLAGS=--xla_force_host_platform_"
                         "device_count=N before launch")
    eg.add_argument("--requests", type=int, default=16)
    eg.add_argument("--rate", type=float, default=0.0,
                    help="Poisson arrival rate, req/s (0 = all at t=0)")
    eg.add_argument("--seed", type=int, default=0,
                    help="workload + sampler RNG seed")
    eg.add_argument("--prefix-cache", action="store_true",
                    help="share identical prompt prefixes across requests "
                         "(ref-counted pages + copy-on-write)")
    eg.add_argument("--shared-prefix", type=int, default=0,
                    help="prepend this many shared system-prompt tokens "
                         "to every synthetic request")
    eg.add_argument("--mixed", type=float, default=0.0,
                    help="fraction of long-prompt/long-gen requests in "
                         "the synthetic workload (0 = homogeneous; drawn "
                         "from a forked RNG stream, so 0 is byte-"
                         "identical to earlier releases)")
    eg.add_argument("--json", default="",
                    help="also dump the engine report to this JSON file")
    eg.add_argument("--tuned-db", default="",
                    help="tuned measurement DB (tools/tune.py output): "
                         "exports REPRO_TUNED_DB so exec-plan routes "
                         "resolve against measurements, and applies the "
                         "DB's best engine knobs: page size (with "
                         "--max-pages-per-req rescaled to keep S_max) "
                         "and spec-k (when --spec-draft is unset)")
    sg = ap.add_argument_group("sampling + speculation", "engine mode")
    sg.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy argmax)")
    sg.add_argument("--top-k", type=int, default=0,
                    help="keep the k largest logits (0 = off)")
    sg.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling mass (1.0 = off)")
    sg.add_argument("--spec-draft", default="",
                    help="draft policy preset for self-speculative "
                         "decoding (e.g. w4a4_kv4_attn4; empty = off)")
    sg.add_argument("--spec-k", type=int, default=4,
                    help="draft tokens per speculative round")
    sg.add_argument("--adaptive-draft", action="store_true",
                    help="adaptive trans-precision drafting: walk the "
                         "default draft-precision ladder for --policy "
                         "with the acceptance-feedback controller "
                         "(repro.runtime.controller) instead of one "
                         "static --spec-draft policy")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    if args.engine and not args.policy:
        args.policy = "kv4_attn8_packed"    # engine needs a fmt_kv preset
    if args.policy:
        cfg = cfg.replace(policy=args.policy)
    if cfg.family in ("encdec", "vlm") or cfg.frontend == "stub":
        raise SystemExit("serve demo targets token-in/token-out archs")
    model = build_model(cfg)
    mesh = make_host_mesh(n_model=args.n_model)
    if args.engine:
        with mesh:
            return run_engine(cfg, model, args)
    print(report_kv_cache(cfg, args.batch, args.prompt_len + args.gen))
    print(report_plan(cfg, args.prompt_len + args.gen))
    with mesh:
        params = model.init(jax.random.PRNGKey(0))
        prompt = jax.random.randint(jax.random.PRNGKey(1),
                                    (args.batch, args.prompt_len), 0,
                                    cfg.vocab_size)
        t0 = time.monotonic()
        out = generate(model, params, prompt, args.gen,
                       args.prompt_len + args.gen)
        out.block_until_ready()
        wall = time.monotonic() - t0
        steps = args.prompt_len + args.gen - 1
        print(f"generated {out.shape} in {wall:.2f}s "
              f"({steps * args.batch / wall:.1f} tok/s, policy={cfg.policy})")
        print("sample:", out[0, :24].tolist())
    return out


if __name__ == "__main__":
    main()
