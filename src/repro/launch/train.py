"""End-to-end training driver.

Production shape: mesh -> sharded state -> deterministic pipeline ->
supervised step loop (checkpoint/restart, failure recovery, straggler
watchdog).  On CPU this runs the reduced configs (examples/) — the same
code path the dry-run lowers for the production mesh.

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b \
      --reduced --steps 200 --batch 8 --seq 128 --policy fp8_dpa
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_config, reduce_config
from repro.data.pipeline import DataConfig, make_pipeline
from repro.distributed import sharding as shd
from repro.distributed.step import make_train_step
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.optim import adamw
from repro.runtime.fault import Supervisor, SupervisorConfig


def build_state(model, key, mesh=None):
    params = model.init(key)
    state = {"params": params, "opt": adamw.init(params)}
    if mesh is not None:
        shardings = {
            "params": shd.make_param_shardings(state["params"], mesh),
            "opt": {"m": shd.make_param_shardings(state["opt"]["m"], mesh),
                    "v": shd.make_param_shardings(state["opt"]["v"], mesh),
                    "count": jax.sharding.NamedSharding(
                        mesh, jax.sharding.PartitionSpec())},
        }
        state = jax.device_put(state, shardings)
        return state, shardings
    return state, None


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized config of the same family")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--policy", default=None)
    ap.add_argument("--vocab", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--n-model", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_config(cfg)
    over = {"max_seq": max(cfg.max_seq, args.seq)}
    if args.policy:
        over["policy"] = args.policy
    if args.vocab:
        over["vocab_size"] = args.vocab
    cfg = cfg.replace(**over)

    mesh = make_host_mesh(n_model=args.n_model)
    model = build_model(cfg)
    opt_cfg = adamw.AdamWConfig(lr=args.lr, warmup_steps=args.steps // 10 + 1,
                                total_steps=args.steps)
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, batch=args.batch,
                          seq=args.seq,
                          frontend=cfg.frontend,
                          d_model=cfg.d_model,
                          frames=16 if cfg.family == "encdec" else 0)
    pipe = make_pipeline(data_cfg)

    with mesh:
        state, _ = build_state(model, jax.random.PRNGKey(0), mesh)
        step_fn = jax.jit(make_train_step(model, opt_cfg),
                          donate_argnums=(0,))
        sup = Supervisor(SupervisorConfig(
            ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every), state=state)

        t_hist = []

        def on_metrics(step, m, dt):
            t_hist.append(dt)
            if step % args.log_every == 0:
                print(f"step {step:5d} loss {float(m['loss']):.4f} "
                      f"gnorm {float(m['grad_norm']):.3f} "
                      f"lr {float(m['lr']):.2e} {dt*1e3:.0f}ms")

        t0 = time.monotonic()
        state = sup.run(step_fn, pipe.batch, args.steps,
                        on_metrics=on_metrics)
        wall = time.monotonic() - t0
        tok_s = args.steps * args.batch * args.seq / wall
        print(f"done: {args.steps} steps in {wall:.1f}s "
              f"({tok_s:.0f} tok/s, median step "
              f"{sorted(t_hist)[len(t_hist)//2]*1e3:.0f}ms)")
    return state


if __name__ == "__main__":
    main()
