"""Whisper-medium [arXiv:2212.04356]: enc-dec, conv frontend STUB
(input_specs supplies precomputed frame embeddings).  24 encoder + 24
decoder layers, LayerNorm/GELU, learned positions (no RoPE)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="encdec",
    n_layers=24, n_enc_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    head_dim=64, d_ff=4096, vocab_size=51865,
    act="gelu", norm="layernorm", rope_theta=0.0,
    frontend="stub", max_seq=32768 + 64,
    dtype="bf16", policy="fp8_dpa", remat="full", attn_chunk=512, logits_chunk=512,
)
N_AUDIO_CTX = 1500  # encoder frames after the (stubbed) conv frontend
