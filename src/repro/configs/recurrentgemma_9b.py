"""RecurrentGemma-9B [arXiv:2402.19427]: RG-LRU + local attention, 1:2
(pattern rg,rg,attn_local), MQA kv=1, window 2048."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="rglru",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, head_dim=256,
    d_ff=12288, vocab_size=256000,
    pattern=("rg", "rg", "attn_local"), window=2048, d_rnn=4096,
    rope_theta=1e4,
    dtype="bf16", policy="fp8_dpa", remat="full", attn_chunk=512, logits_chunk=512,
)
