"""Qwen3-4B [hf:Qwen/Qwen3-8B family]: qk_norm, GQA kv=8."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b", family="decoder",
    n_layers=36, d_model=2560, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=9728, vocab_size=151936,
    qk_norm=True, rope_theta=1e6, tie_embeddings=True,
    dtype="bf16", policy="fp8_dpa", remat="full", attn_chunk=512, logits_chunk=512,
)
