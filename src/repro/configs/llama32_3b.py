"""Llama-3.2-3B [hf:meta-llama/Llama-3.2 family]: small llama3."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-3b", family="decoder",
    n_layers=28, d_model=3072, n_heads=24, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=128256,
    rope_theta=5e5, tie_embeddings=True,
    dtype="bf16", policy="fp8_dpa", remat="full", attn_chunk=512, logits_chunk=512,
)
