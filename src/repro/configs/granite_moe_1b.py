"""Granite-3.0-1B-A400M [hf:ibm-granite]: 32 experts top-8 MoE."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8, head_dim=64,
    d_ff=512, vocab_size=49155,
    n_experts=32, top_k=8, tie_embeddings=True,
    rope_theta=1e4,
    dtype="bf16", policy="fp8_dpa", remat="full", attn_chunk=512, logits_chunk=512,
)
