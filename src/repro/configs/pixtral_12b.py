"""Pixtral-12B [hf:mistralai/Pixtral-12B-2409]: pixtral-ViT frontend
(STUB: input_specs supplies precomputed patch embeddings) + mistral-nemo
backbone."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b", family="vlm",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=131072,
    rope_theta=1e6, frontend="stub",
    dtype="bf16", policy="fp8_dpa", remat="full", attn_chunk=512, logits_chunk=512,
)
