"""xLSTM-1.3B [arXiv:2405.04517]: mLSTM + sLSTM blocks (1 sLSTM per 8),
d_ff=0 (mixer-only blocks), 4 heads."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="xlstm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4, head_dim=512,
    d_ff=0, vocab_size=50304,
    slstm_every=8, chunk=64,
    dtype="bf16", policy="fp8_dpa", remat="full", attn_chunk=512, logits_chunk=512,
)
