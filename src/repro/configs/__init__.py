from .base import (ARCH_MODULES, LONG_CTX_ARCHS, SHAPES, cell_applicable,
                   get_config, list_archs, reduce_config)

__all__ = ["ARCH_MODULES", "SHAPES", "LONG_CTX_ARCHS", "get_config",
           "list_archs", "reduce_config", "cell_applicable"]
