"""DeepSeek-67B [arXiv:2401.02954]: llama-arch dense, GQA kv=8."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b", family="decoder",
    n_layers=95, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=22016, vocab_size=102400,
    rope_theta=1e4,
    dtype="bf16", policy="fp8_dpa", remat="full", attn_chunk=512, logits_chunk=512,
)
