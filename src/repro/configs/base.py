"""Config registry: the ten assigned architectures + shape cells.

Every entry matches the assignment table exactly (layer count, width,
heads, GQA kv, d_ff, vocab, family quirks).  `reduce_config` derives the
CPU smoke-test variant of the same family (small dims, same structure).
"""
from __future__ import annotations

import importlib
from typing import Dict

from repro.models.config import ModelConfig

ARCH_MODULES = {
    "qwen2-72b": "qwen2_72b",
    "deepseek-67b": "deepseek_67b",
    "qwen3-4b": "qwen3_4b",
    "llama3.2-3b": "llama32_3b",
    "pixtral-12b": "pixtral_12b",
    "whisper-medium": "whisper_medium",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "granite-moe-1b-a400m": "granite_moe_1b",
    "dbrx-132b": "dbrx_132b",
    "xlstm-1.3b": "xlstm_1_3b",
}

# (arch x shape) grid: seq, global batch, which step is lowered
SHAPES: Dict[str, dict] = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}

# long_500k runs only for constant-state (sub-quadratic) families
LONG_CTX_ARCHS = ("recurrentgemma-9b", "xlstm-1.3b")


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{ARCH_MODULES[name]}")
    return mod.CONFIG


def list_archs():
    return list(ARCH_MODULES)


def cell_applicable(arch: str, shape: str) -> bool:
    if shape == "long_500k":
        return arch in LONG_CTX_ARCHS
    return True


def reduce_config(cfg: ModelConfig) -> ModelConfig:
    """Same-family smoke config: tiny dims, identical structure/flags."""
    pat = len(cfg.pattern) if cfg.pattern else \
        (cfg.slstm_every if cfg.family == "xlstm" else 1)
    n_layers = max(2, min(cfg.n_layers, pat + 1)) if pat > 1 else 2
    kv = max(1, min(cfg.n_kv_heads, 2))
    heads = max(kv * 2, 4) if cfg.n_kv_heads > 1 else 4
    return cfg.replace(
        n_layers=n_layers,
        d_model=64, n_heads=heads, n_kv_heads=kv, head_dim=16,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab_size=256,
        n_experts=min(cfg.n_experts, 8) if cfg.is_moe else 0,
        top_k=min(cfg.top_k, 2) if cfg.is_moe else 0,
        d_rnn=64 if cfg.d_rnn else 0,
        window=min(cfg.window, 8) if cfg.window else 0,
        chunk=8,
        n_enc_layers=2 if cfg.n_enc_layers else 0,
        max_seq=4096,
        dtype="float32", remat="none",
    )
