"""DBRX-132B [hf:databricks/dbrx-base]: 16 experts top-4, fine-grained."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=10752, vocab_size=100352,
    n_experts=16, top_k=4,
    rope_theta=5e5,
    dtype="bf16", policy="fp8_dpa", remat="full", attn_chunk=512, logits_chunk=512,
)
