"""Sharding rules: parameter/activation PartitionSpecs per model family.

Scheme (DESIGN.md §6): DP over ("pod","data"), TP over "model".
Parameters are FSDP-sharded: the TP-parallel dim lives on "model", the
other matrix dim on the DP axes (XLA all-gathers params at use and
reduce-scatters gradients — ZeRO-ish).  Column-parallel projections
(q/k/v/gate/up) put d_out on "model"; row-parallel (wo/wd) put d_in on
"model" so intermediate activations stay model-sharded Megatron-style.
Expert weights put E on "model" (EP).  Embeddings shard vocab on "model".
KV caches shard sequence on "model" (decode TP: softmax reduces across
the axis).  Every rule is divisibility-guarded: a dim that doesn't divide
its axis group falls back to replication (e.g. batch=1 long-context).

Scan-stacked leaves are recognized by the "groups" path component and get
their leading group axis replicated.
"""
from __future__ import annotations

import contextvars
import math
from typing import Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# Mesh plan: "tp" = TP+sequence-parallel on "model"; "fully_dp" = the
# "model" axis joins the DP group (small models / pure-DP training).
_PLAN = contextvars.ContextVar("repro_mesh_plan", default="tp")


def set_mesh_plan(plan: str):
    _PLAN.set(plan)


def get_mesh_plan() -> str:
    return _PLAN.get()


def data_axes(mesh: Mesh):
    """The DP axis group: ("pod","data") (+"model" under fully_dp)."""
    names = ("pod", "data", "model") if _PLAN.get() == "fully_dp" \
        else ("pod", "data")
    axes = tuple(a for a in mesh.axis_names if a in names)
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


def model_axis():
    return None if _PLAN.get() == "fully_dp" else "model"


def _ambient_mesh():
    from jax._src import mesh as mesh_lib
    m = mesh_lib.thread_resources.env.physical_mesh
    return None if m.empty else m


def _in_manual_region(mesh) -> bool:
    """True inside a shard_map/pmap body over this mesh: its axes are
    bound as named axes, values are per-shard, and a sharding constraint
    on a manual axis is an error rather than a layout hint."""
    for name in mesh.axis_names:
        try:
            jax.lax.axis_index(name)
            return True
        except NameError:
            continue
    return False


def maybe_shard(x, *spec):
    """Guarded with_sharding_constraint for model-internal activations.

    spec elements: "data" (resolved to the DP axis group), "model", or
    None.  No-op when no mesh is ambient (single-device tests/examples),
    inside a shard_map body (per-shard values — the collective layer
    owns the layout there), when the named axis is missing, or when the
    dim doesn't divide the axis size — so model code can pin its
    parallel layout unconditionally (MaxText-style) and still run
    anywhere.
    """
    mesh = _ambient_mesh()
    if mesh is None:
        return x
    if _in_manual_region(mesh):
        return x
    names = set(mesh.axis_names)
    fixed = []
    for dim, ax in zip(x.shape, spec):
        if ax == "data":
            ax = data_axes(mesh)
        elif ax == "model":
            ax = model_axis()
        if ax is not None and (
                (isinstance(ax, tuple) and not set(ax) <= names)
                or (not isinstance(ax, tuple) and ax not in names)):
            ax = None
        if ax is not None and dim % _axis_size(mesh, ax):
            if isinstance(ax, tuple):      # longest dividing prefix
                pick = None
                for k in range(len(ax) - 1, 0, -1):
                    if dim % _axis_size(mesh, ax[:k]) == 0:
                        pick = ax[:k] if k > 1 else ax[0]
                        break
                ax = pick
            else:
                ax = None
        fixed.append(ax)
    fixed += [None] * (x.ndim - len(fixed))
    return jax.lax.with_sharding_constraint(x, P(*fixed))


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return math.prod(mesh.shape[a] for a in axis)
    return mesh.shape[axis]


def _guard(spec, shape, mesh: Mesh):
    """Shard each dim by the longest prefix of its axis group that
    divides it (a 256-batch on a 512-way group shards over the first
    32-way subgroup instead of replicating — the difference between a
    working multi-pod plan and a 1.2 TB/device program)."""
    out = []
    for dim, ax in zip(shape, spec):
        if ax is None or dim % _axis_size(mesh, ax) == 0:
            out.append(ax)
            continue
        if isinstance(ax, tuple):
            pick = None
            for k in range(len(ax) - 1, 0, -1):
                if dim % _axis_size(mesh, ax[:k]) == 0:
                    pick = ax[:k] if k > 1 else ax[0]
                    break
            out.append(pick)
        else:
            out.append(None)
    return P(*out)


def _path_names(path) -> Tuple[str, ...]:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
    return tuple(out)


ROW_PARALLEL = ("wo", "wd")      # contract-dim on "model"


def param_spec(path, leaf, mesh: Mesh, mode: str = "fsdp") -> P:
    """mode "fsdp": FSDP dim on the DP axes; "tp_only": params replicated
    across DP (serving — no per-step weight gathers)."""
    names = _path_names(path)
    joined = "/".join(names)
    da = data_axes(mesh) if mode == "fsdp" else None
    ma = model_axis()
    nd = leaf.ndim
    lead = 1 if "groups" in names else 0
    core = nd - lead

    def pad(spec):
        return _guard([None] * lead + spec, leaf.shape, mesh)

    if "embed" in joined or "unembed" in joined:        # (V, d)
        # NOTE (§Perf A5, refuted): forcing vocab onto "model" under
        # fully_dp conflicts with batch axes and triggers SPMD full
        # rematerialization (+5.7 GiB).  Keep the plan-consistent rule.
        return pad([ma, da if mode == "fsdp" else None])
    if "pos_dec" in joined:                              # (S_max, d)
        return pad([da, None])
    if core <= 1 or "norm" in joined or (
            names and names[-1] in ("b", "scale", "bias", "lam", "r")):
        return P(*([None] * nd))
    if names and names[-1] == "conv":                    # (cw, dr)
        return pad([None, ma])
    if core == 3:                                        # experts (E, di, do)
        return pad([ma, da, None])
    row = any(r in names for r in ROW_PARALLEL)
    if row:                                              # (d_in, d_out)
        return pad([ma, da])
    return pad([da, ma])


def make_param_shardings(params_shape, mesh: Mesh, mode: str = "fsdp"):
    """Pytree of NamedShardings matching a params (shape-)pytree."""
    def spec_of(path, leaf):
        return NamedSharding(mesh, param_spec(path, leaf, mesh, mode))
    return jax.tree_util.tree_map_with_path(spec_of, params_shape)


# -----------------------------------------------------------------------------
# activation / batch / cache specs
# -----------------------------------------------------------------------------

def batch_spec(batch_tree, mesh: Mesh):
    """tokens/labels (B,S), embeddings/frames/enc_out (B,S,d): B on DP."""
    da = data_axes(mesh)

    def spec_of(path, leaf):
        nd = getattr(leaf, "ndim", 0)
        if nd == 0:
            return NamedSharding(mesh, P())
        spec = [da] + [None] * (nd - 1)
        return NamedSharding(mesh, _guard(spec, leaf.shape, mesh))
    return jax.tree_util.tree_map_with_path(spec_of, batch_tree)


def cache_spec(cache_tree, mesh: Mesh):
    """KV caches (B, S, KV, hd): batch on DP, sequence on "model".
    Quantized caches shard the same way — codes AND their per-row scales
    (B, S, KV, 1/hd[/2]) carry the sequence on axis 1, and they must move
    together or a shard would hold codes it cannot dequantize.
    Recurrent states (B, feats...): batch on DP, features replicated."""
    da = data_axes(mesh)
    kv_leaves = ("k", "v", "k_codes", "v_codes", "k_scale", "v_scale")

    def spec_of(path, leaf):
        names = _path_names(path)
        nd = leaf.ndim
        if nd == 0:
            return NamedSharding(mesh, P())
        lead = 1 if "groups" in names else 0
        if names and names[-1] in kv_leaves and nd - lead == 4:
            spec = [None] * lead + [da, "model", None, None]
        else:
            spec = [None] * lead + [da] + [None] * (nd - lead - 1)
        return NamedSharding(mesh, _guard(spec, leaf.shape, mesh))
    return jax.tree_util.tree_map_with_path(spec_of, cache_tree)
