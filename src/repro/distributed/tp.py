"""Tensor-parallel serving context: the mesh the engine traces under.

The continuous-batching engine shards the paged KV pool across a "model"
mesh axis (sequence-sharded pages, the same `cache_spec` rule the train
step uses) and routes its attention through the `*_sharded` exec-plan
entries.  Those routes need the mesh at *trace* time — inside a jit'd
step there is no ambient `with mesh:` — so the engine activates it here
and the registry reads it back.

Bit-identity contract: the sharded routes all-gather the local pool
shards (format-width codes + per-row scales — a pure relayout, and the
narrow wire the paper prices) and then run the exact single-device
attention on the reassembled pool.  No cross-device float reduction ever
touches the softmax, so sharded greedy outputs match single-device
serving bit for bit (tests/test_tp_engine.py pins this across Table-I
KV formats, prefix hits, and spec-decode).
"""
from __future__ import annotations

import contextlib
import contextvars
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

AXIS = "model"

# pool leaves that travel on the wire (codes + per-row scales; block_table
# and positions stay replicated — they are host-driven metadata)
POOL_WIRE_KEYS = ("k_codes", "k_scale", "v_codes", "v_scale")

_ACTIVE_MESH: contextvars.ContextVar = contextvars.ContextVar(
    "repro_tp_mesh", default=None)


@contextlib.contextmanager
def activate(mesh):
    """Make `mesh` visible to exec-plan routes resolved/traced inside."""
    tok = _ACTIVE_MESH.set(mesh)
    try:
        yield mesh
    finally:
        _ACTIVE_MESH.reset(tok)


def active_mesh():
    return _ACTIVE_MESH.get()


def axis_size(axis: str = AXIS) -> int:
    """Size of the TP axis of the active mesh (1 when no mesh is active)."""
    mesh = _ACTIVE_MESH.get()
    if mesh is None:
        return 1
    return int(dict(zip(mesh.axis_names, mesh.devices.shape)).get(axis, 1))


def require_mesh():
    mesh = _ACTIVE_MESH.get()
    if mesh is None:
        raise RuntimeError(
            "sharded exec-plan route resolved without an active TP mesh; "
            "wrap the call in repro.distributed.tp.activate(mesh) "
            "(launch/engine.py does this around every jit'd step)")
    return mesh


def shard_map_compat(body, mesh, in_specs, out_specs, axis: str = AXIS):
    """jax.shard_map across the jax pins (same dual path as flash_decode:
    new-API axis_names/check_vma vs 0.4.x experimental check_rep=False,
    all-manual with replicated P() specs)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, axis_names={axis},
                             check_vma=False)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(body, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=False)


def _gather_pool(shards: dict, axis: str):
    """All-gather the pool shards back into the full pool.

    The wire moves format-width codes + per-row f32 scales — never
    dequantized f32 rows — and tiled all-gather along the within-page row
    axis (axis 1) is a pure relayout: the reassembled pool is bit-
    identical to the unsharded one."""
    return {key: jax.lax.all_gather(x, axis, axis=1, tiled=True)
            for key, x in shards.items()}


def sharded_paged_attn(attn_fn, q, cache, positions, *, axis: str = AXIS):
    """Run a paged-attention fn over the pool sharded on `axis`.

    `attn_fn(q, cache, positions)` is the exact single-device route body;
    the wrapper only changes *where the pool bytes live* (1/n per device)
    and *what the wire carries* (codes + scales, 2x/4x/8x under f32)."""
    mesh = require_mesh()

    def body(q, kc, ks, vc, vs, bt, pos):
        full = _gather_pool(
            dict(zip(POOL_WIRE_KEYS, (kc, ks, vc, vs))), axis)
        full["block_table"] = bt
        return attn_fn(q, full, pos)

    in_specs = (P(), P(None, axis, None, None), P(None, axis, None, None),
                P(None, axis, None, None), P(None, axis, None, None),
                P(), P())
    fn = shard_map_compat(body, mesh, in_specs, P(), axis)
    return fn(q, cache["k_codes"], cache["k_scale"], cache["v_codes"],
              cache["v_scale"], cache["block_table"],
              jnp.asarray(positions, jnp.int32))


def psum_wire(x, axis: str, fmt_name: str = "fp8_e4m3"):
    """All-reduce with format-width wire + f32 accumulation (inside a
    shard_map body).  The DPA contract applied to the collective: each
    device ships its partial at `fmt_name` width plus one f32 scale, and
    the sum happens after widening.  Lossy at the wire format's precision
    — serving's pure-relayout routes never use it; it exists for
    row/column-parallel projection partials and gradient reduction."""
    from repro.distributed.collectives import quantize_for_wire
    q, scale = quantize_for_wire(x, fmt_name)
    qs = jax.lax.all_gather(q, axis)
    ss = jax.lax.all_gather(scale, axis)
    n = qs.shape[0]
    widened = qs.astype(jnp.float32) * ss.reshape((n,) + (1,) * x.ndim)
    return jnp.sum(widened, axis=0)


def all_gather_wire(x, axis: str, fmt_name: str = "fp8_e4m3",
                    *, gather_axis: int = 0):
    """Tiled all-gather with format-width wire: quantize the local shard,
    gather codes + per-shard scales, dequantize each slab after landing.
    For tensors that are already narrow codes (the KV pool) use plain
    all_gather — that wire is already at format width and stays
    bit-exact."""
    from repro.distributed.collectives import (dequantize_from_wire,
                                               quantize_for_wire)
    q, scale = quantize_for_wire(x, fmt_name)
    qs = jax.lax.all_gather(q, axis, axis=gather_axis, tiled=True)
    ss = jax.lax.all_gather(scale, axis)
    n_dev = ss.shape[0]
    parts = jnp.split(qs, n_dev, axis=gather_axis)
    return jnp.concatenate(
        [dequantize_from_wire(p, s) for p, s in zip(parts, ss)],
        axis=gather_axis)


def make_tp_mesh(tp: int):
    """(1, tp) host mesh over the first tp devices: ("data", "model")."""
    from repro.launch.mesh import make_host_mesh
    return make_host_mesh(n_data=1, n_model=tp)
