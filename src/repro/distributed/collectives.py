"""Trans-precision collectives: the paper's DPA contract applied to ICI.

The hardware insight — keep the wires narrow, accumulate wide — maps onto
gradient reduction: ship FP8 (or FP4) shards across the slow axis and
accumulate the dequantized partials in FP32.  Error feedback keeps the
quantization bias from accumulating across steps (the residual of each
compression round is added back before the next).

`ef_compress_allreduce` is written for shard_map bodies (explicit axis
name).  `CompressedReducer` carries the error-feedback state as a pytree
so it checkpoints/restores with the training state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.formats import get_format
from repro.core.quantize import cast_to, compute_scale


def quantize_for_wire(x, fmt_name: str):
    """-> (q: fmt dtype, scale: f32 scalar per tensor)."""
    scale = compute_scale(x, fmt_name)
    q = cast_to(x.astype(jnp.float32) / scale, fmt_name)
    return q, scale


def dequantize_from_wire(q, scale):
    return q.astype(jnp.float32) * scale


def ef_compress_allreduce(grad, err, axis_name: str, fmt_name: str = "fp8_e4m3"):
    """Inside shard_map: all-reduce `grad` over `axis_name` with FP8 wire
    format and FP32 accumulation; returns (mean_grad, new_err).

    Wire pattern: each device quantizes (grad + err); the quantized shards
    are all-gathered at format width (narrow wire — 4x fewer bytes than
    f32) and each device accumulates the widened shards in FP32 (the DPA
    contract).  new_err is the local compression residual.
    """
    g = grad.astype(jnp.float32) + err
    q, scale = quantize_for_wire(g, fmt_name)
    new_err = g - dequantize_from_wire(q, scale)
    qs = jax.lax.all_gather(q, axis_name)            # (n_dev, ...) fp8 wire
    ss = jax.lax.all_gather(scale, axis_name)
    n = qs.shape[0]
    widened = qs.astype(jnp.float32) * ss.reshape((n,) + (1,) * grad.ndim)
    return jnp.mean(widened, axis=0), new_err


def ef_state_like(grads):
    return jax.tree.map(lambda g: jnp.zeros_like(g, dtype=jnp.float32), grads)


def tree_compress_allreduce(grads, err_state, axis_name: str,
                            fmt_name: str = "fp8_e4m3"):
    """Pytree version: -> (mean_grads, new_err_state)."""
    flat_g, td = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree.leaves(err_state)
    outs = [ef_compress_allreduce(g, e, axis_name, fmt_name)
            for g, e in zip(flat_g, flat_e)]
    return td.unflatten([o[0] for o in outs]), td.unflatten(
        [o[1] for o in outs])


def wire_bytes(grads, fmt_name: str) -> int:
    """Bytes per device per round on the compressed wire."""
    fmt = get_format(fmt_name)
    n = sum(g.size for g in jax.tree.leaves(grads))
    return n * fmt.bits // 8


class CompressedReducer:
    """Error-feedback compressed gradient reducer, exec-plan routed.

    Functional by design: the error-feedback state is a plain pytree
    (`init_state`) the caller threads through the train step, so it
    checkpoints/restores with the training state.  Each `reduce` resolves
    the `allreduce` exec-plan op — the wire-compressed route when the
    mesh axis is real, the f32 psum reference on a size-1 axis — instead
    of branching on format/device-count inline (that pre-plan branching
    is gone)."""

    def __init__(self, fmt_name: str = "fp8_e4m3"):
        self.fmt_name = fmt_name

    def init_state(self, grads):
        return ef_state_like(grads)

    def reduce(self, grads, err_state, axis_name: str, *, n_devices: int):
        """Inside a shard_map body: -> (mean_grads, new_err_state).

        `n_devices` is static (the mesh axis size) so route resolution
        happens at trace time, like every other exec-plan call site."""
        from repro.core import exec_plan
        entry = exec_plan.resolve("allreduce", None,
                                  wire_fmt=self.fmt_name,
                                  n_devices=n_devices)
        flat_g, td = jax.tree_util.tree_flatten(grads)
        flat_e = jax.tree.leaves(err_state)
        outs = [entry.run(g, e, axis_name=axis_name,
                          fmt_name=self.fmt_name)
                for g, e in zip(flat_g, flat_e)]
        return (td.unflatten([o[0] for o in outs]),
                td.unflatten([o[1] for o in outs]))
