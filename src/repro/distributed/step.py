"""Train / serve step builders: loss, grads, optimizer, pjit plumbing.

`make_train_step` returns a pure (state, batch) -> (state, metrics)
function suitable for jax.jit with sharded in/out; `make_serve_step`
returns the decode step.  The cross-entropy supports chunked evaluation
over the sequence (beyond-paper memory optimization — the unembedding
logits for a 150k vocab dominate activation memory at 4k seq).

Every matmul in these steps reaches the hardware through
`core.exec_plan.resolve` — the backbone via `apply_linear`/attention
routes, the unembed via the `unembed` plan op (`layers.apply_unembed`),
and the gradient collective in `make_compressed_train_step` via the
`allreduce` op.  No pre-plan branching survives here.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.optim import adamw


def softmax_xent(logits, labels, z_coef: float = 1e-4):
    """logits (B,S,V) f32, labels (B,S) i32 -> scalar mean loss (+z-loss)."""
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = jnp.mean(logz - gold)
    if z_coef:
        loss = loss + z_coef * jnp.mean(jnp.square(logz))
    return loss


def chunked_xent(params, model, x, labels, chunk: int, z_coef: float = 1e-4):
    """Per-chunk unembed + xent: never materializes (B,S,V)."""
    from repro.distributed.sharding import maybe_shard
    cfg = model.cfg
    B, S, _ = x.shape
    n = S // chunk
    table = params["embed"]["table"] if cfg.tie_embeddings \
        else params["unembed"]["table"]

    @jax.checkpoint
    def body(carry, idx):
        # checkpointed: each chunk's (B, chunk, V) logits are recomputed
        # in backward — saving them re-materializes the full (B,S,V)
        # tensor the chunking exists to avoid (§Perf A6)
        xs = jax.lax.dynamic_slice_in_dim(x, idx * chunk, chunk, 1)
        xs = maybe_shard(xs, "data", None, None)
        ls = jax.lax.dynamic_slice_in_dim(labels, idx * chunk, chunk, 1)
        logits = L.apply_unembed(None, xs, table=table)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ls[..., None], axis=-1)[..., 0]
        part = jnp.sum(logz - gold) + z_coef * jnp.sum(jnp.square(logz))
        return carry + part, None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), jnp.arange(n))
    return total / (B * S)


def make_loss_fn(model):
    cfg = model.cfg

    def loss_fn(params, batch):
        seq = batch["labels"].shape[1]
        chunk = min(cfg.logits_chunk, seq) if cfg.logits_chunk else 0
        if chunk and seq % chunk == 0:
            x, aux = model.backbone_features(params, batch)
            loss = chunked_xent(params, model, x, batch["labels"], chunk)
        else:
            logits, aux = model.train_logits(params, batch)
            loss = softmax_xent(logits, batch["labels"])
        return loss + aux, {"loss": loss, "aux": aux}

    return loss_fn


def make_train_step(model, opt_cfg: adamw.AdamWConfig):
    loss_fn = make_loss_fn(model)

    def train_step(state, batch):
        params, opt_state = state["params"], state["opt"]
        (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        new_params, new_opt, om = adamw.update(opt_cfg, grads, opt_state,
                                               params)
        metrics = {"loss": parts["loss"], "aux": parts["aux"],
                   "total": loss, **om}
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def make_compressed_train_step(model, opt_cfg: adamw.AdamWConfig, mesh,
                               fmt_name: str = "fp8_e4m3",
                               axis: str = "data"):
    """Data-parallel train step with wire-compressed gradient reduction.

    shard_map over `axis`: params/opt replicated, batch sharded on its
    leading dim, per-shard grads all-reduced through the exec-plan
    ``allreduce`` op — the wire-compressed route when `fmt_name` names a
    wire format (format-width codes + f32 scales, error feedback carried
    in state["err"]), the f32 psum reference when it is None.  The error
    state has a leading device axis (one residual per device) and
    checkpoints with the rest of the state; build it with
    `init_err_state`.
    """
    from jax.sharding import PartitionSpec as P

    from repro.distributed.collectives import CompressedReducer
    from repro.distributed.tp import shard_map_compat

    loss_fn = make_loss_fn(model)
    reducer = CompressedReducer(fmt_name)
    n_dev = int(dict(zip(mesh.axis_names, mesh.devices.shape))[axis])

    def body(state, batch):
        params, opt_state = state["params"], state["opt"]
        err = jax.tree.map(lambda e: e[0], state["err"])
        (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        grads, new_err = reducer.reduce(grads, err, axis, n_devices=n_dev)
        new_params, new_opt, om = adamw.update(opt_cfg, grads, opt_state,
                                               params)
        loss = jax.lax.pmean(loss, axis)
        parts = jax.tree.map(lambda t: jax.lax.pmean(t, axis), parts)
        metrics = {"loss": parts["loss"], "aux": parts["aux"],
                   "total": loss, **om}
        new_state = {"params": new_params, "opt": new_opt,
                     "err": jax.tree.map(lambda e: e[None], new_err)}
        return new_state, metrics

    state_specs = {"params": P(), "opt": P(), "err": P(axis)}
    return shard_map_compat(body, mesh, in_specs=(state_specs, P(axis)),
                            out_specs=(state_specs, P()), axis=axis)


def init_err_state(params, n_devices: int):
    """Per-device error-feedback residuals, leading axis = mesh axis."""
    return jax.tree.map(
        lambda p: jnp.zeros((n_devices,) + p.shape, jnp.float32), params)


def make_serve_step(model):
    def serve_step(params, batch, caches):
        logits, caches = model.decode_step(params, batch, caches)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, caches
    return serve_step


def make_prefill_step(model):
    def prefill_step(params, batch):
        return model.prefill(params, batch)
    return prefill_step
