from . import collectives, sharding, step  # noqa: F401
