"""Hash-keyed radix prefix cache over the paged quantized KV cache.

At production scale most requests replicate the *same* system-prompt /
few-shot preamble rows.  TransDot's thesis is one shared reconfigurable
datapath replacing FPnew-style replicated lanes; the serving-side mirror
is one shared page pool replacing per-request cache replication — and
prefix sharing completes that move: identical prompt prefixes map onto
the *same* physical pages instead of each request re-prefilling and
re-storing its own copy.  Quantized pages compound the win — a resident
prefix held at format width costs 2–7.5x fewer bytes to keep warm than
an f32 one (`core.kvcache` byte accounting).

Structure — a radix trie at page granularity.  A node is one *full page*
of prompt tokens: its key is the page's token block (a `page_size`-tuple,
hash-keyed through the children dict), its payload the pool page holding
that block's quantized K/V rows for every layer.  A request's prompt
walks the trie block by block from the root; the matched chain's pages
are shared into its block table read-only, and the engine skips the
prefill chunks they cover.

Sharing is safe because of two contracts this module leans on but does
not own:

  refcounts  : `core.kvcache.PageAllocator` counts holders per page.
               The cache itself holds one reference on every node's page
               (taken at `insert`, dropped at eviction), each request
               using the page holds another, and a page only returns to
               the free list at refcount zero — so a shared page is
               never freed or re-handed-out while any block table still
               points at it.
  relayout   : pages hold codes/scales, and attention dequantizes in
               the prologue, so reading a shared page is bit-identical
               to reading a private copy of the same rows.  Sharing is
               pure relayout; a prefix-hit request's greedy outputs are
               bit-identical to the same request served cold
               (`tests/test_prefix_cache.py` pins this across Table-I
               KV formats, packed fp4 included).

Copy-on-write: when a request diverges *inside* a page — its prompt
shares only the first r < page_size rows of a cached block (or simply
ends mid-block) — `match` reports a `cow` source.  The engine copies
those r rows into a private page (`Engine._cow_copy`, pure relayout
again) and the request writes its own divergent rows after them; the
shared source page is never mutated.

Eviction: nodes whose pages have no holder beyond the cache itself
(refcount 1) are cold; under pool pressure `evict` drops the
least-recently-used cold *leaves* first (a parent is always at least as
recently used as any descendant, because every match/insert touches its
whole chain).  Pages in use by a live request (refcount > 1) are pinned.

The scheduler side — taking request references, CoW copies, staging
materialization, skip accounting — lives in `launch.engine`.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple


class PrefixNode:
    """One cached full page of prompt tokens (a radix-trie edge+node)."""
    __slots__ = ("block", "page", "parent", "children", "last_used")

    def __init__(self, block: tuple, page: int, parent: "PrefixNode"):
        self.block = block           # page_size token ids, the hash key
        self.page = page             # pool page holding the block's rows
        self.parent = parent
        self.children = {}           # block tuple -> PrefixNode
        self.last_used = 0


@dataclasses.dataclass
class PrefixMatch:
    """What `match` found for one prompt.

    pages: fully-shared pages in timeline order (the caller increfs and
    points its block table at them read-only); cow: optional (source
    page, rows) partial tail — the first `rows` of `source page` equal
    the prompt's next tokens, to be copied into a private page; tokens:
    total prompt tokens covered (``page_size * len(pages) + cow rows``),
    i.e. the prefill tokens the engine skips."""
    pages: List[int]
    cow: Optional[Tuple[int, int]]
    tokens: int


class PrefixCache:
    """Radix prefix index over an allocator's page pool.

    The cache holds one allocator reference per node page (taken in
    `insert`, released in `evict`), so cached prefixes stay resident —
    and evictable — independent of the requests that created them."""

    def __init__(self, page_size: int, alloc):
        if page_size < 1:
            raise ValueError("page_size must be >= 1")
        self.page_size = page_size
        self.alloc = alloc
        self.root = PrefixNode((), -1, None)     # sentinel, no page
        self.n_nodes = 0
        self._tick = 0                           # LRU clock (match/insert)

    @property
    def n_pages(self) -> int:
        """Pages resident in the cache (one per node)."""
        return self.n_nodes

    def _block(self, tokens, i: int) -> tuple:
        ps = self.page_size
        return tuple(int(t) for t in tokens[i * ps:(i + 1) * ps])

    def match(self, tokens, limit: int) -> PrefixMatch:
        """Longest cached prefix of `tokens`, covering at most `limit`
        tokens (the engine passes ``n_prompt - 1`` so at least one
        prompt token always prefills and yields first-token logits).

        Walks full-page blocks from the root; at the first full-block
        miss (or when fewer than page_size tokens remain under the
        limit) it looks for the child sharing the longest common prefix
        of the partial block — the copy-on-write source.  Touches every
        matched node's LRU stamp."""
        self._tick += 1
        node, pages = self.root, []
        ps = self.page_size
        cap = max(0, min(len(tokens), limit))
        i = 0
        while (i + 1) * ps <= cap:
            child = node.children.get(self._block(tokens, i))
            if child is None:
                break
            child.last_used = self._tick
            pages.append(child.page)
            node = child
            i += 1
        matched = i * ps
        cow = None
        rem = min(cap - matched, ps)
        if rem > 0:
            part = tuple(int(t) for t in tokens[matched:matched + rem])
            best, best_r = None, 0
            for child in node.children.values():
                r = 0
                while r < rem and child.block[r] == part[r]:
                    r += 1
                if r > best_r:
                    best, best_r = child, r
            if best is not None:
                best.last_used = self._tick
                cow = (best.page, best_r)
                matched += best_r
        return PrefixMatch(pages=pages, cow=cow, tokens=matched)

    def insert(self, tokens, pages) -> int:
        """Register a request's full-page prompt blocks after its
        prefill lands (only then do the pages hold the rows).

        `pages` is the request's page list in timeline order; block i
        lives in pages[i].  Existing nodes are kept (first writer wins —
        a concurrent cold duplicate's page simply frees at its finish);
        new nodes take one cache reference on their page.  The partial
        tail block (and any page later shared with generated tokens) is
        never inserted: only pure full-prompt pages are shareable.
        Returns the number of nodes created."""
        self._tick += 1
        node, created = self.root, 0
        n_full = min(len(tokens) // self.page_size, len(pages))
        for i in range(n_full):
            blk = self._block(tokens, i)
            child = node.children.get(blk)
            if child is None:
                child = PrefixNode(blk, int(pages[i]), node)
                node.children[blk] = child
                self.alloc.incref([child.page])
                self.n_nodes += 1
                created += 1
            child.last_used = self._tick
            node = child
        return created

    def evict(self, n: int) -> int:
        """Free up to `n` pages by dropping the coldest zero-external-ref
        leaves (refcount 1 = only the cache holds the page).  Interior
        nodes become leaves as their children go, so repeated eviction
        drains whole cold chains deepest-first.  Returns pages freed."""
        freed = 0
        while freed < n:
            victim = None
            stack = list(self.root.children.values())
            while stack:
                nd = stack.pop()
                stack.extend(nd.children.values())
                if nd.children or self.alloc.refcount(nd.page) != 1:
                    continue                    # interior, or in use
                if victim is None or nd.last_used < victim.last_used:
                    victim = nd
            if victim is None:
                break
            del victim.parent.children[victim.block]
            self.alloc.free([victim.page])      # last holder -> free list
            self.n_nodes -= 1
            freed += 1
        return freed

    def drop_all(self) -> int:
        """Evict every evictable node (shutdown / tests).  Pages still
        referenced by live requests stay resident."""
        return self.evict(self.n_nodes)
