"""Serving-side subsystems: sampling + self-speculative decoding.

`sampler` is the fixed-shape, jit-able token sampler (temperature /
top-k / top-p) with per-request threefry keys, `spec_decode` the
draft-low-precision / verify-high-precision speculative decoder the
continuous-batching engine (`repro.launch.engine`) mounts on top of it.
"""
from .sampler import SamplerConfig           # noqa: F401
from .spec_decode import SpecConfig          # noqa: F401
