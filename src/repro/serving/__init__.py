"""Serving-side subsystems: sampling, speculation, prefix sharing.

`sampler` is the fixed-shape, jit-able token sampler (temperature /
top-k / top-p) with per-request threefry keys, `spec_decode` the
draft-low-precision / verify-high-precision speculative decoder, and
`prefix_cache` the hash-keyed radix index that shares identical prompt
prefixes across requests through ref-counted read-only pages (with
copy-on-write on divergence).  The continuous-batching engine
(`repro.launch.engine`) mounts all three.
"""
from .prefix_cache import PrefixCache        # noqa: F401
from .sampler import SamplerConfig           # noqa: F401
from .spec_decode import SpecConfig          # noqa: F401
