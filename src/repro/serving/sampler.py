"""Fixed-shape, jit-able token sampling with per-request PRNG streams.

The batched serving engine samples every live request in one fused call,
but a request's tokens must not depend on *which other requests* share
its batch — otherwise continuous batching changes outputs run to run.
The fix is to derive randomness per request, never per batch: the stream
for one sampled token is

    fold_in(fold_in(fold_in(PRNGKey(seed), rid), position), role)

keyed on the request id and the token's absolute timeline index, so the
same request produces identical tokens whether it is served alone or
packed into any batch composition (`tests/test_sampler.py` pins this).
`role` separates the independent uses speculative decoding makes of one
position (proposal draw, accept/reject uniform, residual draw).

Every transform is fixed-shape over the full vocab (sort + threshold,
no dynamic gathers), so the whole sampler jits into the engine's decode
step.  ``temperature == 0`` short-circuits to raw-logits argmax —
bit-identical to the greedy path the engine shipped with, which is the
anchor for the speculative-decoding exactness story."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

# role salts: independent streams at one (rid, position)
ROLE_SAMPLE = 0      # plain decode sampling
ROLE_DRAFT = 1       # speculative proposal draw
ROLE_ACCEPT = 2      # accept/reject uniform
ROLE_RESIDUAL = 3    # residual / bonus draw after the accept decision

_NEG = -jnp.inf


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    """Sampling knobs, all static under jit.

    temperature 0 means greedy (argmax over raw logits, bit-for-bit the
    pre-sampler engine behavior); top_k 0 and top_p 1.0 disable those
    filters (`top_k >= vocab` keeps every token too, so it likewise
    disables — never a static out-of-range index).  `seed` roots every
    request's threefry stream."""
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: int = 0

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError("temperature must be >= 0")
        if self.top_k < 0:
            raise ValueError("top_k must be >= 0 (0 disables)")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError("top_p must be in (0, 1]")

    @property
    def greedy(self) -> bool:
        return self.temperature == 0.0


def request_key(seed: int, rid, position, role: int):
    """The per-token threefry key: (seed, rid, position, role) folds.

    `rid`/`position` may be traced i32 scalars — fold_in is jit-safe —
    so one vmap turns this into the engine's per-slot key batch."""
    key = jax.random.PRNGKey(seed)
    key = jax.random.fold_in(key, rid)
    key = jax.random.fold_in(key, position)
    return jax.random.fold_in(key, role)


def greedy_tokens(logits):
    """Argmax with NaN logits masked (..., V) -> (...) i32.

    Bit-identical to raw ``jnp.argmax`` whenever logits are NaN-free —
    which is the greedy anchor the engine equality tests pin — while an
    all-but-one-masked row with NaN entries still picks the finite
    token.  The speculative accept rule uses this same reduction, so
    draft/verify argmax comparisons and plain decode can never disagree
    on how ties against NaN resolve."""
    x = jnp.where(jnp.isnan(logits), _NEG, logits)
    return jnp.argmax(x, axis=-1).astype(jnp.int32)


def filter_logits(logits, cfg: SamplerConfig):
    """Raw logits (..., V) -> f32 filtered/scaled logits.

    NaN entries are treated as masked (-inf) up front, then temperature
    scaling, then top-k (keep the k largest; ties at the k-th value are
    all kept — deterministic; ``k >= V`` keeps everything and so
    disables the filter, like k = 0), then top-p over the *remaining*
    mass: sort descending, keep tokens while the mass strictly before
    them is < p.  When p lands exactly on a cumulative step, exactly
    that prefix survives (the boundary token whose prefix mass equals p
    is cut).  At least one token always survives every filter — an
    all-masked row (every logit NaN/-inf, e.g. a fully-masked vocabulary
    slice) degenerates to token 0, matching `greedy_tokens`' argmax on
    that row, so softmax/categorical (and the speculative p/q ratios)
    never see NaN."""
    x = logits.astype(jnp.float32)
    x = jnp.where(jnp.isnan(x), _NEG, x)
    dead = ~jnp.any(x > _NEG, axis=-1, keepdims=True)
    first = jnp.arange(x.shape[-1]) == 0
    x = jnp.where(dead & first, 0.0, x)
    if cfg.temperature > 0:
        x = x / cfg.temperature
    if 0 < cfg.top_k < x.shape[-1]:
        kth = jnp.sort(x, axis=-1)[..., -cfg.top_k, None]
        x = jnp.where(x < kth, _NEG, x)
    if cfg.top_p < 1.0:
        p = jax.nn.softmax(x, axis=-1)
        sp = jnp.flip(jnp.sort(p, axis=-1), axis=-1)
        mass_before = jnp.cumsum(sp, axis=-1) - sp
        keep = mass_before < cfg.top_p
        # threshold = smallest kept probability (>= 1 token always kept:
        # mass_before of the largest is 0 < p)
        thr = jnp.min(jnp.where(keep, sp, jnp.inf), axis=-1, keepdims=True)
        x = jnp.where(p < thr, _NEG, x)
    return x


def sample_probs(logits, cfg: SamplerConfig):
    """The actual sampling distribution: softmax of the filtered logits
    (zeros at masked slots).  This is the q / p that speculative
    rejection sampling compares, so it must match `sample_tokens`'s
    categorical draw exactly — both go through `filter_logits`."""
    return jax.nn.softmax(filter_logits(logits, cfg), axis=-1)


def sample_tokens(logits, rids, positions, cfg: SamplerConfig,
                  role: int = ROLE_SAMPLE):
    """Batched per-request draw: logits (B, V), rids/positions (B,) i32
    -> (B,) i32 tokens.  Greedy configs take the argmax (no PRNG
    consumed); otherwise one categorical per row under its request key."""
    if cfg.greedy:
        return greedy_tokens(logits)
    keys = jax.vmap(
        lambda r, p: request_key(cfg.seed, r, p, role))(rids, positions)
    x = filter_logits(logits, cfg)
    return jax.vmap(jax.random.categorical)(keys, x).astype(jnp.int32)


def accept_uniforms(rids, positions, cfg: SamplerConfig):
    """(B,) accept/reject uniforms in [0, 1), one per request stream."""
    keys = jax.vmap(
        lambda r, p: request_key(cfg.seed, r, p, ROLE_ACCEPT))(
            rids, positions)
    return jax.vmap(lambda k: jax.random.uniform(k, ()))(keys)


def categorical_from_probs(probs, keys):
    """(B, V) probs + (B,) keys -> (B,) i32 draws (log-space categorical;
    zero-prob slots are exactly excluded)."""
    logp = jnp.where(probs > 0, jnp.log(jnp.maximum(probs, 1e-38)), _NEG)
    return jax.vmap(jax.random.categorical)(keys, logp).astype(jnp.int32)
