"""Trans-precision self-speculative decoding: draft cheap, verify exact.

TransDot's premise is one datapath serving fp16/fp8/fp4 DPA at
2x/4x/8x throughput; speculative decoding is the serving-level mirror
of that trade.  The *same weights* run twice per round:

  draft  : k sequential single-token decode steps under a cheap
           low-precision policy (e.g. `w4a4_kv4_attn4`, the all-fp4
           8-term-DPA route), each proposing the next token;
  verify : ONE batched pass under the serving policy over k+1 query
           tokens (the last accepted token + all k drafts) through the
           ``verify_attn`` exec-plan route — per-request causal masks
           over the paged cache, so row i reproduces bit-for-bit what a
           plain decode step at that position would compute;
  accept : standard speculative rejection sampling per request, so the
           emitted distribution is *exactly* the serving policy's.
           Greedy (temperature 0) degenerates to prefix-match on
           argmax, making spec-decoded outputs token-for-token
           identical to the non-speculative engine — the pinned
           invariant (`tests/test_spec_decode.py`).

Both policies must share the KV-cache storage format (fmt_kv /
kv_packed): draft and verify write the same page pool, and the verify
pass *overwrites* every row the draft phase touched with serving-policy
codes, so accepted rows are indistinguishable from plain-decode rows.
Rows past the accepted length hold rejected-draft values — masked by
position, overwritten on the next round, and their wholly-unused pages
roll back to the request's reservation (`core.kvcache.PageAllocator`).

This module owns the jit-able pieces (draft step, accept rule); the
scheduler side — page commit/rollback, token budgeting, stats — lives
in `launch.engine`.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.policy import get_policy

from . import sampler as S
from .sampler import SamplerConfig


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """Speculation knobs.  `draft_policy` names the low-precision policy
    preset the draft steps run under; `k` is the fixed draft length per
    round (fixed-shape: every round drafts exactly k and verifies k+1,
    so both jitted steps compile once)."""
    draft_policy: str
    k: int = 4

    def __post_init__(self):
        if self.k < 1:
            raise ValueError("spec k must be >= 1")


def validate_policy_pair(draft_policy, serve_policy):
    """Draft and serving policies must share one cache layout.

    Returns the draft policy object.  The cache stores fmt_kv-width
    codes; a draft policy with a different fmt_kv (or packing) would
    write rows the verify pass cannot even type-check against."""
    dpol, spol = get_policy(draft_policy), get_policy(serve_policy)
    if not dpol.kv_quantized:
        raise ValueError(
            f"draft policy {draft_policy!r} keeps a raw f32 cache; "
            "speculative drafting shares the serving engine's paged "
            "code pool, so pick a draft preset with fmt_kv set "
            "(e.g. w4a4_kv4_attn4 over an fp4 cache)")
    if (dpol.fmt_kv, dpol.kv_packed) != (spol.fmt_kv, spol.kv_packed):
        raise ValueError(
            f"draft policy {draft_policy!r} stores KV as "
            f"{dpol.fmt_kv}/packed={dpol.kv_packed} but the serving "
            f"policy stores {spol.fmt_kv}/packed={spol.kv_packed}; "
            "draft and verify must share the cache format (pick a "
            "draft preset with the same fmt_kv/kv_packed)")
    return dpol


def make_draft_step(draft_model, scfg: SamplerConfig):
    """One draft decode step: (params, batch, caches, rids) ->
    (token (B,), draft_probs (B, V) | None, caches).

    `batch` is the engine's decode batch ({"tokens": (B, 1), "index":
    (B,) positions}); the proposed token's timeline index is index + 1,
    which keys its PRNG stream (`ROLE_DRAFT`).  Greedy configs return no
    probs — acceptance is argmax prefix-match and needs none."""
    greedy = scfg.greedy

    def step(params, batch, caches, rids):
        logits, caches = draft_model.decode_step(params, batch, caches)
        tok = S.sample_tokens(logits[:, -1], rids, batch["index"] + 1,
                              scfg, role=S.ROLE_DRAFT)
        probs = None if greedy else S.sample_probs(logits[:, -1], scfg)
        return tok, probs, caches

    return step


def make_accept_fn(scfg: SamplerConfig, k: int):
    """The accept rule: (drafts, draft_probs, target_logits, rids,
    positions) -> (emitted (B, k+1), n_accepted (B,)).

    drafts (B, k) are the proposals for timeline indices positions+1 ..
    positions+k; target_logits (B, k+1, V) are the verify pass's logits,
    row i the serving-policy distribution for index positions+i+1.
    `emitted[:, j]` holds the j-th token the round produces; exactly
    n_accepted+1 of them are valid (accepted drafts, then one correction
    / residual / bonus token), the rest are zero padding.

    Greedy: accept the longest prefix where draft == argmax(target),
    then emit the target argmax at the first mismatch (or the bonus
    argmax after k accepts) — deterministic, no PRNG.

    Sampled: per-draft accept with prob min(1, p(d)/q(d)) under the
    request's `ROLE_ACCEPT` uniform; on rejection sample the residual
    max(p - q, 0)/Z, on full acceptance sample the bonus from p_k —
    both via `ROLE_RESIDUAL` — so the output distribution is exactly
    the target's (standard speculative-sampling correctness)."""
    idx = jnp.arange(k + 1)[None]

    def emit(drafts, acc, extra):
        drafts_p = jnp.pad(drafts, ((0, 0), (0, 1)))
        return jnp.where(idx < acc[:, None], drafts_p,
                         jnp.where(idx == acc[:, None], extra, 0)
                         ).astype(jnp.int32)

    if scfg.greedy:
        def accept(drafts, draft_probs, target_logits, rids, positions):
            t = S.greedy_tokens(target_logits)
            match = (drafts == t[:, :k]).astype(jnp.int32)
            acc = jnp.sum(jnp.cumprod(match, axis=1), axis=1)
            corr = jnp.take_along_axis(t, acc[:, None], axis=1)
            return emit(drafts, acc, corr), acc

        return accept

    def accept(drafts, draft_probs, target_logits, rids, positions):
        p = S.sample_probs(target_logits, scfg)              # (B, k+1, V)
        tok_pos = positions[:, None] + 1 + jnp.arange(k)[None]
        u = jax.vmap(lambda col: S.accept_uniforms(rids, col, scfg),
                     in_axes=1, out_axes=1)(tok_pos)         # (B, k)
        p_d = jnp.take_along_axis(p[:, :k], drafts[..., None],
                                  axis=-1)[..., 0]
        q_d = jnp.take_along_axis(draft_probs, drafts[..., None],
                                  axis=-1)[..., 0]
        ok = (u < jnp.minimum(p_d / jnp.maximum(q_d, 1e-38), 1.0)
              ).astype(jnp.int32)
        acc = jnp.sum(jnp.cumprod(ok, axis=1), axis=1)       # (B,)
        # the (acc)-th emitted token: residual max(p-q,0)/Z at the first
        # rejection; after k accepts q_at == p_at, the residual vanishes
        # and the draw falls through to the bonus target distribution
        at = acc[:, None, None]
        p_at = jnp.take_along_axis(p, at, axis=1)[:, 0]      # (B, V)
        q_pad = jnp.concatenate([draft_probs, p[:, k:]], axis=1)
        q_at = jnp.take_along_axis(q_pad, at, axis=1)[:, 0]
        resid = jnp.maximum(p_at - q_at, 0.0)
        z = jnp.sum(resid, axis=-1, keepdims=True)
        dist = jnp.where(z > 0, resid / jnp.maximum(z, 1e-38), p_at)
        keys = jax.vmap(lambda r, pos: S.request_key(
            scfg.seed, r, pos, S.ROLE_RESIDUAL))(
                rids, positions + 1 + acc)
        extra = S.categorical_from_probs(dist, keys)
        return emit(drafts, acc, extra[:, None]), acc

    return accept
