from . import checkpoint, controller, fault  # noqa: F401
