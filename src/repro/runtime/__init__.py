from . import checkpoint, fault  # noqa: F401
