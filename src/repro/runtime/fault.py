"""Fault-tolerant training supervisor.

Production contract (1000+ nodes): any step may die (device loss, host
OOM, preemption) or straggle (slow host, network).  The supervisor owns
the restart loop:

  - every step runs under a watchdog deadline; a straggling step raises
    StragglerTimeout (on real clusters the hook re-dispatches to a spare
    slice — on a single host we re-execute, which is also the correct
    local semantic);
  - on failure the loop restores the latest checkpoint (elastic: the
    restore accepts a new mesh) and replays from the restored step —
    the deterministic pipeline (data.pipeline) makes the replay exact;
  - failure injection (`inject_failure_at`) exists so the recovery path
    is *tested*, not aspirational (tests/test_fault.py).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Optional

import jax

from . import checkpoint as ckpt


class StragglerTimeout(RuntimeError):
    pass


@dataclasses.dataclass
class SupervisorConfig:
    ckpt_dir: str
    ckpt_every: int = 100
    max_failures: int = 10
    step_deadline_s: Optional[float] = None   # watchdog (None = off)
    async_save: bool = True


class Supervisor:
    def __init__(self, cfg: SupervisorConfig, *, state, restore_fn=None):
        """state: initial train state pytree.  restore_fn(target, step) may
        be provided for elastic restores (custom shardings)."""
        self.cfg = cfg
        self.state = state
        self.restore_fn = restore_fn
        self.saver = ckpt.AsyncSaver()
        self.failures = 0
        self.inject_failure_at: Optional[int] = None   # test hook
        self.events: list = []

    # ---- internals ----------------------------------------------------------
    def _run_with_watchdog(self, fn, *args):
        if self.cfg.step_deadline_s is None:
            return fn(*args)
        result, exc = [], []

        def target():
            try:
                out = fn(*args)
                jax.block_until_ready(out)
                result.append(out)
            except Exception as e:                      # pragma: no cover
                exc.append(e)

        t = threading.Thread(target=target, daemon=True)
        t.start()
        t.join(self.cfg.step_deadline_s)
        if t.is_alive():
            raise StragglerTimeout(
                f"step exceeded {self.cfg.step_deadline_s}s deadline")
        if exc:
            raise exc[0]
        return result[0]

    def _restore(self):
        step = ckpt.latest_step(self.cfg.ckpt_dir)
        if step is None:
            self.events.append(("restart_from_scratch", None))
            return 0
        if self.restore_fn is not None:
            self.state, step = self.restore_fn(self.state, step)
        else:
            self.state, step = ckpt.restore(self.cfg.ckpt_dir, self.state)
        self.events.append(("restored", step))
        return step + 1

    # ---- main loop ----------------------------------------------------------
    def run(self, train_step: Callable, batch_fn: Callable, n_steps: int,
            *, start_step: int = 0, on_metrics: Optional[Callable] = None):
        """Runs train_step(state, batch_fn(step)) for steps [start, n)."""
        step = start_step
        while step < n_steps:
            try:
                if self.inject_failure_at is not None \
                        and step == self.inject_failure_at:
                    self.inject_failure_at = None
                    raise RuntimeError("injected failure (test hook)")
                t0 = time.monotonic()
                self.state, metrics = self._run_with_watchdog(
                    train_step, self.state, batch_fn(step))
                if on_metrics:
                    on_metrics(step, metrics, time.monotonic() - t0)
                if (step + 1) % self.cfg.ckpt_every == 0 \
                        or step + 1 == n_steps:
                    if self.cfg.async_save:
                        self.saver.save(self.state, step, self.cfg.ckpt_dir)
                    else:
                        ckpt.save(self.state, step, self.cfg.ckpt_dir)
                step += 1
            except (StragglerTimeout, RuntimeError, jax.errors.JaxRuntimeError
                    ) as e:
                self.failures += 1
                self.events.append(("failure", step, repr(e)))
                if self.failures > self.cfg.max_failures:
                    raise
                self.saver.join()
                step = self._restore()
        self.saver.join()
        return self.state
