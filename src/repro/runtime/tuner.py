"""Measurement-driven autotuner over the execution plan.

The paper's thesis is one reconfigurable datapath picking the right
precision/width configuration per operation instead of hard-wiring it;
the software analogue of the *choosing* is here.  `core.exec_plan`
resolves routes by static priority and the kernels run hand-chosen
block shapes — this module replaces both constants with measurements,
in the style of dace's distributed cutout tuner:

  1. `enumerate_space()` builds the config space per (op, policy,
     shape-class): every route the priority order could defensibly pick
     (eligible at the class's representative shapes AND inside the
     static choice's reference family) x the route's declared knob grid
     (`PlanEntry.knobs` — kernel block shapes), plus an engine-level
     pseudo-op sweeping page size and speculative k.
  2. `run_sweep()` benchmarks each config as an isolated cutout: the
     op's inputs synthesized at the class's representative shapes,
     warmed once (compile), then timed under `jax.block_until_ready`.
     Results land in a JSON measurement database keyed by
     `config_hash()` — a content hash of (config, shape-class, backend,
     jax version) — so already-measured cutouts are skipped and the
     sweep shards across workers (`shard_of(hash, n) == i`).
  3. `tuned_entry()` is the `resolve()` consult (env `REPRO_TUNED_DB`,
     kill switch `REPRO_TUNED=0`): classify the live ctx into a
     shape-class, take the fastest measured record for (op, policy-key,
     class), and mint a `PlanEntry` that runs the measured route with
     the measured knobs.  The static priority order stays the untuned
     prior: unmeasured keys, unknown/ineligible/out-of-family routes,
     and corrupt DB entries all fall back to it with a warning.

The selection-invariance contract (pinned by `tests/test_tuner.py`): a
tuned DB can only *reorder* among routes whose reference pins already
pass — `_family(entry) = {name, reference}` must intersect the static
choice's family — so any tuned table preserves the plan's numerics,
and bit-pinned ops (paged_decode, verify_attn) stay bit-identical with
tuning on or off.

DB schema (`version` 1)::

    {"version": 1,
     "meta":    {"backend": ..., "jax_version": ...},   # informational
     "records": {<config_hash>: {"op", "policy", "policy_key",
                                 "shape_class", "route", "knobs",
                                 "backend", "jax_version",
                                 "us", "reps"}}}

`tools/tune.py` is the CLI; `benchmarks/tuned/` ships defaults for the
CI shape-classes; `docs/tuning.md` documents the workflow.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time
import warnings
from typing import Callable, Optional

SCHEMA_VERSION = 1

# -- knob grids ---------------------------------------------------------------
# Every grid includes the static default (the kernels' hand-chosen
# constants), so the untuned configuration is always among the measured
# candidates and tuned-vs-static is >= 1.0x by construction on the
# shapes the sweep covered.
DEFAULT_KNOBS = {"bm": 128, "bk": 128, "bn": 128, "bq": 128}
KNOB_GRID = {
    "bm": (32, 64, 128),
    "bk": (64, 128),
    "bn": (64, 128),
    "bq": (32, 64, 128),
}
SMOKE_KNOB_GRID = {
    "bm": (32, 128),
    "bk": (128,),
    "bn": (128,),
    "bq": (32, 128),
}

# -- the engine pseudo-op -----------------------------------------------------
# Page size and speculative draft length are engine-construction knobs,
# not per-op kwargs, so they tune as one whole-engine cutout (a reduced
# qwen3-4b serving a fixed synthetic workload; `synthetic_workload` is
# seed-deterministic, which tests/test_tuner.py pins).
ENGINE_OP = "engine"
ENGINE_ROUTE = "engine_step"
ENGINE_SHAPE_CLASS = "engine_ci"
ENGINE_POLICY = "kv4_attn8_packed"
ENGINE_DRAFT_POLICY = "w4a4_kv4_attn4"
ENGINE_POOL_ROWS = 384          # page_size * n_pages held constant
ENGINE_SEQ_ROWS = 48            # page_size * max_pages_per_req constant
ENGINE_KNOB_GRID = {"page_size": (8, 16), "spec_k": (0, 2, 4)}
SMOKE_ENGINE_KNOB_GRID = {"page_size": (8, 16), "spec_k": (0,)}


# -- shape classes ------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeClass:
    """One equivalence class of resolve() contexts.

    `match(ctx)` decides membership at resolve time; `rep` is both the
    representative resolve-ctx the sweep filters eligibility against
    and the shape the cutout synthesizes inputs at."""
    op: str
    name: str
    match: Callable
    rep: dict


SHAPE_CLASSES = (
    ShapeClass("matmul", "gemm_decode",
               lambda ctx: ctx.get("m") is not None
               and 0 < ctx["m"] <= 16,
               dict(w_dtype="float32", m=8, k=128, n=128)),
    ShapeClass("matmul", "gemm_prefill",
               lambda ctx: ctx.get("m") is not None and ctx["m"] > 16,
               dict(w_dtype="float32", m=128, k=128, n=128)),
    ShapeClass("grouped_matmul", "moe_experts",
               lambda ctx: ctx.get("e") is not None,
               dict(w_dtype="float32", eq="gti,gio->gto", e=4, m=16,
                    k=64, n=64)),
    ShapeClass("flash_attn", "flash_prefill",
               lambda ctx: ctx.get("sq", 1) > 1
               and not ctx.get("has_valid", False),
               dict(sq=32, skv=32, use_flash=True, has_valid=False,
                    kv_on_grid=False)),
    ShapeClass("paged_decode", "paged_single",
               lambda ctx: ctx.get("n_devices", 1) <= 1,
               dict(batch=4, page_size=8, max_pages=6, kv_heads=2, hd=16,
                    n_pages=48, n_devices=1)),
    ShapeClass("verify_attn", "verify_paged",
               lambda ctx: ctx.get("n_devices", 1) <= 1,
               dict(batch=2, sq=4, page_size=8, max_pages=6, kv_heads=2,
                    hd=16, n_pages=48, n_devices=1)),
    ShapeClass("quantize_pack", "qp_fp4_pack",
               lambda ctx: ctx.get("fmt") == "fp4_e2m1"
               and ctx.get("pack", False),
               dict(fmt="fp4_e2m1", pack=True)),
    ShapeClass("quantize_pack", "qp_rows",
               lambda ctx: not ctx.get("pack", False),
               dict(fmt="fp8_e4m3", pack=False)),
)

# policies whose CI shapes the sweep measures, per op (quantize_pack
# routes ignore the policy — the ctx fmt/pack bits drive them)
OP_POLICIES = {
    "matmul": ("fp8_dpa_fused", "fp4_dpa_packed"),
    "grouped_matmul": ("fp8_dpa_fused", "fp4_dpa_packed"),
    "flash_attn": ("attn_fp8_dpa", "fp32"),
    "paged_decode": ("kv4_attn8_packed",),
    "verify_attn": ("kv4_attn8_packed",),
    "quantize_pack": ("fp32",),
}


def classify(op: str, ctx: dict) -> Optional[str]:
    """Shape-class name for a live resolve ctx; None -> untuned prior."""
    for sc in SHAPE_CLASSES:
        if sc.op == op and sc.match(ctx):
            return sc.name
    return None


def shape_class(op: str, name: str) -> ShapeClass:
    for sc in SHAPE_CLASSES:
        if sc.op == op and sc.name == name:
            return sc
    raise KeyError(f"no shape class {op}/{name}")


# -- hashing ------------------------------------------------------------------

def policy_key(policy) -> str:
    """Stable 12-hex digest of a policy's full field set (preset names
    can drift; the fields are the semantics)."""
    from repro.core.policy import get_policy
    pol = get_policy(policy if policy is not None else "fp32")
    blob = json.dumps(dataclasses.asdict(pol), sort_keys=True,
                      separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()[:12]


HASH_FIELDS = ("op", "policy_key", "shape_class", "route", "knobs",
               "backend", "jax_version")


def config_hash(cfg) -> str:
    """Content hash of one measurement key (16 hex chars).

    Key-order and whitespace invariant: only HASH_FIELDS participate
    and they serialize canonically (sorted keys, no spaces).  Accepts a
    dict or its JSON serialization."""
    if isinstance(cfg, str):
        cfg = json.loads(cfg)
    knobs = dict(cfg.get("knobs") or {})
    canon = {"op": cfg["op"], "policy_key": cfg["policy_key"],
             "shape_class": cfg["shape_class"], "route": cfg["route"],
             "knobs": {k: knobs[k] for k in sorted(knobs)},
             "backend": cfg.get("backend", ""),
             "jax_version": cfg.get("jax_version", "")}
    blob = json.dumps(canon, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def shard_of(config_hash_hex: str, n_shards: int) -> int:
    """Deterministic shard index: every worker computes the same
    partition, each config lands in exactly one shard."""
    return int(config_hash_hex, 16) % n_shards


def env_fingerprint() -> dict:
    import jax
    return {"backend": jax.default_backend(), "jax_version": jax.__version__}


# -- measurement database -----------------------------------------------------

_WARNED: set = set()


def warn_once(msg: str) -> None:
    if msg not in _WARNED:
        _WARNED.add(msg)
        warnings.warn(msg, stacklevel=3)


_REQUIRED_RECORD_FIELDS = ("op", "policy_key", "shape_class", "route", "us")


def _valid_record(rec) -> bool:
    if not isinstance(rec, dict):
        return False
    if any(f not in rec for f in _REQUIRED_RECORD_FIELDS):
        return False
    if not isinstance(rec["us"], (int, float)) or rec["us"] <= 0:
        return False
    if rec.get("knobs") is not None and not isinstance(rec["knobs"], dict):
        return False
    return True


def load_db(path: str) -> dict:
    """Read a measurement DB, tolerating damage: a corrupt file yields
    an empty DB and corrupt/partial records are dropped — with one
    warning each — never an exception (the `resolve()` contract)."""
    db = {"version": SCHEMA_VERSION, "meta": {}, "records": {}}
    try:
        with open(path) as f:
            raw = json.load(f)
    except FileNotFoundError:
        return db
    except (OSError, json.JSONDecodeError) as exc:
        warn_once(f"tuned DB {path!r} unreadable ({exc!r}); "
                  "treating as empty")
        return db
    if not isinstance(raw, dict) or not isinstance(raw.get("records"), dict):
        warn_once(f"tuned DB {path!r} has no records table; "
                  "treating as empty")
        return db
    db["meta"] = raw.get("meta") if isinstance(raw.get("meta"), dict) else {}
    dropped = 0
    for h, rec in raw["records"].items():
        if _valid_record(rec):
            db["records"][h] = rec
        else:
            dropped += 1
    if dropped:
        warn_once(f"tuned DB {path!r}: ignored {dropped} corrupt/partial "
                  "record(s)")
    return db


def save_db(path: str, db: dict) -> None:
    """Atomic write (tmp + rename): a killed sweep never leaves a
    half-written DB for `resolve()` to trip on."""
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump({"version": SCHEMA_VERSION, "meta": db.get("meta", {}),
                   "records": db.get("records", {})},
                  f, indent=1, sort_keys=True)
        f.write("\n")
    os.replace(tmp, path)


_DB_CACHE: dict = {}


def _load_db_cached(path: str, mtime_ns: int) -> dict:
    key = (os.path.abspath(path), mtime_ns)
    if key not in _DB_CACHE:
        _DB_CACHE.clear()        # one live DB at a time is the use case
        _DB_CACHE[key] = load_db(path)
    return _DB_CACHE[key]


# -- tuned selection (the resolve() consult) ----------------------------------

def _family(entry) -> set:
    """The reference family a route's numerics are pinned within."""
    return {n for n in (entry.name, entry.reference) if n is not None}


def _best_record(db: dict, op: str, pkey: str, cls: str):
    """Fastest record for the key, preferring measurements from this
    exact environment, then this backend, then anything; deterministic
    tie-break by (us, route, knobs)."""
    pool = [r for r in db["records"].values()
            if r["op"] == op and r["policy_key"] == pkey
            and r["shape_class"] == cls]
    if not pool:
        return None
    fp = env_fingerprint()
    exact = [r for r in pool if r.get("backend") == fp["backend"]
             and r.get("jax_version") == fp["jax_version"]]
    same_backend = [r for r in pool if r.get("backend") == fp["backend"]]
    pool = exact or same_backend or pool
    return min(pool, key=lambda r: (
        r["us"], r["route"],
        json.dumps(dict(r.get("knobs") or {}), sort_keys=True)))


def _knobbed_run(base, knobs: dict) -> Callable:
    def run(*args, **kw):
        # knobs win over call-site defaults (callers pass e.g. bm=128
        # explicitly; a plain partial would raise "multiple values")
        return base.run(*args, **{**kw, **knobs})
    return run


_ENTRY_CACHE: dict = {}


def tuned_entry(db_path: str, op: str, policy, ctx: dict, static):
    """-> a tuned PlanEntry for (op, policy, ctx), or None for the
    static prior.  Called by `exec_plan.resolve()`; every failure mode
    degrades to None.  Minted entries are cached per (DB state, key),
    so repeated resolutions return the identical object — resolution
    stays deterministic under a tuned DB."""
    cls = classify(op, ctx)
    if cls is None:
        return None
    from repro.core.policy import get_policy
    pol = get_policy(policy if policy is not None else "fp32")
    pkey = policy_key(pol)
    try:
        mtime = os.stat(db_path).st_mtime_ns
    except OSError:
        warn_once(f"REPRO_TUNED_DB={db_path!r} not readable; "
                  "using priority order")
        return None
    key = (os.path.abspath(db_path), mtime, op, cls, pkey, static.name)
    if key in _ENTRY_CACHE:
        cached = _ENTRY_CACHE[key]
        if cached is None:
            return None
        # eligibility can shift under the same key (env kill switches
        # like REPRO_PAGED_KERNEL) — re-check, fall back to the prior
        return cached if cached.eligible(pol, ctx) else None
    entry = _mint(db_path, op, pol, cls, pkey, static)
    if entry is not None and not entry.eligible(pol, ctx):
        # don't cache env-dependent ineligibility as a permanent None
        _ENTRY_CACHE[key] = entry
        return None
    _ENTRY_CACHE[key] = entry
    return entry


def _mint(db_path, op, pol, cls, pkey, static):
    import dataclasses as dc

    from repro.core import exec_plan
    db = _load_db_cached(db_path, os.stat(db_path).st_mtime_ns)
    rec = _best_record(db, op, pkey, cls)
    if rec is None:
        return None
    try:
        base = exec_plan.route(op, rec["route"])
    except exec_plan.PlanError:
        warn_once(f"tuned DB names unknown route {op}/{rec['route']}; "
                  "using priority order")
        return None
    if not (_family(base) & _family(static)):
        warn_once(f"tuned route {op}/{base.name} is outside the static "
                  f"choice's reference family ({static.name}); "
                  "using priority order")
        return None
    knobs = dict(rec.get("knobs") or {})
    unknown = sorted(set(knobs) - set(base.knobs))
    if unknown:
        warn_once(f"tuned record for {op}/{base.name} carries unknown "
                  f"knob(s) {unknown}; ignoring them")
        knobs = {k: v for k, v in knobs.items() if k in base.knobs}
    run = _knobbed_run(base, knobs) if knobs else base.run
    return dc.replace(base, run=run, tuned=True, tuned_class=cls,
                      tuned_knobs=tuple(sorted(knobs.items())))


def clear_caches() -> None:
    """Drop the DB and minted-entry caches (tests; long-lived servers
    that swap DBs in place)."""
    _DB_CACHE.clear()
    _ENTRY_CACHE.clear()
    _WARNED.clear()


# -- config-space enumeration -------------------------------------------------

def _knob_combos(knob_names, grid):
    """All knob dicts over `knob_names` from `grid` (sorted order,
    deterministic).  The empty dict (route defaults) is always there —
    it's the static configuration."""
    combos = [{}]
    for name in sorted(knob_names):
        values = grid.get(name)
        if not values:
            continue
        combos = [dict(c, **{name: v}) for c in combos for v in values]
    # route defaults == the all-defaults combo; dedupe against it
    out, seen = [], set()
    for c in combos:
        eff = tuple(sorted({k: v for k, v in c.items()
                            if v != DEFAULT_KNOBS.get(k)}.items()))
        if eff not in seen:
            seen.add(eff)
            out.append(dict(eff))
    return out


def enumerate_space(smoke: bool = False, ops=None, policies=None) -> list:
    """The full config space: one dict per (op, policy, shape-class,
    route, knob-combo) the tuned consult could ever select — routes are
    filtered to the static choice's reference family at the class's
    representative ctx, so no measurement is wasted on a config
    `tuned_entry` would refuse."""
    from repro.core import exec_plan
    from repro.core.policy import get_policy
    grid = SMOKE_KNOB_GRID if smoke else KNOB_GRID
    fp = env_fingerprint()
    space = []
    for sc in SHAPE_CLASSES:
        if ops is not None and sc.op not in ops:
            continue
        for preset in OP_POLICIES.get(sc.op, ()):
            if policies is not None and preset not in policies:
                continue
            pol = get_policy(preset)
            try:
                static = exec_plan.resolve(sc.op, pol, **sc.rep)
            except exec_plan.PlanError:
                continue
            fam = _family(static)
            for route in exec_plan.candidates(sc.op):
                if not route.eligible(pol, sc.rep):
                    continue
                if not (_family(route) & fam):
                    continue
                for knobs in _knob_combos(route.knobs, grid):
                    space.append({
                        "op": sc.op, "policy": preset,
                        "policy_key": policy_key(pol),
                        "shape_class": sc.name, "route": route.name,
                        "knobs": knobs, **fp})
    egrid = SMOKE_ENGINE_KNOB_GRID if smoke else ENGINE_KNOB_GRID
    if (ops is None or ENGINE_OP in ops) and \
            (policies is None or ENGINE_POLICY in policies):
        for ps in egrid["page_size"]:
            for k in egrid["spec_k"]:
                space.append({
                    "op": ENGINE_OP, "policy": ENGINE_POLICY,
                    "policy_key": policy_key(ENGINE_POLICY),
                    "shape_class": ENGINE_SHAPE_CLASS,
                    "route": ENGINE_ROUTE,
                    "knobs": {"page_size": ps, "spec_k": k}, **fp})
    return space


# -- cutout synthesis + measurement -------------------------------------------

def _cutout(op: str, cls_name: str, pol):
    """-> (args, kwargs) for `entry.run` at the class's representative
    shapes (mirrors the tests/test_exec_plan.py fixtures)."""
    import jax
    import jax.numpy as jnp

    rep = shape_class(op, cls_name).rep
    if op == "matmul":
        ks = jax.random.split(jax.random.PRNGKey(0), 2)
        x = jax.random.normal(ks[0], (rep["m"], rep["k"]))
        w = jax.random.normal(ks[1], (rep["k"], rep["n"])) * 0.5
        return (x, w, pol), {}
    if op == "grouped_matmul":
        ks = jax.random.split(jax.random.PRNGKey(4), 2)
        x = jax.random.normal(ks[0], (rep["e"], rep["m"], rep["k"]))
        w = jax.random.normal(ks[1], (rep["e"], rep["k"], rep["n"])) * 0.5
        return (x, w, pol), dict(eq=rep["eq"])
    if op == "flash_attn":
        b, h, kv, hd = 2, 4, 2, 16
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = jax.random.normal(ks[0], (b, rep["sq"], h, hd))
        k = jax.random.normal(ks[1], (b, rep["skv"], kv, hd))
        v = jax.random.normal(ks[2], (b, rep["skv"], kv, hd))
        return (q, k, v), dict(policy=pol, causal=True, window=None,
                               offset=0, valid=None, scale=hd ** -0.5,
                               kv_on_grid=False)
    if op in ("paged_decode", "verify_attn"):
        from repro.core import kvcache as KV
        B, ps, mp = rep["batch"], rep["page_size"], rep["max_pages"]
        n_kv, hd = rep["kv_heads"], rep["hd"]
        sq = rep.get("sq", 1)
        S = mp * ps
        ks = jax.random.split(jax.random.PRNGKey(2), 3)
        k = jax.random.normal(ks[0], (B, S, n_kv, hd))
        v = jax.random.normal(ks[1], (B, S, n_kv, hd))
        ref = KV.update_kv_cache(
            KV.init_kv_cache(B, S, n_kv, hd, fmt=pol.fmt_kv,
                             packed=pol.kv_packed),
            k, v, 0, fmt=pol.fmt_kv, packed=pol.kv_packed)
        cache = KV.paged_from_contiguous(ref, [S] * B, page_size=ps)
        h = 2 * n_kv
        if op == "paged_decode":
            q = jax.random.normal(ks[2], (B, 1, h, hd))
            positions = jnp.asarray([S - 1] * B, jnp.int32)
        else:
            q = jax.random.normal(ks[2], (B, sq, h, hd))
            positions = jnp.asarray([S - sq] * B, jnp.int32)
        return (q, cache, positions), dict(policy=pol, scale=hd ** -0.5)
    if op == "quantize_pack":
        x = jax.random.normal(jax.random.PRNGKey(3), (128, 64))
        return (x,), dict(fmt=rep["fmt"], pack=rep["pack"], bm=128)
    raise KeyError(f"no cutout builder for op {op!r}")


def _time_thunk(thunk: Callable, reps: int) -> float:
    """Warm (compile) + timed mean, us/call."""
    import jax
    jax.block_until_ready(thunk())
    t0 = time.perf_counter()
    for _ in range(reps):
        out = thunk()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def measure_config(cfg: dict, reps: int = 3) -> float:
    """Benchmark one config as an isolated cutout -> us/call."""
    if cfg["op"] == ENGINE_OP:
        return _measure_engine(cfg["knobs"], reps)
    from repro.core import exec_plan
    from repro.core.policy import get_policy
    pol = get_policy(cfg["policy"])
    entry = exec_plan.route(cfg["op"], cfg["route"])
    args, kwargs = _cutout(cfg["op"], cfg["shape_class"], pol)
    kwargs = {**kwargs, **cfg["knobs"]}
    return _time_thunk(lambda: entry.run(*args, **kwargs), reps)


_ENGINE_FIXTURE = None


def _engine_fixture():
    """Reduced qwen3-4b (model, params, vocab), built once per sweep."""
    global _ENGINE_FIXTURE
    if _ENGINE_FIXTURE is None:
        import jax

        from repro.configs import get_config, reduce_config
        from repro.models import build_model
        cfg = reduce_config(get_config("qwen3-4b")).replace(
            policy=ENGINE_POLICY)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        _ENGINE_FIXTURE = (model, params, cfg.vocab_size)
    return _ENGINE_FIXTURE


def engine_config_from_knobs(knobs: dict):
    """EngineConfig (+SpecConfig) for one engine-pseudo-op knob point.
    Pool rows and per-request rows stay constant across page sizes, so
    the sweep compares layouts, not capacities."""
    from repro.launch.engine import EngineConfig, SpecConfig
    ps = int(knobs.get("page_size", 8))
    if ENGINE_POOL_ROWS % ps or ENGINE_SEQ_ROWS % ps:
        raise ValueError(f"page_size {ps} must divide "
                         f"{ENGINE_POOL_ROWS}/{ENGINE_SEQ_ROWS}")
    ecfg = EngineConfig(page_size=ps, n_pages=ENGINE_POOL_ROWS // ps,
                        max_batch=4, max_pages_per_req=ENGINE_SEQ_ROWS // ps,
                        token_budget=16, prefill_chunk=8)
    k = int(knobs.get("spec_k", 0))
    spec = SpecConfig(ENGINE_DRAFT_POLICY, k=k) if k > 0 else None
    return ecfg, spec


def _measure_engine(knobs: dict, reps: int) -> float:
    """Whole-engine cutout: serve the fixed synthetic workload through
    a warm engine; us per generated token (knob points generate
    different token counts under spec, so raw wall is not comparable)."""
    from repro.launch.engine import Engine, synthetic_workload
    model, params, vocab = _engine_fixture()
    ecfg, spec = engine_config_from_knobs(knobs)
    engine = Engine(model, params, ecfg, spec=spec)
    engine.run(synthetic_workload(2, vocab=vocab, seed=1,
                                  prompt_range=(8, 24), gen_range=(4, 10)))
    reqs = synthetic_workload(6, vocab=vocab, seed=0,
                              prompt_range=(8, 24), gen_range=(4, 10))
    best = float("inf")
    for _ in range(reps):
        engine.reset_stats()
        t0 = time.perf_counter()
        rep = engine.run(reqs)
        us = (time.perf_counter() - t0) * 1e6
        best = min(best, us / max(1, rep["gen_tokens"]))
    return best


def best_engine_knobs(db_path: str) -> Optional[dict]:
    """Fastest measured engine knob point in the DB (None if none)."""
    db = load_db(db_path)
    rec = _best_record(db, ENGINE_OP, policy_key(ENGINE_POLICY),
                       ENGINE_SHAPE_CLASS)
    return dict(rec.get("knobs") or {}) if rec else None


# -- the sweep ----------------------------------------------------------------

def run_sweep(db_path: str, *, smoke: bool = False, shard=(0, 1),
              reps: int = 3, ops=None, policies=None,
              progress: Callable = None) -> dict:
    """Measure this shard's unmeasured slice of the config space into
    `db_path`.  Returns {"measured", "skipped", "other_shard", "total"}.

    Sharding partitions by config hash — every worker derives the same
    partition with no coordination; re-running any shard is a no-op for
    already-measured configs (skip-if-measured)."""
    i, n = shard
    if not (0 <= i < n):
        raise ValueError(f"bad shard {i}/{n}")
    space = enumerate_space(smoke=smoke, ops=ops, policies=policies)
    db = load_db(db_path)
    stats = {"measured": 0, "skipped": 0, "other_shard": 0,
             "total": len(space)}
    for cfg in space:
        h = config_hash(cfg)
        if shard_of(h, n) != i:
            stats["other_shard"] += 1
            continue
        if h in db["records"]:
            stats["skipped"] += 1
            continue
        us = measure_config(cfg, reps=reps)
        db["records"][h] = {**cfg, "us": us, "reps": reps}
        db["meta"] = env_fingerprint()
        stats["measured"] += 1
        if progress:
            progress(cfg, us)
        save_db(db_path, db)         # crash-safe: keep what we measured
    return stats


def missing_configs(db_path: str, *, smoke: bool = False) -> list:
    """Configs of the (smoke) space with no record in the DB."""
    db = load_db(db_path)
    return [cfg for cfg in enumerate_space(smoke=smoke)
            if config_hash(cfg) not in db["records"]]
