"""Adaptive trans-precision control loop over the draft-precision ladder.

TransDot's thesis is ONE datapath reconfiguring across fp16/fp8/fp4 DPA
modes through a mode register; the serving analogue is reconfiguring *at
runtime*.  Speculative decoding (`repro.serving.spec_decode`) already
emits the feedback signal energy-proportional transprecision lacks at
the system level: per-round acceptance counts.  This module closes the
loop — a deterministic feedback controller that walks a request's draft
policy up and down a **precision ladder**

    rung 0 (cheapest)  e.g. w4a4_kv4_attn4   8-term DPA, max throughput
    rung 1             e.g. w4a8_kv4_attn8   fp8-class fused pipeline
    rung 2 (precise)   e.g. w16a16_kv4_attn16  fp16-class operands

**demoting** toward fp4 (rung 0) while the acceptance EMA stays high and
**promoting** toward fp8/fp16 when it sags.  Every rung shares the
serving policy's KV-cache storage format (`validate_policy_pair` — one
page pool serves all rungs), so a switch re-routes the *draft* compute
through a different Table-I DPA mode without touching cache state, and
rejection sampling keeps the emitted distribution exactly the serving
policy's regardless of which rung drafted.

Controller contract (the load-bearing properties):

  pure      : ``step(cfg, state, accepted, drafted) -> (state, rung)``
              reads nothing but its arguments — no wall clock, no RNG,
              no globals — so any acceptance trace replays to the same
              rung sequence in unit tests (`replay`).
  hysteresis: distinct demote/promote thresholds (``demote_above`` >
              ``promote_below``) leave a dead band where the EMA can
              wander without flapping the rung.
  dwell     : a rung switch is only considered after ``dwell`` rounds at
              the current rung, so a single outlier round cannot
              oscillate the ladder.

The engine side — one pre-built draft view per rung, per-round batching
of live requests by rung, reservations sized against the ladder-wide
max draft k — lives in `repro.launch.engine`; `tools/plan_table.py
--check` audits every default ladder rung against every serving preset
at CI time so a bad ladder entry fails the build, not the first
adaptive request.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List, Tuple

from repro.core.policy import POLICIES, get_policy

# Default ladders keyed by the serving cache layout (fmt_kv, kv_packed):
# every rung stores KV exactly like the serving policy (the shared-pool
# precondition), ordered cheapest-first along the Table-I DPA modes —
# 8-term fp4, 4-term fp8, 2-term fp16 — with the most precise rung last.
DEFAULT_LADDERS = {
    ("fp4_e2m1", True): ("w4a4_kv4_attn4", "w4a8_kv4_attn8",
                         "w16a16_kv4_attn16"),
    ("fp8_e4m3", False): ("w8a8_kv8_attn8", "attn_fp8_dpa",
                          "kv8_attn_f32"),
    ("fp16", False): ("attn_fp16_dpa", "kv16_attn_f32"),
}


def default_ladder(serve_policy) -> Tuple[str, ...]:
    """The default draft-precision ladder for a serving policy preset.

    Keyed on the policy's cache layout: every rung shares the serving
    fmt_kv/kv_packed (so draft and verify write one page pool), and the
    names are POLICIES presets the engine can pre-build draft views
    for.  Raises for raw-f32-cache policies — the paged engine cannot
    serve them at all, adaptively or not."""
    pol = get_policy(serve_policy)
    if not pol.kv_quantized:
        raise ValueError(
            f"policy {serve_policy!r} keeps a raw f32 cache; the adaptive "
            "draft ladder rides the paged engine, which needs a fmt_kv "
            "preset (e.g. kv4_attn8_packed)")
    key = (pol.fmt_kv, pol.kv_packed)
    if key not in DEFAULT_LADDERS:
        raise ValueError(
            f"no default ladder for cache layout fmt_kv={pol.fmt_kv} "
            f"packed={pol.kv_packed}; known: {sorted(DEFAULT_LADDERS)}")
    return DEFAULT_LADDERS[key]


@dataclasses.dataclass(frozen=True)
class ControllerConfig:
    """Ladder + feedback-loop knobs.

    ladder: draft policy preset names, cheapest (fp4 / 8-term DPA)
    first, most precise last.  ks: per-rung draft length (empty = ``k``
    for every rung); reservations must be sized against ``max_k`` so a
    rung switch can never violate the engine's no-OOM invariant.
    demote_above / promote_below: acceptance-EMA thresholds — strictly
    ordered, the gap between them is the hysteresis dead band.  dwell:
    min rounds at a rung before a switch is considered.  ema_alpha:
    EMA weight of the newest round.  start: initial rung index (-1 =
    the most precise rung — demote as confidence builds)."""
    ladder: Tuple[str, ...]
    ks: Tuple[int, ...] = ()
    k: int = 4
    demote_above: float = 0.75
    promote_below: float = 0.45
    dwell: int = 2
    ema_alpha: float = 0.5
    start: int = -1

    def __post_init__(self):
        object.__setattr__(self, "ladder", tuple(self.ladder))
        object.__setattr__(self, "ks", tuple(self.ks))
        if not self.ladder:
            raise ValueError("ladder must name at least one rung")
        for name in self.ladder:
            if name not in POLICIES:
                raise ValueError(f"ladder rung {name!r} is not a policy "
                                 f"preset")
        if self.ks and len(self.ks) != len(self.ladder):
            raise ValueError(f"ks has {len(self.ks)} entries for a "
                             f"{len(self.ladder)}-rung ladder")
        if any(k < 1 for k in self.rung_ks):
            raise ValueError("every rung draft length must be >= 1")
        if not 0.0 <= self.promote_below < self.demote_above <= 1.0:
            raise ValueError(
                "need 0 <= promote_below < demote_above <= 1 (the gap is "
                f"the hysteresis band); got promote_below="
                f"{self.promote_below}, demote_above={self.demote_above}")
        if self.dwell < 1:
            raise ValueError("dwell must be >= 1 round")
        if not 0.0 < self.ema_alpha <= 1.0:
            raise ValueError("ema_alpha must be in (0, 1]")
        if not -1 <= self.start < len(self.ladder):
            raise ValueError(f"start rung {self.start} outside the "
                             f"{len(self.ladder)}-rung ladder")

    @property
    def rung_ks(self) -> Tuple[int, ...]:
        return self.ks if self.ks else (self.k,) * len(self.ladder)

    @property
    def max_k(self) -> int:
        """Ladder-wide max draft length — what page reservations price."""
        return max(self.rung_ks)

    @property
    def start_rung(self) -> int:
        return len(self.ladder) - 1 if self.start == -1 else self.start


@dataclasses.dataclass(frozen=True)
class ControllerState:
    """Per-request controller state — a value, not an object: replaying
    the same observations from the same state yields the same states.

    ema < 0 means "no observation yet" (the first round's rate seeds the
    EMA directly); ``rounds`` counts rounds at the *current* rung (the
    dwell clock); ``switches`` counts rung changes over the request."""
    rung: int
    ema: float = -1.0
    rounds: int = 0
    switches: int = 0


def init_state(cfg: ControllerConfig) -> ControllerState:
    return ControllerState(rung=cfg.start_rung)


def step(cfg: ControllerConfig, state: ControllerState,
         accepted: int, drafted: int) -> Tuple[ControllerState, int]:
    """One feedback update: fold a round's acceptance count into the
    EMA, then (after the dwell) demote toward fp4 on a high EMA or
    promote toward precision on a low one.

    Pure and deterministic: ``(state, observation) -> (state, rung)``
    with no wall-clock or RNG inputs — the engine replays through here,
    and so can a unit test."""
    if drafted < 1:
        raise ValueError("a round drafts at least one token")
    rate = accepted / drafted
    ema = (rate if state.ema < 0.0
           else cfg.ema_alpha * rate + (1.0 - cfg.ema_alpha) * state.ema)
    rung, rounds, switches = state.rung, state.rounds + 1, state.switches
    if rounds >= cfg.dwell:
        if ema >= cfg.demote_above and rung > 0:
            rung, rounds, switches = rung - 1, 0, switches + 1
        elif ema <= cfg.promote_below and rung < len(cfg.ladder) - 1:
            rung, rounds, switches = rung + 1, 0, switches + 1
    return ControllerState(rung=rung, ema=ema, rounds=rounds,
                           switches=switches), rung


def replay(cfg: ControllerConfig,
           observations: Iterable[Tuple[int, int]]) -> List[int]:
    """Fold a trace of (accepted, drafted) observations through a fresh
    controller; returns the rung each round *ends* on.  Determinism in
    one line: ``replay(cfg, t) == replay(cfg, t)`` bit for bit."""
    state, rungs = init_state(cfg), []
    for accepted, drafted in observations:
        state, rung = step(cfg, state, accepted, drafted)
        rungs.append(rung)
    return rungs
