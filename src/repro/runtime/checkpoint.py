"""Sharded, atomic, async checkpointing with elastic restore.

Layout:  <dir>/step_<N>/
            meta.json            (step, tree structure, shapes, dtypes)
            shard_<i>.npz        (flattened leaves, chunked)
         <dir>/LATEST            (atomic pointer file)

Writes go to a tmp directory first and are renamed into place, so a crash
mid-save never corrupts the latest checkpoint.  `save_async` runs the
serialization on a background thread (training continues on device).
Restore accepts a *different* mesh/sharding than the save ran with
(elastic scaling): leaves are loaded on host and re-placed with the new
sharding.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Optional

import jax
import numpy as np

_FLAT_SEP = "||"


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = _FLAT_SEP.join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path)
        out[key] = np.asarray(leaf)
    return out


def save(state, step: int, ckpt_dir: str, *, shard_mb: int = 512) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(state)
    # chunk into shards by size
    shards, cur, cur_bytes = [], {}, 0
    for k, v in flat.items():
        cur[k] = v
        cur_bytes += v.nbytes
        if cur_bytes >= shard_mb * (1 << 20):
            shards.append(cur)
            cur, cur_bytes = {}, 0
    if cur:
        shards.append(cur)
    meta = {"step": step, "n_shards": len(shards),
            "keys": {k: [list(v.shape), str(v.dtype)]
                     for k, v in flat.items()}}
    for i, sh in enumerate(shards):
        np.savez(os.path.join(tmp, f"shard_{i}.npz"),
                 **{k: v for k, v in sh.items()})
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    # atomic LATEST pointer
    ptr_tmp = os.path.join(ckpt_dir, ".LATEST.tmp")
    with open(ptr_tmp, "w") as f:
        f.write(os.path.basename(final))
    os.replace(ptr_tmp, os.path.join(ckpt_dir, "LATEST"))
    return final


class AsyncSaver:
    """One in-flight save at a time; join() before exit."""

    def __init__(self):
        self._thread: Optional[threading.Thread] = None

    def save(self, state, step: int, ckpt_dir: str):
        self.join()
        host_state = jax.tree.map(np.asarray, state)   # device->host now
        self._thread = threading.Thread(
            target=save, args=(host_state, step, ckpt_dir), daemon=True)
        self._thread.start()

    def join(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(ckpt_dir: str) -> Optional[int]:
    ptr = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(ptr):
        return None
    with open(ptr) as f:
        name = f.read().strip()
    if not os.path.exists(os.path.join(ckpt_dir, name)):
        return None
    return int(name.split("_")[-1])


def restore(ckpt_dir: str, target, *, step: Optional[int] = None,
            shardings=None):
    """Load into the structure of `target` (a pytree of arrays or
    ShapeDtypeStructs).  `shardings`: optional matching pytree of
    NamedShardings for elastic re-placement on a new mesh."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)
    data = {}
    for i in range(meta["n_shards"]):
        with np.load(os.path.join(d, f"shard_{i}.npz")) as z:
            data.update({k: z[k] for k in z.files})

    paths, treedef = jax.tree_util.tree_flatten_with_path(target)
    shard_leaves = jax.tree.leaves(shardings) if shardings is not None \
        else [None] * len(paths)
    leaves = []
    for (path, leaf), shd in zip(paths, shard_leaves):
        key = _FLAT_SEP.join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path)
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = data[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {leaf.shape}")
        if shd is not None:
            arr = jax.device_put(arr, shd)
        leaves.append(arr)
    return treedef.unflatten(leaves), step
